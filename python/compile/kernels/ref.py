"""Pure-jnp oracles for the Pallas kernels.

The correctness contract of the whole stack: ``kernels.mlp`` must match
these reference implementations to float tolerance for every shape/dtype
the model uses. pytest + hypothesis sweep that contract.
"""

import jax
import jax.numpy as jnp


def linear_ref(x, w, b, *, relu=False):
    """act(x @ w + b) in plain jnp (float32 accumulation)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :].astype(
        jnp.float32
    )
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def logistic_score_ref(feats, w, b):
    """sigmoid(feats @ w + b) in plain jnp."""
    z = jnp.dot(feats, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    return jax.nn.sigmoid(z).astype(feats.dtype)


def mlp_ref(params, x):
    """The full classifier forward in plain jnp (see model.classifier_fwd)."""
    h = x
    n_layers = len(params)
    for i, (w, b) in enumerate(params):
        h = linear_ref(h, w, b, relu=(i < n_layers - 1))
    return h


def normalize_ref(x, *, mean=0.5, std=0.25):
    """(x - mean) / std in plain jnp."""
    return ((x - mean) / std).astype(x.dtype)


def softmax_ref(x):
    """Row-wise stable softmax in plain jnp."""
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)
