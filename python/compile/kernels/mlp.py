"""L1: Pallas fused MLP kernels.

The paper's motivating function λ1 "downloads a machine learning model from
a server, analyzes an input image" — the analysis is this model. The hot
spot is the fused linear(+bias)(+ReLU) layer, written as a Pallas kernel so
the whole classifier lowers into one HLO module that the rust coordinator
executes via PJRT.

TPU-oriented structure (DESIGN.md §Hardware-Adaptation):
  * the grid walks output-column blocks (``bn`` = 128, MXU-lane aligned);
  * each grid step holds one ``(m, K)`` activation panel, one ``(K, bn)``
    weight panel and one ``(m, bn)`` accumulator in VMEM — the BlockSpec
    index maps express the HBM->VMEM schedule a CUDA version would write
    with threadblocks;
  * serving batches are small (m <= 16), so the activation panel is kept
    whole rather than tiled over M.

Kernels MUST be lowered with ``interpret=True`` on this CPU image: real-TPU
lowering emits Mosaic custom-calls the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned output-column block.
BLOCK_N = 128


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    """One grid step: o[:, j*bn:(j+1)*bn] = act(x @ w_block + b_block)."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("relu", "interpret"))
def linear(x, w, b, *, relu=False, interpret=True):
    """Fused ``act(x @ w + b)`` as a Pallas kernel.

    Args:
      x: ``(m, k)`` activations.
      w: ``(k, n)`` weights; ``n`` must be a multiple of ``BLOCK_N`` or
         smaller than it (single block).
      b: ``(n,)`` bias.
      relu: fuse a ReLU when True.
      interpret: run the kernel in interpret mode (required on CPU).

    Returns:
      ``(m, n)`` activations with ``x``'s dtype.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    bn = min(BLOCK_N, n)
    assert n % bn == 0, f"n={n} not a multiple of block {bn}"

    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_linear_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),   # x panel: reused per step
            pl.BlockSpec((k, bn), lambda j: (0, j)),  # weight column block
            pl.BlockSpec((bn,), lambda j: (j,)),      # bias block
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w, b)


def _logistic_kernel(f_ref, w_ref, b_ref, o_ref):
    """Batched logistic scorer: o = sigmoid(f @ w + b)."""
    z = jnp.dot(f_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z = z + b_ref[...][None, :]
    o_ref[...] = jax.nn.sigmoid(z).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def logistic_score(feats, w, b, *, interpret=True):
    """The learned next-invocation scorer (predict/learned.rs) as a kernel.

    Args:
      feats: ``(m, 4)`` feature rows ``[chain_conf, hist_conf, recency,
        log_lead]``.
      w: ``(4, 1)`` weights.
      b: ``(1,)`` bias.

    Returns:
      ``(m, 1)`` probabilities.
    """
    m, k = feats.shape
    assert w.shape == (k, 1) and b.shape == (1,)
    return pl.pallas_call(
        _logistic_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), feats.dtype),
        interpret=interpret,
    )(feats, w, b)


def vmem_footprint_bytes(m: int, k: int, n: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM bytes live per grid step (perf analysis, DESIGN §Perf):
    activation panel + weight block + bias block + output block."""
    bn = min(BLOCK_N, n)
    return dtype_bytes * (m * k + k * bn + bn + m * bn)


def _normalize_kernel(x_ref, o_ref, *, mean: float, std: float):
    """Image standardization: o = (x - mean) / std."""
    o_ref[...] = ((x_ref[...] - mean) * (1.0 / std)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mean", "std", "interpret"))
def normalize(x, *, mean=0.5, std=0.25, interpret=True):
    """Fused input standardization (the preprocessing step of λ1's image
    analysis), as a Pallas kernel so it lowers into the same HLO module as
    the matmul layers.

    Args:
      x: ``(m, k)`` raw pixels.
      mean/std: standardization constants (dataset statistics).
    """
    m, k = x.shape
    return pl.pallas_call(
        functools.partial(_normalize_kernel, mean=mean, std=std),
        grid=(1,),
        in_specs=[pl.BlockSpec((m, k), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((m, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), x.dtype),
        interpret=interpret,
    )(x)


def _softmax_kernel(x_ref, o_ref):
    """Row-wise numerically-stable softmax."""
    x = x_ref[...]
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def softmax(x, *, interpret=True):
    """Row softmax over logits ``(m, n)`` — class probabilities."""
    m, n = x.shape
    return pl.pallas_call(
        _softmax_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((m, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x)
