"""L2: the JAX model — λ1's image classifier and the learned predictor.

``classifier_fwd`` is a 3-layer MLP over flattened 32x32x3 images
(3072 -> 512 -> 256 -> 10), every layer running through the L1 Pallas
kernel (`kernels.mlp.linear`), so the whole forward lowers into a single
HLO module for the rust/PJRT request path.

``predictor_fwd`` is the learned next-invocation scorer; its weights MUST
match ``rust/src/predict/learned.rs::DEPLOYED_WEIGHTS`` — the rust
integration test executes the AOT artifact against the native scorer.

Parameters are deterministic (seeded) so the artifact is reproducible and
the rust tests can assert on concrete numerics.
"""

import jax
import jax.numpy as jnp

from compile.kernels import mlp

# Classifier architecture: flattened 32x32 RGB image -> 10 classes.
INPUT_DIM = 3072
HIDDEN = (512, 256)
CLASSES = 10
PARAM_SEED = 0

# Predictor weights — keep in sync with rust predict/learned.rs.
PREDICTOR_WEIGHTS = (3.2, 1.8, 0.9, -0.6)
PREDICTOR_BIAS = -2.0
PREDICTOR_FEATURES = 4


def layer_dims():
    """[(in, out)] per layer."""
    dims = (INPUT_DIM,) + HIDDEN + (CLASSES,)
    return list(zip(dims[:-1], dims[1:]))


def init_params(seed: int = PARAM_SEED):
    """He-initialised MLP parameters, deterministic in ``seed``."""
    key = jax.random.PRNGKey(seed)
    params = []
    for din, dout in layer_dims():
        key, wk = jax.random.split(key)
        scale = jnp.sqrt(2.0 / din)
        w = scale * jax.random.normal(wk, (din, dout), dtype=jnp.float32)
        b = jnp.zeros((dout,), dtype=jnp.float32)
        params.append((w, b))
    return params


# Input standardization constants (dataset statistics, baked into the
# artifact alongside the weights).
PIXEL_MEAN = 0.5
PIXEL_STD = 0.25


def classifier_fwd(params, x, *, interpret=True):
    """Forward pass: standardize, ReLU hidden layers, raw logits out.

    Args:
      params: list of (w, b) from ``init_params``.
      x: ``(batch, INPUT_DIM)`` float32 raw pixels.

    Returns:
      ``(batch, CLASSES)`` logits.
    """
    h = mlp.normalize(x, mean=PIXEL_MEAN, std=PIXEL_STD, interpret=interpret)
    n = len(params)
    for i, (w, b) in enumerate(params):
        h = mlp.linear(h, w, b, relu=(i < n - 1), interpret=interpret)
    return h


def classifier_probs(params, x, *, interpret=True):
    """Forward pass returning class probabilities (fused softmax head)."""
    return mlp.softmax(classifier_fwd(params, x, interpret=interpret), interpret=interpret)


def predictor_params():
    """The deployed logistic weights as jnp arrays."""
    w = jnp.asarray(PREDICTOR_WEIGHTS, dtype=jnp.float32).reshape(
        PREDICTOR_FEATURES, 1
    )
    b = jnp.asarray([PREDICTOR_BIAS], dtype=jnp.float32)
    return w, b


def predictor_fwd(feats, *, interpret=True):
    """Batched next-invocation scores for ``(batch, 4)`` features."""
    w, b = predictor_params()
    return mlp.logistic_score(feats, w, b, interpret=interpret)
