"""AOT lowering: JAX -> HLO **text** artifacts for the rust/PJRT runtime.

Run once at build time (``make artifacts``); python never runs on the
request path. The interchange format is HLO text, NOT a serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts:
  classifier_b{N}.hlo.txt  batched classifier forward, params baked in,
                           one per serving batch size
  predictor.hlo.txt        learned next-invocation scorer (batch 16)
  layer{i}.{w,b}.bin       raw little-endian f32 weight/bias blobs, one
                           pair per layer (the native backend's inputs)
  manifest.json            shapes + sample numerics for rust-side checks,
                           plus the "weights" sidecar section

Weight sidecar schema (mirrored in rust/src/runtime/manifest.rs):

  "weights": {
    "format": "f32-le",
    "normalize": {"mean": 0.5, "std": 0.25},
    "layers": [
      {"in": 3072, "out": 512, "relu": true,
       "weights": "layer0.w.bin", "bias": "layer0.b.bin"},
      ...
    ]
  }

Each weights blob is the layer's ``(in, out)`` parameter matrix dumped
row-major as little-endian f32 (exactly JAX's in-memory layout), each
bias blob is ``out`` values; ``normalize`` carries the input
standardization constants applied before the first layer. The rust
native backend (``rust/src/nn``) executes these directly, so the same
artifact directory serves both backends: HLO text for PJRT, blobs for
native, one manifest describing both.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model

BATCH_SIZES = (1, 4, 8, 16)
PREDICTOR_BATCH = 16


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    ``print_large_constants=True`` is ESSENTIAL: the default printer elides
    big constants as ``constant({...})``, which the text parser on the rust
    side silently reads back as zeros — the model's baked-in weights would
    vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_classifier(params, batch: int) -> str:
    def fwd(x):
        return (model.classifier_fwd(params, x),)

    spec = jax.ShapeDtypeStruct((batch, model.INPUT_DIM), jnp.float32)
    return to_hlo_text(jax.jit(fwd).lower(spec))


def lower_predictor(batch: int) -> str:
    def fwd(feats):
        return (model.predictor_fwd(feats),)

    spec = jax.ShapeDtypeStruct((batch, model.PREDICTOR_FEATURES), jnp.float32)
    return to_hlo_text(jax.jit(fwd).lower(spec))


def dump_weights(params, out_dir: str) -> dict:
    """Write per-layer f32-LE weight sidecars; return the manifest section.

    The rust native backend (``rust/src/nn/mlp.rs``) reads these blobs
    byte-for-byte, so the dtype/order here (``<f4``, row-major) is part of
    the artifact contract — see the schema in the module docstring.
    """
    layers = []
    n = len(params)
    for i, (w, b) in enumerate(params):
        wname, bname = f"layer{i}.w.bin", f"layer{i}.b.bin"
        np.asarray(w, dtype="<f4").tofile(os.path.join(out_dir, wname))
        np.asarray(b, dtype="<f4").tofile(os.path.join(out_dir, bname))
        layers.append(
            {
                "in": int(w.shape[0]),
                "out": int(w.shape[1]),
                "relu": i < n - 1,
                "weights": wname,
                "bias": bname,
            }
        )
        print(f"wrote {wname} ({w.shape[0]}x{w.shape[1]}) + {bname}")
    return {
        "format": "f32-le",
        "normalize": {"mean": model.PIXEL_MEAN, "std": model.PIXEL_STD},
        "layers": layers,
    }


def sample_check(params):
    """Deterministic sample inputs/outputs the rust tests assert against."""
    x = jnp.linspace(-1.0, 1.0, model.INPUT_DIM, dtype=jnp.float32).reshape(
        1, model.INPUT_DIM
    )
    logits = model.classifier_fwd(params, x)
    feats = jnp.asarray(
        [[0.9, 0.8, 0.7, 0.3], [0.0, 0.0, 0.0, 0.0]], dtype=jnp.float32
    )
    scores = model.predictor_fwd(feats)
    return {
        "classifier_input": "linspace(-1,1,3072)",
        "classifier_logits_b1": [float(v) for v in logits[0]],
        "predictor_feats": [[float(v) for v in row] for row in feats],
        "predictor_scores": [float(v) for v in scores[:, 0]],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--batches", type=int, nargs="*", default=list(BATCH_SIZES)
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    params = model.init_params()
    manifest = {
        "input_dim": model.INPUT_DIM,
        "classes": model.CLASSES,
        "hidden": list(model.HIDDEN),
        "param_seed": model.PARAM_SEED,
        "batches": args.batches,
        "predictor_batch": PREDICTOR_BATCH,
        "predictor_weights": list(model.PREDICTOR_WEIGHTS),
        "predictor_bias": model.PREDICTOR_BIAS,
        "artifacts": {},
        "check": sample_check(params),
        "weights": dump_weights(params, args.out_dir),
    }

    for b in args.batches:
        text = lower_classifier(params, b)
        name = f"classifier_b{b}.hlo.txt"
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        manifest["artifacts"][f"classifier_b{b}"] = name
        print(f"wrote {name} ({len(text)} chars)")

    text = lower_predictor(PREDICTOR_BATCH)
    with open(os.path.join(args.out_dir, "predictor.hlo.txt"), "w") as f:
        f.write(text)
    manifest["artifacts"]["predictor"] = "predictor.hlo.txt"
    print(f"wrote predictor.hlo.txt ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json")


if __name__ == "__main__":
    main()
