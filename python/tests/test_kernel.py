"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

hypothesis sweeps the shape/dtype space the serving path uses; this is the
CORE correctness signal for the compiled artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mlp, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32).astype(
        dtype
    )


def _tol(dtype):
    # f32 tolerance allows for summation-order differences on K up to 3072;
    # bf16 is inherently coarse.
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=5e-3, atol=1e-4
    )


class TestLinearKernel:
    @pytest.mark.parametrize("m", [1, 4, 8, 16])
    @pytest.mark.parametrize("k,n", [(3072, 512), (512, 256), (256, 128)])
    def test_model_shapes_match_ref(self, m, k, n):
        x = _rand(1, (m, k), jnp.float32)
        w = _rand(2, (k, n), jnp.float32)
        b = _rand(3, (n,), jnp.float32)
        got = mlp.linear(x, w, b, relu=True)
        want = ref.linear_ref(x, w, b, relu=True)
        np.testing.assert_allclose(got, want, **_tol(jnp.float32))

    @pytest.mark.parametrize("relu", [False, True])
    def test_relu_flag(self, relu):
        x = _rand(4, (2, 64), jnp.float32)
        w = _rand(5, (64, 128), jnp.float32)
        b = _rand(6, (128,), jnp.float32)
        got = mlp.linear(x, w, b, relu=relu)
        want = ref.linear_ref(x, w, b, relu=relu)
        np.testing.assert_allclose(got, want, **_tol(jnp.float32))
        if relu:
            assert (np.asarray(got) >= 0.0).all()

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(1, 16),
        k=st.sampled_from([16, 64, 256, 512]),
        nb=st.integers(1, 4),
        relu=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, m, k, nb, relu, seed):
        n = nb * mlp.BLOCK_N
        x = _rand(seed, (m, k), jnp.float32)
        w = _rand(seed + 1, (k, n), jnp.float32)
        b = _rand(seed + 2, (n,), jnp.float32)
        got = mlp.linear(x, w, b, relu=relu)
        want = ref.linear_ref(x, w, b, relu=relu)
        np.testing.assert_allclose(got, want, **_tol(jnp.float32))

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(1, 8), seed=st.integers(0, 1000))
    def test_bfloat16(self, m, seed):
        x = _rand(seed, (m, 256), jnp.bfloat16)
        w = _rand(seed + 1, (256, 128), jnp.bfloat16)
        b = _rand(seed + 2, (128,), jnp.bfloat16)
        got = mlp.linear(x, w, b, relu=True)
        want = ref.linear_ref(x, w, b, relu=True)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32),
            np.asarray(want, dtype=np.float32),
            **_tol(jnp.bfloat16),
        )

    def test_small_n_single_block(self):
        # n < BLOCK_N: single block path (the logits layer, n=10... padded
        # to block — here n must divide evenly, so test n=64).
        x = _rand(7, (3, 32), jnp.float32)
        w = _rand(8, (32, 64), jnp.float32)
        b = _rand(9, (64,), jnp.float32)
        np.testing.assert_allclose(
            mlp.linear(x, w, b), ref.linear_ref(x, w, b), **_tol(jnp.float32)
        )

    def test_shape_mismatch_raises(self):
        x = _rand(1, (2, 8), jnp.float32)
        w = _rand(2, (9, 64), jnp.float32)
        b = _rand(3, (64,), jnp.float32)
        with pytest.raises(AssertionError):
            mlp.linear(x, w, b)


class TestLogisticKernel:
    @settings(max_examples=25, deadline=None)
    @given(m=st.integers(1, 32), seed=st.integers(0, 1000))
    def test_matches_ref(self, m, seed):
        feats = _rand(seed, (m, 4), jnp.float32)
        w = _rand(seed + 1, (4, 1), jnp.float32)
        b = _rand(seed + 2, (1,), jnp.float32)
        got = mlp.logistic_score(feats, w, b)
        want = ref.logistic_score_ref(feats, w, b)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
        assert ((np.asarray(got) > 0) & (np.asarray(got) < 1)).all()


class TestVmemFootprint:
    def test_fits_vmem(self):
        # Largest layer (b16, 3072->512): panel + block must fit in 16 MiB.
        fp = mlp.vmem_footprint_bytes(16, 3072, 512)
        assert fp < 16 * 1024 * 1024, f"VMEM estimate {fp} too large"

    def test_scales_with_block(self):
        assert mlp.vmem_footprint_bytes(1, 256, 128) < mlp.vmem_footprint_bytes(
            16, 3072, 512
        )


class TestNormalizeKernel:
    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 16),
        k=st.sampled_from([16, 256, 3072]),
        seed=st.integers(0, 1000),
    )
    def test_matches_ref(self, m, k, seed):
        x = _rand(seed, (m, k), jnp.float32)
        got = mlp.normalize(x, mean=0.5, std=0.25)
        want = ref.normalize_ref(x, mean=0.5, std=0.25)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_identity_when_mean0_std1(self):
        x = _rand(3, (4, 32), jnp.float32)
        np.testing.assert_allclose(
            mlp.normalize(x, mean=0.0, std=1.0), x, rtol=1e-7, atol=1e-7
        )


class TestSoftmaxKernel:
    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 16), seed=st.integers(0, 1000))
    def test_matches_ref_and_sums_to_one(self, m, seed):
        x = _rand(seed, (m, 10), jnp.float32) * 5.0
        got = np.asarray(mlp.softmax(x))
        want = np.asarray(ref.softmax_ref(x))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(got.sum(axis=-1), np.ones(m), rtol=1e-5)
        assert (got >= 0).all()

    def test_stability_under_large_logits(self):
        x = jnp.asarray([[1000.0, 999.0, 0.0]], dtype=jnp.float32)
        got = np.asarray(mlp.softmax(x))
        assert np.isfinite(got).all()
        assert got[0, 0] > got[0, 1] > got[0, 2]
