"""L2 correctness: the classifier/predictor models and their AOT lowering."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    return model.init_params()


class TestClassifier:
    def test_shapes(self, params):
        for b in (1, 4, 16):
            x = jnp.zeros((b, model.INPUT_DIM), dtype=jnp.float32)
            logits = model.classifier_fwd(params, x)
            assert logits.shape == (b, model.CLASSES)
            assert logits.dtype == jnp.float32

    def test_matches_pure_jnp(self, params):
        x = jax.random.normal(
            jax.random.PRNGKey(3), (8, model.INPUT_DIM), dtype=jnp.float32
        )
        got = model.classifier_fwd(params, x)
        want = ref.mlp_ref(
            params, ref.normalize_ref(x, mean=model.PIXEL_MEAN, std=model.PIXEL_STD)
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_params_deterministic(self):
        a = model.init_params(seed=0)
        b = model.init_params(seed=0)
        for (wa, ba), (wb, bb) in zip(a, b):
            np.testing.assert_array_equal(wa, wb)
            np.testing.assert_array_equal(ba, bb)
        c = model.init_params(seed=1)
        assert not np.array_equal(np.asarray(a[0][0]), np.asarray(c[0][0]))

    def test_logits_not_degenerate(self, params):
        x = jax.random.normal(
            jax.random.PRNGKey(4), (4, model.INPUT_DIM), dtype=jnp.float32
        )
        logits = np.asarray(model.classifier_fwd(params, x))
        # Different inputs produce different logits; classes are spread.
        assert logits.std() > 0.01
        assert not np.allclose(logits[0], logits[1])


class TestPredictor:
    def test_weights_match_rust_constants(self):
        # predict/learned.rs DEPLOYED_WEIGHTS / DEPLOYED_BIAS.
        assert model.PREDICTOR_WEIGHTS == (3.2, 1.8, 0.9, -0.6)
        assert model.PREDICTOR_BIAS == -2.0

    def test_scores_match_native_logistic(self):
        feats = jnp.asarray(
            [[0.9, 0.8, 0.7, 0.3], [0.0, 0.0, 0.0, 0.0], [1.0, 1.0, 1.0, 1.0]],
            dtype=jnp.float32,
        )
        got = np.asarray(model.predictor_fwd(feats))[:, 0]
        w = np.asarray(model.PREDICTOR_WEIGHTS)
        z = np.asarray(feats) @ w + model.PREDICTOR_BIAS
        want = 1.0 / (1.0 + np.exp(-z))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_strong_chain_signal_scores_high(self):
        hi = float(model.predictor_fwd(jnp.asarray([[0.95, 0.8, 1.0, 0.1]]))[0, 0])
        lo = float(model.predictor_fwd(jnp.asarray([[0.0, 0.0, 0.0, 0.5]]))[0, 0])
        assert hi > 0.85
        assert lo < 0.25


class TestAotLowering:
    def test_classifier_hlo_text(self, params):
        text = aot.lower_classifier(params, batch=1)
        assert text.startswith("HloModule")
        # Params are baked in: the entry computation takes exactly one
        # argument (x) and returns the logits tuple.
        assert (
            "entry_computation_layout={(f32[1,3072]{1,0})->(f32[1,10]{1,0})}"
            in text.replace("((", "(").replace("))", ")")
            or "(f32[1,3072]" in text
        )
        first_line = text.splitlines()[0]
        assert "f32[1,3072]" in first_line and "f32[1,10]" in first_line

    def test_predictor_hlo_text(self):
        text = aot.lower_predictor(batch=16)
        assert text.startswith("HloModule")
        assert "logistic" in text or "parameter(0)" in text

    def test_sample_check_is_stable(self, params):
        a = aot.sample_check(params)
        b = aot.sample_check(params)
        assert a == b
        assert len(a["classifier_logits_b1"]) == model.CLASSES

    def test_hlo_text_parses_back(self, params):
        """The emitted text must round-trip through XLA's HLO parser —
        the same parser the rust loader uses (HloModuleProto::from_text).
        Numeric equivalence is asserted by the rust integration test
        ``runtime_artifacts`` against manifest.json's sample check."""
        from jax._src.lib import xla_client as xc

        for batch in (1, 4):
            text = aot.lower_classifier(params, batch=batch)
            mod = xc._xla.hlo_module_from_text(text)
            proto = mod.as_serialized_hlo_module_proto()
            assert len(proto) > 1000
        text = aot.lower_predictor(batch=16)
        assert xc._xla.hlo_module_from_text(text) is not None

    def test_manifest_written(self, params, tmp_path, monkeypatch):
        """aot.main writes every artifact plus a consistent manifest."""
        import sys

        monkeypatch.setattr(
            sys, "argv", ["aot", "--out-dir", str(tmp_path), "--batches", "1"]
        )
        aot.main()
        import json

        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["input_dim"] == model.INPUT_DIM
        for name in manifest["artifacts"].values():
            assert (tmp_path / name).exists(), name
        assert len(manifest["check"]["classifier_logits_b1"]) == model.CLASSES
        # The weight sidecar section points at existing blobs.
        for entry in manifest["weights"]["layers"]:
            assert (tmp_path / entry["weights"]).exists(), entry
            assert (tmp_path / entry["bias"]).exists(), entry


class TestWeightSidecars:
    def test_dump_schema_and_blob_roundtrip(self, params, tmp_path):
        """The native backend's contract: f32-LE blobs, row-major (in, out),
        relu on every layer but the last, normalize constants recorded."""
        section = aot.dump_weights(params, str(tmp_path))
        assert section["format"] == "f32-le"
        assert section["normalize"] == {
            "mean": model.PIXEL_MEAN,
            "std": model.PIXEL_STD,
        }
        dims = model.layer_dims()
        assert len(section["layers"]) == len(dims)
        for entry, (din, dout), (w, b) in zip(section["layers"], dims, params):
            assert (entry["in"], entry["out"]) == (din, dout)
            blob = np.fromfile(tmp_path / entry["weights"], dtype="<f4")
            np.testing.assert_array_equal(
                blob.reshape(din, dout), np.asarray(w, dtype=np.float32)
            )
            bias = np.fromfile(tmp_path / entry["bias"], dtype="<f4")
            np.testing.assert_array_equal(bias, np.asarray(b, dtype=np.float32))
        assert all(e["relu"] for e in section["layers"][:-1])
        assert section["layers"][-1]["relu"] is False

    def test_dump_is_deterministic(self, params, tmp_path):
        a_dir = tmp_path / "a"
        b_dir = tmp_path / "b"
        a_dir.mkdir()
        b_dir.mkdir()
        aot.dump_weights(params, str(a_dir))
        aot.dump_weights(params, str(b_dir))
        for name in ["layer0.w.bin", "layer2.b.bin"]:
            assert (a_dir / name).read_bytes() == (b_dir / name).read_bytes()


class TestPreprocessAndProbs:
    def test_fwd_normalizes_input(self, params):
        """classifier_fwd(x) == mlp over (x-mean)/std."""
        from compile.kernels import ref as kref

        x = jax.random.uniform(
            jax.random.PRNGKey(5), (2, model.INPUT_DIM), dtype=jnp.float32
        )
        got = model.classifier_fwd(params, x)
        want = kref.mlp_ref(
            params, kref.normalize_ref(x, mean=model.PIXEL_MEAN, std=model.PIXEL_STD)
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_probs_are_distributions(self, params):
        x = jax.random.uniform(
            jax.random.PRNGKey(6), (3, model.INPUT_DIM), dtype=jnp.float32
        )
        p = np.asarray(model.classifier_probs(params, x))
        np.testing.assert_allclose(p.sum(axis=-1), np.ones(3), rtol=1e-5)
        assert (p >= 0).all() and (p <= 1).all()
