//! Bench: Ablation A — freshen lead-time sweep (Figure 3's timing axis).

use freshen_rs::experiments::ablations;
use freshen_rs::testkit::bench::time_once;

fn main() {
    let leads = [-200i64, -100, 0, 100, 250, 500, 1000, 2000, 5000];
    let (rows, elapsed) = time_once(|| ablations::lead_time(&leads, 30, 2020));
    ablations::print_lead(&rows);
    println!("\nregenerated in {elapsed:?}");
}
