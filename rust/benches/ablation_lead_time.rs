//! Bench: Ablation A — freshen lead-time sweep (Figure 3's timing axis),
//! run as a 4-seed sweep through the parallel `SweepRunner` harness. The
//! merged rows are identical for any worker count (asserted below), so
//! the parallelism is pure wall-clock win.

use freshen_rs::experiments::ablations;
use freshen_rs::experiments::harness::SweepRunner;
use freshen_rs::testkit::bench::time_once;

fn main() {
    let leads = [-200i64, -100, 0, 100, 250, 500, 1000, 2000, 5000];
    let seeds = [2020u64, 2021, 2022, 2023];
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let (seq_rows, seq_elapsed) = time_once(|| {
        ablations::lead_time_multi(&leads, 30, &seeds, &SweepRunner::new(1))
    });
    let (rows, par_elapsed) = time_once(|| {
        ablations::lead_time_multi(&leads, 30, &seeds, &SweepRunner::new(workers))
    });
    assert_eq!(
        format!("{seq_rows:?}"),
        format!("{rows:?}"),
        "merged sweep output must not depend on parallelism"
    );

    ablations::print_lead(&rows);
    println!(
        "\n{} grid points ({} leads x {} seeds): sequential {seq_elapsed:?}, \
         {workers} workers {par_elapsed:?} (x{:.2})",
        leads.len() * seeds.len(),
        leads.len(),
        seeds.len(),
        seq_elapsed.as_secs_f64() / par_elapsed.as_secs_f64().max(1e-9)
    );
}
