//! Bench: Ablation B — confidence gating vs mispredict rate (§3.3
//! billing: what gating saves in wasted freshen spend).

use freshen_rs::experiments::ablations;
use freshen_rs::testkit::bench::time_once;

fn main() {
    let rates = [0.0, 0.25, 0.5, 0.75, 1.0];
    let (rows, elapsed) = time_once(|| ablations::confidence(&rates, 60, 2020));
    ablations::print_confidence(&rows);
    println!("\nregenerated in {elapsed:?}");
}
