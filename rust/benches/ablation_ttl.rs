//! Bench: Ablation C — prefetch-TTL sweep (§3.2 freshen cache: traffic
//! saved vs staleness risk).

use freshen_rs::experiments::ablations;
use freshen_rs::testkit::bench::time_once;

fn main() {
    let ttls = [0.0, 1.0, 2.0, 5.0, 10.0, 30.0, 60.0];
    let (rows, elapsed) = time_once(|| ablations::ttl_sweep(&ttls, 60, 2020));
    ablations::print_ttl(&rows);
    println!("\nregenerated in {elapsed:?}");
}
