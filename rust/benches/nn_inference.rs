//! Bench: native nn inference hot path — raw blocked-matmul throughput
//! (serial vs row-parallel), the end-to-end classifier forward across
//! every AOT batch size, and the pad-to-AOT-batch vs exact-size ("no
//! pad") A/B through `ClassifierRuntime` — the dynamic batch-size
//! selection the native engine enables.
//!
//! The model is the paper λ1 shape (3072 → 512 → 256 → 10) with seeded
//! weights: built in memory by `nn::gen::build_mlp` for the kernel
//! benches, and written as a real artifact set for the runtime A/B — no
//! PJRT either way.

use freshen_rs::nn::gen::{build_mlp, generate, GenSpec};
use freshen_rs::nn::kernels::{matmul_bias_act_threads, par_threads};
use freshen_rs::nn::tensor::Matrix;
use freshen_rs::runtime::model::ClassifierRuntime;
use freshen_rs::testkit::bench::{bench, Snapshot};
use freshen_rs::util::rng::Rng;

/// Naive per-element matmul with the kernel's exact op order (bias, then
/// k-ascending accumulation with the zero-skip, then relu): the scalar
/// side of the 8-wide-panel A/B. Kept deliberately free of blocking so
/// the comparison isolates the panel layout, not cache tiling.
fn scalar_reference(x: &Matrix, w: &Matrix, bias: &[f32], relu: bool) -> Vec<f32> {
    let (m, k) = (x.rows(), x.cols());
    let n = w.cols();
    let (xd, wd) = (x.data(), w.data());
    let mut out = vec![0.0f32; m * n];
    for r in 0..m {
        for c in 0..n {
            let mut acc = bias[c];
            for i in 0..k {
                let a = xd[r * k + i];
                if a != 0.0 {
                    acc += a * wd[i * n + c];
                }
            }
            out[r * n + c] = if relu && acc < 0.0 { 0.0 } else { acc };
        }
    }
    out
}

fn random_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    Matrix::from_vec(
        rows,
        cols,
        (0..rows * cols)
            .map(|_| rng.uniform(-1.0, 1.0) as f32)
            .collect(),
    )
    .unwrap()
}

fn main() {
    let mut snap = Snapshot::new("nn_inference");
    println!("== native nn inference (paper λ1 shape: 3072 -> 512 -> 256 -> 10) ==");
    let spec = GenSpec::default();
    let mlp = build_mlp(&spec).expect("build seeded mlp");
    let mut rng = Rng::new(0xBE7C);

    // Raw matmul: the dominant first-layer shape at the largest AOT batch.
    let (m, k, n) = (16usize, spec.input_dim, spec.hidden[0]);
    let x = random_matrix(&mut rng, m, k);
    let w = random_matrix(&mut rng, k, n);
    let bias = vec![0.01f32; n];
    let flops = 2.0 * (m * k * n) as f64;
    let auto = par_threads(m, n, k);
    for threads in [1, auto] {
        let r = bench(
            &format!("nn/matmul {m}x{k}x{n} threads={threads}"),
            2,
            12,
            || {
                let out = matmul_bias_act_threads(&x, &w, &bias, true, threads).unwrap();
                std::hint::black_box(out.data()[0]);
            },
        );
        println!("  -> {:.2} GFLOP/s", flops / r.mean_secs() / 1e9);
        if threads == 1 {
            snap.stats(&r);
        }
    }

    // 8-wide panel kernel vs a naive scalar loop with the same op order:
    // the A/B for the register-panel restructure. Results must stay
    // bit-identical — the panels only reorder work across independent
    // output elements — so the assert doubles as a cheap correctness
    // check on real λ1-shaped data before timing anything.
    let scalar = scalar_reference(&x, &w, &bias, true);
    let panel = matmul_bias_act_threads(&x, &w, &bias, true, 1).unwrap();
    assert_eq!(panel.data(), &scalar[..], "panel kernel diverged from scalar");
    let rs = bench(&format!("nn/matmul-scalar {m}x{k}x{n}"), 2, 12, || {
        let out = scalar_reference(&x, &w, &bias, true);
        std::hint::black_box(out[0]);
    });
    println!("  -> {:.2} GFLOP/s", flops / rs.mean_secs() / 1e9);
    let rp = bench(&format!("matmul/8wide-vs-scalar {m}x{k}x{n}"), 2, 12, || {
        let out = matmul_bias_act_threads(&x, &w, &bias, true, 1).unwrap();
        std::hint::black_box(out.data()[0]);
    });
    snap.stats(&rp);
    println!(
        "  -> {:.2} GFLOP/s ({:.2}x vs scalar reference)",
        flops / rp.mean_secs() / 1e9,
        rs.mean_secs() / rp.mean_secs().max(1e-12)
    );

    // End-to-end forward: every AOT batch size, plus oversized batches the
    // runtime would chunk (shown here as single big executions).
    let mut batches = spec.batches.clone();
    batches.extend_from_slice(&[32, 64]);
    for &b in &batches {
        let xb = random_matrix(&mut rng, b, spec.input_dim);
        let iters = if b >= 32 { 6 } else { 10 };
        let r = bench(&format!("nn/classifier fwd batch={b}"), 2, iters, || {
            let out = mlp.forward(&xb).unwrap();
            std::hint::black_box(out.data()[0]);
        });
        println!(
            "  -> {:.0} rows/s ({:.3} ms/row)",
            b as f64 / r.mean_secs(),
            r.mean_secs() * 1e3 / b as f64
        );
    }

    // Pad-to-AOT vs exact-size A/B through the runtime: request sizes
    // that fall BETWEEN the AOT batches pay the padding tax under the
    // static policy; `--no-pad` executes them exactly. (PJRT keeps
    // padding — its executables are compiled per batch size.)
    println!("== pad-to-AOT vs --no-pad (ClassifierRuntime, native backend) ==");
    let dir = std::env::temp_dir().join("freshen-nn-inference-bench-artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    generate(&dir, &spec).expect("write bench artifact set");
    let mut padded = ClassifierRuntime::load_with(&dir, Default::default())
        .expect("load padded runtime");
    assert!(padded.pads_to_aot());
    let mut exact = ClassifierRuntime::load_with(&dir, Default::default())
        .expect("load exact runtime");
    assert!(!exact.set_pad_to_aot(false), "native backend honours no-pad");
    for &n in &[1usize, 2, 3, 5, 6, 9, 12, 13] {
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                (0..spec.input_dim)
                    .map(|_| rng.uniform(-1.0, 1.0) as f32)
                    .collect()
            })
            .collect();
        let aot = padded.pick_batch(n);
        let rp = bench(&format!("runtime/pad  n={n} (runs as {aot})"), 1, 8, || {
            let out = padded.infer(&rows).unwrap();
            std::hint::black_box(out[0][0]);
        });
        let re = bench(&format!("runtime/exact n={n}"), 1, 8, || {
            let out = exact.infer(&rows).unwrap();
            std::hint::black_box(out[0][0]);
        });
        println!(
            "  n={n}: pad {:.3} ms vs exact {:.3} ms ({:.2}x)",
            rp.mean_secs() * 1e3,
            re.mean_secs() * 1e3,
            rp.mean_secs() / re.mean_secs().max(1e-12)
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
    if let Some(path) = snap.write_if_requested().expect("snapshot write") {
        println!("snapshot written to {}", path.display());
    }
}
