//! Bench: the end-to-end experiments.
//!
//! Part 1 — the sim-substrate chain pipeline (freshen on/off).
//! Part 2 — the real-time serving engine with PJRT inference (requires
//! `make artifacts`; skipped otherwise): bursts served baseline vs
//! freshened, reporting p50/p99/throughput.

use std::path::{Path, PathBuf};
use std::time::Duration;

use freshen_rs::experiments::e2e;
use freshen_rs::serve::{ServeConfig, ServeEngine};
use freshen_rs::testkit::bench::time_once;

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn image(seed: usize) -> Vec<f32> {
    (0..3072).map(|j| ((seed * 131 + j) % 23) as f32 / 23.0).collect()
}

fn serve_mode(dir: PathBuf, freshen: bool) -> anyhow::Result<()> {
    let engine = ServeEngine::start(
        dir,
        ServeConfig {
            freshen,
            workers: 4,
            ..ServeConfig::default()
        },
    )?;
    for burst in 0..4 {
        if freshen {
            engine.freshen().join().ok();
        }
        let rxs: Vec<_> = (0..16).map(|i| engine.submit(image(burst * 16 + i))).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(60))?;
        }
        std::thread::sleep(Duration::from_millis(60));
        engine.recycle();
    }
    let report = engine.shutdown();
    report.print(if freshen { "serve/freshen" } else { "serve/baseline" });
    Ok(())
}

fn main() {
    // Part 1: simulator substrate.
    let (e, elapsed) = time_once(|| e2e::run(2020, 60));
    e.print();
    println!("sim e2e regenerated in {elapsed:?}\n");

    // Part 2: real-time substrate.
    match artifacts() {
        None => println!("(skipping serve-engine bench: run `make artifacts`)"),
        Some(dir) => {
            println!("== real-time serving engine (PJRT classifier) ==");
            if let Err(err) = serve_mode(dir.clone(), false) {
                eprintln!("baseline serve failed: {err:#}");
                return;
            }
            if let Err(err) = serve_mode(dir, true) {
                eprintln!("freshen serve failed: {err:#}");
            }
        }
    }
}
