//! Bench: regenerate Table 1 (trigger-service delays, 20k runs/service
//! through the platform simulator) and time the simulation.

use freshen_rs::experiments::table1;
use freshen_rs::testkit::bench::{throughput, time_once};

fn main() {
    let runs = 20_000;
    let (t, elapsed) = time_once(|| table1::run(runs, 2020));
    t.print();
    println!(
        "\nregenerated in {elapsed:?} ({:.0} simulated trigger runs/sec)",
        throughput(4 * runs as u64, elapsed)
    );
}
