//! Bench: prediction-quality quantification (§6) — precision/recall/lead
//! per predictor per workload regime.

use freshen_rs::experiments::prediction;
use freshen_rs::testkit::bench::time_once;

fn main() {
    let (q, elapsed) = time_once(|| prediction::run(2020));
    q.print();
    println!("\nregenerated in {elapsed:?}");
}
