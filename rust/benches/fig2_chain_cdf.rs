//! Bench: regenerate Figure 2 (functions-per-app CDF, orchestration vs
//! all) and time the synthesis + analysis pipeline.

use freshen_rs::experiments::fig2;
use freshen_rs::testkit::bench::{bench, time_once};

fn main() {
    let (fig, elapsed) = time_once(|| fig2::run(2020));
    fig.print();
    println!("\nregenerated in {elapsed:?}");
    bench("fig2/synthesize+cdf(20k apps)", 1, 10, || {
        std::hint::black_box(fig2::run(2020));
    });
}
