//! Bench: regenerate Figure 5 (warmed vs non-warmed transfers, cloud).

use freshen_rs::experiments::fig5_6::{run, Placement};
use freshen_rs::testkit::bench::time_once;

fn main() {
    let (fig, elapsed) = time_once(|| run(Placement::Cloud, 2020));
    fig.print();
    println!("\nregenerated in {elapsed:?}");
}
