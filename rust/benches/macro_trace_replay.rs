//! Bench: Azure-trace macro pipeline — ingest throughput (rows/s and
//! invocation-counts/s through the streaming CSV reader) and replay
//! throughput (simulated invocations/s through the full platform), serial
//! vs sharded, per-app vs shared-pool, plus the end-to-end `azure-macro`
//! grid rate. The printed `sim events` figures are also the visibility
//! check for the stale-idle-timer fix: superseded eviction checks are
//! cancelled instead of executing as no-ops, so event counts track real
//! work.

use std::io::BufWriter;

use freshen_rs::experiments::SweepRunner;
use freshen_rs::testkit::bench::{throughput, time_once, Snapshot};
use freshen_rs::util::config::{KeepAliveKind, PlacementKind};
use freshen_rs::workload::macrotrace::ingest::AzureTraceReader;
use freshen_rs::workload::macrotrace::replay::{PoolMode, ReplayCfg};
use freshen_rs::workload::macrotrace::shard::{replay_sharded, TraceSource};
use freshen_rs::workload::macrotrace::synth::{write_csv, SynthTraceCfg};

fn bench_cfg() -> SynthTraceCfg {
    SynthTraceCfg {
        apps: 220,
        minutes: 45,
        seed: 0xBE7C,
        ..SynthTraceCfg::default()
    }
}

fn main() {
    let mut snap = Snapshot::new("macro_trace_replay");
    let synth = bench_cfg();
    let dir = std::env::temp_dir().join("freshen-macro-trace-bench");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("bench tmp dir");
    let path = dir.join("azure.csv");

    // --- synthesis + CSV write ---------------------------------------
    let (summary, elapsed) = time_once(|| {
        let file = std::fs::File::create(&path).expect("create bench trace");
        write_csv(&synth, BufWriter::new(file)).expect("write bench trace")
    });
    let bytes = std::fs::metadata(&path).expect("trace written").len();
    snap.rate("synth/rows-written", summary.functions, elapsed);
    println!(
        "synth+write: {} rows / {} invocations ({:.1} MB) in {elapsed:?}  \
         ({:.0} rows/s)",
        summary.functions,
        summary.invocations,
        bytes as f64 / 1e6,
        throughput(summary.functions, elapsed)
    );

    // --- streaming ingest --------------------------------------------
    let (counted, elapsed) = time_once(|| {
        let mut reader = AzureTraceReader::open(&path).expect("open bench trace");
        let mut rows = 0u64;
        let mut invocations = 0u64;
        for row in reader.by_ref() {
            rows += 1;
            invocations += row.invocations();
        }
        assert_eq!(reader.skipped(), 0);
        (rows, invocations)
    });
    assert_eq!(counted.0, summary.functions);
    snap.rate("ingest/rows", counted.0, elapsed);
    snap.rate("ingest/invocation-counts", counted.0 * synth.minutes as u64, elapsed);
    println!(
        "ingest: {} rows in {elapsed:?}  ({:.0} rows/s, {:.2}M counts/s)",
        counted.0,
        throughput(counted.0, elapsed),
        throughput(counted.0 * synth.minutes as u64, elapsed) / 1e6
    );

    // --- replay: serial vs sharded -----------------------------------
    let src = TraceSource::Csv(path);
    let cfg = ReplayCfg {
        warmup_minutes: 8,
        ..ReplayCfg::default()
    };
    let (serial, serial_elapsed) = time_once(|| {
        replay_sharded(&src, 1, &cfg, &SweepRunner::new(1)).expect("serial replay")
    });
    let serial_rate = throughput(serial.metrics.invocations, serial_elapsed);
    snap.rate("replay/serial", serial.metrics.invocations, serial_elapsed);
    // Same measurement under the hot-path PR's slot name: the serial replay
    // now runs interned FnId contexts + enum-coded events, and this slot
    // exists so the snapshot diff against a pre-interning `replay/serial`
    // baseline reads as an explicit before/after pair.
    snap.rate(
        "replay/serial-interned",
        serial.metrics.invocations,
        serial_elapsed,
    );
    println!(
        "replay serial   (1 shard,  1 worker):  {} invocations, {} sim events in \
         {serial_elapsed:?}  ({serial_rate:.0} inv/s)",
        serial.metrics.invocations, serial.metrics.sim_events
    );
    // --- tracing overhead: spans + windows on, same serial replay ----
    // obs/ is compiled in and disabled by default; this pins what
    // enabling it costs (ring writes + per-function window updates) and
    // re-checks that collection never moves the metrics digest.
    let mut spans_cfg = cfg.clone();
    spans_cfg.trace_spans = true;
    spans_cfg.fn_windows = true;
    let (traced, traced_elapsed) = time_once(|| {
        replay_sharded(&src, 1, &spans_cfg, &SweepRunner::new(1)).expect("traced replay")
    });
    assert_eq!(
        serial.metrics.digest(),
        traced.metrics.digest(),
        "span/window collection must be invisible to the metrics digest"
    );
    let traced_rate = throughput(traced.metrics.invocations, traced_elapsed);
    snap.rate(
        "replay/serial-spans-on",
        traced.metrics.invocations,
        traced_elapsed,
    );
    println!(
        "replay traced   (1 shard,  spans+windows): {} invocations, {} spans \
         ({} dropped), {} fn windows in {traced_elapsed:?}  ({traced_rate:.0} inv/s, \
         x{:.2} vs spans-off)",
        traced.metrics.invocations,
        traced.metrics.spans.len(),
        traced.metrics.spans.dropped,
        traced.metrics.fn_windows.len(),
        traced_rate / serial_rate.max(1e-9)
    );

    for (shards, workers) in [(4usize, 4usize), (8, 8)] {
        let (sharded, elapsed) = time_once(|| {
            replay_sharded(&src, shards, &cfg, &SweepRunner::new(workers))
                .expect("sharded replay")
        });
        assert_eq!(
            serial.metrics.digest(),
            sharded.metrics.digest(),
            "sharded replay must be byte-identical to serial"
        );
        let rate = throughput(sharded.metrics.invocations, elapsed);
        snap.rate(
            &format!("replay/sharded-{shards}x{workers}"),
            sharded.metrics.invocations,
            elapsed,
        );
        println!(
            "replay sharded ({shards} shards, {workers} workers): {} invocations in \
             {elapsed:?}  ({rate:.0} inv/s, x{:.2} vs serial)",
            sharded.metrics.invocations,
            rate / serial_rate.max(1e-9)
        );
    }

    // --- shared-pool contention replay -------------------------------
    // One memory-bounded world per shard: tenants compete for warm
    // containers, so keep-alive policy shows up in the eviction mix.
    for kind in [KeepAliveKind::FixedTtl, KeepAliveKind::HybridHistogram] {
        let mut shared = cfg.clone();
        shared.pool = PoolMode::Shared;
        shared.base.keep_alive = kind;
        shared.base.memory_accounting =
            freshen_rs::util::config::MemoryAccounting::FunctionMb;
        let (out, elapsed) = time_once(|| {
            replay_sharded(&src, 4, &shared, &SweepRunner::new(4))
                .expect("shared-pool replay")
        });
        let m = &out.metrics;
        snap.rate(
            &format!("replay/shared-pool-{}", kind.as_str()),
            m.invocations,
            elapsed,
        );
        println!(
            "replay shared  (4 shards, keep-alive {:>6}): {} invocations, {} sim events \
             in {elapsed:?}  (cold {:.2}%, evict idle/press {}/{}, warm kills {}, \
             peak {} MB)",
            kind.as_str(),
            m.invocations,
            m.sim_events,
            100.0 * m.cold_start_rate(),
            m.evictions_idle,
            m.evictions_pressure,
            m.warm_kills,
            m.peak_resident_mb
        );
    }

    // --- placement strategies on the shared pool ----------------------
    // Legacy least-loaded vs warm-affinity on the contended cluster: the
    // pair pins what strategy choice costs at replay speed, and the
    // cross-check re-asserts the shared-pool determinism contract (same
    // strategy, fixed shards, different workers → identical digest).
    for placement in [PlacementKind::LeastLoadedMb, PlacementKind::WarmAffinity] {
        let mut placed = cfg.clone();
        placed.pool = PoolMode::Shared;
        placed.base.memory_accounting =
            freshen_rs::util::config::MemoryAccounting::FunctionMb;
        placed.base.placement = placement;
        let (out, elapsed) = time_once(|| {
            replay_sharded(&src, 4, &placed, &SweepRunner::new(4))
                .expect("placement replay")
        });
        let (check, _) = time_once(|| {
            replay_sharded(&src, 4, &placed, &SweepRunner::new(1))
                .expect("placement replay cross-check")
        });
        assert_eq!(
            out.metrics.digest(),
            check.metrics.digest(),
            "placement {} must be parallel-invariant at fixed shards",
            placement.as_str()
        );
        let m = &out.metrics;
        snap.rate(
            &format!("replay/placement-{}", placement.as_str()),
            m.invocations,
            elapsed,
        );
        println!(
            "replay placed  (4 shards, placement {:>8}): {} invocations, {} sim events \
             in {elapsed:?}  (cold {:.2}%, peak {} MB)",
            placement.as_str(),
            m.invocations,
            m.sim_events,
            100.0 * m.cold_start_rate(),
            m.peak_resident_mb
        );
    }

    // --- snapshot/restore mitigation on the shared pool ----------------
    // Demote-on-idle-expiry instead of evict: vanilla demand-paged restore
    // vs the REAP-style prefetch variant. Pins what the third lifecycle
    // state costs at replay speed and that restores actually engage.
    for prefetch in [false, true] {
        let mut snapd = cfg.clone();
        snapd.pool = PoolMode::Shared;
        snapd.base.memory_accounting =
            freshen_rs::util::config::MemoryAccounting::FunctionMb;
        snapd.base.snapshot.enabled = true;
        snapd.base.snapshot.prefetch = prefetch;
        let (out, elapsed) = time_once(|| {
            replay_sharded(&src, 4, &snapd, &SweepRunner::new(4))
                .expect("snapshot replay")
        });
        let m = &out.metrics;
        let slot = if prefetch { "replay/snapshot-prefetch" } else { "replay/snapshot-mitigation" };
        snap.rate(slot, m.invocations, elapsed);
        println!(
            "replay snapped (4 shards, prefetch {:>5}): {} invocations, {} snapshots, \
             {} restored in {elapsed:?}  (cold {:.2}%, restore {:.1} ms mean, \
             peak {} MB)",
            prefetch,
            m.invocations,
            m.snapshots,
            m.restored_starts,
            100.0 * m.cold_start_rate(),
            m.mean_restore_ms(),
            m.peak_resident_mb
        );
    }

    if let Some(path) = snap.write_if_requested().expect("snapshot write") {
        println!("snapshot written to {}", path.display());
    }
}
