//! Bench: regenerate Figure 6 (warmed vs non-warmed transfers, edge ~50ms).

use freshen_rs::experiments::fig5_6::{run, Placement};
use freshen_rs::testkit::bench::time_once;

fn main() {
    let (fig, elapsed) = time_once(|| run(Placement::Edge50, 2020));
    fig.print();
    println!("\nregenerated in {elapsed:?}");
}
