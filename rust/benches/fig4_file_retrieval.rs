//! Bench: regenerate Figure 4 (file retrieval time vs size x location).

use freshen_rs::experiments::fig4;
use freshen_rs::testkit::bench::{bench, time_once};

fn main() {
    let (fig, elapsed) = time_once(|| fig4::run(2020));
    fig.print();
    println!("\nregenerated in {elapsed:?}");
    bench("fig4/full-sweep(3 sites x 6 sizes x 20 iters)", 2, 20, || {
        std::hint::black_box(fig4::run(2020));
    });
}
