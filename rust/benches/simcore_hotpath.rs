//! Bench: L3 hot paths — raw event-queue throughput (timing wheel vs the
//! reference binary heap, side by side), engine event-loop overhead,
//! platform invocation throughput, and netsim transfer computation. The
//! §Perf targets track these numbers.

use freshen_rs::netsim::cc::CongestionControl;
use freshen_rs::netsim::link::Site;
use freshen_rs::netsim::tcp::Connection;
use freshen_rs::platform::endpoint::Endpoint;
use freshen_rs::platform::exec::invoke;
use freshen_rs::platform::function::FunctionSpec;
use freshen_rs::platform::world::{PlatformSim, World};
use freshen_rs::simcore::wheel::{BinaryHeapQueue, EventQueue, TimingWheel};
use freshen_rs::simcore::{EventFn, Sim};
use freshen_rs::testkit::bench::{bench, throughput, time_once, Snapshot};
use freshen_rs::util::config::Config;
use freshen_rs::util::rng::Rng;
use freshen_rs::util::time::{SimDuration, SimTime};

/// The dense-event workload: `pending` events outstanding at all times,
/// with pop→reschedule churn and a 10% cancellation mix — the regime the
/// paper sweeps (Table 1's 20k triggers, the transfer grids) put the
/// scheduler in. Returns events processed.
fn dense_churn<Q: EventQueue<EventFn<u64>>>(q: &mut Q, pending: usize, churn: usize) -> u64 {
    let mut rng = Rng::new(7);
    let mut seq = 0u64;
    let mut now = 0u64;
    for _ in 0..pending {
        q.insert(
            SimTime(now + rng.range(1, 1_000_000)),
            seq,
            Box::new(|_, _| {}),
        );
        seq += 1;
    }
    let mut processed = 0u64;
    for i in 0..churn {
        let (at, _s, _f) = q.pop().expect("queue stays dense");
        processed += 1;
        now = at.micros();
        q.insert(
            SimTime(now + rng.range(1, 1_000_000)),
            seq,
            Box::new(|_, _| {}),
        );
        seq += 1;
        if i % 10 == 0 {
            // Cancel one recent event (and immediately replace it to keep
            // the density constant).
            let victim = seq - 1 - rng.below(pending as u64 / 2);
            if q.cancel(victim) {
                q.insert(
                    SimTime(now + rng.range(1, 1_000_000)),
                    seq,
                    Box::new(|_, _| {}),
                );
                seq += 1;
            }
        }
    }
    processed
}

/// Sparse self-rescheduling chain on the raw queue: one event pending at
/// a time — the scheduler's constant-factor floor.
fn sparse_chain<Q: EventQueue<EventFn<u64>>>(q: &mut Q, events: u64) -> u64 {
    let mut now = 0u64;
    q.insert(SimTime(1), 0, Box::new(|_, _| {}));
    for seq in 1..=events {
        let (at, _s, _f) = q.pop().expect("chain");
        now = at.micros();
        q.insert(SimTime(now + 1), seq, Box::new(|_, _| {}));
    }
    q.pop().map(|_| ()).expect("tail");
    events + 1
}

fn bench_queue_comparison(snap: &mut Snapshot) {
    const PENDING: usize = 100_000;
    const CHURN: usize = 1_000_000;
    const CHAIN: u64 = 1_000_000;
    println!("== scheduler: timing wheel vs reference binary heap ==");

    let (wheel_dense, wheel_elapsed) = time_once(|| {
        let mut q: TimingWheel<EventFn<u64>> = TimingWheel::new();
        dense_churn(&mut q, PENDING, CHURN)
    });
    let (heap_dense, heap_elapsed) = time_once(|| {
        let mut q: BinaryHeapQueue<EventFn<u64>> = BinaryHeapQueue::new();
        dense_churn(&mut q, PENDING, CHURN)
    });
    assert_eq!(wheel_dense, heap_dense);
    snap.rate("scheduler/dense-churn/wheel", wheel_dense, wheel_elapsed);
    snap.rate("scheduler/dense-churn/heap", heap_dense, heap_elapsed);
    let wheel_rate = throughput(wheel_dense, wheel_elapsed);
    let heap_rate = throughput(heap_dense, heap_elapsed);
    println!(
        "dense ({PENDING} pending, {CHURN} churn): wheel {:.2}M ev/s ({wheel_elapsed:?})  \
         heap {:.2}M ev/s ({heap_elapsed:?})  speedup x{:.2}",
        wheel_rate / 1e6,
        heap_rate / 1e6,
        wheel_rate / heap_rate
    );

    let (wheel_chain, wheel_elapsed) = time_once(|| {
        let mut q: TimingWheel<EventFn<u64>> = TimingWheel::new();
        sparse_chain(&mut q, CHAIN)
    });
    let (heap_chain, heap_elapsed) = time_once(|| {
        let mut q: BinaryHeapQueue<EventFn<u64>> = BinaryHeapQueue::new();
        sparse_chain(&mut q, CHAIN)
    });
    assert_eq!(wheel_chain, heap_chain);
    snap.rate("scheduler/sparse-chain/wheel", wheel_chain, wheel_elapsed);
    snap.rate("scheduler/sparse-chain/heap", heap_chain, heap_elapsed);
    let wheel_rate = throughput(wheel_chain, wheel_elapsed);
    let heap_rate = throughput(heap_chain, heap_elapsed);
    println!(
        "sparse chain ({CHAIN} events):             wheel {:.2}M ev/s ({wheel_elapsed:?})  \
         heap {:.2}M ev/s ({heap_elapsed:?})  speedup x{:.2}",
        wheel_rate / 1e6,
        heap_rate / 1e6,
        wheel_rate / heap_rate
    );
}

fn bench_event_loop(snap: &mut Snapshot) {
    // A self-rescheduling event chain through the full engine: pure
    // engine overhead (now wheel-backed).
    const EVENTS: u64 = 1_000_000;
    let (_, elapsed) = time_once(|| {
        let mut sim: Sim<u64> = Sim::new();
        fn tick(s: &mut Sim<u64>, w: &mut u64) {
            *w += 1;
            if *w < EVENTS {
                s.schedule(SimDuration::from_micros(1), tick);
            }
        }
        let mut w = 0u64;
        sim.schedule(SimDuration::ZERO, tick);
        sim.run(&mut w);
        assert_eq!(w, EVENTS);
    });
    snap.rate("simcore/event-loop", EVENTS, elapsed);
    println!(
        "simcore: {:.2}M events/sec ({elapsed:?} for {EVENTS})",
        throughput(EVENTS, elapsed) / 1e6
    );
}

fn bench_platform_invocations(snap: &mut Snapshot) {
    const INVOCATIONS: usize = 20_000;
    let (_, elapsed) = time_once(|| {
        let mut cfg = Config::default();
        cfg.seed = 1;
        let mut w = World::new(cfg);
        let mut ep = Endpoint::new("store", Site::Edge);
        ep.store.put("ID1", 1e5, SimTime::ZERO);
        w.add_endpoint(ep);
        w.deploy(FunctionSpec::paper_lambda(
            "f",
            "app",
            "store",
            SimDuration::from_millis(5),
        ));
        let mut sim: PlatformSim = Sim::new();
        sim.max_events = 100_000_000;
        for i in 0..INVOCATIONS {
            sim.schedule_at(SimTime(i as u64 * 500_000), |sim, w| {
                invoke(sim, w, "f");
            });
        }
        sim.run(&mut w);
        assert_eq!(w.metrics.count(), INVOCATIONS);
    });
    snap.rate("platform/invocations", INVOCATIONS as u64, elapsed);
    println!(
        "platform: {:.0} simulated invocations/sec ({elapsed:?} for {INVOCATIONS})",
        throughput(INVOCATIONS as u64, elapsed)
    );
}

fn main() {
    let mut snap = Snapshot::new("simcore_hotpath");
    bench_queue_comparison(&mut snap);
    bench_event_loop(&mut snap);
    bench_platform_invocations(&mut snap);
    // Netsim transfer-time computation (the inner loop of Figures 4-6).
    let link = Site::Remote.link();
    let mut rng = Rng::new(3);
    let transfer = bench("netsim/10MB-transfer-model", 10, 200, || {
        let mut conn = Connection::new(link.clone(), CongestionControl::Cubic);
        let d = conn.connect(SimTime::ZERO, &mut rng);
        std::hint::black_box(conn.send_with_ack(SimTime::ZERO + d, &mut rng, 1e7, 0.0));
    });
    snap.stats(&transfer);
    if let Some(path) = snap.write_if_requested().expect("snapshot write") {
        println!("snapshot written to {}", path.display());
    }
}
