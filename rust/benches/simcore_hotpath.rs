//! Bench: L3 hot paths — raw event-loop throughput, platform invocation
//! throughput, and netsim transfer computation. The §Perf targets track
//! these numbers.

use freshen_rs::netsim::cc::CongestionControl;
use freshen_rs::netsim::link::Site;
use freshen_rs::netsim::tcp::Connection;
use freshen_rs::platform::endpoint::Endpoint;
use freshen_rs::platform::exec::invoke;
use freshen_rs::platform::function::FunctionSpec;
use freshen_rs::platform::world::World;
use freshen_rs::simcore::Sim;
use freshen_rs::testkit::bench::{bench, throughput, time_once};
use freshen_rs::util::config::Config;
use freshen_rs::util::rng::Rng;
use freshen_rs::util::time::{SimDuration, SimTime};

fn bench_event_loop() {
    // A self-rescheduling event chain: pure engine overhead.
    const EVENTS: u64 = 1_000_000;
    let (_, elapsed) = time_once(|| {
        let mut sim: Sim<u64> = Sim::new();
        fn tick(s: &mut Sim<u64>, w: &mut u64) {
            *w += 1;
            if *w < EVENTS {
                s.schedule(SimDuration::from_micros(1), tick);
            }
        }
        let mut w = 0u64;
        sim.schedule(SimDuration::ZERO, tick);
        sim.run(&mut w);
        assert_eq!(w, EVENTS);
    });
    println!(
        "simcore: {:.2}M events/sec ({elapsed:?} for {EVENTS})",
        throughput(EVENTS, elapsed) / 1e6
    );
}

fn bench_platform_invocations() {
    const INVOCATIONS: usize = 20_000;
    let (_, elapsed) = time_once(|| {
        let mut cfg = Config::default();
        cfg.seed = 1;
        let mut w = World::new(cfg);
        let mut ep = Endpoint::new("store", Site::Edge);
        ep.store.put("ID1", 1e5, SimTime::ZERO);
        w.add_endpoint(ep);
        w.deploy(FunctionSpec::paper_lambda(
            "f",
            "app",
            "store",
            SimDuration::from_millis(5),
        ));
        let mut sim: Sim<World> = Sim::new();
        sim.max_events = 100_000_000;
        for i in 0..INVOCATIONS {
            sim.schedule_at(SimTime(i as u64 * 500_000), |sim, w| {
                invoke(sim, w, "f");
            });
        }
        sim.run(&mut w);
        assert_eq!(w.metrics.count(), INVOCATIONS);
    });
    println!(
        "platform: {:.0} simulated invocations/sec ({elapsed:?} for {INVOCATIONS})",
        throughput(INVOCATIONS as u64, elapsed)
    );
}

fn main() {
    bench_event_loop();
    bench_platform_invocations();
    // Netsim transfer-time computation (the inner loop of Figures 4-6).
    let link = Site::Remote.link();
    let mut rng = Rng::new(3);
    bench("netsim/10MB-transfer-model", 10, 200, || {
        let mut conn = Connection::new(link.clone(), CongestionControl::Cubic);
        let d = conn.connect(SimTime::ZERO, &mut rng);
        std::hint::black_box(conn.send_with_ack(SimTime::ZERO + d, &mut rng, 1e7, 0.0));
    });
}
