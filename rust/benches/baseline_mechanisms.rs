//! Bench: the §2 argument — existing mechanisms (runtime reuse, kernel
//! metrics cache, TCP Fast Open) vs freshen, across invocation gaps.

use freshen_rs::experiments::baselines;
use freshen_rs::testkit::bench::time_once;

fn main() {
    let (_, elapsed) = time_once(|| {
        for gap in [10.0, 60.0, 120.0, 600.0] {
            baselines::run(50, gap, 2020).print();
        }
    });
    println!("\nregenerated in {elapsed:?}");
}
