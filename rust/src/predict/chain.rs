//! Chain-based prediction (Figures 1 and 2).
//!
//! Orchestration frameworks make chains explicit, so when function fᵢ
//! commits its trigger for fᵢ₊₁ the platform *knows* fᵢ₊₁ is coming; the
//! remaining uncertainty is branching (conditional chains) and the trigger
//! delay. Confidence starts near-certain for linear chains and is
//! discounted by observed branching behaviour.

use crate::predict::{Prediction, PredictionSource};
use crate::util::fxhash::FxHashMap;
use crate::triggers::TriggerService;
use crate::util::time::SimTime;

/// Confidence for a never-observed edge of an explicit chain. Not 1.0:
/// orchestrators can short-circuit (errors, conditions).
const BASE_CHAIN_CONFIDENCE: f64 = 0.9;

/// Tracks per-edge follow-through: of the times fᵢ completed, how often did
/// fᵢ₊₁ actually run? (Handles the paper's "non-deterministic function
/// chains" discussion item.)
#[derive(Debug, Clone, Default)]
pub struct ChainPredictor {
    /// (from, to) -> (followed, total)
    edges: FxHashMap<(String, String), (u64, u64)>,
}

impl ChainPredictor {
    pub fn new() -> ChainPredictor {
        ChainPredictor::default()
    }

    /// Predict the successor's invocation given that `from` has just
    /// committed a trigger to `to` via `trigger` at time `now`.
    pub fn predict_successor(
        &self,
        from: &str,
        to: &str,
        trigger: TriggerService,
        now: SimTime,
    ) -> Prediction {
        let confidence = self.edge_confidence(from, to);
        Prediction {
            function: to.to_string(),
            expected_at: now + trigger.expected_lead(),
            confidence,
            source: PredictionSource::Chain,
        }
    }

    /// Observed follow-through rate for an edge, defaulting to the base
    /// confidence, blended once data accumulates.
    pub fn edge_confidence(&self, from: &str, to: &str) -> f64 {
        match self.edges.get(&(from.to_string(), to.to_string())) {
            None => BASE_CHAIN_CONFIDENCE,
            Some(&(_followed, total)) if total == 0 => BASE_CHAIN_CONFIDENCE,
            Some(&(followed, total)) => {
                // Laplace-smoothed empirical rate.
                (followed as f64 + BASE_CHAIN_CONFIDENCE) / (total as f64 + 1.0)
            }
        }
    }

    /// Record whether the successor actually ran after `from` completed.
    pub fn observe_edge(&mut self, from: &str, to: &str, followed: bool) {
        let e = self
            .edges
            .entry((from.to_string(), to.to_string()))
            .or_insert((0, 0));
        e.1 += 1;
        if followed {
            e.0 += 1;
        }
    }

    /// Bulk-warmup path for trace replay: credit an edge with `followed`
    /// follow-throughs out of `total` completions in one map operation,
    /// instead of `total` individual [`observe_edge`] calls. Used to seed
    /// chain confidence from the warmup window of a macro trace before
    /// replay starts.
    ///
    /// [`observe_edge`]: ChainPredictor::observe_edge
    pub fn warm_edge(&mut self, from: &str, to: &str, followed: u64, total: u64) {
        debug_assert!(followed <= total);
        let e = self
            .edges
            .entry((from.to_string(), to.to_string()))
            .or_insert((0, 0));
        e.0 += followed;
        e.1 += total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::time::SimDuration;

    #[test]
    fn unobserved_edge_uses_base_confidence() {
        let p = ChainPredictor::new();
        let pred = p.predict_successor("a", "b", TriggerService::Direct, SimTime::ZERO);
        assert_eq!(pred.function, "b");
        assert_eq!(pred.confidence, BASE_CHAIN_CONFIDENCE);
        assert_eq!(pred.source, PredictionSource::Chain);
        // Lead equals the trigger's median delay.
        assert_eq!(
            pred.expected_at.since(SimTime::ZERO),
            SimDuration::from_secs_f64(0.060)
        );
    }

    #[test]
    fn branching_discounts_confidence() {
        let mut p = ChainPredictor::new();
        // Edge followed 1 out of 10 times.
        for i in 0..10 {
            p.observe_edge("a", "b", i == 0);
        }
        let c = p.edge_confidence("a", "b");
        assert!(c < 0.25, "confidence {c}");
        // A reliable edge stays high.
        for _ in 0..10 {
            p.observe_edge("a", "c", true);
        }
        assert!(p.edge_confidence("a", "c") > 0.9);
    }

    #[test]
    fn warm_edge_matches_incremental_observes() {
        let mut bulk = ChainPredictor::new();
        bulk.warm_edge("a", "b", 7, 10);
        let mut inc = ChainPredictor::new();
        for i in 0..10 {
            inc.observe_edge("a", "b", i < 7);
        }
        assert_eq!(bulk.edge_confidence("a", "b"), inc.edge_confidence("a", "b"));
        // Warmup composes with later live observations.
        bulk.observe_edge("a", "b", true);
        inc.observe_edge("a", "b", true);
        assert_eq!(bulk.edge_confidence("a", "b"), inc.edge_confidence("a", "b"));
    }

    #[test]
    fn s3_trigger_gives_longest_lead() {
        let p = ChainPredictor::new();
        let direct = p.predict_successor("a", "b", TriggerService::Direct, SimTime::ZERO);
        let s3 = p.predict_successor("a", "b", TriggerService::S3Bucket, SimTime::ZERO);
        assert!(s3.expected_at > direct.expected_at);
    }
}
