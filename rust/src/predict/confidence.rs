//! Outstanding-prediction tracking.
//!
//! Every admitted prediction becomes an outstanding entry with a match
//! window. When the predicted function actually arrives within the window
//! the prediction is a **hit**; when the window expires first it is a
//! **miss** (a wasted freshen the app owner still pays for, §3.3). The
//! hit/miss stream feeds the freshen gate's accuracy window and the
//! billing ledger.

use crate::util::time::{SimDuration, SimTime};

/// Default slack around the expected arrival during which an arrival
/// counts as a hit.
pub const DEFAULT_MATCH_WINDOW: SimDuration = SimDuration(10_000_000); // 10 s

/// One outstanding prediction.
#[derive(Debug, Clone)]
pub struct Outstanding {
    pub id: u64,
    pub function: String,
    pub app: String,
    pub expected_at: SimTime,
    pub deadline: SimTime,
    /// Set when matched by an arrival.
    pub hit: bool,
    /// Set when resolved (hit or expired).
    pub resolved: bool,
}

/// Tracker for outstanding predictions.
#[derive(Debug, Clone, Default)]
pub struct PredictionTracker {
    outstanding: Vec<Outstanding>,
    next_id: u64,
    pub hits: u64,
    pub misses: u64,
}

impl PredictionTracker {
    pub fn new() -> PredictionTracker {
        PredictionTracker::default()
    }

    /// Register an admitted prediction; returns its id. The caller should
    /// schedule an expiry check at the returned deadline.
    pub fn register(
        &mut self,
        function: &str,
        app: &str,
        expected_at: SimTime,
        window: SimDuration,
    ) -> (u64, SimTime) {
        let id = self.next_id;
        self.next_id += 1;
        let deadline = expected_at + window;
        self.outstanding.push(Outstanding {
            id,
            function: function.to_string(),
            app: app.to_string(),
            expected_at,
            deadline,
            hit: false,
            resolved: false,
        });
        (id, deadline)
    }

    /// An invocation of `function` arrived at `now`; match the oldest
    /// unresolved prediction for it whose window covers `now`. Returns the
    /// matched prediction id.
    pub fn on_arrival(&mut self, function: &str, now: SimTime) -> Option<u64> {
        let entry = self.outstanding.iter_mut().find(|o| {
            !o.resolved && o.function == function && now <= o.deadline
        })?;
        entry.hit = true;
        entry.resolved = true;
        self.hits += 1;
        Some(entry.id)
    }

    /// Expiry check for prediction `id` at its deadline. Returns
    /// `Some((app, was_hit))` the first time the prediction resolves as a
    /// miss or is confirmed; `None` if already handled.
    pub fn expire(&mut self, id: u64) -> Option<(String, bool)> {
        let idx = self.outstanding.iter().position(|o| o.id == id)?;
        let o = &mut self.outstanding[idx];
        let result = if o.resolved {
            (o.app.clone(), o.hit)
        } else {
            o.resolved = true;
            self.misses += 1;
            (o.app.clone(), false)
        };
        // Garbage-collect resolved entries to keep the scan short.
        self.outstanding.retain(|o| !o.resolved);
        Some(result)
    }

    pub fn outstanding_count(&self) -> usize {
        self.outstanding.iter().filter(|o| !o.resolved).count()
    }

    pub fn accuracy(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: u64) -> SimTime {
        SimTime(s * 1_000_000)
    }

    #[test]
    fn hit_within_window() {
        let mut tr = PredictionTracker::new();
        let (id, deadline) = tr.register("f", "app", t(10), SimDuration::from_secs(5));
        assert_eq!(deadline, t(15));
        assert_eq!(tr.on_arrival("f", t(12)), Some(id));
        assert_eq!(tr.hits, 1);
        // Expiry after a hit reports the hit, not a miss.
        assert_eq!(tr.expire(id), Some(("app".into(), true)));
        assert_eq!(tr.misses, 0);
    }

    #[test]
    fn miss_on_expiry() {
        let mut tr = PredictionTracker::new();
        let (id, _) = tr.register("f", "app", t(10), SimDuration::from_secs(5));
        assert_eq!(tr.expire(id), Some(("app".into(), false)));
        assert_eq!(tr.misses, 1);
        // Double-expire is None (already GC'd).
        assert_eq!(tr.expire(id), None);
    }

    #[test]
    fn arrival_after_deadline_does_not_match() {
        let mut tr = PredictionTracker::new();
        tr.register("f", "app", t(10), SimDuration::from_secs(5));
        assert_eq!(tr.on_arrival("f", t(20)), None);
    }

    #[test]
    fn matches_oldest_unresolved_first() {
        let mut tr = PredictionTracker::new();
        let (id1, _) = tr.register("f", "app", t(10), SimDuration::from_secs(60));
        let (_id2, _) = tr.register("f", "app", t(20), SimDuration::from_secs(60));
        assert_eq!(tr.on_arrival("f", t(15)), Some(id1));
        assert_eq!(tr.outstanding_count(), 1);
    }

    #[test]
    fn accuracy_math() {
        let mut tr = PredictionTracker::new();
        let (a, _) = tr.register("f", "app", t(1), SimDuration::from_secs(1));
        let (b, _) = tr.register("g", "app", t(1), SimDuration::from_secs(1));
        tr.on_arrival("f", t(1));
        tr.expire(a);
        tr.expire(b);
        assert!((tr.accuracy() - 0.5).abs() < 1e-12);
    }
}
