//! Learned next-invocation scorer.
//!
//! Combines the chain and histogram signals (plus time-of-flow features)
//! into a single calibrated probability via a small logistic model. The
//! model has two execution paths:
//!
//! 1. **Native** — the logistic regression evaluated in rust (always
//!    available; used inside the discrete-event simulator's hot loop).
//! 2. **AOT artifact** — the same weights baked into the JAX/Pallas
//!    predictor artifact (`artifacts/predictor.hlo.txt`), executed through
//!    PJRT by the serving engine. The pytest suite checks the two paths
//!    agree; the rust integration test checks the artifact matches
//!    [`LearnedScorer::score`] bit-for-bit-ish (1e-5).
//!
//! Features (in order, matching `python/compile/model.py::predictor_fwd`):
//! `[chain_conf, hist_conf, recency, log_lead]` — see [`Features`].

use crate::util::time::SimDuration;

/// Input features for one candidate prediction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Features {
    /// Chain-edge follow-through confidence (0 when not in a chain).
    pub chain_conf: f64,
    /// Histogram mode concentration (0 when too little history).
    pub hist_conf: f64,
    /// exp(-idle/300s): how recently the function last ran.
    pub recency: f64,
    /// log1p(expected lead in seconds), normalised by log1p(10).
    pub log_lead: f64,
}

impl Features {
    pub fn build(
        chain_conf: f64,
        hist_conf: f64,
        idle: SimDuration,
        lead: SimDuration,
    ) -> Features {
        Features {
            chain_conf,
            hist_conf,
            recency: (-idle.as_secs_f64() / 300.0).exp(),
            log_lead: (lead.as_secs_f64()).ln_1p() / 10.0f64.ln_1p(),
        }
    }

    pub fn to_vec(&self) -> [f64; 4] {
        [self.chain_conf, self.hist_conf, self.recency, self.log_lead]
    }
}

/// Logistic scorer with fixed, offline-trained weights.
///
/// The weights below were fit on synthetic chain+histogram workloads
/// (see `python/compile/train_predictor.py` which regenerates them and
/// bakes the same values into the AOT artifact). Chain membership is the
/// dominant signal, matching the paper's argument that orchestration
/// chains are the best prediction opportunity.
#[derive(Debug, Clone, Copy)]
pub struct LearnedScorer {
    pub weights: [f64; 4],
    pub bias: f64,
}

/// The canonical deployed weights — MUST match python/compile/model.py.
pub const DEPLOYED_WEIGHTS: [f64; 4] = [3.2, 1.8, 0.9, -0.6];
pub const DEPLOYED_BIAS: f64 = -2.0;

impl Default for LearnedScorer {
    fn default() -> LearnedScorer {
        LearnedScorer {
            weights: DEPLOYED_WEIGHTS,
            bias: DEPLOYED_BIAS,
        }
    }
}

impl LearnedScorer {
    /// Probability that the candidate invocation happens in the window.
    pub fn score(&self, f: &Features) -> f64 {
        let x = f.to_vec();
        let z: f64 = self
            .weights
            .iter()
            .zip(x.iter())
            .map(|(w, xi)| w * xi)
            .sum::<f64>()
            + self.bias;
        1.0 / (1.0 + (-z).exp())
    }

    /// Score a batch (the PJRT artifact path is batched; this is the
    /// native equivalent used by tests and the simulator).
    pub fn score_batch(&self, batch: &[Features]) -> Vec<f64> {
        batch.iter().map(|f| self.score(f)).collect()
    }
}

/// Convenience: combined confidence for a candidate, preferring the
/// learned score when both signals exist, else passing through the single
/// available signal (the simulator's default configuration).
pub fn combined_confidence(
    scorer: &LearnedScorer,
    chain_conf: Option<f64>,
    hist_conf: Option<f64>,
    idle: SimDuration,
    lead: SimDuration,
) -> f64 {
    match (chain_conf, hist_conf) {
        (None, None) => 0.0,
        (Some(c), None) => c,
        (None, Some(h)) => h * 0.8, // histogram alone is weaker evidence
        (Some(c), Some(h)) => scorer.score(&Features::build(c, h, idle, lead)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feats(chain: f64, hist: f64) -> Features {
        Features::build(
            chain,
            hist,
            SimDuration::from_secs(10),
            SimDuration::from_millis(64),
        )
    }

    #[test]
    fn strong_chain_signal_scores_high() {
        let s = LearnedScorer::default();
        let hi = s.score(&feats(0.95, 0.8));
        let lo = s.score(&feats(0.0, 0.0));
        assert!(hi > 0.85, "hi {hi}");
        assert!(lo < 0.25, "lo {lo}");
        assert!(hi > lo);
    }

    #[test]
    fn monotone_in_each_confidence() {
        let s = LearnedScorer::default();
        let mut prev = 0.0;
        for c in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let v = s.score(&feats(c, 0.5));
            assert!(v > prev);
            prev = v;
        }
    }

    #[test]
    fn longer_lead_reduces_score() {
        let s = LearnedScorer::default();
        let near = Features::build(
            0.8,
            0.8,
            SimDuration::from_secs(1),
            SimDuration::from_millis(60),
        );
        let far = Features::build(
            0.8,
            0.8,
            SimDuration::from_secs(1),
            SimDuration::from_secs(600),
        );
        assert!(s.score(&near) > s.score(&far));
    }

    #[test]
    fn batch_matches_scalar() {
        let s = LearnedScorer::default();
        let batch = vec![feats(0.1, 0.2), feats(0.9, 0.9), feats(0.5, 0.0)];
        let scores = s.score_batch(&batch);
        for (f, v) in batch.iter().zip(scores.iter()) {
            assert_eq!(*v, s.score(f));
        }
    }

    #[test]
    fn combined_confidence_fallbacks() {
        let s = LearnedScorer::default();
        assert_eq!(
            combined_confidence(&s, None, None, SimDuration::ZERO, SimDuration::ZERO),
            0.0
        );
        assert_eq!(
            combined_confidence(&s, Some(0.7), None, SimDuration::ZERO, SimDuration::ZERO),
            0.7
        );
        assert!(
            (combined_confidence(&s, None, Some(0.5), SimDuration::ZERO, SimDuration::ZERO)
                - 0.4)
                .abs()
                < 1e-12
        );
        let both =
            combined_confidence(&s, Some(0.9), Some(0.9), SimDuration::ZERO, SimDuration::ZERO);
        assert!(both > 0.8);
    }
}
