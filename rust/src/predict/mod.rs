//! Invocation prediction (§2 "Regaining efficiency via prediction").
//!
//! freshen is only useful if the platform can predict *when a function may
//! run*. The paper identifies the opportunities this module implements:
//!
//! - [`chain`] — explicit function chains from orchestration frameworks
//!   (Figure 1/2): when fᵢ starts (or commits a trigger), fᵢ₊₁ is imminent,
//!   with the trigger-service delay (Table 1) as the lead window.
//! - [`histogram`] — inter-arrival-time histograms per function, the
//!   Shahrad-et-al-style signal for standalone functions.
//! - [`confidence`] — outstanding-prediction tracking: each admitted
//!   prediction is matched against actual arrivals to produce the hit/miss
//!   feedback that drives the freshen gate and billing.
//! - [`learned`] — a learned scorer combining both signals; its weights are
//!   trained offline and it can execute via the AOT predictor artifact on
//!   the PJRT path (see `runtime`).

pub mod chain;
pub mod confidence;
pub mod histogram;
pub mod learned;

use crate::util::time::SimTime;

/// Where a prediction came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictionSource {
    Chain,
    Histogram,
    Learned,
}

/// A predicted impending invocation.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub function: String,
    /// When the invocation is expected to start.
    pub expected_at: SimTime,
    /// Predictor's confidence in [0, 1].
    pub confidence: f64,
    pub source: PredictionSource,
}
