//! Inter-arrival-time (IAT) histogram prediction.
//!
//! For functions outside explicit chains, the paper points at invocation-
//! history prediction ("Serverless in the Wild" [9], Fifer [3]): most
//! functions have strongly periodic or concentrated inter-arrival times, so
//! a per-function IAT histogram predicts the next invocation as
//! `last_arrival + modal_IAT`, with confidence proportional to how
//! concentrated the histogram's mass is around the mode.

use crate::predict::{Prediction, PredictionSource};
use crate::util::fxhash::FxHashMap;
use crate::util::stats::Histogram;
use crate::util::time::{SimDuration, SimTime};

/// Histogram configuration: IATs from 100 ms to `range_s` seconds.
const RANGE_S: f64 = 3600.0;
const NBINS: usize = 240; // 15s bins over an hour

/// Per-function IAT state.
#[derive(Debug, Clone)]
struct FnHistory {
    hist: Histogram,
    last_arrival: Option<SimTime>,
}

impl FnHistory {
    fn new() -> FnHistory {
        FnHistory {
            hist: Histogram::new(0.0, RANGE_S, NBINS),
            last_arrival: None,
        }
    }
}

/// The histogram predictor.
#[derive(Debug, Clone, Default)]
pub struct HistogramPredictor {
    functions: FxHashMap<String, FnHistory>,
    /// Minimum samples before emitting predictions.
    pub min_samples: u64,
}

impl HistogramPredictor {
    pub fn new() -> HistogramPredictor {
        HistogramPredictor {
            functions: FxHashMap::default(),
            min_samples: 4,
        }
    }

    /// Record an observed invocation arrival.
    pub fn observe(&mut self, function: &str, at: SimTime) {
        let h = self
            .functions
            .entry(function.to_string())
            .or_insert_with(FnHistory::new);
        if let Some(last) = h.last_arrival {
            let iat = at.since(last).as_secs_f64();
            h.hist.record(iat);
        }
        h.last_arrival = Some(at);
    }

    /// Predict the next invocation of `function` after `now`, if the
    /// history supports one.
    pub fn predict_next(&self, function: &str, now: SimTime) -> Option<Prediction> {
        let h = self.functions.get(function)?;
        if h.hist.count() < self.min_samples {
            return None;
        }
        let mode = h.hist.mode_bin()?;
        let modal_iat = h.hist.bin_center(mode);
        let confidence = h.hist.mode_concentration();
        let last = h.last_arrival?;
        let expected = last + SimDuration::from_secs_f64(modal_iat);
        // If the modal point is already past, predict "imminent".
        let expected_at = if expected > now { expected } else { now };
        Some(Prediction {
            function: function.to_string(),
            expected_at,
            confidence,
            source: PredictionSource::Histogram,
        })
    }

    /// Bulk-warmup path for trace replay: feed per-minute invocation
    /// counts (the Azure trace representation) directly into the IAT
    /// histogram without creating simulator events or re-resolving the
    /// per-function entry per arrival. Arrivals within a minute are spread
    /// evenly — the histogram's 15 s bins cannot tell the difference, and
    /// the approximation keeps warmup O(total counts) with one map lookup.
    ///
    /// `start` is the trace time of `counts[0]`'s minute; returns the
    /// number of IAT samples recorded.
    pub fn warm_from_minute_counts(
        &mut self,
        function: &str,
        counts: &[u32],
        start: SimTime,
        minute: SimDuration,
    ) -> u64 {
        let h = self
            .functions
            .entry(function.to_string())
            .or_insert_with(FnHistory::new);
        let mut added = 0u64;
        for (m, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let base = start + SimDuration(minute.micros() * m as u64);
            let step = minute.micros() / c as u64;
            for j in 0..c as u64 {
                let at = base + SimDuration(step * j + step / 2);
                if let Some(last) = h.last_arrival {
                    h.hist.record(at.since(last).as_secs_f64());
                    added += 1;
                }
                h.last_arrival = Some(at);
            }
        }
        added
    }

    /// Number of IAT samples recorded for `function`.
    pub fn samples(&self, function: &str) -> u64 {
        self.functions
            .get(function)
            .map(|h| h.hist.count())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: u64) -> SimTime {
        SimTime(s * 1_000_000)
    }

    #[test]
    fn periodic_arrivals_predict_confidently() {
        let mut p = HistogramPredictor::new();
        // Every 60s, 20 observations.
        for i in 0..20 {
            p.observe("cron", t(i * 60));
        }
        let pred = p.predict_next("cron", t(19 * 60)).unwrap();
        // Expected at ~last + 60s (bin centre gives +/- half a bin: 7.5s).
        let delta = pred.expected_at.since(t(19 * 60)).as_secs_f64();
        assert!((delta - 60.0).abs() <= 8.0, "delta {delta}");
        assert!(pred.confidence > 0.9, "confidence {}", pred.confidence);
        assert_eq!(pred.source, PredictionSource::Histogram);
    }

    #[test]
    fn irregular_arrivals_predict_with_low_confidence() {
        let mut p = HistogramPredictor::new();
        let mut rng = crate::util::rng::Rng::new(11);
        let mut at = 0u64;
        for _ in 0..40 {
            at += (rng.uniform(5.0, 3000.0)) as u64;
            p.observe("bursty", t(at));
        }
        let pred = p.predict_next("bursty", t(at)).unwrap();
        assert!(pred.confidence < 0.5, "confidence {}", pred.confidence);
    }

    #[test]
    fn too_few_samples_yield_none() {
        let mut p = HistogramPredictor::new();
        p.observe("f", t(0));
        p.observe("f", t(60));
        assert!(p.predict_next("f", t(61)).is_none());
        assert!(p.predict_next("ghost", t(0)).is_none());
        assert_eq!(p.samples("f"), 1);
    }

    #[test]
    fn bulk_warmup_matches_periodic_observe() {
        // 1/min for 30 minutes via the bulk path predicts like 30
        // individually observed arrivals would.
        let mut p = HistogramPredictor::new();
        let counts = vec![1u32; 30];
        let added =
            p.warm_from_minute_counts("cron", &counts, t(0), SimDuration::from_secs(60));
        assert_eq!(added, 29);
        assert_eq!(p.samples("cron"), 29);
        let pred = p.predict_next("cron", t(30 * 60)).unwrap();
        assert!(pred.confidence > 0.9, "confidence {}", pred.confidence);
        // Empty counts add nothing and create no phantom history.
        assert_eq!(
            p.warm_from_minute_counts("idle", &[0, 0, 0], t(0), SimDuration::from_secs(60)),
            0
        );
        assert!(p.predict_next("idle", t(200)).is_none());
    }

    #[test]
    fn past_mode_predicts_imminent() {
        let mut p = HistogramPredictor::new();
        for i in 0..10 {
            p.observe("f", t(i * 10));
        }
        // Ask long after the modal IAT has elapsed.
        let now = t(90 + 500);
        let pred = p.predict_next("f", now).unwrap();
        assert_eq!(pred.expected_at, now);
    }
}
