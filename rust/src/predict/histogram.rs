//! Inter-arrival-time (IAT) histogram prediction.
//!
//! For functions outside explicit chains, the paper points at invocation-
//! history prediction ("Serverless in the Wild" [9], Fifer [3]): most
//! functions have strongly periodic or concentrated inter-arrival times, so
//! a per-function IAT histogram predicts the next invocation as
//! `last_arrival + modal_IAT`, with confidence proportional to how
//! concentrated the histogram's mass is around the mode.

use std::collections::HashMap;

use crate::predict::{Prediction, PredictionSource};
use crate::util::stats::Histogram;
use crate::util::time::{SimDuration, SimTime};

/// Histogram configuration: IATs from 100 ms to `range_s` seconds.
const RANGE_S: f64 = 3600.0;
const NBINS: usize = 240; // 15s bins over an hour

/// Per-function IAT state.
#[derive(Debug, Clone)]
struct FnHistory {
    hist: Histogram,
    last_arrival: Option<SimTime>,
}

impl FnHistory {
    fn new() -> FnHistory {
        FnHistory {
            hist: Histogram::new(0.0, RANGE_S, NBINS),
            last_arrival: None,
        }
    }
}

/// The histogram predictor.
#[derive(Debug, Clone, Default)]
pub struct HistogramPredictor {
    functions: HashMap<String, FnHistory>,
    /// Minimum samples before emitting predictions.
    pub min_samples: u64,
}

impl HistogramPredictor {
    pub fn new() -> HistogramPredictor {
        HistogramPredictor {
            functions: HashMap::new(),
            min_samples: 4,
        }
    }

    /// Record an observed invocation arrival.
    pub fn observe(&mut self, function: &str, at: SimTime) {
        let h = self
            .functions
            .entry(function.to_string())
            .or_insert_with(FnHistory::new);
        if let Some(last) = h.last_arrival {
            let iat = at.since(last).as_secs_f64();
            h.hist.record(iat);
        }
        h.last_arrival = Some(at);
    }

    /// Predict the next invocation of `function` after `now`, if the
    /// history supports one.
    pub fn predict_next(&self, function: &str, now: SimTime) -> Option<Prediction> {
        let h = self.functions.get(function)?;
        if h.hist.count() < self.min_samples {
            return None;
        }
        let mode = h.hist.mode_bin()?;
        let modal_iat = h.hist.bin_center(mode);
        let confidence = h.hist.mode_concentration();
        let last = h.last_arrival?;
        let expected = last + SimDuration::from_secs_f64(modal_iat);
        // If the modal point is already past, predict "imminent".
        let expected_at = if expected > now { expected } else { now };
        Some(Prediction {
            function: function.to_string(),
            expected_at,
            confidence,
            source: PredictionSource::Histogram,
        })
    }

    /// Number of IAT samples recorded for `function`.
    pub fn samples(&self, function: &str) -> u64 {
        self.functions
            .get(function)
            .map(|h| h.hist.count())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: u64) -> SimTime {
        SimTime(s * 1_000_000)
    }

    #[test]
    fn periodic_arrivals_predict_confidently() {
        let mut p = HistogramPredictor::new();
        // Every 60s, 20 observations.
        for i in 0..20 {
            p.observe("cron", t(i * 60));
        }
        let pred = p.predict_next("cron", t(19 * 60)).unwrap();
        // Expected at ~last + 60s (bin centre gives +/- half a bin: 7.5s).
        let delta = pred.expected_at.since(t(19 * 60)).as_secs_f64();
        assert!((delta - 60.0).abs() <= 8.0, "delta {delta}");
        assert!(pred.confidence > 0.9, "confidence {}", pred.confidence);
        assert_eq!(pred.source, PredictionSource::Histogram);
    }

    #[test]
    fn irregular_arrivals_predict_with_low_confidence() {
        let mut p = HistogramPredictor::new();
        let mut rng = crate::util::rng::Rng::new(11);
        let mut at = 0u64;
        for _ in 0..40 {
            at += (rng.uniform(5.0, 3000.0)) as u64;
            p.observe("bursty", t(at));
        }
        let pred = p.predict_next("bursty", t(at)).unwrap();
        assert!(pred.confidence < 0.5, "confidence {}", pred.confidence);
    }

    #[test]
    fn too_few_samples_yield_none() {
        let mut p = HistogramPredictor::new();
        p.observe("f", t(0));
        p.observe("f", t(60));
        assert!(p.predict_next("f", t(61)).is_none());
        assert!(p.predict_next("ghost", t(0)).is_none());
        assert_eq!(p.samples("f"), 1);
    }

    #[test]
    fn past_mode_predicts_imminent() {
        let mut p = HistogramPredictor::new();
        for i in 0..10 {
            p.observe("f", t(i * 10));
        }
        // Ask long after the modal IAT has elapsed.
        let now = t(90 + 500);
        let pred = p.predict_next("f", now).unwrap();
        assert_eq!(pred.expected_at, now);
    }
}
