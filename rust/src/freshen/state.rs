//! `fr_state`: the ordered, runtime-scoped list of freshen resources (§3.3).
//!
//! Each entry tracks one resource the function touches, in program order —
//! in the paper's λ, `DataGet` is index 0 and `DataPut` is index 1. An entry
//! carries the paper's metadata: a *state* (not-run / running / finished), a
//! *result* (the prefetched data), a *TTL*, and a *timestamp* of the last
//! freshen. Both the freshen hook and the function's wrappers race on these
//! entries; whoever starts first marks the entry `Running` and the other
//! side waits or skips (Algorithms 2, 4, 5).

use crate::util::time::{SimDuration, SimTime};

/// State of one freshen resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrStatus {
    /// Nobody has touched this resource yet this cycle.
    NotRun,
    /// Freshen (or a wrapper) is currently working on it.
    Running,
    /// Work is complete; `result` is valid (subject to TTL).
    Finished,
}

/// The result a finished entry holds.
#[derive(Debug, Clone, PartialEq)]
pub enum FrResult {
    /// Prefetched object: identifier, version and payload size.
    Data {
        object_id: String,
        version: u64,
        bytes: f64,
    },
    /// The resource (a connection) was warmed; nothing to return.
    Warmed,
    /// The freshen action failed (e.g. endpoint unreachable); the wrapper
    /// must redo the work itself. Failure to freshen is never fatal (§3.3).
    Failed,
}

/// One freshen resource entry.
#[derive(Debug, Clone)]
pub struct FrEntry {
    pub status: FrStatus,
    pub result: Option<FrResult>,
    /// How long a `Data` result stays fresh.
    pub ttl: SimDuration,
    /// When the entry was last freshened (valid when `Finished`).
    pub freshened_at: SimTime,
    /// Who completed the entry (metrics/billing attribution).
    pub completed_by: Option<Completer>,
}

/// Which side completed an entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Completer {
    /// The proactive freshen hook.
    Freshen,
    /// The function's own wrapper (freshen was late or absent).
    Function,
}

impl FrEntry {
    pub fn new(ttl: SimDuration) -> FrEntry {
        FrEntry {
            status: FrStatus::NotRun,
            result: None,
            ttl,
            freshened_at: SimTime::ZERO,
            completed_by: None,
        }
    }

    /// Is a `Finished` entry still usable at `now`?
    ///
    /// `Warmed` results never expire by TTL (the connection object itself
    /// tracks liveness); `Data` results expire after `ttl`; `Failed`
    /// results are never fresh.
    pub fn is_fresh(&self, now: SimTime) -> bool {
        if self.status != FrStatus::Finished {
            return false;
        }
        match &self.result {
            Some(FrResult::Data { .. }) => now.since(self.freshened_at) <= self.ttl,
            Some(FrResult::Warmed) => true,
            Some(FrResult::Failed) | None => false,
        }
    }

    /// Transition to `Running`. Returns false if the entry was already
    /// running or finished-and-fresh (i.e. the caller lost the race).
    pub fn try_start(&mut self, now: SimTime) -> bool {
        match self.status {
            FrStatus::Running => false,
            FrStatus::Finished if self.is_fresh(now) => false,
            _ => {
                self.status = FrStatus::Running;
                self.result = None;
                true
            }
        }
    }

    /// Complete the entry with a result.
    pub fn finish(&mut self, result: FrResult, now: SimTime, by: Completer) {
        debug_assert_eq!(self.status, FrStatus::Running, "finish without start");
        self.status = FrStatus::Finished;
        self.result = Some(result);
        self.freshened_at = now;
        self.completed_by = Some(by);
    }

    /// Reset for the next freshen/invocation cycle (keeps a fresh Data
    /// result so it can be reused within its TTL — the freshen cache
    /// behaviour of §3.2; everything else clears). A `Running` entry is
    /// left alone: a freshen thread is actively working on it and the
    /// function-side wrapper must coordinate through `FrWait`, not clobber
    /// the state from under it.
    pub fn recycle(&mut self, now: SimTime) {
        if self.status == FrStatus::Running || self.is_fresh(now) {
            return;
        }
        self.status = FrStatus::NotRun;
        self.result = None;
        self.completed_by = None;
    }
}

/// The ordered runtime-scoped list of freshen resources.
#[derive(Debug, Clone, Default)]
pub struct FrState {
    entries: Vec<FrEntry>,
}

impl FrState {
    pub fn new() -> FrState {
        FrState::default()
    }

    /// (Re)build the list for a function with `n` resources, preserving
    /// still-fresh entries from the previous cycle at matching indices.
    pub fn ensure_len(&mut self, n: usize, default_ttl: SimDuration, now: SimTime) {
        self.entries.resize_with(n, || FrEntry::new(default_ttl));
        for e in &mut self.entries {
            e.recycle(now);
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, id: usize) -> Option<&FrEntry> {
        self.entries.get(id)
    }

    pub fn get_mut(&mut self, id: usize) -> Option<&mut FrEntry> {
        self.entries.get_mut(id)
    }

    /// Count of entries completed by the freshen hook (hit-rate metrics).
    pub fn freshened_count(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| e.completed_by == Some(Completer::Freshen))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: u64) -> SimTime {
        SimTime(s * 1_000_000)
    }

    #[test]
    fn lifecycle_not_run_running_finished() {
        let mut e = FrEntry::new(SimDuration::from_secs(10));
        assert_eq!(e.status, FrStatus::NotRun);
        assert!(!e.is_fresh(t(0)));
        assert!(e.try_start(t(0)));
        assert_eq!(e.status, FrStatus::Running);
        // Second starter loses the race.
        assert!(!e.try_start(t(0)));
        e.finish(
            FrResult::Data {
                object_id: "m".into(),
                version: 1,
                bytes: 100.0,
            },
            t(1),
            Completer::Freshen,
        );
        assert!(e.is_fresh(t(5)));
        assert!(!e.try_start(t(5))); // fresh: no need to redo
    }

    #[test]
    fn ttl_expiry_allows_restart() {
        let mut e = FrEntry::new(SimDuration::from_secs(10));
        assert!(e.try_start(t(0)));
        e.finish(
            FrResult::Data {
                object_id: "m".into(),
                version: 1,
                bytes: 100.0,
            },
            t(0),
            Completer::Freshen,
        );
        assert!(e.is_fresh(t(10)));
        assert!(!e.is_fresh(t(11)));
        assert!(e.try_start(t(11))); // stale: can refresh
    }

    #[test]
    fn warmed_results_do_not_expire() {
        let mut e = FrEntry::new(SimDuration::from_secs(1));
        assert!(e.try_start(t(0)));
        e.finish(FrResult::Warmed, t(0), Completer::Freshen);
        assert!(e.is_fresh(t(1_000)));
    }

    #[test]
    fn failed_results_are_not_fresh() {
        let mut e = FrEntry::new(SimDuration::from_secs(10));
        assert!(e.try_start(t(0)));
        e.finish(FrResult::Failed, t(0), Completer::Freshen);
        assert!(!e.is_fresh(t(0)));
        assert!(e.try_start(t(0))); // wrapper redoes the work
    }

    #[test]
    fn recycle_keeps_fresh_data() {
        let mut e = FrEntry::new(SimDuration::from_secs(10));
        e.try_start(t(0));
        e.finish(
            FrResult::Data {
                object_id: "m".into(),
                version: 1,
                bytes: 9.0,
            },
            t(0),
            Completer::Freshen,
        );
        e.recycle(t(5));
        assert_eq!(e.status, FrStatus::Finished); // kept
        e.recycle(t(30));
        assert_eq!(e.status, FrStatus::NotRun); // expired -> cleared
        assert!(e.result.is_none());
    }

    #[test]
    fn ensure_len_preserves_fresh_entries() {
        let mut st = FrState::new();
        st.ensure_len(2, SimDuration::from_secs(10), t(0));
        st.get_mut(0).unwrap().try_start(t(0));
        st.get_mut(0).unwrap().finish(
            FrResult::Data {
                object_id: "a".into(),
                version: 3,
                bytes: 1.0,
            },
            t(0),
            Completer::Freshen,
        );
        st.ensure_len(2, SimDuration::from_secs(10), t(5));
        assert!(st.get(0).unwrap().is_fresh(t(5)));
        assert_eq!(st.get(1).unwrap().status, FrStatus::NotRun);
        assert_eq!(st.freshened_count(), 1);
    }
}
