//! The freshen-maintained prefetch cache (§3.2 "Proactive data fetching").
//!
//! "Prefetching leads to the concept of a freshen-maintained cache of
//! prefetched data. If the function is invoked frequently within the same
//! runtime and accesses a read-only data resource, it may only be necessary
//! to fetch the data once every *n* seconds instead of every time the
//! function is run, reducing network traffic."
//!
//! Keys are `(endpoint, object_id)`. TTLs come from, in priority order: a
//! per-resource TTL (library-configured), the developer's freshen config,
//! or the platform default. Entries carry the object version so staleness
//! can also be decided by version comparison.

use crate::util::fxhash::FxHashMap;
use crate::util::time::{SimDuration, SimTime};

/// One cached object.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedObject {
    pub version: u64,
    pub bytes: f64,
    pub fetched_at: SimTime,
    pub ttl: SimDuration,
}

impl CachedObject {
    pub fn is_fresh(&self, now: SimTime) -> bool {
        now.since(self.fetched_at) <= self.ttl
    }
}

/// Cache statistics — the "reducing network traffic" claim is quantified
/// from these in the TTL ablation bench.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub expired: u64,
    pub version_stale: u64,
    /// Network bytes avoided by hits.
    pub bytes_saved: f64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses + self.expired + self.version_stale;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Runtime-scoped prefetch cache.
#[derive(Debug, Clone, Default)]
pub struct FreshenCache {
    entries: FxHashMap<(String, String), CachedObject>,
    pub stats: CacheStats,
}

impl FreshenCache {
    pub fn new() -> FreshenCache {
        FreshenCache::default()
    }

    /// Look up an object. `live_version` (when known, e.g. from a cheap
    /// HEAD or a datastore notification) invalidates version-stale hits.
    pub fn get(
        &mut self,
        endpoint: &str,
        object_id: &str,
        now: SimTime,
        live_version: Option<u64>,
    ) -> Option<CachedObject> {
        let key = (endpoint.to_string(), object_id.to_string());
        match self.entries.get(&key) {
            None => {
                self.stats.misses += 1;
                None
            }
            Some(obj) if !obj.is_fresh(now) => {
                self.stats.expired += 1;
                None
            }
            Some(obj) => {
                if let Some(live) = live_version {
                    if obj.version < live {
                        self.stats.version_stale += 1;
                        return None;
                    }
                }
                self.stats.hits += 1;
                self.stats.bytes_saved += obj.bytes;
                Some(obj.clone())
            }
        }
    }

    /// Peek without touching stats (used by freshen to decide whether a
    /// prefetch is even needed).
    pub fn peek_fresh(&self, endpoint: &str, object_id: &str, now: SimTime) -> bool {
        self.entries
            .get(&(endpoint.to_string(), object_id.to_string()))
            .map(|o| o.is_fresh(now))
            .unwrap_or(false)
    }

    /// Insert/replace after a (pre)fetch.
    pub fn put(
        &mut self,
        endpoint: &str,
        object_id: &str,
        version: u64,
        bytes: f64,
        ttl: SimDuration,
        now: SimTime,
    ) {
        self.entries.insert(
            (endpoint.to_string(), object_id.to_string()),
            CachedObject {
                version,
                bytes,
                fetched_at: now,
                ttl,
            },
        );
    }

    /// Drop every entry (container recycled for another function).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: u64) -> SimTime {
        SimTime(s * 1_000_000)
    }

    #[test]
    fn hit_within_ttl_saves_bytes() {
        let mut c = FreshenCache::new();
        c.put("store", "model", 1, 5e6, SimDuration::from_secs(10), t(0));
        let got = c.get("store", "model", t(5), None).unwrap();
        assert_eq!(got.version, 1);
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.bytes_saved, 5e6);
    }

    #[test]
    fn expiry_counts_separately_from_miss() {
        let mut c = FreshenCache::new();
        assert!(c.get("store", "x", t(0), None).is_none());
        assert_eq!(c.stats.misses, 1);
        c.put("store", "x", 1, 100.0, SimDuration::from_secs(2), t(0));
        assert!(c.get("store", "x", t(5), None).is_none());
        assert_eq!(c.stats.expired, 1);
    }

    #[test]
    fn version_staleness_invalidates() {
        let mut c = FreshenCache::new();
        c.put("store", "m", 3, 100.0, SimDuration::from_secs(100), t(0));
        assert!(c.get("store", "m", t(1), Some(4)).is_none());
        assert_eq!(c.stats.version_stale, 1);
        // Equal version is fine.
        assert!(c.get("store", "m", t(1), Some(3)).is_some());
    }

    #[test]
    fn hit_rate_math() {
        let mut c = FreshenCache::new();
        c.put("e", "a", 1, 10.0, SimDuration::from_secs(10), t(0));
        c.get("e", "a", t(1), None); // hit
        c.get("e", "b", t(1), None); // miss
        assert!((c.stats.hit_rate() - 0.5).abs() < 1e-12);
        let empty = FreshenCache::new();
        assert_eq!(empty.stats.hit_rate(), 0.0);
    }

    #[test]
    fn peek_does_not_touch_stats() {
        let mut c = FreshenCache::new();
        c.put("e", "a", 1, 10.0, SimDuration::from_secs(10), t(0));
        assert!(c.peek_fresh("e", "a", t(1)));
        assert!(!c.peek_fresh("e", "zzz", t(1)));
        assert_eq!(c.stats, CacheStats::default());
    }

    #[test]
    fn clear_empties() {
        let mut c = FreshenCache::new();
        c.put("e", "a", 1, 10.0, SimDuration::from_secs(10), t(0));
        assert_eq!(c.len(), 1);
        c.clear();
        assert!(c.is_empty());
    }
}
