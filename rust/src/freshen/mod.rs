//! The paper's contribution: the **`freshen`** primitive.
//!
//! `freshen` is a hook in the language runtime that the provider (or the
//! developer) runs *before* a function is predicted to execute. It shares
//! runtime-scoped state with the function — an ordered list of *freshen
//! resources* (`fr_state`, §3.3) — and coordinates through two wrapper
//! functions injected around the function's resource accesses:
//!
//! - [`wrappers`]`::fr_fetch_decision` (Algorithm 4) around data fetches, and
//! - [`wrappers`]`::fr_warm_decision` (Algorithm 5) around connection-using writes.
//!
//! Sub-modules:
//! - [`state`] — `fr_state` entries and their state machine.
//! - [`wrappers`] — the pure decision logic of Algorithms 4/5 (shared by
//!   the simulator and the real-time serving engine).
//! - [`hooks`] — freshen hook bodies: the action list a hook executes
//!   (Algorithm 2 generalised).
//! - [`infer`] — provider-side static analysis that generates hooks from
//!   function code (§3.3 "code generation").
//! - [`cache`] — the TTL'd prefetch cache.
//! - [`policy`] — billing attribution, confidence gating, abuse guards.

pub mod cache;
pub mod hooks;
pub mod infer;
pub mod policy;
pub mod state;
pub mod wrappers;

pub use hooks::{FreshenAction, FreshenHook};
pub use state::{Completer, FrEntry, FrResult, FrState, FrStatus};
pub use wrappers::WrapperDecision;
