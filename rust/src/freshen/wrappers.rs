//! `FrFetch` / `FrWarm` wrapper decision logic — Algorithms 4 and 5.
//!
//! The paper's wrappers intercept the function's access to each freshen
//! resource and synchronise with the freshen hook through `fr_state`:
//!
//! ```text
//! if fr_state[id] == finished  -> return fr_state[id].result
//! if fr_state[id] == running   -> FrWait(id); return fr_state[id].result
//! else                         -> fr_state[id] = running
//!                                 do the work yourself; mark finished
//! ```
//!
//! The decision itself is pure over the entry (plus freshness inputs), so
//! the discrete-event simulator and the real-time serving engine share it;
//! only *how to wait* differs between substrates (event continuation vs
//! condvar).

use crate::freshen::state::{FrEntry, FrResult, FrStatus};
use crate::util::time::SimTime;

/// What the wrapper should do for this resource access.
#[derive(Debug, Clone, PartialEq)]
pub enum WrapperDecision {
    /// Freshen already completed the work; consume its result
    /// (Alg. 4 line 4 / Alg. 5 line 4).
    UseResult(FrResult),
    /// Freshen is mid-flight; park until it finishes, then consume
    /// (Alg. 4/5 line 6, `FrWait`).
    Wait,
    /// Freshen did not run (or its result is stale/failed); the wrapper
    /// performs the action itself (Alg. 4/5 line 10). The entry has been
    /// marked `Running` on behalf of the caller.
    DoItYourself,
}

/// Algorithm 4 — `FrFetch(id, code)` decision for a data fetch.
///
/// `live_version`: the store's current version of the object if the caller
/// wants strict version freshness (§3.2 "associated timestamps or version
/// numbers could be used to determine the freshness of items"); `None`
/// accepts any TTL-fresh result.
pub fn fr_fetch_decision(
    entry: &mut FrEntry,
    now: SimTime,
    live_version: Option<u64>,
) -> WrapperDecision {
    match entry.status {
        FrStatus::Finished if entry.is_fresh(now) => {
            let stale_version = match (&entry.result, live_version) {
                (Some(FrResult::Data { version, .. }), Some(live)) => *version < live,
                _ => false,
            };
            if stale_version {
                // Prefetched copy is outdated: redo the fetch.
                entry.status = FrStatus::NotRun;
                entry.result = None;
                let started = entry.try_start(now);
                debug_assert!(started);
                WrapperDecision::DoItYourself
            } else {
                WrapperDecision::UseResult(
                    entry.result.clone().expect("finished entry has a result"),
                )
            }
        }
        FrStatus::Running => WrapperDecision::Wait,
        _ => {
            let started = entry.try_start(now);
            debug_assert!(started, "NotRun/stale entry must be startable");
            WrapperDecision::DoItYourself
        }
    }
}

/// Algorithm 5 — `FrWarm(id, resource)` decision for a warmable resource.
/// Identical control flow; the "result" carries no data.
pub fn fr_warm_decision(entry: &mut FrEntry, now: SimTime) -> WrapperDecision {
    match entry.status {
        FrStatus::Finished if entry.is_fresh(now) => {
            WrapperDecision::UseResult(FrResult::Warmed)
        }
        FrStatus::Running => WrapperDecision::Wait,
        _ => {
            let started = entry.try_start(now);
            debug_assert!(started);
            WrapperDecision::DoItYourself
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::freshen::state::Completer;
    use crate::util::time::SimDuration;

    fn t(s: u64) -> SimTime {
        SimTime(s * 1_000_000)
    }

    fn data(v: u64) -> FrResult {
        FrResult::Data {
            object_id: "model".into(),
            version: v,
            bytes: 1e6,
        }
    }

    #[test]
    fn finished_fresh_returns_result() {
        let mut e = FrEntry::new(SimDuration::from_secs(10));
        e.try_start(t(0));
        e.finish(data(1), t(0), Completer::Freshen);
        match fr_fetch_decision(&mut e, t(1), None) {
            WrapperDecision::UseResult(FrResult::Data { version, .. }) => assert_eq!(version, 1),
            other => panic!("expected UseResult, got {other:?}"),
        }
    }

    #[test]
    fn running_waits() {
        let mut e = FrEntry::new(SimDuration::from_secs(10));
        e.try_start(t(0));
        assert_eq!(fr_fetch_decision(&mut e, t(0), None), WrapperDecision::Wait);
        assert_eq!(fr_warm_decision(&mut e, t(0)), WrapperDecision::Wait);
    }

    #[test]
    fn not_run_means_do_it_yourself_and_claims_entry() {
        let mut e = FrEntry::new(SimDuration::from_secs(10));
        assert_eq!(
            fr_fetch_decision(&mut e, t(0), None),
            WrapperDecision::DoItYourself
        );
        // Entry is now claimed: a late freshen hook would observe Running.
        assert_eq!(e.status, FrStatus::Running);
    }

    #[test]
    fn ttl_expired_redoes_work() {
        let mut e = FrEntry::new(SimDuration::from_secs(5));
        e.try_start(t(0));
        e.finish(data(1), t(0), Completer::Freshen);
        assert_eq!(
            fr_fetch_decision(&mut e, t(20), None),
            WrapperDecision::DoItYourself
        );
    }

    #[test]
    fn version_mismatch_redoes_fetch() {
        let mut e = FrEntry::new(SimDuration::from_secs(100));
        e.try_start(t(0));
        e.finish(data(3), t(0), Completer::Freshen);
        // Store has moved to version 5: prefetched copy is stale even
        // though TTL-fresh.
        assert_eq!(
            fr_fetch_decision(&mut e, t(1), Some(5)),
            WrapperDecision::DoItYourself
        );
        // Same version: fine.
        let mut e2 = FrEntry::new(SimDuration::from_secs(100));
        e2.try_start(t(0));
        e2.finish(data(5), t(0), Completer::Freshen);
        assert!(matches!(
            fr_fetch_decision(&mut e2, t(1), Some(5)),
            WrapperDecision::UseResult(_)
        ));
    }

    #[test]
    fn failed_freshen_is_not_fatal() {
        let mut e = FrEntry::new(SimDuration::from_secs(10));
        e.try_start(t(0));
        e.finish(FrResult::Failed, t(0), Completer::Freshen);
        assert_eq!(
            fr_fetch_decision(&mut e, t(1), None),
            WrapperDecision::DoItYourself
        );
    }

    #[test]
    fn warm_decision_uses_warmed_result() {
        let mut e = FrEntry::new(SimDuration::from_secs(10));
        e.try_start(t(0));
        e.finish(FrResult::Warmed, t(0), Completer::Freshen);
        assert_eq!(
            fr_warm_decision(&mut e, t(500)),
            WrapperDecision::UseResult(FrResult::Warmed)
        );
    }
}
