//! Provider-side freshen inference (§3.3 "Implementation").
//!
//! "For common resources and for popular serverless languages, freshen code
//! could be inferred by the serverless framework itself." The inference
//! relies on the paper's scoping observations:
//!
//! 1. failure to infer is not fatal — the platform continues unmodified;
//! 2. source is available for static analysis (our op DSL);
//! 3. only ops with **constant** credentials/identifiers are inferrable;
//! 4. inference targets the provider's own client libraries (`DataGet`/
//!    `DataPut` here), not arbitrary user code.
//!
//! Given a [`FunctionSpec`], we walk its ops in program order, assign each
//! connection-touching op a freshen-resource index (DataGet → 0, DataPut →
//! 1 for the paper's λ), and emit the corresponding actions:
//! `DataGet(Const, Const)` → `EnsureConnection` + `Prefetch`;
//! `DataPut(Const, Const)` → `EnsureConnection` + `WarmCwnd`. Ops with
//! invocation-derived arguments are skipped and reported.

use crate::freshen::hooks::{FreshenAction, FreshenHook, HookOrigin};
use crate::netsim::tcp::TransferDirection;
use crate::platform::function::{FunctionSpec, Op};
use crate::util::time::SimDuration;

/// Result of inference: the hook plus a report of what couldn't be covered.
#[derive(Debug, Clone)]
pub struct InferenceReport {
    pub hook: FreshenHook,
    /// Op indices that touch resources but weren't inferrable (Param args),
    /// with the reason.
    pub skipped: Vec<(usize, String)>,
    /// Fraction of resource ops covered.
    pub coverage: f64,
}

/// Infer a freshen hook for `func`. `default_ttl` applies when the function
/// doesn't override its prefetch TTL.
pub fn infer_hook(func: &FunctionSpec, default_ttl: SimDuration) -> InferenceReport {
    let resource_indices = func.resource_indices();
    let resource_count = func.resource_count();
    let mut hook = FreshenHook::new(HookOrigin::Inferred, resource_count);
    let mut skipped = Vec::new();
    let ttl = func.prefetch_ttl.unwrap_or(default_ttl);
    let mut seen_endpoints: Vec<&str> = Vec::new();

    for (op_idx, op) in func.ops.iter().enumerate() {
        let Some(res_idx) = resource_indices[op_idx] else {
            continue; // non-resource op: nothing to freshen
        };
        match op {
            Op::DataGet {
                endpoint,
                creds,
                object_id,
            } => {
                if !creds.is_const() || !object_id.is_const() {
                    skipped.push((
                        op_idx,
                        format!(
                            "DataGet on '{endpoint}' uses invocation-derived arguments; \
                             cannot prefetch"
                        ),
                    ));
                    continue;
                }
                // First touch of an endpoint also ensures the connection —
                // covers both the runtime-scoped (liveness check) and
                // invocation-scoped (pre-establish) cases of §3.2.
                if !seen_endpoints.contains(&endpoint.as_str()) {
                    seen_endpoints.push(endpoint);
                    hook.push(
                        res_idx,
                        FreshenAction::EnsureConnection {
                            endpoint: endpoint.clone(),
                        },
                    );
                }
                hook.push(
                    res_idx,
                    FreshenAction::Prefetch {
                        endpoint: endpoint.clone(),
                        object_id: object_id.const_value().unwrap().to_string(),
                        ttl,
                    },
                );
            }
            Op::DataPut {
                endpoint,
                creds,
                object_id,
                bytes,
            } => {
                if !creds.is_const() || !object_id.is_const() {
                    skipped.push((
                        op_idx,
                        format!(
                            "DataPut on '{endpoint}' uses invocation-derived arguments; \
                             cannot warm"
                        ),
                    ));
                    continue;
                }
                if !seen_endpoints.contains(&endpoint.as_str()) {
                    seen_endpoints.push(endpoint);
                    hook.push(
                        res_idx,
                        FreshenAction::EnsureConnection {
                            endpoint: endpoint.clone(),
                        },
                    );
                }
                hook.push(
                    res_idx,
                    FreshenAction::WarmCwnd {
                        endpoint: endpoint.clone(),
                        direction: TransferDirection::Upload,
                        anticipated_bytes: *bytes,
                    },
                );
            }
            _ => {}
        }
    }

    let covered = resource_count - skipped.len();
    InferenceReport {
        hook,
        skipped,
        coverage: if resource_count == 0 {
            1.0
        } else {
            covered as f64 / resource_count as f64
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::function::Arg;

    fn ttl() -> SimDuration {
        SimDuration::from_secs(10)
    }

    #[test]
    fn paper_lambda_fully_inferred() {
        let f = FunctionSpec::paper_lambda("l", "a", "store", SimDuration::from_millis(10));
        let report = infer_hook(&f, ttl());
        assert!(report.skipped.is_empty());
        assert_eq!(report.coverage, 1.0);
        // EnsureConnection + Prefetch for DataGet(0); WarmCwnd for DataPut(1)
        // (connection already ensured: same endpoint).
        let kinds: Vec<(usize, &str)> = report
            .hook
            .actions
            .iter()
            .map(|(i, a)| {
                (
                    *i,
                    match a {
                        FreshenAction::EnsureConnection { .. } => "conn",
                        FreshenAction::Prefetch { .. } => "prefetch",
                        FreshenAction::WarmCwnd { .. } => "warm",
                    },
                )
            })
            .collect();
        assert_eq!(
            kinds,
            vec![(0, "conn"), (0, "prefetch"), (1, "warm")]
        );
    }

    #[test]
    fn param_args_are_skipped_not_fatal() {
        let f = FunctionSpec::new(
            "f",
            "a",
            vec![
                Op::DataGet {
                    endpoint: "store".into(),
                    creds: Arg::Const("CREDS".into()),
                    object_id: Arg::Param("user_key".into()), // not inferrable
                },
                Op::DataPut {
                    endpoint: "store".into(),
                    creds: Arg::Const("CREDS".into()),
                    object_id: Arg::Const("OUT".into()),
                    bytes: 1e5,
                },
            ],
        );
        let report = infer_hook(&f, ttl());
        assert_eq!(report.skipped.len(), 1);
        assert_eq!(report.skipped[0].0, 0);
        assert!((report.coverage - 0.5).abs() < 1e-12);
        // The DataPut is still warmed (resource index 1).
        assert!(report
            .hook
            .actions
            .iter()
            .any(|(i, a)| *i == 1 && matches!(a, FreshenAction::WarmCwnd { .. })));
    }

    #[test]
    fn per_function_ttl_override() {
        let mut f = FunctionSpec::paper_lambda("l", "a", "store", SimDuration::from_millis(10));
        f.prefetch_ttl = Some(SimDuration::from_secs(99));
        let report = infer_hook(&f, ttl());
        let prefetch_ttl = report
            .hook
            .actions
            .iter()
            .find_map(|(_, a)| match a {
                FreshenAction::Prefetch { ttl, .. } => Some(*ttl),
                _ => None,
            })
            .unwrap();
        assert_eq!(prefetch_ttl, SimDuration::from_secs(99));
    }

    #[test]
    fn distinct_endpoints_each_get_connection() {
        let f = FunctionSpec::new(
            "f",
            "a",
            vec![
                Op::DataGet {
                    endpoint: "edge-store".into(),
                    creds: Arg::Const("C".into()),
                    object_id: Arg::Const("A".into()),
                },
                Op::DataPut {
                    endpoint: "cloud-store".into(),
                    creds: Arg::Const("C".into()),
                    object_id: Arg::Const("B".into()),
                    bytes: 1.0,
                },
            ],
        );
        let report = infer_hook(&f, ttl());
        let conns: Vec<&str> = report
            .hook
            .actions
            .iter()
            .filter_map(|(_, a)| match a {
                FreshenAction::EnsureConnection { endpoint } => Some(endpoint.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(conns, vec!["edge-store", "cloud-store"]);
    }

    #[test]
    fn pure_compute_function_infers_empty_hook() {
        let f = FunctionSpec::new(
            "f",
            "a",
            vec![Op::Compute {
                duration: SimDuration::from_millis(5),
            }],
        );
        let report = infer_hook(&f, ttl());
        assert!(report.hook.is_empty());
        assert_eq!(report.coverage, 1.0);
    }
}
