//! Freshen policy: billing-aware gating and abuse guards (§3.3).
//!
//! "Confidence in prediction could be used to dictate if freshen is called
//! or not. Metrics kept inside a container, or communicated to the
//! serverless global scheduling entity, could be used to stop freshen from
//! running if predictions have been too inaccurate. Service categories
//! chosen by the application developer could also control freshen
//! behavior."
//!
//! The gate combines: a master switch, the developer's service category,
//! the numeric confidence threshold, a per-app rate limiter (abuse guard),
//! and a feedback loop from observed prediction accuracy.

use crate::util::config::{FreshenConfig, ServiceCategory};
use crate::util::fxhash::FxHashMap;
use crate::util::time::SimTime;

/// Why a freshen request was (not) admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateDecision {
    Go,
    SkipDisabled,
    SkipCategory,
    SkipLowConfidence,
    SkipRateLimited,
    SkipInaccurate,
}

impl GateDecision {
    pub fn admitted(&self) -> bool {
        *self == GateDecision::Go
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            GateDecision::Go => "go",
            GateDecision::SkipDisabled => "skip_disabled",
            GateDecision::SkipCategory => "skip_category",
            GateDecision::SkipLowConfidence => "skip_low_confidence",
            GateDecision::SkipRateLimited => "skip_rate_limited",
            GateDecision::SkipInaccurate => "skip_inaccurate",
        }
    }
}

/// Sliding-window accuracy for one app's predictions: was each admitted
/// freshen followed by the predicted invocation?
#[derive(Debug, Clone, Default)]
struct AccuracyWindow {
    outcomes: Vec<bool>, // ring of recent outcomes
    next: usize,
}

const ACCURACY_WINDOW: usize = 64;
/// Below this hit-rate the gate stops freshening for the app until the
/// window recovers (outcomes keep being recorded by the predictor).
const MIN_ACCURACY: f64 = 0.3;
/// Minimum observations before accuracy gating kicks in.
const MIN_OBSERVATIONS: usize = 16;

impl AccuracyWindow {
    fn record(&mut self, hit: bool) {
        if self.outcomes.len() < ACCURACY_WINDOW {
            self.outcomes.push(hit);
        } else {
            self.outcomes[self.next] = hit;
            self.next = (self.next + 1) % ACCURACY_WINDOW;
        }
    }

    fn accuracy(&self) -> Option<f64> {
        if self.outcomes.len() < MIN_OBSERVATIONS {
            return None;
        }
        let hits = self.outcomes.iter().filter(|&&h| h).count();
        Some(hits as f64 / self.outcomes.len() as f64)
    }
}

/// Token-bucket rate limiter (per app).
#[derive(Debug, Clone)]
struct Bucket {
    tokens: f64,
    last_refill: SimTime,
}

/// The freshen admission gate.
#[derive(Debug, Clone)]
pub struct FreshenGate {
    pub config: FreshenConfig,
    /// When false, the observed-accuracy feedback loop is bypassed
    /// (the "ungated" arm of the confidence ablation).
    pub accuracy_gating: bool,
    buckets: FxHashMap<String, Bucket>,
    accuracy: FxHashMap<String, AccuracyWindow>,
    /// Counters by decision (reporting).
    pub admitted: u64,
    pub skipped: u64,
}

impl FreshenGate {
    pub fn new(config: FreshenConfig) -> FreshenGate {
        FreshenGate {
            config,
            accuracy_gating: true,
            buckets: FxHashMap::default(),
            accuracy: FxHashMap::default(),
            admitted: 0,
            skipped: 0,
        }
    }

    /// Decide whether to run a freshen for `app` given the predictor's
    /// `confidence` in the impending invocation.
    pub fn should_freshen(
        &mut self,
        app: &str,
        confidence: f64,
        category: ServiceCategory,
        now: SimTime,
    ) -> GateDecision {
        let d = self.decide(app, confidence, category, now);
        if d.admitted() {
            self.admitted += 1;
        } else {
            self.skipped += 1;
        }
        d
    }

    fn decide(
        &mut self,
        app: &str,
        confidence: f64,
        category: ServiceCategory,
        now: SimTime,
    ) -> GateDecision {
        if !self.config.enabled {
            return GateDecision::SkipDisabled;
        }
        if category == ServiceCategory::LatencyInsensitive {
            return GateDecision::SkipCategory;
        }
        let threshold = self.config.min_confidence.max(category.confidence_floor());
        if confidence < threshold {
            return GateDecision::SkipLowConfidence;
        }
        if self.accuracy_gating {
            if let Some(acc) = self.accuracy.get(app).and_then(AccuracyWindow::accuracy) {
                if acc < MIN_ACCURACY {
                    return GateDecision::SkipInaccurate;
                }
            }
        }
        if !self.take_token(app, now) {
            return GateDecision::SkipRateLimited;
        }
        GateDecision::Go
    }

    /// Feed back whether an admitted freshen's predicted invocation
    /// actually arrived (within the prediction window).
    pub fn record_outcome(&mut self, app: &str, hit: bool) {
        self.accuracy.entry(app.to_string()).or_default().record(hit);
    }

    /// Current measured accuracy for an app (None until enough data).
    pub fn accuracy(&self, app: &str) -> Option<f64> {
        self.accuracy.get(app).and_then(AccuracyWindow::accuracy)
    }

    fn take_token(&mut self, app: &str, now: SimTime) -> bool {
        let rate_per_sec = self.config.max_freshens_per_min as f64 / 60.0;
        let cap = (self.config.max_freshens_per_min as f64 / 6.0).max(1.0); // 10s burst
        let b = self.buckets.entry(app.to_string()).or_insert(Bucket {
            tokens: cap,
            last_refill: now,
        });
        let elapsed = now.since(b.last_refill).as_secs_f64();
        b.tokens = (b.tokens + elapsed * rate_per_sec).min(cap);
        b.last_refill = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Why implementing the whole function inside freshen is self-defeating
/// (§3.3 "Preventing abuse and misconfiguration") — encoded as a validator
/// run when developers register hand-written hooks: hooks must not exceed a
/// size budget, must reference only constant endpoints, and have no access
/// to invocation arguments by construction (see
/// [`crate::freshen::hooks::FreshenAction`] — there is no argument slot).
pub fn validate_hook(hook: &crate::freshen::hooks::FreshenHook) -> Result<(), String> {
    const MAX_ACTIONS: usize = 32;
    if hook.actions.len() > MAX_ACTIONS {
        return Err(format!(
            "freshen hook has {} actions (max {MAX_ACTIONS}); implement work in the \
             function body, not the hook",
            hook.actions.len()
        ));
    }
    for (idx, action) in &hook.actions {
        if *idx >= hook.resource_count {
            return Err(format!(
                "action references resource {idx} but the function declares only {} \
                 freshen resources",
                hook.resource_count
            ));
        }
        if action.endpoint().is_empty() {
            return Err("action references an empty endpoint".into());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::freshen::hooks::{FreshenAction, FreshenHook, HookOrigin};

    fn t(s: u64) -> SimTime {
        SimTime(s * 1_000_000)
    }

    fn gate() -> FreshenGate {
        FreshenGate::new(FreshenConfig::default())
    }

    #[test]
    fn disabled_gate_skips() {
        let mut g = gate();
        g.config.enabled = false;
        assert_eq!(
            g.should_freshen("app", 0.9, ServiceCategory::Standard, t(0)),
            GateDecision::SkipDisabled
        );
        assert_eq!(g.skipped, 1);
    }

    #[test]
    fn category_controls_threshold() {
        let mut g = gate();
        // Standard floor is 0.5: confidence 0.3 skipped.
        assert_eq!(
            g.should_freshen("a", 0.3, ServiceCategory::Standard, t(0)),
            GateDecision::SkipLowConfidence
        );
        // Latency-sensitive floor is 0.2 but numeric min_confidence=0.5
        // still applies (max of the two).
        assert_eq!(
            g.should_freshen("a", 0.3, ServiceCategory::LatencySensitive, t(0)),
            GateDecision::SkipLowConfidence
        );
        g.config.min_confidence = 0.0;
        assert_eq!(
            g.should_freshen("a", 0.3, ServiceCategory::LatencySensitive, t(0)),
            GateDecision::Go
        );
        // Insensitive never freshens.
        assert_eq!(
            g.should_freshen("a", 1.0, ServiceCategory::LatencyInsensitive, t(0)),
            GateDecision::SkipCategory
        );
    }

    #[test]
    fn rate_limiter_caps_burst() {
        let mut g = gate();
        g.config.max_freshens_per_min = 60; // 1/s, burst 10
        let mut admitted = 0;
        for _ in 0..100 {
            if g.should_freshen("app", 0.9, ServiceCategory::Standard, t(0)).admitted() {
                admitted += 1;
            }
        }
        assert_eq!(admitted, 10); // burst cap
        // After 5 seconds, ~5 more tokens.
        let mut more = 0;
        for _ in 0..100 {
            if g.should_freshen("app", 0.9, ServiceCategory::Standard, t(5)).admitted() {
                more += 1;
            }
        }
        assert_eq!(more, 5);
    }

    #[test]
    fn inaccurate_predictions_stop_freshen() {
        let mut g = gate();
        for _ in 0..32 {
            g.record_outcome("app", false);
        }
        assert_eq!(g.accuracy("app"), Some(0.0));
        assert_eq!(
            g.should_freshen("app", 0.9, ServiceCategory::Standard, t(0)),
            GateDecision::SkipInaccurate
        );
        // Recovery: a run of hits restores admission.
        for _ in 0..60 {
            g.record_outcome("app", true);
        }
        assert!(g.accuracy("app").unwrap() > MIN_ACCURACY);
        assert_eq!(
            g.should_freshen("app", 0.9, ServiceCategory::Standard, t(0)),
            GateDecision::Go
        );
    }

    #[test]
    fn accuracy_needs_min_observations() {
        let mut g = gate();
        for _ in 0..(MIN_OBSERVATIONS - 1) {
            g.record_outcome("app", false);
        }
        assert_eq!(g.accuracy("app"), None);
        // Not enough data: gate stays open.
        assert!(g
            .should_freshen("app", 0.9, ServiceCategory::Standard, t(0))
            .admitted());
    }

    #[test]
    fn hook_validation() {
        let mut ok = FreshenHook::new(HookOrigin::Developer, 1);
        ok.push(
            0,
            FreshenAction::EnsureConnection {
                endpoint: "store".into(),
            },
        );
        assert!(validate_hook(&ok).is_ok());

        let mut huge = FreshenHook::new(HookOrigin::Developer, 64);
        for i in 0..40 {
            huge.actions.push((
                i,
                FreshenAction::EnsureConnection {
                    endpoint: "store".into(),
                },
            ));
        }
        assert!(validate_hook(&huge).is_err());

        let bad_idx = FreshenHook {
            actions: vec![(
                5,
                FreshenAction::EnsureConnection {
                    endpoint: "store".into(),
                },
            )],
            origin: HookOrigin::Developer,
            resource_count: 2,
        };
        assert!(validate_hook(&bad_idx).is_err());
    }
}
