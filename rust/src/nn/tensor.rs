//! A minimal dense matrix over a flat row-major `f32` buffer.
//!
//! This is deliberately not a general tensor library: the inference
//! kernels need exactly one layout (row-major, contiguous) and two
//! shapes (activations `batch × features`, weights `in × out`), so the
//! type stays small enough to audit and the kernels can slice rows
//! without stride arithmetic.

use anyhow::{bail, Result};

/// Dense row-major `f32` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Wrap an existing flat row-major buffer; `data.len()` must equal
    /// `rows * cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Matrix> {
        if data.len() != rows * cols {
            bail!(
                "matrix shape {rows}x{cols} needs {} values, got {}",
                rows * cols,
                data.len()
            );
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Copy a flat slice of `rows * cols` values.
    pub fn from_slice(rows: usize, cols: usize, data: &[f32]) -> Result<Matrix> {
        Matrix::from_vec(rows, cols, data.to_vec())
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `i` as a slice of `cols` values.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// The whole buffer, row-major.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_rows() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.get(1, 2), 6.0);
    }

    #[test]
    fn shape_mismatch_errors() {
        assert!(Matrix::from_vec(2, 3, vec![0.0; 5]).is_err());
        assert!(Matrix::from_slice(1, 2, &[0.0; 2]).is_ok());
    }

    #[test]
    fn mutation_through_rows() {
        let mut m = Matrix::zeros(2, 2);
        m.row_mut(1)[0] = 7.0;
        m.set(0, 1, 3.0);
        assert_eq!(m.data(), &[0.0, 3.0, 7.0, 0.0]);
        assert_eq!(m.into_data(), vec![0.0, 3.0, 7.0, 0.0]);
    }
}
