//! Native-rust neural inference: the offline twin of the PJRT runtime.
//!
//! The default build vendors a compile-time `xla` stub, so the AOT
//! artifacts cannot execute through PJRT without patching in the real
//! crate. This subsystem closes that gap: a small, dependency-free tensor
//! and MLP engine that executes the *same model* — the manifest's weight
//! sidecars, written by `python/compile/aot.py` (or by [`gen`] entirely in
//! rust) — so `repro serve` and `repro check-artifacts` run end-to-end in
//! any checkout.
//!
//! Layout:
//!
//! - [`tensor`] — [`tensor::Matrix`], a flat row-major `f32` buffer with
//!   shape; the only data type the kernels traffic in.
//! - [`kernels`] — blocked matmul with a fused bias+activation epilogue
//!   (row-quad blocking: each streamed weight row is reused across four
//!   input rows), a row-parallel `std::thread` path for large batches,
//!   plus row softmax, input standardization, and the logistic scorer.
//! - [`mlp`] — [`mlp::Mlp`]: normalize → (linear+ReLU)* → logits, loaded
//!   from a [`crate::runtime::manifest::Manifest`]'s weight sidecars, with
//!   a naive `f64` reference forward for parity tests.
//! - [`gen`] — deterministic artifact-set generator: writes a manifest +
//!   weight blobs (and their sample-check numerics) without python, JAX,
//!   or network access. Backs the CI smoke tests and `repro gen-artifacts`.
//!
//! Determinism contract: every kernel accumulates in a fixed k-ascending
//! order per output row, and the parallel path only partitions *rows*
//! across threads, so results are bit-identical for any thread count.
//!
//! The serving integration lives in [`crate::runtime::backend`]: the
//! [`crate::runtime::backend::NativeMlpBackend`] adapter exposes
//! [`mlp::Mlp`] through the same `InferenceBackend` trait the PJRT path
//! implements, and `ClassifierRuntime` applies the identical
//! pad-to-AOT-batch policy on top of either.

pub mod gen;
pub mod kernels;
pub mod mlp;
pub mod tensor;

pub use mlp::Mlp;
pub use tensor::Matrix;
