//! Deterministic native artifact-set generator.
//!
//! Writes everything the native backend needs to serve — a
//! `manifest.json` (with the `weights` sidecar section and the sample
//! check numerics) plus per-layer raw `f32` little-endian blobs — using
//! only this crate: no python, no JAX, no PJRT, no network. This is what
//! makes the `serve`/`check-artifacts` path testable in CI from a fresh
//! offline checkout: `repro gen-artifacts` (or a test calling
//! [`generate`]) replaces `make artifacts` for the native backend.
//!
//! The recorded `check.classifier_logits_b1` values come from
//! [`Mlp::forward_reference`], the naive `f64` forward — so the
//! runtime's `self_check` replays a genuinely independent computation
//! against the blocked/threaded f32 kernels, the same contract the
//! python-generated manifests enforce with JAX-computed logits. The
//! predictor rows are scored by [`LearnedScorer`], keeping the
//! deployed-weights agreement check meaningful.
//!
//! Weights are seeded He-initialised normals, so two runs with the same
//! [`GenSpec`] produce byte-identical artifact sets.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::nn::mlp::{write_f32_blob, Layer, Mlp};
use crate::nn::tensor::Matrix;
use crate::predict::learned::{Features, LearnedScorer, DEPLOYED_BIAS, DEPLOYED_WEIGHTS};
use crate::runtime::manifest::Manifest;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Shape and seed of a generated artifact set.
#[derive(Debug, Clone)]
pub struct GenSpec {
    pub input_dim: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
    /// AOT batch sizes the pad policy may pick from.
    pub batches: Vec<usize>,
    pub predictor_batch: usize,
    pub seed: u64,
    /// Input standardization constants baked into the manifest.
    pub mean: f64,
    pub std: f64,
}

impl Default for GenSpec {
    /// The paper model's shape (λ1: 3072 → 512 → 256 → 10).
    fn default() -> GenSpec {
        GenSpec {
            input_dim: 3072,
            hidden: vec![512, 256],
            classes: 10,
            batches: vec![1, 4, 8, 16],
            predictor_batch: 16,
            seed: 0x5EED,
            mean: 0.5,
            std: 0.25,
        }
    }
}

impl GenSpec {
    /// A deliberately small network for smoke tests (fast to generate,
    /// fast to execute, still multi-layer).
    pub fn tiny() -> GenSpec {
        GenSpec {
            input_dim: 32,
            hidden: vec![16, 8],
            classes: 5,
            batches: vec![1, 2, 4],
            predictor_batch: 16,
            seed: 0x7111,
            ..GenSpec::default()
        }
    }

    /// `[in, hidden..., classes]` — the full dimension chain.
    fn dims(&self) -> Vec<usize> {
        let mut d = Vec::with_capacity(self.hidden.len() + 2);
        d.push(self.input_dim);
        d.extend_from_slice(&self.hidden);
        d.push(self.classes);
        d
    }

    fn validate(&self) -> Result<()> {
        if self.input_dim < 2 {
            bail!("input_dim must be >= 2 (the linspace check probe needs it)");
        }
        if self.classes == 0 || self.hidden.iter().any(|&h| h == 0) {
            bail!("layer widths must be positive");
        }
        if self.batches.is_empty() || self.batches.contains(&0) {
            bail!("need at least one positive batch size");
        }
        if self.predictor_batch == 0 {
            bail!("predictor_batch must be positive");
        }
        if self.std <= 0.0 {
            bail!("std must be positive");
        }
        Ok(())
    }
}

/// Build the seeded network in memory (shared by [`generate`] and the
/// `nn_inference` bench, which doesn't need files on disk).
pub fn build_mlp(spec: &GenSpec) -> Result<Mlp> {
    spec.validate()?;
    let mut rng = Rng::new(spec.seed);
    let dims = spec.dims();
    let mut layers = Vec::with_capacity(dims.len() - 1);
    for i in 0..dims.len() - 1 {
        let (din, dout) = (dims[i], dims[i + 1]);
        // He initialisation, like python/compile/model.py::init_params —
        // keeps activations O(1) so f32-vs-reference drift stays small.
        let scale = (2.0 / din as f64).sqrt();
        let w: Vec<f32> = (0..din * dout)
            .map(|_| (rng.normal() * scale) as f32)
            .collect();
        let bias: Vec<f32> = (0..dout).map(|_| rng.uniform(-0.05, 0.05) as f32).collect();
        layers.push(Layer {
            w: Matrix::from_vec(din, dout, w)?,
            bias,
            relu: i + 2 < dims.len(),
        });
    }
    Mlp::from_layers(layers, spec.mean as f32, spec.std as f32)
}

/// The deterministic probe row the classifier check replays
/// (`linspace(-1, 1, input_dim)`, matching `aot.py::sample_check`).
pub fn check_probe(input_dim: usize) -> Vec<f32> {
    (0..input_dim)
        .map(|i| -1.0 + 2.0 * i as f32 / (input_dim as f32 - 1.0))
        .collect()
}

/// Predictor feature rows recorded in the check section (same rows
/// `aot.py` uses).
pub fn predictor_check_feats() -> Vec<[f64; 4]> {
    vec![[0.9, 0.8, 0.7, 0.3], [0.0, 0.0, 0.0, 0.0]]
}

/// Generate a complete native artifact set in `dir` and load it back.
pub fn generate(dir: &Path, spec: &GenSpec) -> Result<Manifest> {
    spec.validate()?;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating artifact dir {}", dir.display()))?;
    let mlp = build_mlp(spec)?;

    // Weight sidecars + their manifest entries.
    let mut layer_entries = Vec::with_capacity(mlp.layers.len());
    for (i, layer) in mlp.layers.iter().enumerate() {
        let wname = format!("layer{i}.w.bin");
        let bname = format!("layer{i}.b.bin");
        write_f32_blob(&dir.join(&wname), layer.w.data())?;
        write_f32_blob(&dir.join(&bname), &layer.bias)?;
        layer_entries.push(Json::obj(vec![
            ("in", Json::num(layer.w.rows() as f64)),
            ("out", Json::num(layer.w.cols() as f64)),
            ("relu", Json::Bool(layer.relu)),
            ("weights", Json::str(&wname)),
            ("bias", Json::str(&bname)),
        ]));
    }

    // Sample-check numerics: naive f64 reference for the classifier, the
    // native learned scorer for the predictor.
    let logits = mlp.forward_reference(&check_probe(spec.input_dim));
    let scorer = LearnedScorer::default();
    let feats = predictor_check_feats();
    let scores: Vec<f64> = feats
        .iter()
        .map(|f| {
            scorer.score(&Features {
                chain_conf: f[0],
                hist_conf: f[1],
                recency: f[2],
                log_lead: f[3],
            })
        })
        .collect();

    let manifest = Json::obj(vec![
        ("generator", Json::str("repro gen-artifacts (native-rust)")),
        ("input_dim", Json::num(spec.input_dim as f64)),
        ("classes", Json::num(spec.classes as f64)),
        (
            "hidden",
            Json::arr(spec.hidden.iter().map(|&h| Json::num(h as f64))),
        ),
        ("param_seed", Json::num(spec.seed as f64)),
        (
            "batches",
            Json::arr(spec.batches.iter().map(|&b| Json::num(b as f64))),
        ),
        ("predictor_batch", Json::num(spec.predictor_batch as f64)),
        (
            "predictor_weights",
            Json::arr(DEPLOYED_WEIGHTS.iter().map(|&w| Json::num(w))),
        ),
        ("predictor_bias", Json::num(DEPLOYED_BIAS)),
        // No HLO artifacts: this set serves the native backend only.
        ("artifacts", Json::Obj(Vec::new())),
        (
            "check",
            Json::obj(vec![
                (
                    "classifier_input",
                    Json::str(&format!("linspace(-1,1,{})", spec.input_dim)),
                ),
                (
                    "classifier_logits_b1",
                    Json::arr(logits.iter().map(|&v| Json::num(v))),
                ),
                (
                    "predictor_feats",
                    Json::arr(
                        feats
                            .iter()
                            .map(|row| Json::arr(row.iter().map(|&v| Json::num(v)))),
                    ),
                ),
                (
                    "predictor_scores",
                    Json::arr(scores.iter().map(|&v| Json::num(v))),
                ),
            ]),
        ),
        (
            "weights",
            Json::obj(vec![
                ("format", Json::str("f32-le")),
                (
                    "normalize",
                    Json::obj(vec![
                        ("mean", Json::num(spec.mean)),
                        ("std", Json::num(spec.std)),
                    ]),
                ),
                ("layers", Json::Arr(layer_entries)),
            ]),
        ),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest.pretty())
        .with_context(|| format!("writing manifest.json in {}", dir.display()))?;
    Manifest::load(dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("freshen-nn-gen-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn generated_set_loads_and_matches_its_own_check() {
        let dir = temp("roundtrip");
        let m = generate(&dir, &GenSpec::tiny()).unwrap();
        assert_eq!(m.input_dim, 32);
        assert_eq!(m.classes, 5);
        assert_eq!(m.batches, vec![1, 2, 4]);
        assert!(m.weights.is_some());

        // The fast kernels must reproduce the recorded reference logits.
        let mlp = Mlp::load(&m).unwrap();
        let got = mlp.forward_flat(1, &check_probe(m.input_dim)).unwrap();
        assert_eq!(got.len(), m.classes);
        for (g, want) in got.iter().zip(m.check_logits_b1.iter()) {
            assert!((*g as f64 - want).abs() < 1e-3, "{g} vs {want}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = GenSpec::tiny();
        let d1 = temp("det-a");
        let d2 = temp("det-b");
        generate(&d1, &spec).unwrap();
        generate(&d2, &spec).unwrap();
        for name in ["manifest.json", "layer0.w.bin", "layer2.b.bin"] {
            let a = std::fs::read(d1.join(name)).unwrap();
            let b = std::fs::read(d2.join(name)).unwrap();
            assert_eq!(a, b, "{name} differs between identical specs");
        }
    }

    #[test]
    fn bad_specs_are_rejected() {
        let dir = temp("bad");
        for spec in [
            GenSpec {
                input_dim: 1,
                ..GenSpec::tiny()
            },
            GenSpec {
                batches: vec![],
                ..GenSpec::tiny()
            },
            GenSpec {
                classes: 0,
                ..GenSpec::tiny()
            },
            GenSpec {
                std: 0.0,
                ..GenSpec::tiny()
            },
        ] {
            assert!(generate(&dir, &spec).is_err(), "{spec:?} should fail");
        }
    }
}
