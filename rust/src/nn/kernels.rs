//! Inference kernels over [`Matrix`]: blocked matmul with a fused
//! bias+activation epilogue, row softmax, input standardization, and the
//! logistic scorer.
//!
//! # Matmul shape and blocking
//!
//! `matmul_bias_act(x, w, bias, relu)` computes `act(x·w + bias)` for
//! activations `x: m×k` and weights `w: k×n`, both row-major — the layout
//! `python/compile/aot.py` dumps, so weight blobs map straight into the
//! kernel with no transpose. The loop nest is k-streaming with row-quad
//! blocking: weight rows are read in k order (contiguous, prefetch
//! friendly) and each is applied to up to [`ROW_BLOCK`] input rows before
//! moving on, so a streamed `w` row is reused from L1 instead of being
//! re-fetched per input row. Within a quad the output is computed in
//! [`LANES`]-wide column panels: up to `ROW_BLOCK × [f32; LANES]`
//! accumulators stay in registers across the whole k stream (a fixed-size
//! inner loop the compiler auto-vectorizes on stable rust — no `std::simd`)
//! and spill to the output buffer once per panel instead of once per
//! `k`. Zero input values skip their weight row — this makes the
//! zero-padded tail rows of a static batch nearly free.
//!
//! # Parallelism and determinism
//!
//! Batches large enough to amortize thread spawn ([`par_threads`]) split
//! their *rows* across `std::thread::scope` workers; every output row is
//! always accumulated by exactly one thread in fixed k-ascending order,
//! so results are bit-identical for any thread count (asserted by tests).

use anyhow::{bail, Result};

use crate::nn::tensor::Matrix;

/// Input rows sharing one streamed weight row (register/L1 reuse).
pub const ROW_BLOCK: usize = 4;

/// Output columns per register panel: one AVX2 f32 vector. Each panel's
/// accumulators live in `[f32; LANES]` blocks for the whole k stream.
pub const LANES: usize = 8;

/// Threads are only worth spawning above this many flops (2·m·n·k).
const PAR_FLOPS_MIN: f64 = 4e6;

/// Cap on worker threads for one matmul.
const PAR_THREADS_MAX: usize = 8;

/// Worker threads the auto path would use for an `m×k · k×n` matmul.
pub fn par_threads(m: usize, n: usize, k: usize) -> usize {
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    if m < 2 || flops < PAR_FLOPS_MIN {
        return 1;
    }
    std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1)
        .min(PAR_THREADS_MAX)
        .min(m)
}

/// `act(x·w + bias)` with the thread count chosen by [`par_threads`].
pub fn matmul_bias_act(x: &Matrix, w: &Matrix, bias: &[f32], relu: bool) -> Result<Matrix> {
    let threads = par_threads(x.rows(), w.cols(), x.cols());
    matmul_bias_act_threads(x, w, bias, relu, threads)
}

/// `act(x·w + bias)` on an explicit number of worker threads (`<=1` runs
/// inline). Exposed for the `nn_inference` bench's serial-vs-parallel
/// comparison; results are identical across `threads`.
pub fn matmul_bias_act_threads(
    x: &Matrix,
    w: &Matrix,
    bias: &[f32],
    relu: bool,
    threads: usize,
) -> Result<Matrix> {
    if x.cols() != w.rows() {
        bail!(
            "matmul shape mismatch: x is {}x{}, w is {}x{}",
            x.rows(),
            x.cols(),
            w.rows(),
            w.cols()
        );
    }
    if bias.len() != w.cols() {
        bail!("bias length {} != output width {}", bias.len(), w.cols());
    }
    let (m, n) = (x.rows(), w.cols());
    let mut out = Matrix::zeros(m, n);
    if m == 0 || n == 0 {
        return Ok(out);
    }
    let threads = threads.clamp(1, m);
    if threads == 1 {
        block_forward(x, 0, w, bias, relu, out.data_mut());
    } else {
        let rows_per = (m + threads - 1) / threads;
        // simlint: allow(D006, each worker owns a disjoint row chunk of the output; no collection order exists)
        std::thread::scope(|scope| {
            for (ci, chunk) in out.data_mut().chunks_mut(rows_per * n).enumerate() {
                scope.spawn(move || block_forward(x, ci * rows_per, w, bias, relu, chunk));
            }
        });
    }
    Ok(out)
}

/// Compute output rows `row0..row0 + out_chunk.len()/n` into `out_chunk`.
fn block_forward(
    x: &Matrix,
    row0: usize,
    w: &Matrix,
    bias: &[f32],
    relu: bool,
    out_chunk: &mut [f32],
) {
    let n = w.cols();
    let kdim = w.rows();
    let mut done = 0usize;
    for quad in out_chunk.chunks_mut(ROW_BLOCK * n) {
        let rows_here = quad.len() / n;
        // 8-wide panels. Every output element still receives its bias
        // first and then its products in k-ascending order (with the
        // `a != 0.0` skip), so the panels only reorder work across
        // independent elements — results are bit-identical to the
        // unblocked kernel and to `forward_reference` in the tests.
        let mut j0 = 0usize;
        while j0 + LANES <= n {
            let mut acc = [[0.0f32; LANES]; ROW_BLOCK];
            for row_acc in acc.iter_mut().take(rows_here) {
                row_acc.copy_from_slice(&bias[j0..j0 + LANES]);
            }
            for k in 0..kdim {
                let wv: &[f32; LANES] =
                    w.row(k)[j0..j0 + LANES].try_into().expect("panel width");
                for r in 0..rows_here {
                    let a = x.get(row0 + done + r, k);
                    if a != 0.0 {
                        for (o, wvl) in acc[r].iter_mut().zip(wv.iter()) {
                            *o += a * wvl;
                        }
                    }
                }
            }
            for (r, row_acc) in acc.iter_mut().enumerate().take(rows_here) {
                if relu {
                    for v in row_acc.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                quad[r * n + j0..r * n + j0 + LANES].copy_from_slice(row_acc);
            }
            j0 += LANES;
        }
        // Scalar epilogue for the n % LANES tail columns, same op order.
        if j0 < n {
            for r in 0..rows_here {
                quad[r * n + j0..(r + 1) * n].copy_from_slice(&bias[j0..]);
            }
            for k in 0..kdim {
                let wrow = w.row(k);
                for r in 0..rows_here {
                    let a = x.get(row0 + done + r, k);
                    if a != 0.0 {
                        let orow = &mut quad[r * n + j0..(r + 1) * n];
                        for (o, wv) in orow.iter_mut().zip(wrow[j0..].iter()) {
                            *o += a * wv;
                        }
                    }
                }
            }
            if relu {
                for r in 0..rows_here {
                    for v in quad[r * n + j0..(r + 1) * n].iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
        }
        done += rows_here;
    }
}

/// In-place input standardization: `x = (x - mean) / std`.
pub fn normalize(x: &mut Matrix, mean: f32, std: f32) -> Result<()> {
    if std == 0.0 || !std.is_finite() {
        bail!("normalize: std must be finite and non-zero, got {std}");
    }
    let inv = 1.0 / std;
    for v in x.data_mut() {
        *v = (*v - mean) * inv;
    }
    Ok(())
}

/// In-place row-wise softmax (max-subtracted for stability).
pub fn softmax_rows(x: &mut Matrix) {
    for i in 0..x.rows() {
        let row = x.row_mut(i);
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        if sum > 0.0 {
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
    }
}

/// `sigmoid(x·weights + bias)` per row — the learned next-invocation
/// scorer ([`crate::predict::learned`]) evaluated batched in f32.
pub fn logistic_score(x: &Matrix, weights: &[f32], bias: f32) -> Result<Vec<f32>> {
    if x.cols() != weights.len() {
        bail!(
            "logistic feature width {} != weight count {}",
            x.cols(),
            weights.len()
        );
    }
    let mut out = Vec::with_capacity(x.rows());
    for i in 0..x.rows() {
        let z: f32 = x
            .row(i)
            .iter()
            .zip(weights.iter())
            .map(|(a, b)| a * b)
            .sum::<f32>()
            + bias;
        out.push(1.0 / (1.0 + (-z).exp()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, vals: &[f32]) -> Matrix {
        Matrix::from_slice(rows, cols, vals).unwrap()
    }

    /// Naive per-element reference: bias first, then products in
    /// k-ascending order with the `a != 0.0` skip — the exact f32 op
    /// order the panel kernel must preserve.
    fn forward_reference(x: &Matrix, w: &Matrix, bias: &[f32], relu: bool) -> Matrix {
        let (m, n, kdim) = (x.rows(), w.cols(), w.rows());
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut v = bias[j];
                for k in 0..kdim {
                    let a = x.get(i, k);
                    if a != 0.0 {
                        v += a * w.get(k, j);
                    }
                }
                if relu && v < 0.0 {
                    v = 0.0;
                }
                out.set(i, j, v);
            }
        }
        out
    }

    #[test]
    fn panel_kernel_is_bit_identical_to_reference() {
        // Dims straddle every blocking boundary: quads (rows % 4), full
        // panels, the scalar column tail (n % 8), and n < LANES outright.
        let mut rng = crate::util::rng::Rng::new(0x8A7E);
        for &(m, k, n) in &[
            (1usize, 3usize, 5usize),
            (4, 8, 8),
            (5, 16, 9),
            (13, 37, 29),
            (3, 12, 16),
            (9, 7, 24),
        ] {
            let x = Matrix::from_vec(
                m,
                k,
                (0..m * k)
                    // Sprinkle exact zeros so the skip path is exercised.
                    .map(|i| {
                        if i % 5 == 0 {
                            0.0
                        } else {
                            rng.uniform(-1.0, 1.0) as f32
                        }
                    })
                    .collect(),
            )
            .unwrap();
            let w = Matrix::from_vec(
                k,
                n,
                (0..k * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
            )
            .unwrap();
            let bias: Vec<f32> = (0..n).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
            for relu in [false, true] {
                let fast = matmul_bias_act_threads(&x, &w, &bias, relu, 1).unwrap();
                let reference = forward_reference(&x, &w, &bias, relu);
                assert_eq!(
                    fast.data(),
                    reference.data(),
                    "m={m} k={k} n={n} relu={relu} diverged from reference"
                );
            }
        }
    }

    #[test]
    fn matmul_matches_hand_computation() {
        // [1 2; 3 4] · [5 6; 7 8] + [10, 20] = [29 42; 53 70]
        let x = mat(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let w = mat(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let out = matmul_bias_act(&x, &w, &[10.0, 20.0], false).unwrap();
        assert_eq!(out.data(), &[29.0, 42.0, 53.0, 70.0]);
    }

    #[test]
    fn relu_epilogue_clamps() {
        let x = mat(1, 2, &[1.0, -3.0]);
        let w = mat(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let lin = matmul_bias_act(&x, &w, &[0.0, 0.0], false).unwrap();
        assert_eq!(lin.data(), &[1.0, -3.0]);
        let act = matmul_bias_act(&x, &w, &[0.0, 0.0], true).unwrap();
        assert_eq!(act.data(), &[1.0, 0.0]);
    }

    #[test]
    fn shape_mismatches_error() {
        let x = mat(1, 3, &[0.0; 3]);
        let w = mat(2, 2, &[0.0; 4]);
        assert!(matmul_bias_act(&x, &w, &[0.0, 0.0], false).is_err());
        let w3 = mat(3, 2, &[0.0; 6]);
        assert!(matmul_bias_act(&x, &w3, &[0.0], false).is_err());
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        // Deterministic pseudo-random fill; dims straddle the quad block.
        let mut rng = crate::util::rng::Rng::new(0x17E);
        let m = 13;
        let k = 37;
        let n = 29;
        let x = Matrix::from_vec(
            m,
            k,
            (0..m * k).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        )
        .unwrap();
        let w = Matrix::from_vec(
            k,
            n,
            (0..k * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
        )
        .unwrap();
        let bias: Vec<f32> = (0..n).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
        let serial = matmul_bias_act_threads(&x, &w, &bias, true, 1).unwrap();
        for threads in [2, 3, 4, 8, 64] {
            let par = matmul_bias_act_threads(&x, &w, &bias, true, threads).unwrap();
            assert_eq!(serial.data(), par.data(), "threads={threads} diverged");
        }
    }

    #[test]
    fn par_threads_keeps_small_work_serial() {
        assert_eq!(par_threads(1, 512, 3072), 1, "batch 1 stays inline");
        assert_eq!(par_threads(4, 2, 2), 1, "tiny matmul stays inline");
        assert!(par_threads(16, 512, 3072) >= 1);
    }

    #[test]
    fn softmax_rows_are_distributions() {
        let mut x = mat(2, 3, &[1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        softmax_rows(&mut x);
        for i in 0..2 {
            let sum: f32 = x.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
            assert!(x.row(i).iter().all(|&v| v > 0.0));
            // Monotone in the logits.
            assert!(x.get(i, 2) > x.get(i, 0));
        }
    }

    #[test]
    fn normalize_standardizes() {
        let mut x = mat(1, 2, &[0.5, 1.0]);
        normalize(&mut x, 0.5, 0.25).unwrap();
        assert_eq!(x.data(), &[0.0, 2.0]);
        assert!(normalize(&mut x, 0.0, 0.0).is_err());
    }

    #[test]
    fn logistic_matches_native_scorer() {
        let x = mat(1, 4, &[0.9, 0.8, 0.7, 0.3]);
        let w: Vec<f32> = crate::predict::learned::DEPLOYED_WEIGHTS
            .iter()
            .map(|&v| v as f32)
            .collect();
        let got = logistic_score(&x, &w, crate::predict::learned::DEPLOYED_BIAS as f32).unwrap();
        let native = crate::predict::learned::LearnedScorer::default().score(
            &crate::predict::learned::Features {
                chain_conf: 0.9,
                hist_conf: 0.8,
                recency: 0.7,
                log_lead: 0.3,
            },
        );
        assert!((got[0] as f64 - native).abs() < 1e-6, "{} vs {native}", got[0]);
    }
}
