//! The classifier MLP assembled from manifest weight sidecars.
//!
//! [`Mlp::load`] reads the `weights` section of `artifacts/manifest.json`
//! (see [`crate::runtime::manifest::WeightsSpec`] for the schema) and the
//! per-layer raw little-endian `f32` blobs next to it, producing the same
//! network `python/compile/model.py::classifier_fwd` lowers into the HLO
//! artifacts: standardize → (linear + ReLU)* → linear → logits. The blob
//! layout is row-major `in × out` exactly as JAX holds the parameters, so
//! loading is a straight byte reinterpretation.
//!
//! [`Mlp::forward_reference`] is a deliberately naive `f64` re-computation
//! used by tests to cross-check the blocked/threaded f32 kernels — two
//! implementations, one contract.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::nn::kernels;
use crate::nn::tensor::Matrix;
use crate::runtime::manifest::Manifest;

/// One dense layer: weights `in × out` (row-major), bias `out`.
#[derive(Debug, Clone)]
pub struct Layer {
    pub w: Matrix,
    pub bias: Vec<f32>,
    pub relu: bool,
}

/// The loaded network plus its input standardization constants.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub layers: Vec<Layer>,
    pub mean: f32,
    pub std: f32,
}

impl Mlp {
    /// Assemble from in-memory layers, validating the dimension chain.
    pub fn from_layers(layers: Vec<Layer>, mean: f32, std: f32) -> Result<Mlp> {
        if layers.is_empty() {
            bail!("mlp needs at least one layer");
        }
        for (i, l) in layers.iter().enumerate() {
            if l.bias.len() != l.w.cols() {
                bail!(
                    "layer {i}: bias length {} != output width {}",
                    l.bias.len(),
                    l.w.cols()
                );
            }
            if i + 1 < layers.len() && l.w.cols() != layers[i + 1].w.rows() {
                bail!(
                    "layer {i} output {} does not feed layer {} input {}",
                    l.w.cols(),
                    i + 1,
                    layers[i + 1].w.rows()
                );
            }
        }
        Ok(Mlp { layers, mean, std })
    }

    /// Load the classifier weights listed in `manifest`'s sidecar section.
    pub fn load(manifest: &Manifest) -> Result<Mlp> {
        let spec = manifest.weights.as_ref().context(
            "manifest has no 'weights' section (native backend needs the \
             weight sidecars; regenerate with `make artifacts` / `repro \
             gen-artifacts`, or use the pjrt backend)",
        )?;
        let mut layers = Vec::with_capacity(spec.layers.len());
        for (i, l) in spec.layers.iter().enumerate() {
            let w = read_f32_blob(&manifest.dir.join(&l.weights_file), l.input * l.output)
                .with_context(|| format!("layer {i} weights ({})", l.weights_file))?;
            let bias = read_f32_blob(&manifest.dir.join(&l.bias_file), l.output)
                .with_context(|| format!("layer {i} bias ({})", l.bias_file))?;
            layers.push(Layer {
                w: Matrix::from_vec(l.input, l.output, w)?,
                bias,
                relu: l.relu,
            });
        }
        let mlp = Mlp::from_layers(layers, spec.mean as f32, spec.std as f32)?;
        if mlp.input_dim() != manifest.input_dim {
            bail!(
                "weights input dim {} != manifest input_dim {}",
                mlp.input_dim(),
                manifest.input_dim
            );
        }
        if mlp.output_dim() != manifest.classes {
            bail!(
                "weights output dim {} != manifest classes {}",
                mlp.output_dim(),
                manifest.classes
            );
        }
        Ok(mlp)
    }

    pub fn input_dim(&self) -> usize {
        self.layers[0].w.rows()
    }

    pub fn output_dim(&self) -> usize {
        self.layers[self.layers.len() - 1].w.cols()
    }

    /// Batched forward pass: standardize, then every layer through the
    /// blocked (and, for large batches, row-parallel) kernels.
    pub fn forward(&self, x: &Matrix) -> Result<Matrix> {
        if x.cols() != self.input_dim() {
            bail!(
                "input width {} != model input dim {}",
                x.cols(),
                self.input_dim()
            );
        }
        let mut h = x.clone();
        kernels::normalize(&mut h, self.mean, self.std)?;
        for layer in &self.layers {
            h = kernels::matmul_bias_act(&h, &layer.w, &layer.bias, layer.relu)?;
        }
        Ok(h)
    }

    /// Forward over a flat row-major buffer of `rows × input_dim` floats;
    /// returns `rows × output_dim` flat logits.
    pub fn forward_flat(&self, rows: usize, flat: &[f32]) -> Result<Vec<f32>> {
        let x = Matrix::from_slice(rows, self.input_dim(), flat)?;
        Ok(self.forward(&x)?.into_data())
    }

    /// Naive single-row `f64` forward — the executable spec the fast
    /// kernels are tested against (and the source of the generated
    /// manifests' `check_logits_b1` numerics).
    pub fn forward_reference(&self, row: &[f32]) -> Vec<f64> {
        let mut h: Vec<f64> = row
            .iter()
            .map(|&v| (v as f64 - self.mean as f64) / self.std as f64)
            .collect();
        for layer in &self.layers {
            let mut next = vec![0.0f64; layer.w.cols()];
            for (j, slot) in next.iter_mut().enumerate() {
                let mut acc = layer.bias[j] as f64;
                for (k, &a) in h.iter().enumerate() {
                    acc += a * layer.w.get(k, j) as f64;
                }
                *slot = if layer.relu { acc.max(0.0) } else { acc };
            }
            h = next;
        }
        h
    }
}

/// Read a raw little-endian `f32` blob of exactly `expect` values.
pub fn read_f32_blob(path: &Path, expect: usize) -> Result<Vec<f32>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != expect * 4 {
        bail!(
            "{}: expected {} f32 values ({} bytes), found {} bytes",
            path.display(),
            expect,
            expect * 4,
            bytes.len()
        );
    }
    let mut out = Vec::with_capacity(expect);
    for c in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    Ok(out)
}

/// Write a raw little-endian `f32` blob (the sidecar format `aot.py`
/// emits and [`read_f32_blob`] parses).
pub fn write_f32_blob(path: &Path, values: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(values.len() * 4);
    for v in values {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_mlp() -> Mlp {
        // 3 -> 2 (relu) -> 2
        let l0 = Layer {
            w: Matrix::from_slice(3, 2, &[0.5, -0.25, 1.0, 0.75, -0.5, 0.25]).unwrap(),
            bias: vec![0.1, -0.1],
            relu: true,
        };
        let l1 = Layer {
            w: Matrix::from_slice(2, 2, &[1.0, -1.0, 0.5, 0.5]).unwrap(),
            bias: vec![0.0, 0.2],
            relu: false,
        };
        Mlp::from_layers(vec![l0, l1], 0.0, 1.0).unwrap()
    }

    #[test]
    fn forward_matches_reference() {
        let mlp = tiny_mlp();
        let rows = [
            vec![1.0f32, -2.0, 0.5],
            vec![0.0, 0.0, 0.0],
            vec![-1.5, 2.5, 3.0],
        ];
        let x = Matrix::from_vec(3, 3, rows.concat()).unwrap();
        let fast = mlp.forward(&x).unwrap();
        for (i, row) in rows.iter().enumerate() {
            let want = mlp.forward_reference(row);
            for (a, b) in fast.row(i).iter().zip(want.iter()) {
                assert!((*a as f64 - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn dimension_chain_is_validated() {
        let bad = Layer {
            w: Matrix::zeros(3, 4),
            bias: vec![0.0; 4],
            relu: true,
        };
        let mismatched = Layer {
            w: Matrix::zeros(5, 2),
            bias: vec![0.0; 2],
            relu: false,
        };
        assert!(Mlp::from_layers(vec![bad, mismatched], 0.0, 1.0).is_err());
        assert!(Mlp::from_layers(vec![], 0.0, 1.0).is_err());
        let wrong_bias = Layer {
            w: Matrix::zeros(2, 2),
            bias: vec![0.0; 3],
            relu: false,
        };
        assert!(Mlp::from_layers(vec![wrong_bias], 0.0, 1.0).is_err());
    }

    #[test]
    fn forward_rejects_wrong_width() {
        let mlp = tiny_mlp();
        let x = Matrix::zeros(1, 5);
        assert!(mlp.forward(&x).is_err());
    }

    #[test]
    fn blob_roundtrip_and_length_check() {
        let dir = std::env::temp_dir().join("freshen-nn-blob-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let vals = [1.5f32, -2.25, 0.0, 3.0e-8];
        write_f32_blob(&path, &vals).unwrap();
        assert_eq!(read_f32_blob(&path, 4).unwrap(), vals.to_vec());
        assert!(read_f32_blob(&path, 5).is_err(), "length is enforced");
        assert!(read_f32_blob(&dir.join("missing.bin"), 1).is_err());
    }
}
