//! Wait lists: the continuation-passing primitive behind the paper's
//! `FrWait` (Algorithms 4/5).
//!
//! A [`WaitList`] holds callbacks registered by simulated "threads" that are
//! blocked on a condition (a freshen resource finishing, a container
//! becoming free). When the owning component completes the condition it
//! drains the list and schedules every waiter as an `immediate` event, so
//! waiters resume at the completion timestamp in registration order —
//! exactly the semantics of waking threads blocked on a condition variable.
//!
//! Generic over the engine's event type `E` (default [`ClosureEvent`], the
//! boxed-closure engine) so enum-event simulations can park continuations
//! too; the waiters themselves are always boxed closures — parking is rare
//! and irregular, exactly the escape-hatch case.

use std::collections::VecDeque;

use crate::simcore::{ClosureEvent, EventBody, Sim};

type Waiter<W, E> = Box<dyn FnOnce(&mut Sim<W, E>, &mut W)>;

/// A set of parked continuations keyed by nothing (one list per condition).
pub struct WaitList<W, E: EventBody<W> = ClosureEvent<W>> {
    /// FIFO of parked waiters. A deque, not a `Vec`: [`WaitList::wake_one`]
    /// releases from the front, which must stay O(1) under the paper's
    /// capacity-token churn (a `Vec::remove(0)` was O(n) per wake).
    waiters: VecDeque<Waiter<W, E>>,
}

impl<W: 'static, E: EventBody<W> + 'static> Default for WaitList<W, E> {
    fn default() -> Self {
        WaitList::new()
    }
}

impl<W: 'static, E: EventBody<W> + 'static> WaitList<W, E> {
    pub fn new() -> WaitList<W, E> {
        WaitList {
            waiters: VecDeque::new(),
        }
    }

    /// Park a continuation until [`WaitList::wake_all`].
    pub fn wait<F>(&mut self, f: F)
    where
        F: FnOnce(&mut Sim<W, E>, &mut W) + 'static,
    {
        self.waiters.push_back(Box::new(f));
    }

    pub fn is_empty(&self) -> bool {
        self.waiters.is_empty()
    }

    pub fn len(&self) -> usize {
        self.waiters.len()
    }

    /// Wake every parked waiter at the current timestamp (FIFO).
    ///
    /// Waiters are *scheduled*, not called inline, so the waker's own event
    /// finishes first — mirroring a notify-then-return condition variable.
    pub fn wake_all(&mut self, sim: &mut Sim<W, E>) {
        for w in self.waiters.drain(..) {
            sim.immediate(w);
        }
    }

    /// Wake only the first parked waiter, if any (for capacity tokens).
    /// O(1): pops the deque front, preserving FIFO order.
    pub fn wake_one(&mut self, sim: &mut Sim<W, E>) -> bool {
        match self.waiters.pop_front() {
            Some(w) => {
                sim.immediate(w);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::time::SimDuration;

    #[derive(Default)]
    struct World {
        list: Option<WaitList<World>>,
        log: Vec<&'static str>,
    }

    #[test]
    fn waiters_wake_in_order_at_completion_time() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World {
            list: Some(WaitList::new()),
            ..Default::default()
        };
        // Two "threads" block at t=1ms and t=2ms.
        sim.schedule(SimDuration::from_millis(1), |_, w: &mut World| {
            w.list.as_mut().unwrap().wait(|s, w| {
                assert_eq!(s.now().micros(), 5_000);
                w.log.push("waiter-a");
            });
        });
        sim.schedule(SimDuration::from_millis(2), |_, w: &mut World| {
            w.list.as_mut().unwrap().wait(|s, w| {
                assert_eq!(s.now().micros(), 5_000);
                w.log.push("waiter-b");
            });
        });
        // Completion at t=5ms wakes both.
        sim.schedule(SimDuration::from_millis(5), |s, w: &mut World| {
            w.log.push("complete");
            let mut list = w.list.take().unwrap();
            list.wake_all(s);
            w.list = Some(list);
        });
        sim.run(&mut w);
        assert_eq!(w.log, vec!["complete", "waiter-a", "waiter-b"]);
    }

    #[test]
    fn wake_one_releases_single_waiter() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World {
            list: Some(WaitList::new()),
            ..Default::default()
        };
        sim.schedule(SimDuration::from_millis(1), |_, w: &mut World| {
            let list = w.list.as_mut().unwrap();
            list.wait(|_, w| w.log.push("first"));
            list.wait(|_, w| w.log.push("second"));
        });
        sim.schedule(SimDuration::from_millis(2), |s, w: &mut World| {
            let mut list = w.list.take().unwrap();
            assert!(list.wake_one(s));
            assert_eq!(list.len(), 1);
            w.list = Some(list);
        });
        sim.run(&mut w);
        assert_eq!(w.log, vec!["first"]);
    }
}
