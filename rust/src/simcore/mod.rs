//! Deterministic discrete-event simulation engine.
//!
//! Every paper experiment (Table 1's 20 k trigger runs, Figures 4–6's
//! transfer sweeps, the chain workloads) runs on this engine: a
//! hierarchical timing-wheel event queue ([`wheel::TimingWheel`]) over
//! virtual microseconds ([`crate::util::time::SimTime`]), with strictly
//! deterministic ordering — events at the same timestamp fire in schedule
//! order (FIFO by sequence number), so a given seed always produces the
//! same run.
//!
//! # Model
//!
//! The engine is generic over a *world* type `W` (the mutable simulation
//! state — the platform, network, stores) and an *event* type `E`
//! implementing [`EventBody`]. The default event type,
//! [`ClosureEvent`], is a boxed `FnOnce(&mut Sim<W>, &mut W)` — the
//! historical model, maximally flexible, one heap allocation per event.
//! Hot simulations define an enum event instead (e.g. the platform's
//! `PlatformEvent`): its recurring timer shapes are plain variants stored
//! inline in the queue — zero per-event allocations, no vtable call —
//! with a boxed-closure variant retained as the escape hatch that
//! [`EventBody::from_closure`] routes `schedule` through, so closure-based
//! call sites compile unchanged against either event type. An event may
//! schedule further events, cancel pending ones, and mutate the world.
//! "Processes" that block (e.g. the paper's `FrWait`) are written in
//! continuation-passing style: the waiter registers a callback that the
//! completing event fires.
//!
//! # Scheduler
//!
//! Scheduling and cancellation are O(1) on the wheel (amortised O(1)
//! cascading per event), versus O(log n) on the previous global binary
//! heap; the heap survives as [`wheel::BinaryHeapQueue`], the executable
//! specification the property tests check the wheel against event for
//! event. Cancelling marks a per-slot tombstone in place — there is no
//! global tombstone set, and cancelling an already-fired event is a
//! `false` no-op that leaks nothing.

pub mod waitlist;
pub mod wheel;

use std::marker::PhantomData;

use crate::util::time::{SimDuration, SimTime};

use wheel::{EventQueue, TimingWheel};

/// Identifier of a scheduled event, usable for cancellation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

/// A boxed event body (the closure escape hatch). `E` is the concrete
/// event type of the engine the closure runs on; the default keeps the
/// historical `Box<dyn FnOnce(&mut Sim<W>, &mut W)>` shape.
pub type EventFn<W, E = ClosureEvent<W>> = Box<dyn FnOnce(&mut Sim<W, E>, &mut W)>;

/// What the engine stores on the wheel and fires in [`Sim::step`].
///
/// Implementations are either [`ClosureEvent`] (every event is a boxed
/// closure) or a simulation-specific enum whose recurring variants are
/// stored inline — plus a closure variant that `from_closure` wraps, so
/// `Sim::schedule` keeps working for the irregular shapes.
pub trait EventBody<W>: Sized {
    /// Execute the event.
    fn fire(self, sim: &mut Sim<W, Self>, world: &mut W);
    /// Wrap a boxed closure (the escape hatch `Sim::schedule` uses).
    fn from_closure(f: EventFn<W, Self>) -> Self;
}

/// The default event type: a boxed `FnOnce` closure per event (one heap
/// allocation + vtable call each — fine for experiments, not for the
/// macro-replay hot path, which uses an enum event instead).
pub struct ClosureEvent<W>(pub EventFn<W>);

impl<W> EventBody<W> for ClosureEvent<W> {
    fn fire(self, sim: &mut Sim<W, Self>, world: &mut W) {
        (self.0)(sim, world)
    }

    fn from_closure(f: EventFn<W>) -> Self {
        ClosureEvent(f)
    }
}

/// The simulation engine: virtual clock + timing-wheel event queue.
pub struct Sim<W, E: EventBody<W> = ClosureEvent<W>> {
    now: SimTime,
    seq: u64,
    queue: TimingWheel<E>,
    executed: u64,
    /// Hard cap on executed events; guards against runaway feedback loops
    /// in experiments (0 = unlimited).
    pub max_events: u64,
    /// Equivalence-test toggle: when set, [`Sim::schedule_event`] routes
    /// enum events through the closure escape hatch (`from_closure` over a
    /// `fire` thunk) instead of storing them inline. Sequence numbers and
    /// firing order are identical either way — a run with the toggle on is
    /// the reference model a run with it off must match event for event.
    pub force_closures: bool,
    _world: PhantomData<fn(&mut W)>,
}

impl<W, E: EventBody<W> + 'static> Default for Sim<W, E> {
    fn default() -> Self {
        Sim::new()
    }
}

impl<W, E: EventBody<W> + 'static> Sim<W, E> {
    pub fn new() -> Sim<W, E> {
        Sim {
            now: SimTime::ZERO,
            seq: 0,
            queue: TimingWheel::new(),
            executed: 0,
            max_events: 0,
            force_closures: false,
            _world: PhantomData,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Number of events still pending (cancelled events excluded).
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule `f` to run after `delay`. Returns an id for cancellation.
    pub fn schedule<F>(&mut self, delay: SimDuration, f: F) -> EventId
    where
        F: FnOnce(&mut Sim<W, E>, &mut W) + 'static,
    {
        self.schedule_at(self.now + delay, f)
    }

    /// Schedule `f` at an absolute virtual time (must not be in the past).
    pub fn schedule_at<F>(&mut self, at: SimTime, f: F) -> EventId
    where
        F: FnOnce(&mut Sim<W, E>, &mut W) + 'static,
    {
        self.insert_event(at, E::from_closure(Box::new(f)))
    }

    /// Schedule an event body to fire after `delay`. For enum event types
    /// this stores the variant inline on the wheel — no allocation.
    pub fn schedule_event(&mut self, delay: SimDuration, ev: E) -> EventId {
        self.schedule_event_at(self.now + delay, ev)
    }

    /// Schedule an event body at an absolute virtual time.
    pub fn schedule_event_at(&mut self, at: SimTime, ev: E) -> EventId {
        if self.force_closures {
            // Reference mode: round-trip through the closure escape hatch.
            // One seq is consumed either way, so ordering is identical.
            let wrapped = E::from_closure(Box::new(move |sim, w| ev.fire(sim, w)));
            return self.insert_event(at, wrapped);
        }
        self.insert_event(at, ev)
    }

    fn insert_event(&mut self, at: SimTime, ev: E) -> EventId {
        debug_assert!(at >= self.now, "scheduling into the past");
        let seq = self.seq;
        self.seq += 1;
        self.queue.insert(at.max(self.now), seq, ev);
        EventId(seq)
    }

    /// Schedule `f` to run immediately after the current event (same
    /// timestamp, FIFO order). The paper's freshen hook firing "simultaneously"
    /// with `run` is modelled with two `immediate` events.
    pub fn immediate<F>(&mut self, f: F) -> EventId
    where
        F: FnOnce(&mut Sim<W, E>, &mut W) + 'static,
    {
        self.schedule(SimDuration::ZERO, f)
    }

    /// Cancel a pending event. Cancelling an already-fired or already-
    /// cancelled event is a no-op (returns false) and leaks nothing: the
    /// wheel tracks fired/pending status per event, so a stale [`EventId`]
    /// cannot tombstone anything.
    pub fn cancel(&mut self, id: EventId) -> bool {
        self.queue.cancel(id.0)
    }

    /// Run one event; returns false when the queue is exhausted.
    pub fn step(&mut self, world: &mut W) -> bool {
        match self.queue.pop() {
            None => false,
            Some((at, _seq, ev)) => {
                debug_assert!(at >= self.now);
                self.now = self.now.max(at);
                self.executed += 1;
                ev.fire(self, world);
                true
            }
        }
    }

    /// Run until the queue is empty (or `max_events` is hit).
    pub fn run(&mut self, world: &mut W) {
        while self.step(world) {
            if self.max_events != 0 && self.executed >= self.max_events {
                panic!(
                    "simulation exceeded max_events={} at t={}",
                    self.max_events, self.now
                );
            }
        }
    }

    /// Run until virtual time `until` (events at exactly `until` still run).
    pub fn run_until(&mut self, world: &mut W, until: SimTime) {
        while let Some(head_at) = self.queue.peek_at() {
            if head_at > until {
                break;
            }
            self.step(world);
            if self.max_events != 0 && self.executed >= self.max_events {
                panic!("simulation exceeded max_events={}", self.max_events);
            }
        }
        // Even with no events, time logically advances to `until`.
        if self.now < until {
            self.now = until;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct World {
        log: Vec<(u64, &'static str)>,
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.schedule(SimDuration::from_millis(20), |s, w| {
            w.log.push((s.now().micros(), "b"))
        });
        sim.schedule(SimDuration::from_millis(10), |s, w| {
            w.log.push((s.now().micros(), "a"))
        });
        sim.schedule(SimDuration::from_millis(30), |s, w| {
            w.log.push((s.now().micros(), "c"))
        });
        sim.run(&mut w);
        assert_eq!(
            w.log,
            vec![(10_000, "a"), (20_000, "b"), (30_000, "c")]
        );
        assert_eq!(sim.executed(), 3);
    }

    #[test]
    fn same_time_events_are_fifo() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        for name in ["first", "second", "third"] {
            sim.schedule(SimDuration::from_millis(5), move |s, w| {
                w.log.push((s.now().micros(), name))
            });
        }
        sim.run(&mut w);
        let names: Vec<&str> = w.log.iter().map(|(_, n)| *n).collect();
        assert_eq!(names, vec!["first", "second", "third"]);
    }

    #[test]
    fn events_can_schedule_events() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.schedule(SimDuration::from_millis(1), |s, _| {
            s.schedule(SimDuration::from_millis(1), |s, w: &mut World| {
                w.log.push((s.now().micros(), "nested"));
            });
        });
        sim.run(&mut w);
        assert_eq!(w.log, vec![(2_000, "nested")]);
    }

    #[test]
    fn cancellation() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        let id = sim.schedule(SimDuration::from_millis(1), |s, w| {
            w.log.push((s.now().micros(), "cancelled"))
        });
        sim.schedule(SimDuration::from_millis(2), |s, w| {
            w.log.push((s.now().micros(), "kept"))
        });
        assert!(sim.cancel(id));
        assert!(!sim.cancel(id)); // double-cancel is a no-op
        sim.run(&mut w);
        assert_eq!(w.log, vec![(2_000, "kept")]);
    }

    #[test]
    fn cancel_after_fire_is_a_false_noop_and_leaks_nothing() {
        // Regression: the old scheduler returned `true` for a cancel of an
        // already-fired event and inserted a permanent tombstone, which
        // also disabled the step() fast path forever.
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        let id = sim.schedule(SimDuration::from_millis(1), |s, w| {
            w.log.push((s.now().micros(), "fired"))
        });
        sim.run(&mut w);
        assert_eq!(w.log, vec![(1_000, "fired")]);
        assert!(!sim.cancel(id), "cancel-after-fire must report false");
        assert!(!sim.cancel(id), "and stay false on repeat");
        assert_eq!(sim.pending(), 0, "no tombstone may leak");
        // The engine keeps running normally afterwards.
        sim.schedule(SimDuration::from_millis(1), |s, w| {
            w.log.push((s.now().micros(), "later"))
        });
        sim.run(&mut w);
        assert_eq!(w.log.len(), 2);
        assert_eq!(sim.pending(), 0);
    }

    #[test]
    fn pending_counts_live_events_only() {
        let mut sim: Sim<World> = Sim::new();
        let id = sim.schedule(SimDuration::from_millis(1), |_, _| {});
        sim.schedule(SimDuration::from_millis(2), |_, _| {});
        assert_eq!(sim.pending(), 2);
        assert!(sim.cancel(id));
        assert_eq!(sim.pending(), 1, "cancelled events are not pending");
    }

    #[test]
    fn run_until_stops_and_advances_clock() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.schedule(SimDuration::from_secs(1), |s, w| {
            w.log.push((s.now().micros(), "late"))
        });
        sim.run_until(&mut w, SimTime(500_000));
        assert!(w.log.is_empty());
        assert_eq!(sim.now(), SimTime(500_000));
        sim.run(&mut w);
        assert_eq!(w.log.len(), 1);
    }

    #[test]
    fn schedule_after_run_until_fires_in_order() {
        // run_until peeks past `until`; a subsequent schedule below the
        // peeked head must still fire before it.
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.schedule(SimDuration::from_secs(1), |s, w| {
            w.log.push((s.now().micros(), "late"))
        });
        sim.run_until(&mut w, SimTime(200_000));
        sim.schedule_at(SimTime(300_000), |s, w| {
            w.log.push((s.now().micros(), "early"))
        });
        sim.run(&mut w);
        assert_eq!(w.log, vec![(300_000, "early"), (1_000_000, "late")]);
    }

    #[test]
    fn immediate_runs_at_same_timestamp() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.schedule(SimDuration::from_millis(3), |s, w: &mut World| {
            let t0 = s.now();
            w.log.push((t0.micros(), "outer"));
            s.immediate(move |s, w: &mut World| {
                assert_eq!(s.now(), t0);
                w.log.push((s.now().micros(), "inner"));
            });
        });
        sim.run(&mut w);
        assert_eq!(w.log, vec![(3_000, "outer"), (3_000, "inner")]);
    }

    #[test]
    #[should_panic(expected = "max_events")]
    fn max_events_guards_runaway() {
        fn tick(s: &mut Sim<World>, _w: &mut World) {
            s.schedule(SimDuration::from_micros(1), tick);
        }
        let mut sim: Sim<World> = Sim::new();
        sim.max_events = 1000;
        let mut w = World::default();
        sim.schedule(SimDuration::ZERO, tick);
        sim.run(&mut w);
    }

    // ---- enum-coded events -------------------------------------------

    /// A tiny enum event type exercising the inline-variant path.
    enum TestEvent {
        Tag(&'static str),
        Closure(EventFn<World, TestEvent>),
    }

    impl EventBody<World> for TestEvent {
        fn fire(self, sim: &mut Sim<World, Self>, world: &mut World) {
            match self {
                TestEvent::Tag(name) => world.log.push((sim.now().micros(), name)),
                TestEvent::Closure(f) => f(sim, world),
            }
        }

        fn from_closure(f: EventFn<World, Self>) -> Self {
            TestEvent::Closure(f)
        }
    }

    #[test]
    fn enum_events_interleave_with_closures_in_seq_order() {
        let mut sim: Sim<World, TestEvent> = Sim::new();
        let mut w = World::default();
        sim.schedule_event(SimDuration::from_millis(5), TestEvent::Tag("enum-b"));
        sim.schedule(SimDuration::from_millis(5), |s, w: &mut World| {
            w.log.push((s.now().micros(), "closure"))
        });
        sim.schedule_event(SimDuration::from_millis(2), TestEvent::Tag("enum-a"));
        sim.run(&mut w);
        assert_eq!(
            w.log,
            vec![(2_000, "enum-a"), (5_000, "enum-b"), (5_000, "closure")]
        );
    }

    #[test]
    fn force_closures_is_order_identical_to_inline_variants() {
        // The reference-model equivalence the platform replay relies on:
        // identical schedule sequence, identical (timestamp, seq) firing
        // order, identical effects — with and without inline storage.
        let drive = |force: bool| -> Vec<(u64, &'static str)> {
            let mut sim: Sim<World, TestEvent> = Sim::new();
            sim.force_closures = force;
            let mut w = World::default();
            for (delay_ms, name) in [(3, "x"), (1, "y"), (3, "z")] {
                sim.schedule_event(
                    SimDuration::from_millis(delay_ms),
                    TestEvent::Tag(name),
                );
            }
            sim.schedule(SimDuration::from_millis(3), |s, w: &mut World| {
                w.log.push((s.now().micros(), "tail"));
                s.schedule_event(SimDuration::from_millis(1), TestEvent::Tag("nested"));
            });
            sim.run(&mut w);
            w.log
        };
        assert_eq!(drive(false), drive(true));
        assert_eq!(
            drive(false),
            vec![
                (1_000, "y"),
                (3_000, "x"),
                (3_000, "z"),
                (3_000, "tail"),
                (4_000, "nested"),
            ]
        );
    }
}
