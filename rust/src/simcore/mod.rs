//! Deterministic discrete-event simulation engine.
//!
//! Every paper experiment (Table 1's 20 k trigger runs, Figures 4–6's
//! transfer sweeps, the chain workloads) runs on this engine: a binary-heap
//! event queue over virtual microseconds ([`crate::util::time::SimTime`]),
//! with strictly deterministic ordering — events at the same timestamp fire
//! in schedule order (FIFO by sequence number), so a given seed always
//! produces the same run.
//!
//! # Model
//!
//! The engine is generic over a *world* type `W` (the mutable simulation
//! state — the platform, network, stores). Events are boxed `FnOnce(&mut
//! Sim<W>, &mut W)` closures; an event may schedule further events, cancel
//! pending ones, and mutate the world. "Processes" that block (e.g. the
//! paper's `FrWait`) are written in continuation-passing style: the waiter
//! registers a callback that the completing event fires.

pub mod waitlist;

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::fxhash::FxHashSet;

use crate::util::time::{SimDuration, SimTime};

/// Identifier of a scheduled event, usable for cancellation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

type EventFn<W> = Box<dyn FnOnce(&mut Sim<W>, &mut W)>;

struct Scheduled<W> {
    at: SimTime,
    seq: u64,
    f: EventFn<W>,
}

// Order the heap as a *min*-heap on (time, seq).
impl<W> PartialEq for Scheduled<W> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<W> Eq for Scheduled<W> {}
impl<W> PartialOrd for Scheduled<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<W> Ord for Scheduled<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

/// The simulation engine: virtual clock + event queue.
pub struct Sim<W> {
    now: SimTime,
    seq: u64,
    queue: BinaryHeap<Scheduled<W>>,
    cancelled: FxHashSet<u64>,
    executed: u64,
    /// Hard cap on executed events; guards against runaway feedback loops
    /// in experiments (0 = unlimited).
    pub max_events: u64,
}

impl<W> Default for Sim<W> {
    fn default() -> Self {
        Sim::new()
    }
}

impl<W> Sim<W> {
    pub fn new() -> Sim<W> {
        Sim {
            now: SimTime::ZERO,
            seq: 0,
            queue: BinaryHeap::new(),
            cancelled: FxHashSet::default(),
            executed: 0,
            max_events: 0,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Number of events still pending.
    pub fn pending(&self) -> usize {
        self.queue.len() - self.cancelled.len().min(self.queue.len())
    }

    /// Schedule `f` to run after `delay`. Returns an id for cancellation.
    pub fn schedule<F>(&mut self, delay: SimDuration, f: F) -> EventId
    where
        F: FnOnce(&mut Sim<W>, &mut W) + 'static,
    {
        self.schedule_at(self.now + delay, f)
    }

    /// Schedule `f` at an absolute virtual time (must not be in the past).
    pub fn schedule_at<F>(&mut self, at: SimTime, f: F) -> EventId
    where
        F: FnOnce(&mut Sim<W>, &mut W) + 'static,
    {
        debug_assert!(at >= self.now, "scheduling into the past");
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Scheduled {
            at: at.max(self.now),
            seq,
            f: Box::new(f),
        });
        EventId(seq)
    }

    /// Schedule `f` to run immediately after the current event (same
    /// timestamp, FIFO order). The paper's freshen hook firing "simultaneously"
    /// with `run` is modelled with two `immediate` events.
    pub fn immediate<F>(&mut self, f: F) -> EventId
    where
        F: FnOnce(&mut Sim<W>, &mut W) + 'static,
    {
        self.schedule(SimDuration::ZERO, f)
    }

    /// Cancel a pending event. Cancelling an already-fired or already-
    /// cancelled event is a no-op (returns false).
    pub fn cancel(&mut self, id: EventId) -> bool {
        if id.0 >= self.seq {
            return false;
        }
        self.cancelled.insert(id.0)
    }

    /// Run one event; returns false when the queue is exhausted.
    pub fn step(&mut self, world: &mut W) -> bool {
        loop {
            match self.queue.pop() {
                None => return false,
                Some(ev) => {
                    // Fast path: no cancellations outstanding (the common
                    // case) skips the tombstone lookup entirely.
                    if !self.cancelled.is_empty() && self.cancelled.remove(&ev.seq) {
                        continue; // tombstoned
                    }
                    debug_assert!(ev.at >= self.now);
                    self.now = ev.at;
                    self.executed += 1;
                    (ev.f)(self, world);
                    return true;
                }
            }
        }
    }

    /// Run until the queue is empty (or `max_events` is hit).
    pub fn run(&mut self, world: &mut W) {
        while self.step(world) {
            if self.max_events != 0 && self.executed >= self.max_events {
                panic!(
                    "simulation exceeded max_events={} at t={}",
                    self.max_events, self.now
                );
            }
        }
    }

    /// Run until virtual time `until` (events at exactly `until` still run).
    pub fn run_until(&mut self, world: &mut W, until: SimTime) {
        while let Some(head) = self.queue.peek() {
            if head.at > until {
                break;
            }
            self.step(world);
            if self.max_events != 0 && self.executed >= self.max_events {
                panic!("simulation exceeded max_events={}", self.max_events);
            }
        }
        // Even with no events, time logically advances to `until`.
        if self.now < until {
            self.now = until;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct World {
        log: Vec<(u64, &'static str)>,
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.schedule(SimDuration::from_millis(20), |s, w| {
            w.log.push((s.now().micros(), "b"))
        });
        sim.schedule(SimDuration::from_millis(10), |s, w| {
            w.log.push((s.now().micros(), "a"))
        });
        sim.schedule(SimDuration::from_millis(30), |s, w| {
            w.log.push((s.now().micros(), "c"))
        });
        sim.run(&mut w);
        assert_eq!(
            w.log,
            vec![(10_000, "a"), (20_000, "b"), (30_000, "c")]
        );
        assert_eq!(sim.executed(), 3);
    }

    #[test]
    fn same_time_events_are_fifo() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        for name in ["first", "second", "third"] {
            sim.schedule(SimDuration::from_millis(5), move |s, w| {
                w.log.push((s.now().micros(), name))
            });
        }
        sim.run(&mut w);
        let names: Vec<&str> = w.log.iter().map(|(_, n)| *n).collect();
        assert_eq!(names, vec!["first", "second", "third"]);
    }

    #[test]
    fn events_can_schedule_events() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.schedule(SimDuration::from_millis(1), |s, _| {
            s.schedule(SimDuration::from_millis(1), |s, w: &mut World| {
                w.log.push((s.now().micros(), "nested"));
            });
        });
        sim.run(&mut w);
        assert_eq!(w.log, vec![(2_000, "nested")]);
    }

    #[test]
    fn cancellation() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        let id = sim.schedule(SimDuration::from_millis(1), |s, w| {
            w.log.push((s.now().micros(), "cancelled"))
        });
        sim.schedule(SimDuration::from_millis(2), |s, w| {
            w.log.push((s.now().micros(), "kept"))
        });
        assert!(sim.cancel(id));
        assert!(!sim.cancel(id)); // double-cancel is a no-op
        sim.run(&mut w);
        assert_eq!(w.log, vec![(2_000, "kept")]);
    }

    #[test]
    fn run_until_stops_and_advances_clock() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.schedule(SimDuration::from_secs(1), |s, w| {
            w.log.push((s.now().micros(), "late"))
        });
        sim.run_until(&mut w, SimTime(500_000));
        assert!(w.log.is_empty());
        assert_eq!(sim.now(), SimTime(500_000));
        sim.run(&mut w);
        assert_eq!(w.log.len(), 1);
    }

    #[test]
    fn immediate_runs_at_same_timestamp() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.schedule(SimDuration::from_millis(3), |s, w: &mut World| {
            let t0 = s.now();
            w.log.push((t0.micros(), "outer"));
            s.immediate(move |s, w: &mut World| {
                assert_eq!(s.now(), t0);
                w.log.push((s.now().micros(), "inner"));
            });
        });
        sim.run(&mut w);
        assert_eq!(w.log, vec![(3_000, "outer"), (3_000, "inner")]);
    }

    #[test]
    #[should_panic(expected = "max_events")]
    fn max_events_guards_runaway() {
        fn tick(s: &mut Sim<World>, _w: &mut World) {
            s.schedule(SimDuration::from_micros(1), tick);
        }
        let mut sim: Sim<World> = Sim::new();
        sim.max_events = 1000;
        let mut w = World::default();
        sim.schedule(SimDuration::ZERO, tick);
        sim.run(&mut w);
    }
}
