//! Hierarchical timing-wheel event queue — the engine behind [`Sim`].
//!
//! The queue maps each pending event to a slot in one of [`LEVELS`] wheels
//! of [`SLOTS`] slots each. Level `k` buckets timestamps by bit-field
//! `at[6k .. 6k+6]`; an event lives at the *smallest* level whose next
//! coarser window it shares with the current cursor (the Linux timer-wheel
//! placement rule, `level = msb(at ^ now) / 6`). Events further than
//! `2^(6·LEVELS)` µs (≈ 19 h) ahead go to a sorted overflow heap and are
//! re-homed onto the wheels when the cursor approaches.
//!
//! The queue is generic over the event payload `E`, stored *inline* in the
//! side table: with an enum event type ([`crate::simcore::EventBody`])
//! scheduling allocates nothing beyond amortised map growth, where the old
//! `EventFn`-only store paid one `Box<dyn FnOnce>` heap allocation plus a
//! vtable call per event. Closure-based engines simply instantiate
//! `E = ClosureEvent<W>` and behave exactly as before.
//!
//! Determinism: the engine's contract is exact `(timestamp, seq)` FIFO
//! order. Slots store bare `(at, seq)` pairs; the payloads live in a
//! side table keyed by `seq`. Draining a slot re-inserts its pairs
//! relative to the advanced cursor, which provably lands them at a
//! strictly lower level, until they reach the sorted `ready` buffer the
//! pop path consumes.
//!
//! Cancellation is O(1): `cancel` removes the payload from the side
//! table; the orphaned `(at, seq)` pair stays in its slot as a per-slot
//! tombstone and is dropped when that slot drains. Nothing is consulted
//! on the hot pop path beyond the side-table lookup every pop already
//! does, and a cancel of an already-fired event finds no payload and
//! reports `false` — there is no global tombstone set to leak into.
//!
//! [`Sim`]: crate::simcore::Sim

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::util::fxhash::FxHashMap;
use crate::util::time::SimTime;

/// log2 of the slot count per level.
pub const BITS: usize = 6;
/// Slots per level.
pub const SLOTS: usize = 1 << BITS;
/// Number of wheel levels; beyond `2^(BITS·LEVELS)` µs lies the overflow.
pub const LEVELS: usize = 6;

const SLOT_MASK: u64 = (SLOTS as u64) - 1;

/// A pending event reference: `(timestamp µs, sequence number)`.
type Pair = (u64, u64);

/// The abstract event-queue interface over payload type `E`, so benches
/// and property tests can drive the wheel and the reference binary heap
/// identically.
pub trait EventQueue<E> {
    /// Add an event. `seq` values must be unique and monotonically
    /// increasing across inserts (the engine's schedule counter).
    fn insert(&mut self, at: SimTime, seq: u64, ev: E);
    /// Remove a pending event. Returns `false` (and changes nothing) if
    /// the event already fired, was already cancelled, or never existed.
    fn cancel(&mut self, seq: u64) -> bool;
    /// Remove and return the earliest event by `(timestamp, seq)`.
    fn pop(&mut self) -> Option<(SimTime, u64, E)>;
    /// Timestamp of the earliest pending event, if any.
    fn peek_at(&mut self) -> Option<SimTime>;
    /// Number of live (non-cancelled, non-fired) events.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Hierarchical timing wheel. See the module docs for the invariants.
pub struct TimingWheel<E> {
    /// Cursor: all live events have `at >= now` except entries parked in
    /// `ready` (which may briefly trail `now` after a peek advanced the
    /// cursor and the engine then scheduled an earlier event).
    now: u64,
    /// Imminent events, sorted ascending by `(at, seq)`; every entry
    /// satisfies `at <= self.now`.
    ready: VecDeque<Pair>,
    /// `LEVELS × SLOTS` buckets, flattened; `slots[level * SLOTS + slot]`.
    slots: Vec<Vec<Pair>>,
    /// One occupancy bit per slot, per level, for O(1) next-slot scans.
    occupied: [u64; LEVELS],
    /// Far-future events, min-heap by `(at, seq)`.
    overflow: BinaryHeap<Reverse<Pair>>,
    /// seq → payload. Cancel removes from here; pairs whose seq is gone
    /// are tombstones, collected when their slot drains.
    store: FxHashMap<u64, E>,
}

impl<E> Default for TimingWheel<E> {
    fn default() -> Self {
        TimingWheel::new()
    }
}

impl<E> TimingWheel<E> {
    pub fn new() -> TimingWheel<E> {
        TimingWheel {
            now: 0,
            ready: VecDeque::new(),
            slots: (0..LEVELS * SLOTS).map(|_| Vec::new()).collect(),
            occupied: [0; LEVELS],
            overflow: BinaryHeap::new(),
            store: FxHashMap::default(),
        }
    }

    /// Route a pair to `ready`, a wheel slot, or the overflow, relative to
    /// the current cursor.
    fn push_pair(&mut self, p: Pair) {
        let (at, _) = p;
        if at <= self.now {
            // Keep `ready` sorted by (at, seq). New seqs are maximal, so
            // appends dominate; out-of-order inserts only occur after a
            // peek ran the cursor ahead (run_until), and binary-insert.
            match self.ready.back() {
                Some(&back) if back > p => {
                    let idx = self.ready.partition_point(|&q| q < p);
                    self.ready.insert(idx, p);
                }
                _ => self.ready.push_back(p),
            }
            return;
        }
        let diff = at ^ self.now; // nonzero: at > now
        let level = ((63 - diff.leading_zeros()) / BITS as u32) as usize;
        if level >= LEVELS {
            self.overflow.push(Reverse(p));
            return;
        }
        let slot = ((at >> (level * BITS)) & SLOT_MASK) as usize;
        self.slots[level * SLOTS + slot].push(p);
        self.occupied[level] |= 1u64 << slot;
    }

    /// Move events toward `ready` until it provably holds the *complete*
    /// batch for its front timestamp: every remaining wheel slot and the
    /// overflow head must lie strictly later than `ready`'s front before
    /// this returns. (A partial batch would break FIFO: `pop` serves
    /// `ready` without re-consulting the wheels, and an event executed
    /// from a partial batch could schedule an immediate that would then
    /// overtake a same-timestamp, lower-seq event still parked in a
    /// slot.) Returns `false` iff nothing is left anywhere.
    fn refill(&mut self) -> bool {
        loop {
            // Candidate = the occupied slot with the smallest window base
            // across levels (finer level wins ties), vs the overflow head.
            let mut best: Option<(u64, usize, usize)> = None; // (bound, level, slot)
            for level in 0..LEVELS {
                let occ = self.occupied[level];
                if occ == 0 {
                    continue;
                }
                let shift = level * BITS;
                let cursor = ((self.now >> shift) & SLOT_MASK) as u32;
                let ahead = occ & (u64::MAX << cursor);
                // Invariant: every resident pair shares the level's coarser
                // window with the cursor, so no occupied slot trails it.
                debug_assert_eq!(ahead, occ, "slot behind cursor at level {level}");
                let slot = ahead.trailing_zeros() as usize;
                let span = 1u64 << ((level + 1) * BITS);
                let base = (self.now & !(span - 1)) | ((slot as u64) << shift);
                let bound = base.max(self.now);
                if best.map_or(true, |(b, _, _)| bound < b) {
                    best = Some((bound, level, slot));
                }
            }
            let overflow_at = self.overflow.peek().map(|&Reverse((at, _))| at);
            let next = match (best, overflow_at) {
                (None, None) => return !self.ready.is_empty(),
                (Some((b, _, _)), Some(o)) => b.min(o),
                (Some((b, _, _)), None) => b,
                (None, Some(o)) => o,
            };
            // Bounds are lower bounds on their source's contents, so once
            // every source lies strictly past the front timestamp, the
            // front batch is complete.
            if let Some(&(front_at, _)) = self.ready.front() {
                if next > front_at {
                    return true;
                }
            }
            match (best, overflow_at) {
                // On a bound tie, drain the overflow first: an
                // overflow-resident event was scheduled against a farther
                // horizon than any wheel-resident event with the same
                // timestamp, so it carries the lower seq. (Order is
                // restored by the sorted `ready` insert either way; this
                // just reaches the fixpoint in fewer drains.)
                (Some((bound, level, slot)), ov) if ov.map_or(true, |o| bound < o) => {
                    self.drain_slot(level, slot, bound);
                }
                _ => self.drain_overflow(),
            }
        }
    }

    /// Advance the cursor to `bound` and re-route every live pair in the
    /// slot. Pairs land at a strictly lower level (or in `ready`), so
    /// each event cascades at most `LEVELS` times over its lifetime.
    fn drain_slot(&mut self, level: usize, slot: usize, bound: u64) {
        self.occupied[level] &= !(1u64 << slot);
        let pairs = std::mem::take(&mut self.slots[level * SLOTS + slot]);
        self.now = self.now.max(bound);
        for p in pairs {
            if self.store.contains_key(&p.1) {
                self.push_pair(p);
            }
            // else: tombstone of a cancelled event — collected here.
        }
    }

    /// Called when the overflow head is the global minimum: advance the
    /// cursor to it and re-home every overflow event that now fits on the
    /// wheels.
    fn drain_overflow(&mut self) {
        let Some(Reverse(head)) = self.overflow.pop() else {
            return;
        };
        self.now = self.now.max(head.0);
        if self.store.contains_key(&head.1) {
            self.push_pair(head);
        }
        while let Some(&Reverse(p)) = self.overflow.peek() {
            let at = p.0;
            if at > self.now {
                let level = ((63 - (at ^ self.now).leading_zeros()) / BITS as u32) as usize;
                if level >= LEVELS {
                    break; // still beyond the horizon; stays in overflow
                }
            }
            self.overflow.pop();
            if self.store.contains_key(&p.1) {
                self.push_pair(p);
            }
        }
    }
}

impl<E> EventQueue<E> for TimingWheel<E> {
    fn insert(&mut self, at: SimTime, seq: u64, ev: E) {
        self.store.insert(seq, ev);
        self.push_pair((at.micros(), seq));
    }

    fn cancel(&mut self, seq: u64) -> bool {
        self.store.remove(&seq).is_some()
    }

    fn pop(&mut self) -> Option<(SimTime, u64, E)> {
        loop {
            while let Some((at, seq)) = self.ready.pop_front() {
                if let Some(ev) = self.store.remove(&seq) {
                    return Some((SimTime(at), seq, ev));
                }
            }
            if !self.refill() {
                return None;
            }
        }
    }

    fn peek_at(&mut self) -> Option<SimTime> {
        loop {
            while let Some(&(at, seq)) = self.ready.front() {
                if self.store.contains_key(&seq) {
                    return Some(SimTime(at));
                }
                self.ready.pop_front();
            }
            if !self.refill() {
                return None;
            }
        }
    }

    fn len(&self) -> usize {
        self.store.len()
    }
}

/// The pre-wheel scheduler: a global binary min-heap over `(at, seq)`.
/// Kept as the executable specification for the property tests and the
/// heap-vs-wheel bench comparison.
pub struct BinaryHeapQueue<E> {
    heap: BinaryHeap<Reverse<Pair>>,
    store: FxHashMap<u64, E>,
}

impl<E> Default for BinaryHeapQueue<E> {
    fn default() -> Self {
        BinaryHeapQueue::new()
    }
}

impl<E> BinaryHeapQueue<E> {
    pub fn new() -> BinaryHeapQueue<E> {
        BinaryHeapQueue {
            heap: BinaryHeap::new(),
            store: FxHashMap::default(),
        }
    }
}

impl<E> EventQueue<E> for BinaryHeapQueue<E> {
    fn insert(&mut self, at: SimTime, seq: u64, ev: E) {
        self.store.insert(seq, ev);
        self.heap.push(Reverse((at.micros(), seq)));
    }

    fn cancel(&mut self, seq: u64) -> bool {
        self.store.remove(&seq).is_some()
    }

    fn pop(&mut self) -> Option<(SimTime, u64, E)> {
        while let Some(Reverse((at, seq))) = self.heap.pop() {
            if let Some(ev) = self.store.remove(&seq) {
                return Some((SimTime(at), seq, ev));
            }
        }
        None
    }

    fn peek_at(&mut self) -> Option<SimTime> {
        while let Some(&Reverse((at, seq))) = self.heap.peek() {
            if self.store.contains_key(&seq) {
                return Some(SimTime(at));
            }
            self.heap.pop();
        }
        None
    }

    fn len(&self) -> usize {
        self.store.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Payloads are irrelevant to ordering; store the zero-sized `()`.
    type Q = TimingWheel<()>;

    /// Drain a queue to the popped (at, seq) order.
    fn drain<E, Q: EventQueue<E>>(q: &mut Q) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        while let Some((at, seq, _ev)) = q.pop() {
            out.push((at.micros(), seq));
        }
        out
    }

    #[test]
    fn orders_by_time_then_seq_across_levels() {
        let mut q = Q::new();
        // Spread across L0 (near), mid levels, and the overflow (~19h+).
        let times = [
            5u64,
            3,
            3, // same-timestamp FIFO
            200,
            70,
            5_000,
            64 * 64 * 64 + 17,
            1u64 << 40, // overflow territory
            (1u64 << 40) + 1,
            123_456_789,
        ];
        for (i, &t) in times.iter().enumerate() {
            q.insert(SimTime(t), i as u64, ());
        }
        let got = drain(&mut q);
        let mut want: Vec<(u64, u64)> = times
            .iter()
            .enumerate()
            .map(|(i, &t)| (t, i as u64))
            .collect();
        want.sort();
        assert_eq!(got, want);
    }

    #[test]
    fn cancel_is_exact_and_tombstones_collect() {
        let mut q = Q::new();
        for i in 0..10u64 {
            q.insert(SimTime(100 * i), i, ());
        }
        assert!(q.cancel(3));
        assert!(!q.cancel(3), "double-cancel is a no-op");
        assert!(!q.cancel(99), "never-scheduled seq");
        assert_eq!(q.len(), 9);
        let (at, seq, _) = q.pop().unwrap();
        assert_eq!((at.micros(), seq), (0, 0));
        assert!(!q.cancel(0), "cancel-after-fire is a no-op");
        let rest = drain(&mut q);
        let want: Vec<(u64, u64)> = (1..10u64)
            .filter(|&i| i != 3)
            .map(|i| (100 * i, i))
            .collect();
        assert_eq!(rest, want);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn schedule_behind_a_peeked_cursor_still_fires_first() {
        let mut q = Q::new();
        q.insert(SimTime(10_000), 0, ());
        // Peek advances the internal cursor to 10_000.
        assert_eq!(q.peek_at(), Some(SimTime(10_000)));
        // A later schedule below the cursor (run_until semantics).
        q.insert(SimTime(4_000), 1, ());
        q.insert(SimTime(7_000), 2, ());
        assert_eq!(q.peek_at(), Some(SimTime(4_000)));
        assert_eq!(drain(&mut q), vec![(4_000, 1), (7_000, 2), (10_000, 0)]);
    }

    #[test]
    fn interleaved_pop_and_insert_keeps_fifo() {
        let mut q = Q::new();
        let mut seq = 0u64;
        let mut sched = |q: &mut Q, at: u64, seq: &mut u64| {
            q.insert(SimTime(at), *seq, ());
            *seq += 1;
        };
        sched(&mut q, 50, &mut seq);
        sched(&mut q, 50, &mut seq);
        let (at, s, _) = q.pop().unwrap();
        assert_eq!((at.micros(), s), (50, 0));
        // "Immediate" events at the popped timestamp go behind seq 1.
        sched(&mut q, 50, &mut seq);
        sched(&mut q, 51, &mut seq);
        assert_eq!(drain(&mut q), vec![(50, 1), (50, 2), (51, 3)]);
    }

    #[test]
    fn heap_reference_agrees_on_a_fixed_script() {
        let mut wheel: TimingWheel<()> = TimingWheel::new();
        let mut heap: BinaryHeapQueue<()> = BinaryHeapQueue::new();
        let script: &[(u64, u64)] = &[
            (9, 0),
            (1, 1),
            (1 << 20, 2),
            (1 << 37, 3),
            (9, 4),
            (300, 5),
        ];
        for &(at, seq) in script {
            wheel.insert(SimTime(at), seq, ());
            heap.insert(SimTime(at), seq, ());
        }
        wheel.cancel(5);
        heap.cancel(5);
        assert_eq!(drain(&mut wheel), drain(&mut heap));
    }
}
