//! `simlint` — the determinism & invariant static-analysis pass (`repro lint`).
//!
//! Every headline number in this repo rests on byte-identical replay digests
//! (shard × parallel invariance, pinned legacy prefixes). The rules that keep
//! those digests stable used to live in reviewers' heads; this module turns
//! them into a dependency-free analyzer that scans the crate's own sources on
//! every build: a hand-rolled lexer ([`lexer`]) feeds token-sequence rules
//! ([`rules`], D001–D007), findings carry file:line + rule + fix hint, and
//! suppression is explicit and audited via
//! `// simlint: allow(D00x, reason)` comments (same line or the line above
//! the finding; a missing reason is itself a finding, S001, and an allow that
//! matches nothing is flagged stale, S002).
//!
//! The static rules are paired with `debug_assertions`-gated dynamic
//! invariants in `platform/` (memory accounting never negative, queue
//! seniority monotone, container incarnation monotone) so the two layers
//! cover each other: the lint catches nondeterminism sources the asserts
//! can't see, the asserts catch logic drift the lexer can't prove.

pub mod lexer;
pub mod rules;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One lint finding: where, which rule, what, and how to fix it.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Source path relative to the lint root, `/`-separated.
    pub path: String,
    /// 1-based line.
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
    pub hint: &'static str,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {} {}\n    fix: {}",
            self.path, self.line, self.rule, self.message, self.hint
        )
    }
}

// ---- suppression directives ----------------------------------------------

#[derive(Debug)]
struct Directive {
    line: u32,
    rules: Vec<String>,
    used: bool,
}

/// Parse `simlint:` directives out of a file's comments. A directive must
/// *lead* the comment (after doc markers), so prose that merely mentions
/// the tool — like this module's own docs — is not parsed. Malformed ones
/// (no rule ids, or an empty reason) become S001 findings directly.
fn parse_directives(
    path: &str,
    comments: &[lexer::Comment],
    skipped: &[(u32, u32)],
) -> (Vec<Directive>, Vec<Finding>) {
    let mut dirs = Vec::new();
    let mut bad = Vec::new();
    for c in comments {
        if skipped.iter().any(|&(a, b)| c.line >= a && c.line <= b) {
            continue; // test code is not linted; its directives are inert
        }
        let content = c.text.trim_start_matches(['/', '!', ' ', '\t']);
        if !content.starts_with("simlint") {
            continue;
        }
        match parse_allow(content) {
            Some((rules, reason)) if !rules.is_empty() && !reason.is_empty() => {
                dirs.push(Directive {
                    line: c.line,
                    rules,
                    used: false,
                });
            }
            _ => bad.push(Finding {
                path: path.to_string(),
                line: c.line,
                rule: "S001",
                message: format!("malformed simlint directive: `{}`", c.text.trim()),
                hint: rules::rule("S001").hint,
            }),
        }
    }
    (dirs, bad)
}

/// Parse `simlint: allow(D001 D002, reason...)` starting at the `simlint`
/// keyword. Returns (rule ids, reason) or None when the shape is wrong.
fn parse_allow(text: &str) -> Option<(Vec<String>, String)> {
    let rest = text.strip_prefix("simlint")?.trim_start();
    let rest = rest.strip_prefix(':')?.trim_start();
    let rest = rest.strip_prefix("allow")?.trim_start();
    let rest = rest.strip_prefix('(')?;
    let body = &rest[..rest.rfind(')')?];
    // Leading comma/space-separated rule ids, then the reason.
    let mut rules = Vec::new();
    let mut reason = String::new();
    for (i, part) in body.split(',').enumerate() {
        let p = part.trim();
        if reason.is_empty() && p.split_whitespace().all(is_rule_id) && !p.is_empty() {
            rules.extend(p.split_whitespace().map(str::to_string));
        } else {
            if i == 0 {
                return None; // first segment must be rule ids
            }
            if !reason.is_empty() {
                reason.push(',');
            }
            reason.push_str(p);
        }
    }
    Some((rules, reason.trim().to_string()))
}

fn is_rule_id(s: &str) -> bool {
    s.len() == 4
        && (s.starts_with('D') || s.starts_with('S'))
        && s[1..].chars().all(|c| c.is_ascii_digit())
}

// ---- the engine -----------------------------------------------------------

/// Lint one source file. `path` is the root-relative, `/`-separated path the
/// scoping rules key on. Returns findings sorted by (line, rule).
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let lexed = lexer::lex(src);
    let (toks, skipped) = lexer::strip_cfg_test(&lexed.toks);
    let (mut dirs, mut out) = parse_directives(path, &lexed.comments, &skipped);

    for f in rules::scan(path, &toks) {
        // A directive on the finding's line, or the line directly above it,
        // naming the finding's rule, suppresses it (and is marked used).
        let mut suppressed = false;
        for d in dirs.iter_mut() {
            if (d.line == f.line || d.line + 1 == f.line) && d.rules.iter().any(|r| r == f.rule) {
                d.used = true;
                suppressed = true;
            }
        }
        if !suppressed {
            out.push(f);
        }
    }

    for d in &dirs {
        if !d.used {
            out.push(Finding {
                path: path.to_string(),
                line: d.line,
                rule: "S002",
                message: format!("suppression allow({}) matched no finding", d.rules.join(" ")),
                hint: rules::rule("S002").hint,
            });
        }
    }

    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Lint every `.rs` file under `root` (recursively, in sorted path order).
/// Returns findings sorted by (path, line, rule) plus the file count.
pub fn lint_tree(root: &Path) -> io::Result<(Vec<Finding>, usize)> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    let mut rels: Vec<(String, PathBuf)> = files
        .into_iter()
        .map(|p| {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            (rel, p)
        })
        .collect();
    rels.sort();

    let mut out = Vec::new();
    let count = rels.len();
    for (rel, full) in rels {
        let src = fs::read_to_string(&full)?;
        out.extend(lint_source(&rel, &src));
    }
    out.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok((out, count))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .collect::<Result<Vec<_>, _>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_allow_single_rule() {
        let (rules, reason) = parse_allow("simlint: allow(D001, legacy digest is pinned)").unwrap();
        assert_eq!(rules, vec!["D001"]);
        assert_eq!(reason, "legacy digest is pinned");
    }

    #[test]
    fn parse_allow_multiple_rules_and_commas_in_reason() {
        let (rules, reason) =
            parse_allow("simlint: allow(D003 D005, rounded, then clamped)").unwrap();
        assert_eq!(rules, vec!["D003", "D005"]);
        assert_eq!(reason, "rounded, then clamped");
    }

    #[test]
    fn parse_allow_rejects_missing_reason_or_rules() {
        assert_eq!(parse_allow("simlint: allow(D001)").unwrap().1, "");
        assert!(parse_allow("simlint: allow(, because)").is_none());
        assert!(parse_allow("simlint: D001 please").is_none());
    }

    #[test]
    fn suppression_same_line_and_next_line() {
        let src = "\
use std::collections::HashMap; // simlint: allow(D001, exercised below)
// simlint: allow(D001, wrapper type, never iterated)
fn f() -> HashMap<u32, u32> {
    HashMap::new()
}";
        let out = lint_source("platform/x.rs", src);
        // Line 1 and line 3 are suppressed; line 4's HashMap::new is not.
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].rule, "D001");
        assert_eq!(out[0].line, 4);
    }

    #[test]
    fn missing_reason_is_s001() {
        let src = "use std::collections::HashMap; // simlint: allow(D001)";
        let out = lint_source("platform/x.rs", src);
        assert!(out.iter().any(|f| f.rule == "S001"));
        assert!(out.iter().any(|f| f.rule == "D001"), "unparsed allow must not suppress");
    }

    #[test]
    fn unused_suppression_is_s002() {
        let src = "// simlint: allow(D002, no clock here after refactor)\nfn f() {}";
        let out = lint_source("platform/x.rs", src);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "S002");
        assert_eq!(out[0].line, 1);
    }

    #[test]
    fn prose_mentions_are_not_directives() {
        // Doc comments talking *about* simlint (like this module's header)
        // must not parse as directives or raise S001.
        let src = "\
//! The `simlint` analyzer and its allow(...) form are documented here.
// write `// simlint: allow(D00x, reason)` to suppress
fn f() {}";
        assert!(lint_source("platform/x.rs", src).is_empty());
    }

    #[test]
    fn directives_inside_cfg_test_are_inert() {
        let src = "\
#[cfg(test)]
mod tests {
    // simlint: allow(D001, never fires, test code is unlinted)
    fn t() {}
}";
        assert!(lint_source("platform/x.rs", src).is_empty());
    }

    #[test]
    fn findings_render_with_hint() {
        let out = lint_source("metrics/x.rs", "fn f(x: u64) -> u32 { x as u32 }");
        let s = out[0].to_string();
        assert!(s.contains("metrics/x.rs:1: D005"));
        assert!(s.contains("fix:"));
    }
}
