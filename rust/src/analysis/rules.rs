//! The `simlint` determinism rules (D001–D007).
//!
//! Each rule is a token-sequence check scoped to the path prefixes where the
//! determinism contract applies. Paths are relative to the source root and
//! `/`-separated (`platform/world.rs`). `#[cfg(test)]` items are stripped
//! before rules run — test code may use wall clocks, ad-hoc seeds, and std
//! maps freely.
//!
//! The engine-hygiene findings S001 (malformed suppression) and S002 (unused
//! suppression) live in `mod.rs` with the suppression machinery.

use super::lexer::{Tok, TokKind};
use super::Finding;

pub struct RuleInfo {
    pub id: &'static str,
    pub summary: &'static str,
    pub hint: &'static str,
}

/// The shipped rule catalog, in id order (rendered by `repro lint --rules`
/// and the README).
pub const CATALOG: &[RuleInfo] = &[
    RuleInfo {
        id: "D001",
        summary: "std HashMap/HashSet in a sim/metrics/digest path: iteration order is \
                  per-instance random, so any drain that feeds output breaks replay digests",
        hint: "use util::fxhash::FxHashMap/FxHashSet (deterministic fixed-seed order) and \
               sort before draining into output, or a BTreeMap",
    },
    RuleInfo {
        id: "D002",
        summary: "wall-clock read (Instant::now/SystemTime) outside the serve/runtime/testkit \
                  allowlist: simulated components must take time from the Sim clock",
        hint: "thread SimTime through the call, or move the timing into serve/ or testkit/",
    },
    RuleInfo {
        id: "D003",
        summary: "float field in a mergeable-metrics struct: the digest contract requires \
                  shard-merged metrics to be integer-only so merges commute exactly",
        hint: "store integer units (us, bytes, counts) and convert to float at report time",
    },
    RuleInfo {
        id: "D004",
        summary: "Rng::new with a hard-coded literal seed in a sim path: derived streams must \
                  come from the config seed via util::rng::mix64 or Rng::fork",
        hint: "seed from Rng::new(mix64(run_seed, stable_id)) or fork an existing stream",
    },
    RuleInfo {
        id: "D005",
        summary: "unchecked `as` narrowing on a metric/counter value: silent truncation \
                  corrupts merged counters without failing any test",
        hint: "use try_from(..).expect(..) so overflow is loud, or widen the counter",
    },
    RuleInfo {
        id: "D006",
        summary: "cross-thread fan-out outside serve/testkit: results collected in completion \
                  order are nondeterministic; merges must be grid-index ordered",
        hint: "write each worker's result into a position-indexed slot (see \
               experiments::harness::SweepRunner) and reduce in index order",
    },
    RuleInfo {
        id: "D007",
        summary: "String-keyed FxHashMap/BTreeMap in a platform/simcore hot path: every \
                  lookup re-hashes the name bytes and every insert clones the key; hot \
                  per-event state must key on interned FnId (a u32)",
        hint: "intern the name once via platform::symbols::Symbols and key the map on FnId; \
               String keys belong only at deploy/ingest/CLI boundaries",
    },
    RuleInfo {
        id: "S001",
        summary: "malformed simlint directive: allow(...) needs rule ids and a non-empty reason",
        hint: "write `// simlint: allow(D00x, reason)` — the reason is the audit trail",
    },
    RuleInfo {
        id: "S002",
        summary: "unused simlint suppression: the allow(...) matched no finding on its line \
                  or the next",
        hint: "delete the stale directive, or move it onto the line it is meant to cover",
    },
];

pub fn rule(id: &str) -> &'static RuleInfo {
    CATALOG
        .iter()
        .find(|r| r.id == id)
        .expect("unknown rule id")
}

// ---- path scoping ---------------------------------------------------------

/// Paths where map-iteration order can reach simulator state, metrics, or
/// digests. `util/` (the FxHashMap wrapper itself), `cli/`, `serve/`,
/// `runtime/`, `nn/`, `analysis/`, and `testkit/` are exempt. `obs/` is
/// deliberately IN scope: span streams and telemetry windows carry their
/// own digests, so tracing must obey the same determinism contract as the
/// metrics it observes.
const SIM_PATHS: &[&str] = &[
    "platform/", "metrics/", "simcore/", "workload/", "predict/", "freshen/", "netsim/",
    "billing/", "experiments/", "triggers/", "obs/",
];

/// Paths allowed to read the wall clock: the real-time serving engine, the
/// real-time inference runtime, and the bench harness.
const WALL_CLOCK_ALLOW: &[&str] = &["serve/", "runtime/", "testkit/"];

/// Paths whose structs feed the shard-merged, digest-pinned reports.
/// `obs/` windows and span sinks merge across shards exactly like
/// `MacroMetrics`, so they must stay integer-only too.
const MERGED_METRICS_PATHS: &[&str] = &["metrics/", "workload/macrotrace/", "obs/"];

/// Paths where `as` narrowing lands on counters that reach merged metrics.
const COUNTER_PATHS: &[&str] = &["metrics/", "workload/", "billing/"];

/// Paths exempt from the cross-thread heuristic: serve/ is genuinely
/// real-time, testkit/ hosts the bench/property harnesses.
const THREAD_EXEMPT: &[&str] = &["serve/", "testkit/"];

/// Paths where per-event lookups must key on interned [`FnId`]s rather
/// than name strings (the executor/scheduler hot path).
const HOT_KEY_PATHS: &[&str] = &["platform/", "simcore/"];

/// Hot-path files that are deploy/ingest boundaries: their maps key on
/// externally-supplied ids (object ids, endpoint registrations) that
/// arrive as strings by contract and are not per-event state.
const HOT_KEY_ALLOW: &[&str] = &["platform/datastore.rs", "platform/endpoint.rs"];

fn in_any(path: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| path.starts_with(p))
}

// ---- matching helpers -----------------------------------------------------

fn seq(toks: &[Tok], i: usize, pat: &[&str]) -> bool {
    toks.len() >= i + pat.len() && pat.iter().enumerate().all(|(k, p)| toks[i + k].text == *p)
}

fn finding(path: &str, line: u32, id: &'static str, message: String) -> Finding {
    Finding {
        path: path.to_string(),
        line,
        rule: id,
        message,
        hint: rule(id).hint,
    }
}

// ---- the rules ------------------------------------------------------------

/// Run every determinism rule over one file's (cfg(test)-stripped) tokens.
pub fn scan(path: &str, toks: &[Tok]) -> Vec<Finding> {
    let mut out = Vec::new();
    d001_std_maps(path, toks, &mut out);
    d002_wall_clock(path, toks, &mut out);
    d003_float_metrics(path, toks, &mut out);
    d004_literal_seed(path, toks, &mut out);
    d005_as_narrowing(path, toks, &mut out);
    d006_thread_fanout(path, toks, &mut out);
    d007_string_keyed_hot_maps(path, toks, &mut out);
    out
}

fn d001_std_maps(path: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if !in_any(path, SIM_PATHS) {
        return;
    }
    for t in toks {
        if t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
            out.push(finding(
                path,
                t.line,
                "D001",
                format!("std::collections::{} in a determinism-sensitive path", t.text),
            ));
        }
    }
}

fn d002_wall_clock(path: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if in_any(path, WALL_CLOCK_ALLOW) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident {
            continue;
        }
        if t.text == "SystemTime" {
            out.push(finding(
                path,
                t.line,
                "D002",
                "SystemTime outside the wall-clock allowlist".to_string(),
            ));
        } else if seq(toks, i, &["Instant", ":", ":", "now"]) {
            out.push(finding(
                path,
                t.line,
                "D002",
                "Instant::now() outside the wall-clock allowlist".to_string(),
            ));
        }
    }
}

fn d003_float_metrics(path: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if !in_any(path, MERGED_METRICS_PATHS) {
        return;
    }
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].kind == TokKind::Ident && toks[i].text == "struct" && i + 1 < toks.len() {
            let name = &toks[i + 1].text;
            let mergeable =
                name.contains("Metrics") || name.contains("Snap") || name.contains("Hist");
            // Find the struct body (skip a possible generics list).
            let mut j = i + 2;
            while j < toks.len() && toks[j].text != "{" && toks[j].text != ";" && toks[j].text != "(" {
                j += 1;
            }
            if mergeable && j < toks.len() && toks[j].text == "{" {
                let mut depth = 1usize;
                let mut k = j + 1;
                while k < toks.len() && depth > 0 {
                    match toks[k].text.as_str() {
                        "{" => depth += 1,
                        "}" => depth -= 1,
                        "f64" | "f32" if toks[k].kind == TokKind::Ident => {
                            out.push(finding(
                                path,
                                toks[k].line,
                                "D003",
                                format!("{} field in mergeable-metrics struct `{name}`", toks[k].text),
                            ));
                        }
                        _ => {}
                    }
                    k += 1;
                }
                i = k;
                continue;
            }
        }
        i += 1;
    }
}

fn d004_literal_seed(path: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if !in_any(path, SIM_PATHS) && !path.starts_with("nn/") {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if t.text == "Rng" && seq(toks, i, &["Rng", ":", ":", "new", "("]) {
            if let (Some(arg), Some(close)) = (toks.get(i + 5), toks.get(i + 6)) {
                let literal = arg.kind == TokKind::Literal
                    && arg.text.starts_with(|c: char| c.is_ascii_digit());
                if literal && close.text == ")" {
                    out.push(finding(
                        path,
                        t.line,
                        "D004",
                        format!("Rng::new({}) hard-codes a seed, bypassing mix64/fork", arg.text),
                    ));
                }
            }
        }
    }
}

fn d005_as_narrowing(path: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if !in_any(path, COUNTER_PATHS) {
        return;
    }
    const NARROW: &[&str] = &["u8", "u16", "u32", "i8", "i16", "i32"];
    for (i, t) in toks.iter().enumerate() {
        if t.kind == TokKind::Ident && t.text == "as" {
            if let Some(ty) = toks.get(i + 1) {
                if ty.kind == TokKind::Ident && NARROW.contains(&ty.text.as_str()) {
                    out.push(finding(
                        path,
                        t.line,
                        "D005",
                        format!("unchecked `as {}` narrowing", ty.text),
                    ));
                }
            }
        }
    }
}

fn d006_thread_fanout(path: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if in_any(path, THREAD_EXEMPT) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if t.text == "thread"
            && (seq(toks, i, &["thread", ":", ":", "spawn"])
                || seq(toks, i, &["thread", ":", ":", "scope"])
                || seq(toks, i, &["thread", ":", ":", "Builder"]))
        {
            out.push(finding(
                path,
                t.line,
                "D006",
                format!("cross-thread fan-out (thread::{})", toks[i + 3].text),
            ));
        }
    }
}

fn d007_string_keyed_hot_maps(path: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if !in_any(path, HOT_KEY_PATHS) || HOT_KEY_ALLOW.contains(&path) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if t.kind == TokKind::Ident
            && (t.text == "FxHashMap" || t.text == "BTreeMap")
            && seq(toks, i + 1, &["<", "String"])
        {
            out.push(finding(
                path,
                t.line,
                "D007",
                format!("{}<String, _> in an executor/scheduler hot path", t.text),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer;

    fn scan_src(path: &str, src: &str) -> Vec<Finding> {
        let lexed = lexer::lex(src);
        let (toks, _) = lexer::strip_cfg_test(&lexed.toks);
        scan(path, &toks)
    }

    #[test]
    fn d001_fires_in_scope_only() {
        let src = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, u32> = HashMap::new(); }";
        let hits = scan_src("platform/foo.rs", src);
        assert_eq!(hits.iter().filter(|f| f.rule == "D001").count(), 3);
        assert!(scan_src("cli/foo.rs", src).is_empty());
        assert!(scan_src("util/foo.rs", src).is_empty());
    }

    #[test]
    fn d001_ignores_fxhashmap() {
        let src = "use crate::util::fxhash::FxHashMap;\nfn f() { let m: FxHashMap<u32, u32> = FxHashMap::default(); }";
        assert!(scan_src("platform/foo.rs", src).is_empty());
    }

    #[test]
    fn d002_allowlist() {
        let src = "fn f() { let t = Instant::now(); let s = SystemTime::now(); }";
        let hits = scan_src("simcore/clock.rs", src);
        assert_eq!(hits.iter().filter(|f| f.rule == "D002").count(), 2);
        assert!(scan_src("serve/engine.rs", src).is_empty());
        assert!(scan_src("testkit/bench.rs", src).is_empty());
        // obs/ is sim-time-only: wall-clock reads there are findings.
        assert_eq!(
            scan_src("obs/span.rs", src).iter().filter(|f| f.rule == "D002").count(),
            2
        );
    }

    #[test]
    fn obs_is_inside_the_determinism_perimeter() {
        let maps = "use std::collections::HashMap;";
        assert_eq!(scan_src("obs/window.rs", maps).len(), 1);
        let floats = "struct WindowHist { count: u64, rate: f64 }";
        let hits = scan_src("obs/window.rs", floats);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "D003");
    }

    #[test]
    fn d003_only_mergeable_structs() {
        let src = "struct DayMetrics { cold: u64, rate: f64 }\nstruct Helper { x: f64 }";
        let hits = scan_src("workload/macrotrace/replay.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "D003");
        assert_eq!(hits[0].line, 1);
        assert!(scan_src("experiments/foo.rs", src).is_empty());
    }

    #[test]
    fn d004_literal_seed_only() {
        let bad = "fn f() { let r = Rng::new(42); }";
        let good = "fn f(seed: u64) { let r = Rng::new(seed); let q = Rng::new(mix64(seed, 3)); }";
        assert_eq!(scan_src("workload/gen.rs", bad).len(), 1);
        assert!(scan_src("workload/gen.rs", good).is_empty());
    }

    #[test]
    fn d005_narrowing() {
        let src = "fn f(x: u64) -> u32 { x as u32 }";
        let hits = scan_src("metrics/mod.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "D005");
        assert!(scan_src("simcore/wheel.rs", src).is_empty());
        // `as u64` widening is fine.
        assert!(scan_src("metrics/mod.rs", "fn f(x: u32) -> u64 { x as u64 }").is_empty());
    }

    #[test]
    fn d006_thread_heuristic() {
        let src = "fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }";
        let hits = scan_src("experiments/harness.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "D006");
        assert!(scan_src("serve/pool.rs", src).is_empty());
    }

    #[test]
    fn d007_string_keyed_hot_maps() {
        let bad = "struct S { queues: FxHashMap<String, VecDeque<u64>>, b: BTreeMap<String, u32> }";
        let hits = scan_src("platform/dispatch.rs", bad);
        assert_eq!(hits.iter().filter(|f| f.rule == "D007").count(), 2);
        // FnId-keyed and Rc<str>-interner maps are the sanctioned forms.
        let good = "struct S { queues: FxHashMap<FnId, VecDeque<u64>>, ids: FxHashMap<Rc<str>, FnId> }";
        assert!(scan_src("platform/dispatch.rs", good).is_empty());
        // Out of scope: boundary files and non-hot subsystems.
        assert!(scan_src("platform/datastore.rs", bad).is_empty());
        assert!(scan_src("predict/hist.rs", bad).is_empty());
        assert!(scan_src("cli/mod.rs", bad).is_empty());
    }

    #[test]
    fn cfg_test_items_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn t() { let r = Rng::new(7); }\n}";
        assert!(scan_src("platform/foo.rs", src).is_empty());
    }
}
