//! A minimal, dependency-free Rust lexer for `simlint` (`repro lint`).
//!
//! The workspace is offline — no `syn`, no `proc-macro2` — so the analyzer
//! tokenizes source by hand: identifiers, literals (including raw and byte
//! strings), lifetimes, and single-character punctuation (`::` arrives as two
//! `:` tokens). Comments are captured out-of-band — suppression directives
//! live there — and every token carries a 1-based line number. It does NOT
//! parse: the rule engine works on token sequences plus a little context
//! (struct bodies, `#[cfg(test)]` items), which is all the determinism rules
//! need.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Numeric, string, char, or byte literal (raw strings included).
    Literal,
    /// A single punctuation character.
    Punct,
    /// A lifetime such as `'a` — distinct from char literals.
    Lifetime,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A comment (line or block), keyed by its starting line. Block-comment text
/// keeps interior newlines; directive parsing only looks at the first line.
#[derive(Debug, Clone)]
pub struct Comment {
    pub text: String,
    pub line: u32,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let at = line;
            let start = i + 2;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            out.comments.push(Comment {
                text: cs[start.min(i)..i].iter().collect(),
                line: at,
            });
        } else if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let at = line;
            i += 2;
            let start = i;
            let mut depth = 1u32;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            let end = i.saturating_sub(2).max(start);
            out.comments.push(Comment {
                text: cs[start..end].iter().collect(),
                line: at,
            });
        } else if c == '"' {
            let at = line;
            let text = lex_string(&cs, &mut i, &mut line);
            out.toks.push(Tok {
                kind: TokKind::Literal,
                text,
                line: at,
            });
        } else if c == '\'' {
            // Lifetime (`'a`) vs char literal (`'a'`, `'\n'`).
            let next_is_ident = i + 1 < n && is_ident_start(cs[i + 1]);
            let closes = i + 2 < n && cs[i + 2] == '\'';
            if next_is_ident && !closes {
                let at = line;
                let start = i;
                i += 2;
                while i < n && is_ident_continue(cs[i]) {
                    i += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: cs[start..i].iter().collect(),
                    line: at,
                });
            } else {
                let at = line;
                let start = i;
                i += 1;
                while i < n {
                    if cs[i] == '\\' {
                        i += 2;
                    } else if cs[i] == '\'' {
                        i += 1;
                        break;
                    } else {
                        i += 1;
                    }
                }
                out.toks.push(Tok {
                    kind: TokKind::Literal,
                    text: cs[start..i.min(n)].iter().collect(),
                    line: at,
                });
            }
        } else if (c == 'r' || c == 'b') && lex_prefixed_literal(&cs, &mut i, &mut line, &mut out) {
            // raw / byte string consumed by the helper
        } else if is_ident_start(c) {
            let at = line;
            let start = i;
            while i < n && is_ident_continue(cs[i]) {
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: cs[start..i].iter().collect(),
                line: at,
            });
        } else if c.is_ascii_digit() {
            let at = line;
            let start = i;
            let mut seen_dot = false;
            while i < n {
                let d = cs[i];
                if is_ident_continue(d) {
                    i += 1;
                } else if d == '.'
                    && !seen_dot
                    && i + 1 < n
                    && cs[i + 1].is_ascii_digit()
                {
                    seen_dot = true;
                    i += 1;
                } else {
                    break;
                }
            }
            out.toks.push(Tok {
                kind: TokKind::Literal,
                text: cs[start..i].iter().collect(),
                line: at,
            });
        } else {
            out.toks.push(Tok {
                kind: TokKind::Punct,
                text: c.to_string(),
                line,
            });
            i += 1;
        }
    }
    out
}

/// Lex a `"..."` string starting at `*i` (which must point at the opening
/// quote). Returns the full text including quotes; tracks newlines.
fn lex_string(cs: &[char], i: &mut usize, line: &mut u32) -> String {
    let n = cs.len();
    let start = *i;
    *i += 1;
    while *i < n {
        match cs[*i] {
            '\\' => *i += 2,
            '\n' => {
                *line += 1;
                *i += 1;
            }
            '"' => {
                *i += 1;
                break;
            }
            _ => *i += 1,
        }
    }
    cs[start..(*i).min(n)].iter().collect()
}

/// Try to lex a raw string (`r"…"`, `r#"…"#`), byte string (`b"…"`,
/// `br#"…"#`), or byte char (`b'…'`) starting at `*i`. Returns true (and
/// pushes a Literal) when one was consumed; false leaves `*i` untouched so
/// the caller lexes a plain identifier.
fn lex_prefixed_literal(cs: &[char], i: &mut usize, line: &mut u32, out: &mut Lexed) -> bool {
    let n = cs.len();
    let start = *i;
    let mut j = *i;
    let mut raw = false;
    if cs[j] == 'b' {
        j += 1;
        if j < n && cs[j] == 'r' {
            raw = true;
            j += 1;
        }
    } else {
        // cs[j] == 'r'
        raw = true;
        j += 1;
    }

    if raw {
        let mut hashes = 0usize;
        while j < n && cs[j] == '#' {
            hashes += 1;
            j += 1;
        }
        if j >= n || cs[j] != '"' {
            return false; // e.g. `r#ident` or a plain ident like `rng`
        }
        let at = *line;
        j += 1;
        // Scan for `"` followed by `hashes` hash marks.
        while j < n {
            if cs[j] == '\n' {
                *line += 1;
                j += 1;
            } else if cs[j] == '"' && cs[j + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes {
                j += 1 + hashes;
                break;
            } else {
                j += 1;
            }
        }
        out.toks.push(Tok {
            kind: TokKind::Literal,
            text: cs[start..j.min(n)].iter().collect(),
            line: at,
        });
        *i = j;
        true
    } else if j < n && cs[j] == '"' {
        // b"..." — escapes apply.
        let at = *line;
        *i = j;
        let body = lex_string(cs, i, line);
        out.toks.push(Tok {
            kind: TokKind::Literal,
            text: format!("b{body}"),
            line: at,
        });
        true
    } else if j < n && cs[j] == '\'' {
        // b'x' byte char.
        let at = *line;
        j += 1;
        while j < n {
            if cs[j] == '\\' {
                j += 2;
            } else if cs[j] == '\'' {
                j += 1;
                break;
            } else {
                j += 1;
            }
        }
        out.toks.push(Tok {
            kind: TokKind::Literal,
            text: cs[start..j.min(n)].iter().collect(),
            line: at,
        });
        *i = j;
        true
    } else {
        false
    }
}

/// Remove every item annotated `#[cfg(test)]` from the token stream (test
/// mods, test-only fns/structs). Returns the surviving tokens plus the
/// skipped (start, end) line spans so comment handling can ignore
/// suppressions inside test code.
pub fn strip_cfg_test(toks: &[Tok]) -> (Vec<Tok>, Vec<(u32, u32)>) {
    let mut keep: Vec<Tok> = Vec::with_capacity(toks.len());
    let mut spans: Vec<(u32, u32)> = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if is_cfg_test_at(toks, i) {
            let first_line = toks[i].line;
            let mut j = i + 7; // past `# [ cfg ( test ) ]`
            // Skip any further attribute groups (`#[allow(...)]`, ...).
            while j + 1 < toks.len() && toks[j].text == "#" && toks[j + 1].text == "[" {
                let mut depth = 0usize;
                j += 1;
                while j < toks.len() {
                    match toks[j].text.as_str() {
                        "[" => depth += 1,
                        "]" => {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            }
            // Skip the annotated item: everything up to the first `;` at
            // nesting depth 0, or through the matching `}` of its first block.
            let mut braces = 0usize;
            let mut nest = 0usize; // parens + brackets, e.g. the `;` in `[u8; 4]`
            while j < toks.len() {
                match toks[j].text.as_str() {
                    "{" => braces += 1,
                    "}" => {
                        braces = braces.saturating_sub(1);
                        if braces == 0 {
                            j += 1;
                            break;
                        }
                    }
                    "(" | "[" => nest += 1,
                    ")" | "]" => nest = nest.saturating_sub(1),
                    ";" if braces == 0 && nest == 0 => {
                        j += 1;
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
            let last_line = toks[j.saturating_sub(1).min(toks.len() - 1)].line;
            spans.push((first_line, last_line));
            i = j;
        } else {
            keep.push(toks[i].clone());
            i += 1;
        }
    }
    (keep, spans)
}

fn is_cfg_test_at(toks: &[Tok], i: usize) -> bool {
    const PAT: [&str; 7] = ["#", "[", "cfg", "(", "test", ")", "]"];
    toks.len() >= i + PAT.len() && PAT.iter().enumerate().all(|(k, p)| toks[i + k].text == *p)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_puncts_lines() {
        let l = lex("let x = a::b;\nfoo()");
        let t: Vec<(&str, u32)> = l.toks.iter().map(|t| (t.text.as_str(), t.line)).collect();
        assert_eq!(
            t,
            vec![
                ("let", 1),
                ("x", 1),
                ("=", 1),
                ("a", 1),
                (":", 1),
                (":", 1),
                ("b", 1),
                (";", 1),
                ("foo", 2),
                ("(", 2),
                (")", 2),
            ]
        );
    }

    #[test]
    fn comments_are_captured_not_tokenized() {
        let l = lex("a // HashMap here\nb /* Instant::now */ c");
        let toks: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(toks, vec!["a", "b", "c"]);
        assert_eq!(l.comments.len(), 2);
        assert_eq!(l.comments[0].line, 1);
        assert!(l.comments[0].text.contains("HashMap"));
        assert_eq!(l.comments[1].line, 2);
    }

    #[test]
    fn strings_hide_contents() {
        let t = texts(r#"f("HashMap::new()", r"SystemTime", b"x")"#);
        assert!(!t.iter().any(|s| s == "HashMap" || s == "SystemTime"));
    }

    #[test]
    fn raw_string_with_hashes_and_newlines() {
        let l = lex("let s = r#\"a \"quoted\" b\nsecond\"#;\nnext");
        let last = l.toks.last().unwrap();
        assert_eq!(last.text, "next");
        assert_eq!(last.line, 3);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) { match c { 'x' => 1, '\\n' => 2, '0'..='9' => 3 } }");
        let lifetimes: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a"]);
        let chars: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Literal && t.text.starts_with('\''))
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(chars, vec!["'x'", "'\\n'", "'0'", "'9'"]);
    }

    #[test]
    fn nested_block_comments() {
        let t = texts("a /* outer /* inner */ still comment */ b");
        assert_eq!(t, vec!["a", "b"]);
    }

    #[test]
    fn numbers_lex_as_single_literals() {
        let t = texts("1.0e-3 0x7ACE 2f64 1_000 0..3");
        assert_eq!(t, vec!["1.0e", "-", "3", "0x7ACE", "2f64", "1_000", "0", ".", ".", "3"]);
    }

    #[test]
    fn strip_cfg_test_removes_mod_and_reports_span() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    use super::*;\n    #[test]\n    fn t() { HashMap::new(); }\n}\nfn after() {}";
        let l = lex(src);
        let (kept, spans) = strip_cfg_test(&l.toks);
        let names: Vec<&str> = kept.iter().map(|t| t.text.as_str()).collect();
        assert!(names.contains(&"live"));
        assert!(names.contains(&"after"));
        assert!(!names.contains(&"HashMap"));
        assert_eq!(spans, vec![(2, 7)]);
    }

    #[test]
    fn strip_cfg_test_handles_semicolon_items() {
        let src = "#[cfg(test)]\nmod tests;\nfn live() {}";
        let l = lex(src);
        let (kept, _) = strip_cfg_test(&l.toks);
        let names: Vec<&str> = kept.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(names, vec!["fn", "live", "(", ")", "{", "}"]);
    }
}
