//! Table 1 — trigger-service overheads, measured *through the platform*.
//!
//! Methodology mirrors the paper's (via Sequoia [12]): timestamps are taken
//! just before the trigger commits and at the start of the triggered
//! function, over 20 k runs per service, with cold starts carefully
//! avoided (the target container is pre-warmed). The measured delay is the
//! trigger service's delivery latency plus the platform's warm dispatch.
//!
//! Multi-seed: [`run_multi`] fans the `services × seeds` grid over a
//! [`SweepRunner`]; per-service raw delay samples pool in seed order
//! before the median/p95 are taken, so merged rows are deterministic for
//! any `--parallel`.

use crate::experiments::harness::SweepRunner;
use crate::experiments::{fmt_secs, print_table};
use crate::netsim::link::Site;
use crate::platform::endpoint::Endpoint;
use crate::platform::exec::invoke;
use crate::platform::function::{FunctionSpec, Op};
use crate::platform::world::{PlatformSim, World};
use crate::simcore::Sim;
use crate::triggers::TriggerService;
use crate::util::config::Config;
use crate::util::stats::median;
use crate::util::time::SimDuration;

/// One row of the regenerated table.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub service: TriggerService,
    pub median_s: f64,
    pub p95_s: f64,
    pub paper_s: f64,
    pub runs: usize,
}

#[derive(Debug, Clone)]
pub struct Table1 {
    pub rows: Vec<Table1Row>,
}

/// Measure one service: `runs` raw trigger->start delays (seconds)
/// through the DES — one `(service, seed)` grid point.
fn measure_samples(service: TriggerService, runs: usize, seed: u64) -> Vec<f64> {
    let mut cfg = Config::default();
    cfg.seed = seed;
    cfg.warm_start = SimDuration::from_millis(1); // dispatch cost within
                                                  // the measured window
    cfg.freshen.enabled = false; // isolate the trigger path
    let mut world = World::new(cfg);
    world.add_endpoint(Endpoint::new("store", Site::Local));
    // Triggered function: trivial body so start time is what we measure.
    world.deploy(FunctionSpec::new(
        "target",
        "bench",
        vec![Op::Compute {
            duration: SimDuration::from_micros(100),
        }],
    ));

    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 50_000_000;
    // Pre-warm the container (cold starts carefully avoided).
    invoke(&mut sim, &mut world, "target");
    sim.run(&mut world);

    // Fire `runs` triggers, far enough apart that runs never overlap.
    let mut commit_times = Vec::with_capacity(runs);
    let mut t = sim.now() + SimDuration::from_secs(1);
    for _ in 0..runs {
        let delay = service.sample_delay(&mut world.rng);
        commit_times.push(t);
        sim.schedule_at(t + delay, move |sim, w| {
            invoke(sim, w, "target");
        });
        t += SimDuration::from_secs(10); // well past any delivery tail
    }
    sim.run(&mut world);

    // Delay = function start - trigger commit (skip the warmup record).
    let samples: Vec<f64> = world
        .metrics
        .records()
        .iter()
        .skip(1)
        .zip(commit_times.iter())
        .map(|(r, commit)| r.started_at.since(*commit).as_secs_f64())
        .collect();
    assert_eq!(samples.len(), runs);
    samples
}

/// Single-seed convenience over [`run_multi`].
pub fn run(runs_per_service: usize, seed: u64) -> Table1 {
    run_multi(runs_per_service, &[seed], &SweepRunner::new(1))
}

/// Multi-seed sweep: the `services × seeds` grid runs on `runner`;
/// per-service delay samples pool in seed order before summarising.
pub fn run_multi(runs_per_service: usize, seeds: &[u64], runner: &SweepRunner) -> Table1 {
    assert!(!seeds.is_empty(), "table1 needs at least one seed");
    let services: Vec<(usize, TriggerService)> = TriggerService::all()
        .iter()
        .copied()
        .enumerate()
        .collect();
    let rows = runner
        .run_grid(&services, seeds, |&(i, svc), seed| {
            measure_samples(svc, runs_per_service, seed ^ (i as u64) << 8)
        })
        .into_iter()
        .zip(services.iter())
        .map(|(per_seed, &(_, service))| {
            let mut samples = Vec::new();
            for s in per_seed {
                samples.extend(s);
            }
            let mut sorted = samples.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            Table1Row {
                service,
                median_s: median(&samples),
                p95_s: crate::util::stats::percentile_sorted(&sorted, 95.0),
                paper_s: service.paper_median(),
                runs: samples.len(),
            }
        })
        .collect();
    Table1 { rows }
}

impl Table1 {
    pub fn print(&self) {
        println!(
            "\n== Table 1: trigger overhead ({} runs/service) ==",
            self.rows.first().map(|r| r.runs).unwrap_or(0)
        );
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.service.as_str().to_string(),
                    fmt_secs(r.median_s),
                    fmt_secs(r.p95_s),
                    fmt_secs(r.paper_s),
                ]
            })
            .collect();
        print_table(
            &["Trigger Service", "median", "p95", "paper median"],
            &rows,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn medians_track_paper_within_dispatch_overhead() {
        // Smaller run count for test speed; medians are stable.
        let t = run(2_000, 0xAB1E);
        for row in &t.rows {
            // Measured = trigger delay + ~1ms dispatch; within 10% + 2ms.
            let tol = row.paper_s * 0.10 + 0.002;
            assert!(
                (row.median_s - row.paper_s).abs() < tol,
                "{}: measured {} vs paper {}",
                row.service.as_str(),
                row.median_s,
                row.paper_s
            );
            assert!(row.p95_s > row.median_s);
        }
        // Ordering: Direct < StepFunctions < SNS < S3.
        let by: std::collections::HashMap<&str, f64> = t
            .rows
            .iter()
            .map(|r| (r.service.as_str(), r.median_s))
            .collect();
        assert!(by["Direct (Boto3)"] < by["Step Functions"]);
        assert!(by["Step Functions"] < by["SNS Pub/Sub"]);
        assert!(by["SNS Pub/Sub"] < by["S3 bucket"]);
    }

    #[test]
    fn multi_seed_sweep_is_identical_across_parallelism() {
        let seeds = [7u64, 8];
        let seq = run_multi(200, &seeds, &crate::experiments::SweepRunner::new(1));
        let par = run_multi(200, &seeds, &crate::experiments::SweepRunner::new(4));
        assert_eq!(format!("{seq:?}"), format!("{par:?}"));
        // Pooled rows carry every seed's samples.
        assert!(seq.rows.iter().all(|r| r.runs == 400));
    }

    #[test]
    fn single_seed_multi_matches_legacy_entry_point() {
        let legacy = run(150, 0xAB);
        let multi = run_multi(150, &[0xAB], &crate::experiments::SweepRunner::new(2));
        assert_eq!(format!("{legacy:?}"), format!("{multi:?}"));
    }
}
