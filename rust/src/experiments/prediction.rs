//! Prediction-quality quantification (§6: "Prediction success must be
//! additionally quantified, especially in the case of non-deterministic
//! function chains").
//!
//! Synthetic ground-truth workloads with known structure drive each
//! predictor; we score precision (admitted predictions that were followed
//! by the invocation inside the match window) and recall (actual arrivals
//! that had been predicted), plus the mean lead time — the window freshen
//! actually gets.

use crate::experiments::print_table;
use crate::predict::chain::ChainPredictor;
use crate::predict::confidence::{PredictionTracker, DEFAULT_MATCH_WINDOW};
use crate::predict::histogram::HistogramPredictor;
use crate::predict::learned::{combined_confidence, LearnedScorer};
use crate::triggers::TriggerService;
use crate::util::rng::Rng;
use crate::util::time::{SimDuration, SimTime};
use crate::workload::generator::ArrivalProcess;

/// Which predictor is being scored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Predictor {
    Chain,
    Histogram,
    Learned,
}

impl Predictor {
    pub fn as_str(&self) -> &'static str {
        match self {
            Predictor::Chain => "chain",
            Predictor::Histogram => "histogram",
            Predictor::Learned => "learned(combined)",
        }
    }
}

/// Workload regime the predictor is scored on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// Deterministic linear chain (orchestrated).
    LinearChain,
    /// Non-deterministic 70/30 branch.
    BranchyChain,
    /// Standalone periodic function.
    Periodic,
    /// Standalone bursty function.
    Bursty,
}

impl Regime {
    pub fn all() -> [Regime; 4] {
        [
            Regime::LinearChain,
            Regime::BranchyChain,
            Regime::Periodic,
            Regime::Bursty,
        ]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Regime::LinearChain => "linear chain",
            Regime::BranchyChain => "70/30 branch",
            Regime::Periodic => "periodic (60s)",
            Regime::Bursty => "bursty",
        }
    }
}

#[derive(Debug, Clone)]
pub struct QualityRow {
    pub regime: Regime,
    pub predictor: Predictor,
    pub precision: f64,
    pub recall: f64,
    /// Mean lead between prediction emission and actual arrival (seconds,
    /// matched predictions only).
    pub mean_lead_s: f64,
    pub predictions: u64,
    pub arrivals: u64,
    /// Raw counters the ratios derive from, kept so multi-seed sweeps can
    /// merge rows exactly (sum counters, recompute ratios) instead of
    /// averaging averages.
    pub hits: u64,
    pub misses: u64,
    pub lead_sum_s: f64,
    pub lead_count: u64,
}

impl QualityRow {
    /// Recompute the derived ratios from the raw counters.
    fn finalize(&mut self) {
        let hits = self.hits as f64;
        let misses = self.misses as f64;
        self.predictions = self.hits + self.misses;
        self.precision = if hits + misses == 0.0 {
            0.0
        } else {
            hits / (hits + misses)
        };
        self.recall = if self.arrivals == 0 {
            0.0
        } else {
            (hits / self.arrivals as f64).min(1.0)
        };
        self.mean_lead_s = if self.lead_count == 0 {
            0.0
        } else {
            self.lead_sum_s / self.lead_count as f64
        };
    }
}

/// Score one (regime, predictor) pair over a synthetic timeline.
fn score(regime: Regime, predictor: Predictor, seed: u64) -> QualityRow {
    let mut rng = Rng::new(seed);
    let mut tracker = PredictionTracker::new();
    let mut hist = HistogramPredictor::new();
    let chain = ChainPredictor::new();
    let scorer = LearnedScorer::default();
    let horizon = SimDuration::from_secs(6 * 3600);

    // Ground truth: target-function arrival times, plus (for chains) the
    // head-completion times that precede them by the trigger delay.
    let trigger = TriggerService::Direct;
    let mut head_completions: Vec<SimTime> = Vec::new();
    let mut arrivals: Vec<SimTime> = Vec::new();
    match regime {
        Regime::LinearChain | Regime::BranchyChain => {
            let heads = ArrivalProcess::Poisson { rate: 1.0 / 90.0 }.generate(horizon, &mut rng);
            let follow_p = if regime == Regime::LinearChain { 1.0 } else { 0.7 };
            for h in heads {
                head_completions.push(h);
                if rng.bernoulli(follow_p) {
                    arrivals.push(h + trigger.sample_delay(&mut rng));
                }
            }
        }
        Regime::Periodic => {
            arrivals = ArrivalProcess::Periodic {
                period: SimDuration::from_secs(60),
                jitter: 0.05,
            }
            .generate(horizon, &mut rng);
        }
        Regime::Bursty => {
            arrivals = ArrivalProcess::Bursty {
                burst_len: 4,
                intra: SimDuration::from_millis(500),
                off_mean_s: 300.0,
            }
            .generate(horizon, &mut rng);
        }
    }
    arrivals.sort();

    // Causal replay: interleave emission events and arrivals in timestamp
    // order, expiring outstanding predictions as the clock passes their
    // deadlines — exactly what the online platform does.
    #[derive(Clone, Copy)]
    enum Event {
        HeadCompletion(SimTime),
        Arrival(SimTime),
    }
    let mut events: Vec<Event> = Vec::new();
    if matches!(regime, Regime::LinearChain | Regime::BranchyChain)
        && matches!(predictor, Predictor::Chain | Predictor::Learned)
    {
        events.extend(head_completions.iter().map(|&h| Event::HeadCompletion(h)));
    }
    events.extend(arrivals.iter().map(|&a| Event::Arrival(a)));
    events.sort_by_key(|e| match e {
        Event::HeadCompletion(t) | Event::Arrival(t) => *t,
    });

    let mut outstanding: Vec<(u64, SimTime, SimTime)> = Vec::new(); // (id, emitted, deadline)
    let mut lead_sum = 0.0;
    let mut lead_count = 0u64;
    let register = |tracker: &mut PredictionTracker,
                        outstanding: &mut Vec<(u64, SimTime, SimTime)>,
                        emitted: SimTime,
                        expected: SimTime| {
        let (id, deadline) = tracker.register("target", "app", expected, DEFAULT_MATCH_WINDOW);
        outstanding.push((id, emitted, deadline));
    };

    for ev in events {
        let now = match ev {
            Event::HeadCompletion(t) | Event::Arrival(t) => t,
        };
        // Expire predictions whose deadline passed.
        outstanding.retain(|(id, _, deadline)| {
            if *deadline < now {
                tracker.expire(*id);
                false
            } else {
                true
            }
        });
        match ev {
            Event::HeadCompletion(h) => {
                let pred = chain.predict_successor("head", "target", trigger, h);
                let conf = match predictor {
                    Predictor::Chain => pred.confidence,
                    _ => combined_confidence(
                        &scorer,
                        Some(pred.confidence),
                        None,
                        SimDuration::from_secs(30),
                        trigger.expected_lead(),
                    ),
                };
                if conf >= 0.5 {
                    register(&mut tracker, &mut outstanding, h, pred.expected_at);
                }
            }
            Event::Arrival(a) => {
                if let Some(id) = tracker.on_arrival("target", a) {
                    if let Some((_, emitted, _)) =
                        outstanding.iter().find(|(oid, _, _)| *oid == id)
                    {
                        lead_sum += a.since(*emitted).as_secs_f64();
                        lead_count += 1;
                    }
                }
                if matches!(predictor, Predictor::Histogram | Predictor::Learned) {
                    hist.observe("target", a);
                    if let Some(pred) = hist.predict_next("target", a) {
                        let conf = match predictor {
                            Predictor::Histogram => pred.confidence,
                            _ => combined_confidence(
                                &scorer,
                                None,
                                Some(pred.confidence),
                                SimDuration::ZERO,
                                pred.expected_at.since(a),
                            ),
                        };
                        if conf >= 0.4 {
                            register(&mut tracker, &mut outstanding, a, pred.expected_at);
                        }
                    }
                }
            }
        }
    }
    // Expire the stragglers.
    for (id, _, _) in outstanding {
        tracker.expire(id);
    }

    let mut row = QualityRow {
        regime,
        predictor,
        precision: 0.0,
        recall: 0.0,
        mean_lead_s: 0.0,
        predictions: 0,
        arrivals: arrivals.len() as u64,
        hits: tracker.hits,
        misses: tracker.misses,
        lead_sum_s: lead_sum,
        lead_count,
    };
    row.finalize();
    row
}

#[derive(Debug, Clone)]
pub struct PredictionQuality {
    pub rows: Vec<QualityRow>,
}

/// The `(regime, predictor)` cells the quality table reports.
fn cells() -> Vec<(Regime, Predictor)> {
    let mut out = Vec::new();
    for regime in Regime::all() {
        let predictors: &[Predictor] = match regime {
            Regime::LinearChain | Regime::BranchyChain => {
                &[Predictor::Chain, Predictor::Learned]
            }
            _ => &[Predictor::Histogram],
        };
        for &p in predictors {
            out.push((regime, p));
        }
    }
    out
}

pub fn run(seed: u64) -> PredictionQuality {
    run_multi(&[seed], &crate::experiments::harness::SweepRunner::new(1))
}

/// Multi-seed sweep: every `(regime, predictor, seed)` cell is an
/// independent run; per-cell rows merge by summing the raw counters
/// (hits, misses, arrivals, lead sums) in seed order and recomputing the
/// ratios — deterministic for any `--parallel`.
pub fn run_multi(
    seeds: &[u64],
    runner: &crate::experiments::harness::SweepRunner,
) -> PredictionQuality {
    assert!(
        !seeds.is_empty(),
        "prediction::run_multi needs at least one seed"
    );
    let cells = cells();
    let rows = runner
        .run_grid(&cells, seeds, |&(regime, predictor), seed| {
            score(regime, predictor, seed)
        })
        .into_iter()
        .map(|per_seed| {
            let mut merged = per_seed[0].clone();
            for row in &per_seed[1..] {
                merged.hits += row.hits;
                merged.misses += row.misses;
                merged.arrivals += row.arrivals;
                merged.lead_sum_s += row.lead_sum_s;
                merged.lead_count += row.lead_count;
            }
            merged.finalize();
            merged
        })
        .collect();
    PredictionQuality { rows }
}

impl PredictionQuality {
    pub fn print(&self) {
        println!("\n== Prediction quality (§6 quantification) ==");
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.regime.as_str().to_string(),
                    r.predictor.as_str().to_string(),
                    format!("{:.0}%", 100.0 * r.precision),
                    format!("{:.0}%", 100.0 * r.recall),
                    format!("{:.2}s", r.mean_lead_s),
                    r.predictions.to_string(),
                    r.arrivals.to_string(),
                ]
            })
            .collect();
        print_table(
            &["regime", "predictor", "precision", "recall", "mean lead", "preds", "arrivals"],
            &rows,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_chains_predict_nearly_perfectly() {
        let q = run(0x9ED1);
        let row = q
            .rows
            .iter()
            .find(|r| r.regime == Regime::LinearChain && r.predictor == Predictor::Chain)
            .unwrap();
        assert!(row.precision > 0.9, "precision {}", row.precision);
        assert!(row.recall > 0.9, "recall {}", row.recall);
    }

    #[test]
    fn branchy_chains_lose_precision_not_recall() {
        let q = run(0x9ED2);
        let linear = q
            .rows
            .iter()
            .find(|r| r.regime == Regime::LinearChain && r.predictor == Predictor::Chain)
            .unwrap();
        let branchy = q
            .rows
            .iter()
            .find(|r| r.regime == Regime::BranchyChain && r.predictor == Predictor::Chain)
            .unwrap();
        // Predicting every head completion on a 70% branch: precision ~0.7.
        assert!(branchy.precision < linear.precision - 0.1);
        assert!((0.5..=0.9).contains(&branchy.precision), "{}", branchy.precision);
        assert!(branchy.recall > 0.9, "recall {}", branchy.recall);
    }

    #[test]
    fn periodic_beats_bursty_for_histogram() {
        let q = run(0x9ED3);
        let periodic = q
            .rows
            .iter()
            .find(|r| r.regime == Regime::Periodic)
            .unwrap();
        let bursty = q.rows.iter().find(|r| r.regime == Regime::Bursty).unwrap();
        assert!(periodic.precision > 0.8, "periodic {}", periodic.precision);
        assert!(
            bursty.precision < periodic.precision,
            "bursty {} vs periodic {}",
            bursty.precision,
            periodic.precision
        );
    }

    #[test]
    fn chain_lead_tracks_trigger_delay() {
        let q = run(0x9ED4);
        let row = q
            .rows
            .iter()
            .find(|r| r.regime == Regime::LinearChain)
            .unwrap();
        // Direct trigger median is 60ms; mean lead should be of that order.
        assert!((0.02..=0.5).contains(&row.mean_lead_s), "{}", row.mean_lead_s);
    }
}
