//! Figure 4 — file retrieval overheads freshen can save.
//!
//! Paper setup: an OpenWhisk function queries a server for a file of one of
//! six sizes over a TCP connection; measured time runs from connection
//! start until the file is fully received; server at three locations
//! (local on-host, edge on-site on a 10 Gbps LAN, remote off-site ~50 ms
//! away); 20 iterations; log-scale y. "Maximum benefits range from
//! 11-622ms."
//!
//! Every retrieval here uses a *fresh* connection (connect + slow-start
//! fetch) — precisely the overhead a proactive freshen removes.

use crate::experiments::{fmt_secs, print_table};
use crate::netsim::cc::CongestionControl;
use crate::netsim::link::Site;
use crate::netsim::tcp::Connection;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::time::SimTime;

/// The paper's six file sizes (bytes).
pub const SIZES: [f64; 6] = [1e3, 1e4, 1e5, 1e6, 5e6, 1e7];
pub const ITERATIONS: usize = 20;

#[derive(Debug, Clone)]
pub struct Fig4Cell {
    pub site: Site,
    pub size: f64,
    /// Retrieval time stats over the iterations (seconds).
    pub stats: Summary,
}

#[derive(Debug, Clone)]
pub struct Fig4 {
    pub cells: Vec<Fig4Cell>,
}

/// One cold retrieval: connect + request/response of `size` bytes.
pub fn cold_retrieval_s(site: Site, size: f64, rng: &mut Rng) -> f64 {
    let mut conn = Connection::new(site.link(), CongestionControl::Cubic);
    let t0 = SimTime::ZERO;
    let d_conn = conn.connect(t0, rng);
    let d_xfer = conn.request_response(t0 + d_conn, rng, 256.0, size, 1e-3);
    (d_conn + d_xfer).as_secs_f64()
}

/// Raw per-seed samples, one `(site, size, samples)` triple per cell,
/// with the rng stream threaded across cells exactly as before.
fn run_samples(seed: u64) -> Vec<(Site, f64, Vec<f64>)> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for site in Site::all() {
        for &size in &SIZES {
            let samples: Vec<f64> = (0..ITERATIONS)
                .map(|_| cold_retrieval_s(site, size, &mut rng))
                .collect();
            out.push((site, size, samples));
        }
    }
    out
}

pub fn run(seed: u64) -> Fig4 {
    run_multi(&[seed], &crate::experiments::harness::SweepRunner::new(1))
}

/// Multi-seed sweep: one independent retrieval simulation per seed,
/// samples pooled per `(site, size)` cell in seed order.
pub fn run_multi(
    seeds: &[u64],
    runner: &crate::experiments::harness::SweepRunner,
) -> Fig4 {
    assert!(!seeds.is_empty(), "fig4::run_multi needs at least one seed");
    let per_seed = runner.run(seeds, |_, &seed| run_samples(seed));
    let cells = per_seed[0]
        .iter()
        .enumerate()
        .map(|(i, &(site, size, _))| {
            let mut samples = Vec::new();
            for seed_run in &per_seed {
                samples.extend_from_slice(&seed_run[i].2);
            }
            Fig4Cell {
                site,
                size,
                stats: Summary::of(&samples).expect("non-empty"),
            }
        })
        .collect();
    Fig4 { cells }
}

impl Fig4 {
    /// Max benefit per site = median retrieval time of the largest file
    /// (all of it is saved when freshen prefetches).
    pub fn max_benefit_s(&self, site: Site) -> f64 {
        self.cells
            .iter()
            .filter(|c| c.site == site)
            .map(|c| c.stats.p50)
            .fold(0.0, f64::max)
    }

    pub fn print(&self) {
        println!(
            "\n== Figure 4: file retrieval time (connect + fetch), {} iterations ==",
            ITERATIONS
        );
        let mut rows = Vec::new();
        for &size in &SIZES {
            let mut row = vec![fmt_bytes(size)];
            for site in Site::all() {
                let c = self
                    .cells
                    .iter()
                    .find(|c| c.site == site && c.size == size)
                    .unwrap();
                row.push(fmt_secs(c.stats.p50));
            }
            rows.push(row);
        }
        print_table(&["file size", "local", "edge", "remote"], &rows);
        println!(
            "max benefit: local={} edge={} remote={}  (paper range: 11ms-622ms)",
            fmt_secs(self.max_benefit_s(Site::Local)),
            fmt_secs(self.max_benefit_s(Site::Edge)),
            fmt_secs(self.max_benefit_s(Site::Remote)),
        );
    }
}

pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e6 {
        format!("{:.0}MB", b / 1e6)
    } else {
        format!("{:.0}KB", b / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let f = run(4);
        // Locations separate cleanly (log-scale separation in the paper):
        // remote >> edge > local for every size.
        for &size in &SIZES {
            let by = |s: Site| {
                f.cells
                    .iter()
                    .find(|c| c.site == s && c.size == size)
                    .unwrap()
                    .stats
                    .p50
            };
            assert!(by(Site::Remote) > 5.0 * by(Site::Edge), "size {size}");
            assert!(by(Site::Edge) > by(Site::Local), "size {size}");
        }
        // Retrieval time grows with size within a site.
        for site in Site::all() {
            let times: Vec<f64> = SIZES
                .iter()
                .map(|&s| {
                    f.cells
                        .iter()
                        .find(|c| c.site == site && c.size == s)
                        .unwrap()
                        .stats
                        .p50
                })
                .collect();
            for w in times.windows(2) {
                assert!(w[1] >= w[0] * 0.95, "{site:?}: non-monotone {times:?}");
            }
        }
        // Max-benefit band: paper reports 11ms (local) to 622ms (remote).
        let local = f.max_benefit_s(Site::Local);
        let remote = f.max_benefit_s(Site::Remote);
        assert!(
            (0.002..=0.05).contains(&local),
            "local max benefit {local}s (paper ~11ms)"
        );
        assert!(
            (0.3..=1.2).contains(&remote),
            "remote max benefit {remote}s (paper ~622ms)"
        );
    }
}
