//! Parallel multi-seed experiment harness.
//!
//! [`SweepRunner`] fans an arbitrary grid — typically `(scenario, seed,
//! config-override)` tuples — out across `std::thread` workers. Each grid
//! point runs a self-contained closure that owns its own `Sim<World>`
//! (nothing simulator-side is shared between threads), and results are
//! returned **indexed by grid position, never by completion order**, so
//! the merged output of a sweep is byte-identical whether it ran on one
//! worker or sixteen.
//!
//! Determinism contract:
//! 1. the per-point closure must derive all randomness from the grid
//!    point (its seed), and
//! 2. any cross-point aggregation must consume the returned `Vec` in
//!    order (it is already grid-ordered).
//!
//! The experiment modules (`ablations`, `prediction`, `fig4`, `fig5_6`)
//! expose `*_multi` entry points built on this; the CLI maps
//! `--seeds a..b --parallel N` onto them.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A pool of `std::thread` workers executing a grid of independent runs.
#[derive(Debug, Clone)]
pub struct SweepRunner {
    parallel: usize,
}

impl Default for SweepRunner {
    fn default() -> Self {
        SweepRunner::new(1)
    }
}

impl SweepRunner {
    /// `parallel` worker threads; `0` and `1` both mean sequential.
    pub fn new(parallel: usize) -> SweepRunner {
        SweepRunner {
            parallel: parallel.max(1),
        }
    }

    /// Worker threads this runner uses.
    pub fn parallel(&self) -> usize {
        self.parallel
    }

    /// Run `f(index, &points[index])` for every grid point and return the
    /// results **in grid order**. Work is claimed dynamically (an atomic
    /// cursor), so stragglers don't serialise the sweep, but the output
    /// vector is position-indexed and therefore independent of scheduling.
    ///
    /// Panics in `f` propagate (the scope re-raises them), so a failing
    /// grid point fails the sweep rather than silently vanishing.
    pub fn run<P, R, F>(&self, points: &[P], f: F) -> Vec<R>
    where
        P: Sync,
        R: Send,
        F: Fn(usize, &P) -> R + Sync,
    {
        if self.parallel == 1 || points.len() <= 1 {
            return points.iter().enumerate().map(|(i, p)| f(i, p)).collect();
        }
        let next = AtomicUsize::new(0);
        let cells: Vec<Mutex<Option<R>>> = points.iter().map(|_| Mutex::new(None)).collect();
        let workers = self.parallel.min(points.len());
        // simlint: allow(D006, results land in position-indexed cells and are drained in grid order below)
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= points.len() {
                        break;
                    }
                    let r = f(i, &points[i]);
                    *cells[i].lock().unwrap() = Some(r);
                });
            }
        });
        cells
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap()
                    .expect("every grid point completed")
            })
            .collect()
    }

    /// Convenience: the cartesian grid `params × seeds`, run in parallel,
    /// regrouped **per parameter** (outer Vec follows `params` order; the
    /// inner Vec follows `seeds` order). This is the shape every
    /// multi-seed experiment merge consumes.
    pub fn run_grid<P, R, F>(&self, params: &[P], seeds: &[u64], f: F) -> Vec<Vec<R>>
    where
        P: Sync,
        R: Send,
        F: Fn(&P, u64) -> R + Sync,
    {
        let grid: Vec<(usize, u64)> = params
            .iter()
            .enumerate()
            .flat_map(|(pi, _)| seeds.iter().map(move |&s| (pi, s)))
            .collect();
        let flat = self.run(&grid, |_, &(pi, seed)| f(&params[pi], seed));
        let mut out: Vec<Vec<R>> = Vec::with_capacity(params.len());
        let mut it = flat.into_iter();
        for _ in 0..params.len() {
            out.push(it.by_ref().take(seeds.len()).collect());
        }
        out
    }
}

/// Parse a seed specification: `"7"` (one seed), `"a..b"` (half-open
/// range) or `"a..=b"` (inclusive). Returns `None` on malformed input or
/// an empty range.
pub fn parse_seed_spec(s: &str) -> Option<Vec<u64>> {
    let s = s.trim();
    if let Some((a, b)) = s.split_once("..") {
        let (inclusive, b) = match b.strip_prefix('=') {
            Some(rest) => (true, rest),
            None => (false, b),
        };
        let a: u64 = a.trim().parse().ok()?;
        let b: u64 = b.trim().parse().ok()?;
        let end = if inclusive { b.checked_add(1)? } else { b };
        if end <= a {
            return None;
        }
        Some((a..end).collect())
    } else {
        s.parse().ok().map(|n| vec![n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pseudo_work(i: usize, seed: u64) -> u64 {
        // Deterministic per-point value with some spin so threads overlap.
        let mut rng = crate::util::rng::Rng::new(seed ^ (i as u64) << 32);
        let mut acc = 0u64;
        for _ in 0..500 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        acc
    }

    #[test]
    fn parallel_output_is_byte_identical_to_sequential() {
        let points: Vec<u64> = (0..23).collect();
        let seq = SweepRunner::new(1).run(&points, |i, &s| pseudo_work(i, s));
        for workers in [2, 4, 8] {
            let par = SweepRunner::new(workers).run(&points, |i, &s| pseudo_work(i, s));
            assert_eq!(seq, par, "parallel={workers} diverged");
        }
    }

    #[test]
    fn results_are_grid_ordered_not_completion_ordered() {
        let points: Vec<usize> = (0..16).collect();
        let out = SweepRunner::new(4).run(&points, |i, &p| {
            // Make early grid points finish last.
            std::thread::sleep(std::time::Duration::from_millis(
                (16 - i) as u64 % 5,
            ));
            p * 10
        });
        assert_eq!(out, points.iter().map(|p| p * 10).collect::<Vec<_>>());
    }

    #[test]
    fn run_grid_groups_by_parameter() {
        let params = ["a", "b", "c"];
        let seeds = [1u64, 2, 3, 4];
        let grouped =
            SweepRunner::new(3).run_grid(&params, &seeds, |p, s| format!("{p}{s}"));
        assert_eq!(grouped.len(), 3);
        assert_eq!(grouped[0], vec!["a1", "a2", "a3", "a4"]);
        assert_eq!(grouped[2], vec!["c1", "c2", "c3", "c4"]);
    }

    #[test]
    fn seed_spec_forms() {
        assert_eq!(parse_seed_spec("7"), Some(vec![7]));
        assert_eq!(parse_seed_spec("2..5"), Some(vec![2, 3, 4]));
        assert_eq!(parse_seed_spec("2..=5"), Some(vec![2, 3, 4, 5]));
        assert_eq!(parse_seed_spec("5..5"), None);
        assert_eq!(parse_seed_spec("5..2"), None);
        assert_eq!(parse_seed_spec("x..y"), None);
        assert_eq!(parse_seed_spec(" 0..2 "), Some(vec![0, 1]));
    }

    #[test]
    fn zero_parallel_is_sequential() {
        let r = SweepRunner::new(0);
        assert_eq!(r.parallel(), 1);
        assert_eq!(r.run(&[1, 2, 3], |_, &x| x + 1), vec![2, 3, 4]);
    }
}
