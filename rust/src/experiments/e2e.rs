//! End-to-end chain workload: freshen ON vs OFF (our headline experiment).
//!
//! A λ1-style pipeline (`ingest -> classify -> store`, chained through
//! Direct triggers) is driven by a bursty arrival process on the
//! simulator substrate. We compare the vanilla platform against the same
//! platform with freshen admitted by chain prediction, reporting
//! end-to-end chain latency, freshen hit rate, cold starts, and billing.
//! (The real-time twin of this experiment — real batched inference, real
//! sleeps — is `examples/ml_pipeline.rs` / the `e2e_serving` bench.)
//!
//! Multi-seed: [`run_multi`] fans the `mode × seeds` grid over a
//! [`SweepRunner`]; per-mode raw latency samples pool in seed order and
//! counters sum, so the merged comparison is deterministic for any
//! `--parallel`.

use crate::experiments::harness::SweepRunner;
use crate::experiments::print_table;
use crate::netsim::link::Site;
use crate::platform::endpoint::Endpoint;
use crate::platform::exec::invoke;
use crate::platform::function::{Arg, FunctionSpec, Op};
use crate::platform::world::{PlatformSim, World};
use crate::simcore::Sim;
use crate::triggers::TriggerService;
use crate::util::config::Config;
use crate::util::stats::Summary;
use crate::util::time::{SimDuration, SimTime};
use crate::workload::generator::ArrivalProcess;

/// Result of one platform run.
#[derive(Debug, Clone)]
pub struct E2eRun {
    pub label: &'static str,
    /// Latency of the chain's final function (ms).
    pub tail_latency: Summary,
    /// Latency across all functions (ms).
    pub all_latency: Summary,
    pub freshen_hit_rate: f64,
    pub cold_starts: u64,
    pub freshens_completed: u64,
    pub freshens_wasted: u64,
    pub network_bytes: f64,
    pub network_bytes_saved: f64,
    pub invocations: usize,
}

impl E2eRun {
    /// Coefficient of variation of end-to-end latency — §6: "Quantifying
    /// how freshen affects variability in application behavior would be an
    /// important component of this evaluation."
    pub fn latency_cv(&self) -> f64 {
        if self.all_latency.mean == 0.0 {
            0.0
        } else {
            self.all_latency.std_dev / self.all_latency.mean
        }
    }

    /// Tail amplification: p99 / p50.
    pub fn tail_ratio(&self) -> f64 {
        if self.all_latency.p50 == 0.0 {
            0.0
        } else {
            self.all_latency.p99 / self.all_latency.p50
        }
    }
}

#[derive(Debug, Clone)]
pub struct E2e {
    pub baseline: E2eRun,
    pub freshened: E2eRun,
}

/// Build the 3-stage pipeline world.
fn build_world(freshen: bool, seed: u64) -> World {
    let mut cfg = Config::default();
    cfg.seed = seed;
    cfg.freshen.enabled = freshen;
    cfg.freshen.min_confidence = 0.3;
    let mut w = World::new(cfg);

    let mut store = Endpoint::new("store", Site::Remote);
    store.store.put("model", 5e6, SimTime::ZERO);
    store.store.put("batch-config", 1e5, SimTime::ZERO);
    w.add_endpoint(store);

    // ingest: fetch config, light compute, trigger classify.
    w.deploy(FunctionSpec::new(
        "ingest",
        "pipeline",
        vec![
            Op::DataGet {
                endpoint: "store".into(),
                creds: Arg::Const("CREDS".into()),
                object_id: Arg::Const("batch-config".into()),
            },
            Op::Compute {
                duration: SimDuration::from_millis(10),
            },
            // The canonical serverless image pipeline: ingest drops the
            // image in a bucket; the notification triggers classify. The
            // S3 trigger's ~1.28 s delivery delay (Table 1) is exactly the
            // window freshen needs to prefetch the 5 MB model.
            Op::InvokeNext {
                function: "classify".into(),
                trigger: TriggerService::S3Bucket,
            },
        ],
    ));
    // classify: fetch the 5MB model, infer, trigger store step.
    w.deploy(FunctionSpec::new(
        "classify",
        "pipeline",
        vec![
            Op::DataGet {
                endpoint: "store".into(),
                creds: Arg::Const("CREDS".into()),
                object_id: Arg::Const("model".into()),
            },
            Op::Infer {
                model: "classifier".into(),
                input_bytes: 3072.0 * 4.0,
            },
            Op::InvokeNext {
                function: "persist".into(),
                trigger: TriggerService::SnsPubSub,
            },
        ],
    ));
    // persist: write the result.
    w.deploy(FunctionSpec::new(
        "persist",
        "pipeline",
        vec![
            Op::Compute {
                duration: SimDuration::from_millis(5),
            },
            Op::DataPut {
                endpoint: "store".into(),
                creds: Arg::Const("CREDS".into()),
                object_id: Arg::Const("result".into()),
                bytes: 256.0 * 1024.0,
            },
        ],
    ));
    w.registry
        .register_chain(
            "pipeline",
            vec!["ingest".into(), "classify".into(), "persist".into()],
        )
        .expect("chain");
    w
}

/// Raw output of one `(mode, seed)` run, mergeable across seeds.
struct E2eSample {
    tail: Vec<SimDuration>,
    all: Vec<SimDuration>,
    freshen_hits: u64,
    freshen_total: u64,
    cold_starts: u64,
    freshens_completed: u64,
    freshens_wasted: u64,
    network_bytes: f64,
    network_bytes_saved: f64,
    invocations: usize,
}

fn run_one(freshen: bool, seed: u64, chains: usize) -> E2eSample {
    let mut w = build_world(freshen, seed);
    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 100_000_000;

    // Bursty arrivals: bursts of 4 chains, quiet gaps ~45s — long enough
    // for connections to idle-decay and prefetches to expire, which is the
    // regime the paper targets.
    let mut arrival_rng = w.rng.fork(99);
    let arrivals = ArrivalProcess::Bursty {
        burst_len: 4,
        intra: SimDuration::from_millis(400),
        off_mean_s: 45.0,
    }
    .generate(SimDuration::from_secs(30 * chains as u64), &mut arrival_rng);
    for at in arrivals.iter().take(chains) {
        sim.schedule_at(*at + SimDuration::from_secs(1), |sim, w| {
            invoke(sim, w, "ingest");
        });
    }
    sim.run(&mut w);

    let tail: Vec<SimDuration> = w
        .metrics
        .records()
        .iter()
        .filter(|r| r.function == "persist")
        .map(|r| r.latency())
        .collect();
    let all: Vec<SimDuration> = w.metrics.records().iter().map(|r| r.latency()).collect();
    let (freshen_hits, freshen_total) = w.metrics.freshen_hit_counts();
    let acct = w.ledger.account("pipeline");
    E2eSample {
        tail,
        all,
        freshen_hits,
        freshen_total,
        cold_starts: w.metrics.cold_starts,
        freshens_completed: w.metrics.freshens_completed,
        freshens_wasted: w.metrics.freshens_wasted,
        network_bytes: acct.network_bytes,
        network_bytes_saved: acct.network_bytes_saved,
        invocations: w.metrics.count(),
    }
}

/// Pool one mode's per-seed samples (latencies in seed order, counters
/// summed) into the reported run.
fn merge(label: &'static str, samples: Vec<E2eSample>) -> E2eRun {
    let mut tail = Vec::new();
    let mut all = Vec::new();
    let (mut hits, mut total) = (0u64, 0u64);
    let (mut cold, mut completed, mut wasted) = (0u64, 0u64, 0u64);
    let (mut net, mut saved) = (0.0f64, 0.0f64);
    let mut invocations = 0usize;
    for s in samples {
        tail.extend(s.tail);
        all.extend(s.all);
        hits += s.freshen_hits;
        total += s.freshen_total;
        cold += s.cold_starts;
        completed += s.freshens_completed;
        wasted += s.freshens_wasted;
        net += s.network_bytes;
        saved += s.network_bytes_saved;
        invocations += s.invocations;
    }
    E2eRun {
        label,
        tail_latency: Summary::of_durations_ms(&tail).expect("persist ran"),
        all_latency: Summary::of_durations_ms(&all).expect("records"),
        freshen_hit_rate: if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        },
        cold_starts: cold,
        freshens_completed: completed,
        freshens_wasted: wasted,
        network_bytes: net,
        network_bytes_saved: saved,
        invocations,
    }
}

/// Single-seed convenience over [`run_multi`].
pub fn run(seed: u64, chains: usize) -> E2e {
    run_multi(&[seed], chains, &SweepRunner::new(1))
}

/// Multi-seed sweep: both modes run for every seed on `runner`, and each
/// mode's rows merge deterministically regardless of parallelism.
pub fn run_multi(seeds: &[u64], chains: usize, runner: &SweepRunner) -> E2e {
    assert!(!seeds.is_empty(), "e2e needs at least one seed");
    let modes = [false, true];
    let mut grouped = runner
        .run_grid(&modes, seeds, |&freshen, seed| run_one(freshen, seed, chains))
        .into_iter();
    let baseline = merge("baseline", grouped.next().expect("baseline grid row"));
    let freshened = merge("freshen", grouped.next().expect("freshen grid row"));
    E2e {
        baseline,
        freshened,
    }
}

impl E2e {
    pub fn print(&self) {
        println!("\n== E2E: 3-stage chain pipeline, freshen on vs off ==");
        let row = |r: &E2eRun| {
            vec![
                r.label.to_string(),
                format!("{:.1}", r.all_latency.p50),
                format!("{:.1}", r.all_latency.p99),
                format!("{:.1}", r.tail_latency.p50),
                format!("{:.0}%", 100.0 * r.freshen_hit_rate),
                r.cold_starts.to_string(),
                format!("{:.1}MB", r.network_bytes / 1e6),
                format!("{:.1}MB", r.network_bytes_saved / 1e6),
            ]
        };
        print_table(
            &[
                "mode",
                "p50 ms",
                "p99 ms",
                "persist p50",
                "fr hits",
                "cold",
                "net",
                "net saved",
            ],
            &[row(&self.baseline), row(&self.freshened)],
        );
        let speedup = self.baseline.all_latency.p50 / self.freshened.all_latency.p50;
        println!("p50 speedup: {speedup:.2}x");
        println!(
            "variability (§6): CV {:.2} -> {:.2}, p99/p50 {:.1}x -> {:.1}x",
            self.baseline.latency_cv(),
            self.freshened.latency_cv(),
            self.baseline.tail_ratio(),
            self.freshened.tail_ratio(),
        );
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn freshen_improves_chain_latency() {
        let e = super::run(0xE2E, 40);
        assert_eq!(e.baseline.freshens_completed, 0, "baseline has no freshen");
        assert!(e.freshened.freshens_completed > 0);
        assert!(e.freshened.freshen_hit_rate > 0.2, "hit rate {}", e.freshened.freshen_hit_rate);
        assert!(
            e.freshened.all_latency.p50 < e.baseline.all_latency.p50,
            "freshen p50 {} should beat baseline {}",
            e.freshened.all_latency.p50,
            e.baseline.all_latency.p50
        );
        // Same number of invocations processed.
        assert_eq!(e.baseline.invocations, e.freshened.invocations);
    }

    #[test]
    fn multi_seed_sweep_is_identical_across_parallelism() {
        use crate::experiments::SweepRunner;
        let seeds = [0xE2E0u64, 0xE2E1];
        let seq = super::run_multi(&seeds, 10, &SweepRunner::new(1));
        let par = super::run_multi(&seeds, 10, &SweepRunner::new(4));
        assert_eq!(format!("{seq:?}"), format!("{par:?}"));
        // Both seeds' invocations are pooled.
        let single = super::run(0xE2E0, 10);
        assert!(seq.baseline.invocations > single.baseline.invocations);
    }
}
