//! End-to-end chain workload: freshen ON vs OFF (our headline experiment).
//!
//! A λ1-style pipeline (`ingest -> classify -> store`, chained through
//! Direct triggers) is driven by a bursty arrival process on the
//! simulator substrate. We compare the vanilla platform against the same
//! platform with freshen admitted by chain prediction, reporting
//! end-to-end chain latency, freshen hit rate, cold starts, and billing.
//! (The real-time twin of this experiment — real PJRT inference, real
//! sleeps — is `examples/ml_pipeline.rs` / the `e2e_serving` bench.)

use crate::experiments::print_table;
use crate::netsim::link::Site;
use crate::platform::endpoint::Endpoint;
use crate::platform::exec::invoke;
use crate::platform::function::{Arg, FunctionSpec, Op};
use crate::platform::world::World;
use crate::simcore::Sim;
use crate::triggers::TriggerService;
use crate::util::config::Config;
use crate::util::stats::Summary;
use crate::util::time::{SimDuration, SimTime};
use crate::workload::generator::ArrivalProcess;

/// Result of one platform run.
#[derive(Debug, Clone)]
pub struct E2eRun {
    pub label: &'static str,
    /// Latency of the chain's final function (ms).
    pub tail_latency: Summary,
    /// Latency across all functions (ms).
    pub all_latency: Summary,
    pub freshen_hit_rate: f64,
    pub cold_starts: u64,
    pub freshens_completed: u64,
    pub freshens_wasted: u64,
    pub network_bytes: f64,
    pub network_bytes_saved: f64,
    pub invocations: usize,
}

impl E2eRun {
    /// Coefficient of variation of end-to-end latency — §6: "Quantifying
    /// how freshen affects variability in application behavior would be an
    /// important component of this evaluation."
    pub fn latency_cv(&self) -> f64 {
        if self.all_latency.mean == 0.0 {
            0.0
        } else {
            self.all_latency.std_dev / self.all_latency.mean
        }
    }

    /// Tail amplification: p99 / p50.
    pub fn tail_ratio(&self) -> f64 {
        if self.all_latency.p50 == 0.0 {
            0.0
        } else {
            self.all_latency.p99 / self.all_latency.p50
        }
    }
}

#[derive(Debug, Clone)]
pub struct E2e {
    pub baseline: E2eRun,
    pub freshened: E2eRun,
}

/// Build the 3-stage pipeline world.
fn build_world(freshen: bool, seed: u64) -> World {
    let mut cfg = Config::default();
    cfg.seed = seed;
    cfg.freshen.enabled = freshen;
    cfg.freshen.min_confidence = 0.3;
    let mut w = World::new(cfg);

    let mut store = Endpoint::new("store", Site::Remote);
    store.store.put("model", 5e6, SimTime::ZERO);
    store.store.put("batch-config", 1e5, SimTime::ZERO);
    w.add_endpoint(store);

    // ingest: fetch config, light compute, trigger classify.
    w.deploy(FunctionSpec::new(
        "ingest",
        "pipeline",
        vec![
            Op::DataGet {
                endpoint: "store".into(),
                creds: Arg::Const("CREDS".into()),
                object_id: Arg::Const("batch-config".into()),
            },
            Op::Compute {
                duration: SimDuration::from_millis(10),
            },
            // The canonical serverless image pipeline: ingest drops the
            // image in a bucket; the notification triggers classify. The
            // S3 trigger's ~1.28 s delivery delay (Table 1) is exactly the
            // window freshen needs to prefetch the 5 MB model.
            Op::InvokeNext {
                function: "classify".into(),
                trigger: TriggerService::S3Bucket,
            },
        ],
    ));
    // classify: fetch the 5MB model, infer, trigger store step.
    w.deploy(FunctionSpec::new(
        "classify",
        "pipeline",
        vec![
            Op::DataGet {
                endpoint: "store".into(),
                creds: Arg::Const("CREDS".into()),
                object_id: Arg::Const("model".into()),
            },
            Op::Infer {
                model: "classifier".into(),
                input_bytes: 3072.0 * 4.0,
            },
            Op::InvokeNext {
                function: "persist".into(),
                trigger: TriggerService::SnsPubSub,
            },
        ],
    ));
    // persist: write the result.
    w.deploy(FunctionSpec::new(
        "persist",
        "pipeline",
        vec![
            Op::Compute {
                duration: SimDuration::from_millis(5),
            },
            Op::DataPut {
                endpoint: "store".into(),
                creds: Arg::Const("CREDS".into()),
                object_id: Arg::Const("result".into()),
                bytes: 256.0 * 1024.0,
            },
        ],
    ));
    w.registry
        .register_chain(
            "pipeline",
            vec!["ingest".into(), "classify".into(), "persist".into()],
        )
        .expect("chain");
    w
}

fn run_one(freshen: bool, seed: u64, chains: usize) -> E2eRun {
    let mut w = build_world(freshen, seed);
    let mut sim: Sim<World> = Sim::new();
    sim.max_events = 100_000_000;

    // Bursty arrivals: bursts of 4 chains, quiet gaps ~45s — long enough
    // for connections to idle-decay and prefetches to expire, which is the
    // regime the paper targets.
    let mut arrival_rng = w.rng.fork(99);
    let arrivals = ArrivalProcess::Bursty {
        burst_len: 4,
        intra: SimDuration::from_millis(400),
        off_mean_s: 45.0,
    }
    .generate(SimDuration::from_secs(30 * chains as u64), &mut arrival_rng);
    for at in arrivals.iter().take(chains) {
        sim.schedule_at(*at + SimDuration::from_secs(1), |sim, w| {
            invoke(sim, w, "ingest");
        });
    }
    sim.run(&mut w);

    let tail: Vec<SimDuration> = w
        .metrics
        .records()
        .iter()
        .filter(|r| r.function == "persist")
        .map(|r| r.latency())
        .collect();
    let all: Vec<SimDuration> = w.metrics.records().iter().map(|r| r.latency()).collect();
    let acct = w.ledger.account("pipeline");
    E2eRun {
        label: if freshen { "freshen" } else { "baseline" },
        tail_latency: Summary::of_durations_ms(&tail).expect("persist ran"),
        all_latency: Summary::of_durations_ms(&all).expect("records"),
        freshen_hit_rate: w.metrics.freshen_hit_rate(),
        cold_starts: w.metrics.cold_starts,
        freshens_completed: w.metrics.freshens_completed,
        freshens_wasted: w.metrics.freshens_wasted,
        network_bytes: acct.network_bytes,
        network_bytes_saved: acct.network_bytes_saved,
        invocations: w.metrics.count(),
    }
}

pub fn run(seed: u64, chains: usize) -> E2e {
    E2e {
        baseline: run_one(false, seed, chains),
        freshened: run_one(true, seed, chains),
    }
}

impl E2e {
    pub fn print(&self) {
        println!("\n== E2E: 3-stage chain pipeline, freshen on vs off ==");
        let row = |r: &E2eRun| {
            vec![
                r.label.to_string(),
                format!("{:.1}", r.all_latency.p50),
                format!("{:.1}", r.all_latency.p99),
                format!("{:.1}", r.tail_latency.p50),
                format!("{:.0}%", 100.0 * r.freshen_hit_rate),
                r.cold_starts.to_string(),
                format!("{:.1}MB", r.network_bytes / 1e6),
                format!("{:.1}MB", r.network_bytes_saved / 1e6),
            ]
        };
        print_table(
            &[
                "mode",
                "p50 ms",
                "p99 ms",
                "persist p50",
                "fr hits",
                "cold",
                "net",
                "net saved",
            ],
            &[row(&self.baseline), row(&self.freshened)],
        );
        let speedup = self.baseline.all_latency.p50 / self.freshened.all_latency.p50;
        println!("p50 speedup: {speedup:.2}x");
        println!(
            "variability (§6): CV {:.2} -> {:.2}, p99/p50 {:.1}x -> {:.1}x",
            self.baseline.latency_cv(),
            self.freshened.latency_cv(),
            self.baseline.tail_ratio(),
            self.freshened.tail_ratio(),
        );
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn freshen_improves_chain_latency() {
        let e = super::run(0xE2E, 40);
        assert_eq!(e.baseline.freshens_completed, 0, "baseline has no freshen");
        assert!(e.freshened.freshens_completed > 0);
        assert!(e.freshened.freshen_hit_rate > 0.2, "hit rate {}", e.freshened.freshen_hit_rate);
        assert!(
            e.freshened.all_latency.p50 < e.baseline.all_latency.p50,
            "freshen p50 {} should beat baseline {}",
            e.freshened.all_latency.p50,
            e.baseline.all_latency.p50
        );
        // Same number of invocations processed.
        assert_eq!(e.baseline.invocations, e.freshened.invocations);
    }
}
