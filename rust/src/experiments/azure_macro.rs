//! `azure-macro` — the platform-scale Azure-trace macro benchmark.
//!
//! Replays an Azure-Functions-shaped trace (a real CSV or the offline
//! synthesizer) through the full platform under the paper's ablation axes:
//! freshen off (`baseline`) and freshen on with histogram-only /
//! chain-only / combined prediction. Reports the metrics the literature
//! compares on — cold-start rate, p50/p99 end-to-end latency, freshen hit
//! rate, and the wasted-freshen fraction — per variant, merged across
//! shards and seeds.
//!
//! The grid is **shard-major**: each [`SweepRunner`] worker gathers its
//! shard's rows ONCE (one streaming pass over a CSV, or direct synthesis
//! of its apps) and replays that slice under every `(variant × seed)`
//! combination — a real 1440-minute trace is scanned `shards` times total,
//! not `variants × seeds × shards` times. Parallelism therefore tops out
//! at `--shards`; run with `--shards >= --parallel`. Merges follow the
//! macrotrace determinism contract: byte-identical output for any
//! `--shards` × `--parallel` combination (regression-tested in
//! `tests/azure_macro_determinism.rs`).

use anyhow::Result;

use crate::experiments::harness::SweepRunner;
use crate::experiments::print_table;
use crate::workload::macrotrace::replay::{replay_app, MacroMetrics, PredictorPolicy, ReplayCfg};
use crate::workload::macrotrace::shard::{load_shard_apps, TraceSource};

/// One benchmark variant: a freshen switch + predictor policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Vanilla platform, freshen off.
    Baseline,
    /// Freshen admitted by IAT-histogram predictions only.
    Histogram,
    /// Freshen admitted by explicit-chain predictions only.
    Chain,
    /// The full system: both prediction sources.
    Both,
}

impl Variant {
    pub fn all() -> [Variant; 4] {
        [Variant::Baseline, Variant::Histogram, Variant::Chain, Variant::Both]
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "baseline" | "off" => Some(Variant::Baseline),
            "hist" | "histogram" => Some(Variant::Histogram),
            "chain" => Some(Variant::Chain),
            "both" | "full" => Some(Variant::Both),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
            Variant::Histogram => "hist",
            Variant::Chain => "chain",
            Variant::Both => "both",
        }
    }

    fn policy(&self) -> PredictorPolicy {
        match self {
            Variant::Baseline => PredictorPolicy::None,
            Variant::Histogram => PredictorPolicy::Histogram,
            Variant::Chain => PredictorPolicy::Chain,
            Variant::Both => PredictorPolicy::Both,
        }
    }

    fn freshen_enabled(&self) -> bool {
        !matches!(self, Variant::Baseline)
    }

    /// The replay configuration this variant runs under.
    pub fn replay_cfg(&self, seed: u64, warmup_minutes: usize) -> ReplayCfg {
        let mut cfg = ReplayCfg::default();
        cfg.base.freshen.enabled = self.freshen_enabled();
        cfg.policy = self.policy();
        cfg.seed = seed;
        cfg.warmup_minutes = warmup_minutes;
        cfg
    }
}

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct AzureMacroCfg {
    pub source: TraceSource,
    pub shards: usize,
    pub warmup_minutes: usize,
    pub variants: Vec<Variant>,
}

impl AzureMacroCfg {
    pub fn new(source: TraceSource) -> AzureMacroCfg {
        AzureMacroCfg {
            source,
            shards: 4,
            warmup_minutes: 10,
            variants: Variant::all().to_vec(),
        }
    }
}

/// The merged benchmark result.
#[derive(Debug, Clone)]
pub struct AzureMacro {
    /// Per-variant metrics, merged across shards and seeds.
    pub variants: Vec<(Variant, MacroMetrics)>,
    pub shards: usize,
    pub seeds: Vec<u64>,
    /// Rows in one pass over the trace (and malformed rows skipped).
    pub trace_rows: u64,
    pub skipped_rows: u64,
}

/// One shard worker's output: per-variant metrics (seeds merged in), the
/// shard's row count, and the scan's skip count.
struct ShardSlice {
    per_variant: Vec<MacroMetrics>,
    rows: u64,
    skipped: u64,
}

/// Run the benchmark. Shard-major: each worker ingests its shard once and
/// replays it under every `(variant × seed)`; shard slices then merge per
/// variant in shard order (commutative sums — any order gives the bytes).
pub fn run_multi(
    cfg: &AzureMacroCfg,
    seeds: &[u64],
    runner: &SweepRunner,
) -> Result<AzureMacro> {
    assert!(!seeds.is_empty(), "azure-macro needs at least one seed");
    assert!(!cfg.variants.is_empty(), "azure-macro needs at least one variant");
    let shards = cfg.shards.max(1);
    let grid: Vec<usize> = (0..shards).collect();
    let flat = runner.run(&grid, |_, &shard| -> Result<ShardSlice> {
        let (apps, skipped) = load_shard_apps(&cfg.source, shard, shards)?;
        let rows = apps.iter().map(|(_, r)| r.len() as u64).sum();
        let mut per_variant = vec![MacroMetrics::default(); cfg.variants.len()];
        for (vi, variant) in cfg.variants.iter().enumerate() {
            for &seed in seeds {
                let rcfg = variant.replay_cfg(seed, cfg.warmup_minutes);
                for (app, app_rows) in &apps {
                    per_variant[vi].merge(&replay_app(app, app_rows, &rcfg));
                }
            }
        }
        Ok(ShardSlice {
            per_variant,
            rows,
            skipped,
        })
    });

    let mut variants: Vec<(Variant, MacroMetrics)> = cfg
        .variants
        .iter()
        .map(|&v| (v, MacroMetrics::default()))
        .collect();
    let mut trace_rows = 0u64;
    let mut skipped_rows = 0u64;
    for (shard, slice) in flat.into_iter().enumerate() {
        let slice = slice?;
        for (vi, m) in slice.per_variant.iter().enumerate() {
            variants[vi].1.merge(m);
        }
        trace_rows += slice.rows;
        // Every CSV shard scans (and skip-counts) the whole file; report
        // the per-scan number once.
        if shard == 0 {
            skipped_rows = slice.skipped;
        }
    }
    Ok(AzureMacro {
        variants,
        shards,
        seeds: seeds.to_vec(),
        trace_rows,
        skipped_rows,
    })
}

impl AzureMacro {
    /// Canonical fingerprint of the merged metrics (one line per variant)
    /// — what the determinism regression tests compare byte-for-byte.
    pub fn digest(&self) -> String {
        self.variants
            .iter()
            .map(|(v, m)| format!("{}: {}", v.as_str(), m.digest()))
            .collect::<Vec<String>>()
            .join("\n")
    }

    pub fn print(&self) {
        let first = &self.variants[0].1;
        println!(
            "\n== azure-macro: {} invocations / {} functions / {} apps per variant, \
             {} shards, seeds {:?} ==",
            first.invocations, first.functions, first.apps, self.shards, self.seeds
        );
        if self.skipped_rows > 0 {
            println!("(skipped {} malformed trace rows)", self.skipped_rows);
        }
        let rows: Vec<Vec<String>> = self
            .variants
            .iter()
            .map(|(v, m)| {
                vec![
                    v.as_str().to_string(),
                    m.invocations.to_string(),
                    format!("{:.2}%", 100.0 * m.cold_start_rate()),
                    format!("{:.1}", m.p50_ms()),
                    format!("{:.1}", m.p99_ms()),
                    format!("{:.0}%", 100.0 * m.freshen_hit_rate()),
                    format!("{:.1}%", 100.0 * m.wasted_freshen_fraction()),
                    format!("{:.1}MB", m.network_bytes_saved as f64 / 1e6),
                ]
            })
            .collect();
        print_table(
            &[
                "variant",
                "invocations",
                "cold rate",
                "p50 ms",
                "p99 ms",
                "fr hits",
                "fr wasted",
                "net saved",
            ],
            &rows,
        );
        let demoted = self
            .variants
            .iter()
            .map(|(_, m)| m.chains_demoted)
            .max()
            .unwrap_or(0);
        if demoted > 0 {
            println!(
                "({demoted} apps had non-mirrored chain counts and replayed as \
                 independent rows)"
            );
        }
        if let Some((_, base)) = self
            .variants
            .iter()
            .find(|(v, _)| *v == Variant::Baseline)
        {
            for (v, m) in &self.variants {
                if *v == Variant::Baseline || m.p50_ms() == 0.0 {
                    continue;
                }
                println!(
                    "{}: p50 speedup {:.2}x, cold starts {} -> {}",
                    v.as_str(),
                    base.p50_ms() / m.p50_ms(),
                    base.cold_starts,
                    m.cold_starts
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::macrotrace::synth::SynthTraceCfg;

    fn small_cfg() -> AzureMacroCfg {
        let mut cfg = AzureMacroCfg::new(TraceSource::Synth(SynthTraceCfg {
            apps: 24,
            minutes: 12,
            seed: 3,
            ..SynthTraceCfg::default()
        }));
        cfg.shards = 2;
        cfg.warmup_minutes = 3;
        cfg.variants = vec![Variant::Baseline, Variant::Both];
        cfg
    }

    #[test]
    fn baseline_never_freshens_and_full_system_does() {
        let r = run_multi(&small_cfg(), &[1], &SweepRunner::new(2)).unwrap();
        let base = &r.variants[0].1;
        let both = &r.variants[1].1;
        assert!(base.invocations > 0);
        assert_eq!(base.freshens_started, 0);
        assert!(both.freshens_started > 0);
        assert!(r.trace_rows > 0);
        // Every variant replays the same trace volume.
        assert_eq!(base.functions, both.functions);
        assert_eq!(base.apps, both.apps);
    }

    #[test]
    fn variant_parse_roundtrip() {
        for v in Variant::all() {
            assert_eq!(Variant::parse(v.as_str()), Some(v));
        }
        assert_eq!(Variant::parse("full"), Some(Variant::Both));
        assert_eq!(Variant::parse("bogus"), None);
    }

    #[test]
    fn multi_seed_pools_across_seeds() {
        let cfg = small_cfg();
        let one = run_multi(&cfg, &[1], &SweepRunner::new(1)).unwrap();
        let two = run_multi(&cfg, &[1, 2], &SweepRunner::new(4)).unwrap();
        assert!(
            two.variants[0].1.invocations > one.variants[0].1.invocations,
            "two seeds pool more invocations"
        );
        // Trace accounting is per pass, not per grid point.
        assert_eq!(one.trace_rows, two.trace_rows);
    }
}
