//! `azure-macro` — the platform-scale Azure-trace macro benchmark.
//!
//! Replays an Azure-Functions-shaped trace (a real CSV or the offline
//! synthesizer) through the full platform under three ablation axes:
//!
//! - **predictor variant** (freshen off / histogram / chain / both) — the
//!   paper's axis;
//! - **pool mode** (`--pool per-app|shared`) — isolated per-app worlds,
//!   or one memory-bounded world per shard where warm containers of all
//!   tenants genuinely compete;
//! - **keep-alive policy** (`--keep-alive fixed,lru,hybrid`) — which
//!   [`KeepAlivePolicy`](crate::platform::keepalive::KeepAlivePolicy)
//!   governs idle/pressure eviction;
//! - **queue discipline** (`--queue legacy,fifo,memaware`) — which
//!   [`QueueDiscipline`](crate::platform::dispatch::QueueDiscipline)
//!   holds and drains invocations waiting on cluster memory;
//! - **placement strategy** (`--placement legacy,random,rr,affinity,constrained`)
//!   — which [`Placement`](crate::platform::placement::Placement) strategy
//!   chooses the invoker host a cold start lands on, optionally over
//!   heterogeneous `--host-classes` (cloud vs edge);
//! - **cold-start mitigation** (`--mitigation keepalive,snapshot,freshen,hybrid`)
//!   — which mechanism absorbs cold starts at a fixed memory budget:
//!   plain keep-alive, snapshot/restore (idle expiry parks a discounted
//!   snapshot that later restores at base + page-in cost), predictive
//!   freshen, or snapshot + freshen-on-restore combined.
//!
//! Reports the metrics the literature compares on — cold-start rate,
//! p50/p99 end-to-end latency, freshen hit rate, wasted-freshen fraction
//! — plus, for contended configurations, evictions by cause, warm-kill
//! rate, and peak/integral resident memory, and (on a queue-discipline
//! grid) queue depth, time-in-queue and stale-freshen-abort counters; per
//! variant×policy×queue cell, merged across shards and seeds. `--days N`
//! replays N day slices with pool + predictor state carried across day
//! boundaries and per-day metrics.
//!
//! The grid is **shard-major**: each [`SweepRunner`] worker gathers its
//! shard's rows ONCE (one streaming pass over a CSV, or direct synthesis
//! of its apps) and replays that slice under every `(variant × policy ×
//! seed)` combination — a real 1440-minute trace is scanned `shards`
//! times total, not per grid cell. Parallelism therefore tops out at
//! `--shards`; run with `--shards >= --parallel`. Merges follow the
//! macrotrace determinism contract: byte-identical output for any
//! `--shards` × `--parallel` combination in per-app mode, and for any
//! `--parallel` at fixed `--shards` in shared mode (regression-tested in
//! `tests/azure_macro_determinism.rs`).

use anyhow::{bail, Result};

use crate::experiments::harness::SweepRunner;
use crate::experiments::print_table;
use crate::util::config::{HostClass, KeepAliveKind, MemoryAccounting, PlacementKind, QueueKind};
use crate::util::rng::mix64;
use crate::workload::macrotrace::replay::{
    app_hash, replay_pool_days, shared_world_seed, MacroMetrics, PoolMode, PredictorPolicy,
    ReplayCfg,
};
use crate::workload::macrotrace::shard::{
    load_shard_apps, replay_shard_apps, shard_synth_apps, shard_synth_day, ShardApps,
    TraceSource,
};

/// One benchmark variant: a freshen switch + predictor policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Vanilla platform, freshen off.
    Baseline,
    /// Freshen admitted by IAT-histogram predictions only.
    Histogram,
    /// Freshen admitted by explicit-chain predictions only.
    Chain,
    /// The full system: both prediction sources.
    Both,
}

impl Variant {
    pub fn all() -> [Variant; 4] {
        [Variant::Baseline, Variant::Histogram, Variant::Chain, Variant::Both]
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "baseline" | "off" => Some(Variant::Baseline),
            "hist" | "histogram" => Some(Variant::Histogram),
            "chain" => Some(Variant::Chain),
            "both" | "full" => Some(Variant::Both),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
            Variant::Histogram => "hist",
            Variant::Chain => "chain",
            Variant::Both => "both",
        }
    }

    fn policy(&self) -> PredictorPolicy {
        match self {
            Variant::Baseline => PredictorPolicy::None,
            Variant::Histogram => PredictorPolicy::Histogram,
            Variant::Chain => PredictorPolicy::Chain,
            Variant::Both => PredictorPolicy::Both,
        }
    }

    fn freshen_enabled(&self) -> bool {
        !matches!(self, Variant::Baseline)
    }

    /// The replay configuration this variant runs under.
    pub fn replay_cfg(&self, seed: u64, warmup_minutes: usize) -> ReplayCfg {
        let mut cfg = ReplayCfg::default();
        cfg.base.freshen.enabled = self.freshen_enabled();
        cfg.policy = self.policy();
        cfg.seed = seed;
        cfg.warmup_minutes = warmup_minutes;
        cfg
    }
}

/// One cold-start mitigation strategy — the macro benchmark's fifth
/// ablation axis. Each cell fixes the snapshot/freshen switches; the
/// variant still chooses the predictor policy for freshen-using cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mitigation {
    /// Plain keep-alive: freshen off, snapshot off (the pure-eviction
    /// baseline every other mitigation is compared against).
    Keepalive,
    /// Snapshot/restore: idle expiry demotes the container to a parked
    /// snapshot at a discounted memory charge; the next arrival restores
    /// it at base + page-in cost instead of cold-starting.
    Snapshot,
    /// Predictive freshen (the paper's system), snapshot off.
    Freshen,
    /// Snapshot/restore plus a freshen run launched on every restore
    /// (`snapshot.freshen_on_restore`), with the variant's predictors.
    Hybrid,
}

impl Mitigation {
    pub fn all() -> [Mitigation; 4] {
        [
            Mitigation::Keepalive,
            Mitigation::Snapshot,
            Mitigation::Freshen,
            Mitigation::Hybrid,
        ]
    }

    pub fn parse(s: &str) -> Option<Mitigation> {
        match s {
            "keepalive" | "keep-alive" | "ka" => Some(Mitigation::Keepalive),
            "snapshot" | "snap" => Some(Mitigation::Snapshot),
            "freshen" => Some(Mitigation::Freshen),
            "hybrid" => Some(Mitigation::Hybrid),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Mitigation::Keepalive => "keepalive",
            Mitigation::Snapshot => "snapshot",
            Mitigation::Freshen => "freshen",
            Mitigation::Hybrid => "hybrid",
        }
    }
}

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct AzureMacroCfg {
    pub source: TraceSource,
    pub shards: usize,
    pub warmup_minutes: usize,
    pub variants: Vec<Variant>,
    /// Per-app worlds (default) or one shared pool per shard.
    pub pool: PoolMode,
    /// Keep-alive policies to ablate (default: `[FixedTtl]`, the legacy
    /// behavior).
    pub policies: Vec<KeepAliveKind>,
    /// Queue disciplines to ablate (default: `[LegacyOneShot]`, the
    /// legacy behavior).
    pub queues: Vec<QueueKind>,
    /// Placement strategies to ablate (default: `[LeastLoadedMb]`, the
    /// legacy behavior).
    pub placements: Vec<PlacementKind>,
    /// Heterogeneous host classes for the replay worlds (default `None` =
    /// the homogeneous legacy cluster).
    pub host_classes: Option<Vec<HostClass>>,
    /// Abort stale freshen runs on pressure-reclaimed containers
    /// (`Config::freshen_incarnation_guard`; default off = legacy).
    pub freshen_guard: bool,
    /// Day slices to replay with cross-day state carry (synth only; 1 =
    /// the historical single-horizon run).
    pub days: usize,
    /// Cluster sizing overrides for the replay worlds.
    pub invokers: Option<usize>,
    pub invoker_memory_mb: Option<u64>,
    /// Record lifecycle spans (`obs::Tracer`) in every replay world.
    /// Off by default: the tracer stays compiled-in but disabled, and
    /// stdout/digests are byte-identical to a spans-off run.
    pub trace_spans: bool,
    /// Substring filter on function names for recorded spans (shared
    /// pools qualify names `app/function`, so an app name selects a
    /// whole tenant).
    pub span_filter: Option<String>,
    /// Per-world span ring capacity (oldest events drop beyond it).
    pub span_cap: usize,
    /// Collect rolling per-function telemetry windows
    /// (`obs::WindowSet`) and print the per-function table.
    pub fn_windows: bool,
    /// Override the `MemoryAware` queue anti-starvation aging bound,
    /// seconds (`Config::queue_aging_bound`; default 30 s).
    pub queue_aging_bound: Option<u64>,
    /// Cold-start mitigations to ablate (`--mitigation`). `None` (the
    /// default) is the legacy grid: no mitigation dimension, no label
    /// segment, every historical digest byte-for-byte unchanged.
    pub mitigations: Option<Vec<Mitigation>>,
}

impl AzureMacroCfg {
    pub fn new(source: TraceSource) -> AzureMacroCfg {
        AzureMacroCfg {
            source,
            shards: 4,
            warmup_minutes: 10,
            variants: Variant::all().to_vec(),
            pool: PoolMode::PerApp,
            policies: vec![KeepAliveKind::FixedTtl],
            queues: vec![QueueKind::LegacyOneShot],
            placements: vec![PlacementKind::LeastLoadedMb],
            host_classes: None,
            freshen_guard: false,
            days: 1,
            invokers: None,
            invoker_memory_mb: None,
            trace_spans: false,
            span_filter: None,
            span_cap: crate::obs::DEFAULT_SPAN_CAP,
            fn_windows: false,
            queue_aging_bound: None,
            mitigations: None,
        }
    }

    /// The replay config for one `(mitigation, placement, queue, policy,
    /// variant, seed)` grid cell.
    fn cell_cfg(
        &self,
        mitigation: Option<Mitigation>,
        variant: Variant,
        policy: KeepAliveKind,
        queue: QueueKind,
        placement: PlacementKind,
        seed: u64,
    ) -> ReplayCfg {
        let mut r = variant.replay_cfg(seed, self.warmup_minutes);
        r.pool = self.pool;
        r.base.keep_alive = policy;
        r.base.queue = queue;
        r.base.placement = placement;
        if let Some(classes) = &self.host_classes {
            r.base.host_classes = classes.clone();
        }
        r.base.freshen_incarnation_guard = self.freshen_guard;
        if let Some(n) = self.invokers {
            r.base.invokers = n;
        }
        if let Some(mb) = self.invoker_memory_mb {
            r.base.invoker_memory_mb = Some(mb);
        }
        if self.pool == PoolMode::Shared {
            // A shared cluster charges real per-function memory — that is
            // the contention the mode exists to model.
            r.base.memory_accounting = MemoryAccounting::FunctionMb;
        }
        if let Some(secs) = self.queue_aging_bound {
            r.base.queue_aging_bound = crate::util::time::SimDuration::from_secs(secs);
        }
        r.trace_spans = self.trace_spans;
        r.span_cap = self.span_cap;
        r.span_filter = self.span_filter.clone();
        r.fn_windows = self.fn_windows;
        // The mitigation axis only flips the freshen/snapshot switches —
        // the variant's predictor policy (and therefore the arrival
        // stream, chains included) is untouched, so the four mitigations
        // of a cell replay the identical workload at the identical
        // memory budget.
        if let Some(m) = mitigation {
            match m {
                Mitigation::Keepalive => {
                    r.base.freshen.enabled = false;
                }
                Mitigation::Snapshot => {
                    r.base.freshen.enabled = false;
                    r.base.snapshot.enabled = true;
                }
                Mitigation::Freshen => {}
                Mitigation::Hybrid => {
                    r.base.snapshot.enabled = true;
                    r.base.snapshot.freshen_on_restore = true;
                }
            }
        }
        r
    }

    /// Does the report need the contention extras (non-legacy axes)?
    fn contended(&self) -> bool {
        self.pool == PoolMode::Shared
            || self.days > 1
            || self.policies != vec![KeepAliveKind::FixedTtl]
            || self.queues != vec![QueueKind::LegacyOneShot]
            || self.placements != vec![PlacementKind::LeastLoadedMb]
            || self.host_classes.is_some()
            || self.freshen_guard
            || self.mitigations.is_some()
    }
}

/// One `(variant, keep-alive policy, queue discipline, placement)` cell
/// of the merged benchmark.
#[derive(Debug, Clone)]
pub struct MacroRow {
    pub variant: Variant,
    pub policy: KeepAliveKind,
    pub queue: QueueKind,
    pub placement: PlacementKind,
    /// Cold-start mitigation for this cell; `None` on a legacy grid.
    pub mitigation: Option<Mitigation>,
    /// Metrics merged across shards, seeds and days.
    pub metrics: MacroMetrics,
    /// Per-day metrics (length = `days`), merged across shards and seeds.
    pub per_day: Vec<MacroMetrics>,
}

impl MacroRow {
    /// Row label: the variant, qualified by the policy / queue discipline
    /// / placement strategy / mitigation when those axes are in play. The
    /// placement and mitigation segments only appear on grids that sweep
    /// them, so every historical `variant/policy/queue` label (and digest
    /// line) is unchanged.
    fn label(
        &self,
        with_policy: bool,
        with_queue: bool,
        with_placement: bool,
        with_mitigation: bool,
    ) -> String {
        let mut s = self.variant.as_str().to_string();
        if with_policy {
            s.push('/');
            s.push_str(self.policy.as_str());
        }
        if with_queue {
            s.push('/');
            s.push_str(self.queue.as_str());
        }
        if with_placement {
            s.push('/');
            s.push_str(self.placement.as_str());
        }
        if with_mitigation {
            if let Some(m) = self.mitigation {
                s.push('/');
                s.push_str(m.as_str());
            }
        }
        s
    }
}

/// The merged benchmark result.
#[derive(Debug, Clone)]
pub struct AzureMacro {
    /// Per-cell metrics (mitigation-major, then placement, then queue,
    /// then policy, variants in request order within — the default
    /// single-mitigation single-placement single-queue grid is
    /// policy-major, as before).
    pub rows: Vec<MacroRow>,
    pub shards: usize,
    pub seeds: Vec<u64>,
    pub pool: PoolMode,
    pub days: usize,
    /// Rows in one pass over the trace (and malformed rows skipped).
    pub trace_rows: u64,
    pub skipped_rows: u64,
    /// Whether the report carries the contention extras.
    contended: bool,
    /// Whether the incarnation guard ran (gates the queue table even on a
    /// single-discipline grid, so the stale-abort counter is visible).
    guard: bool,
    /// Whether per-function windows were collected (gates their table, so
    /// default stdout stays byte-identical).
    windows: bool,
}

/// One shard worker's output: per-cell, per-day metrics (seeds merged
/// in), the shard's row count, and the scan's skip count.
struct ShardSlice {
    per_cell: Vec<Vec<MacroMetrics>>,
    rows: u64,
    skipped: u64,
}

/// Run the benchmark. Shard-major: each worker ingests its shard once and
/// replays it under every `(placement × queue × policy × variant ×
/// seed)`; shard slices then merge per cell in shard order (commutative
/// merges — any order gives the same bytes).
pub fn run_multi(
    cfg: &AzureMacroCfg,
    seeds: &[u64],
    runner: &SweepRunner,
) -> Result<AzureMacro> {
    assert!(!seeds.is_empty(), "azure-macro needs at least one seed");
    assert!(!cfg.variants.is_empty(), "azure-macro needs at least one variant");
    assert!(!cfg.policies.is_empty(), "azure-macro needs at least one keep-alive policy");
    assert!(!cfg.queues.is_empty(), "azure-macro needs at least one queue discipline");
    assert!(!cfg.placements.is_empty(), "azure-macro needs at least one placement strategy");
    if let Some(mits) = &cfg.mitigations {
        assert!(!mits.is_empty(), "azure-macro needs at least one mitigation when the axis is swept");
    }
    let days = cfg.days.max(1);
    if days > 1 && !matches!(cfg.source, TraceSource::Synth(_)) {
        bail!("--days needs the synthesizer (day-sliced CSVs are not ingestable yet)");
    }
    let shards = cfg.shards.max(1);
    let mits: Vec<Option<Mitigation>> = match &cfg.mitigations {
        None => vec![None],
        Some(ms) => ms.iter().map(|&m| Some(m)).collect(),
    };
    let cells: Vec<(Option<Mitigation>, PlacementKind, QueueKind, KeepAliveKind, Variant)> = mits
        .iter()
        .flat_map(|&m| {
            cfg.placements.iter().flat_map(move |&pl| {
                cfg.queues.iter().flat_map(move |&q| {
                    cfg.policies
                        .iter()
                        .flat_map(move |&p| cfg.variants.iter().map(move |&v| (m, pl, q, p, v)))
                })
            })
        })
        .collect();
    let grid: Vec<usize> = (0..shards).collect();
    let flat = runner.run(&grid, |_, &shard| -> Result<ShardSlice> {
        // Gather the shard's trace slice once. Multi-day runs also
        // materialise each later day's counts (same apps, new arrivals).
        let (apps, skipped) = load_shard_apps(&cfg.source, shard, shards)?;
        // Multi-day rows, materialised ONCE per shard. Shared mode keeps
        // them day-major (`day_slices`); per-app mode transposes them
        // into per-app day columns (`per_app_days`) by move, so the rows
        // are never cloned per grid cell.
        let mut day_slices: Vec<ShardApps> = Vec::new();
        let mut per_app_days: Vec<Vec<ShardApps>> = Vec::new();
        if days > 1 {
            let TraceSource::Synth(synth) = &cfg.source else {
                unreachable!("validated above");
            };
            let idx = shard_synth_apps(synth, shard, shards);
            // Day 0 is exactly what load_shard_apps materialised
            // (regression-tested in shard.rs) — reuse it instead of
            // paying a second synthesis pass.
            let mut slices = Vec::with_capacity(days);
            slices.push(apps.clone());
            slices.extend((1..days).map(|d| shard_synth_day(synth, &idx, d)));
            if cfg.pool == PoolMode::PerApp {
                per_app_days = (0..apps.len()).map(|_| Vec::with_capacity(days)).collect();
                for day in slices {
                    for (a, pair) in day.into_iter().enumerate() {
                        per_app_days[a].push(vec![pair]);
                    }
                }
            } else {
                day_slices = slices;
            }
        }
        let day_minutes = match &cfg.source {
            TraceSource::Synth(s) => s.minutes,
            TraceSource::Csv(_) => 0,
        };
        let rows = apps.iter().map(|(_, r)| r.len() as u64).sum();
        let mut per_cell = vec![vec![MacroMetrics::default(); days]; cells.len()];
        for (ci, &(mitigation, placement, queue, policy, variant)) in cells.iter().enumerate() {
            for &seed in seeds {
                let rcfg = cfg.cell_cfg(mitigation, variant, policy, queue, placement, seed);
                let per_day: Vec<MacroMetrics> = if days > 1 {
                    match cfg.pool {
                        PoolMode::Shared => replay_pool_days(
                            &day_slices,
                            &rcfg,
                            shared_world_seed(rcfg.seed, shard),
                            day_minutes,
                        ),
                        PoolMode::PerApp => {
                            let mut acc = vec![MacroMetrics::default(); days];
                            for (a, (app, _)) in apps.iter().enumerate() {
                                let seed_a = mix64(rcfg.seed, app_hash(app));
                                let pd = replay_pool_days(
                                    &per_app_days[a],
                                    &rcfg,
                                    seed_a,
                                    day_minutes,
                                );
                                for (d, m) in pd.iter().enumerate() {
                                    acc[d].merge(m);
                                }
                            }
                            acc
                        }
                    }
                } else {
                    vec![replay_shard_apps(&apps, shard, &rcfg)]
                };
                for (d, m) in per_day.iter().enumerate() {
                    per_cell[ci][d].merge(m);
                }
            }
        }
        Ok(ShardSlice {
            per_cell,
            rows,
            skipped,
        })
    });

    let mut rows_out: Vec<MacroRow> = cells
        .iter()
        .map(|&(mitigation, placement, queue, policy, variant)| MacroRow {
            variant,
            policy,
            queue,
            placement,
            mitigation,
            metrics: MacroMetrics::default(),
            per_day: vec![MacroMetrics::default(); days],
        })
        .collect();
    let mut trace_rows = 0u64;
    let mut skipped_rows = 0u64;
    for (shard, slice) in flat.into_iter().enumerate() {
        let slice = slice?;
        for (ci, days_m) in slice.per_cell.iter().enumerate() {
            for (d, m) in days_m.iter().enumerate() {
                rows_out[ci].per_day[d].merge(m);
                rows_out[ci].metrics.merge(m);
            }
        }
        trace_rows += slice.rows;
        // Every CSV shard scans (and skip-counts) the whole file; report
        // the per-scan number once.
        if shard == 0 {
            skipped_rows = slice.skipped;
        }
    }
    Ok(AzureMacro {
        rows: rows_out,
        shards,
        seeds: seeds.to_vec(),
        pool: cfg.pool,
        days,
        trace_rows,
        skipped_rows,
        contended: cfg.contended(),
        guard: cfg.freshen_guard,
        windows: cfg.fn_windows,
    })
}

impl AzureMacro {
    /// Does the report label rows with their keep-alive policy? (Any
    /// grid with a non-default policy; a mixed grid necessarily has one.)
    fn policy_axis(&self) -> bool {
        self.rows.iter().any(|r| r.policy != KeepAliveKind::FixedTtl)
    }

    /// Does the report label rows with their queue discipline?
    fn queue_axis(&self) -> bool {
        self.rows.iter().any(|r| r.queue != QueueKind::LegacyOneShot)
    }

    /// Does the report label rows with their placement strategy? Gated so
    /// an all-legacy grid keeps the historical three-segment labels (and
    /// digest lines) byte-for-byte.
    fn placement_axis(&self) -> bool {
        self.rows.iter().any(|r| r.placement != PlacementKind::LeastLoadedMb)
    }

    /// Does the report label rows with their cold-start mitigation?
    /// A legacy grid has `mitigation == None` on every row, so the label
    /// segment (and the mitigation table) never appears there.
    fn mitigation_axis(&self) -> bool {
        self.rows.iter().any(|r| r.mitigation.is_some())
    }

    /// Canonical fingerprint of the merged metrics (one line per cell,
    /// plus per-day lines on multi-day runs) — what the determinism
    /// regression tests compare byte-for-byte. Labels are fully
    /// qualified (`variant/policy/queue`, plus `/placement` on a
    /// placement grid).
    pub fn digest(&self) -> String {
        let with_placement = self.placement_axis();
        let with_mitigation = self.mitigation_axis();
        let mut lines: Vec<String> = self
            .rows
            .iter()
            .map(|r| {
                format!(
                    "{}: {}",
                    r.label(true, true, with_placement, with_mitigation),
                    r.metrics.digest()
                )
            })
            .collect();
        if self.days > 1 {
            for r in &self.rows {
                for (d, m) in r.per_day.iter().enumerate() {
                    lines.push(format!(
                        "{} day{}: {}",
                        r.label(true, true, with_placement, with_mitigation),
                        d,
                        m.digest()
                    ));
                }
            }
        }
        lines.join("\n")
    }

    /// Per-cell span streams for export: `(fully-qualified cell label,
    /// sink)` in row order — what `--span-log` writes through
    /// [`crate::obs::export::export`].
    pub fn span_rows(&self) -> Vec<(String, &crate::obs::SpanSink)> {
        let with_placement = self.placement_axis();
        let with_mitigation = self.mitigation_axis();
        self.rows
            .iter()
            .map(|r| {
                (
                    r.label(true, true, with_placement, with_mitigation),
                    &r.metrics.spans,
                )
            })
            .collect()
    }

    /// Canonical fingerprint of the recorded span streams, one line per
    /// cell — what the trace-determinism tests compare across `--shards`
    /// × `--parallel` grids. Deliberately separate from [`digest`]
    /// (`AzureMacro::digest`), which stays byte-identical whether
    /// tracing is on or off.
    pub fn span_digest(&self) -> String {
        let with_placement = self.placement_axis();
        let with_mitigation = self.mitigation_axis();
        self.rows
            .iter()
            .map(|r| {
                format!(
                    "{}: {}",
                    r.label(true, true, with_placement, with_mitigation),
                    r.metrics.span_digest()
                )
            })
            .collect::<Vec<String>>()
            .join("\n")
    }

    pub fn print(&self) {
        let with_policy = self.policy_axis();
        let with_queue = self.queue_axis();
        let with_placement = self.placement_axis();
        let with_mitigation = self.mitigation_axis();
        let first = &self.rows[0].metrics;
        println!(
            "\n== azure-macro: {} invocations / {} functions / {} apps per variant, \
             {} shards, seeds {:?} ==",
            first.invocations, first.functions, first.apps, self.shards, self.seeds
        );
        if self.contended {
            println!(
                "(pool={}, keep-alive x variant grid, {} day{})",
                self.pool.as_str(),
                self.days,
                if self.days == 1 { "" } else { "s" }
            );
        }
        if self.skipped_rows > 0 {
            println!("(skipped {} malformed trace rows)", self.skipped_rows);
        }
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                let m = &r.metrics;
                vec![
                    r.label(with_policy, with_queue, with_placement, with_mitigation),
                    m.invocations.to_string(),
                    format!("{:.2}%", 100.0 * m.cold_start_rate()),
                    format!("{:.1}", m.p50_ms()),
                    format!("{:.1}", m.p99_ms()),
                    format!("{:.0}%", 100.0 * m.freshen_hit_rate()),
                    format!("{:.1}%", 100.0 * m.wasted_freshen_fraction()),
                    format!("{:.1}MB", m.network_bytes_saved as f64 / 1e6),
                ]
            })
            .collect();
        print_table(
            &[
                "variant",
                "invocations",
                "cold rate",
                "p50 ms",
                "p99 ms",
                "fr hits",
                "fr wasted",
                "net saved",
            ],
            &rows,
        );
        if self.contended {
            // Contention extras: evictions by cause, warm kills, memory.
            let rows: Vec<Vec<String>> = self
                .rows
                .iter()
                .map(|r| {
                    let m = &r.metrics;
                    vec![
                        r.label(with_policy, with_queue, with_placement, with_mitigation),
                        m.evictions.to_string(),
                        m.evictions_idle.to_string(),
                        m.evictions_pressure.to_string(),
                        format!("{:.1}%", 100.0 * m.warm_kill_rate()),
                        m.peak_resident_mb.to_string(),
                        format!("{:.0}", m.resident_mb_s()),
                    ]
                })
                .collect();
            print_table(
                &[
                    "variant",
                    "evictions",
                    "idle",
                    "pressure",
                    "warm-kill",
                    "peak MB",
                    "MB·s",
                ],
                &rows,
            );
        }
        if with_queue || self.guard {
            // Queue-discipline extras: depth, time-in-queue, stale aborts.
            // Only printed when the queue axis (or the incarnation guard)
            // is in play, so legacy-default stdout stays byte-identical.
            let rows: Vec<Vec<String>> = self
                .rows
                .iter()
                .map(|r| {
                    let m = &r.metrics;
                    vec![
                        r.label(with_policy, with_queue, with_placement, with_mitigation),
                        m.queued_total.to_string(),
                        m.queue_peak_depth.to_string(),
                        format!("{:.1}", m.queue_wait_s()),
                        format!("{:.1}", m.queue_wait_max_ms()),
                        m.stale_freshen_aborts.to_string(),
                        m.dropped_infeasible.to_string(),
                    ]
                })
                .collect();
            print_table(
                &[
                    "variant",
                    "queued",
                    "peak depth",
                    "wait s",
                    "wait max ms",
                    "stale aborts",
                    "dropped",
                ],
                &rows,
            );
        }
        if with_mitigation {
            // Mitigation extras: how many containers parked as snapshots,
            // how much traffic restores served, and what the restores
            // cost. Only printed on a mitigation grid, so legacy stdout
            // stays byte-identical.
            let rows: Vec<Vec<String>> = self
                .rows
                .iter()
                .map(|r| {
                    let m = &r.metrics;
                    vec![
                        r.label(with_policy, with_queue, with_placement, with_mitigation),
                        m.snapshots.to_string(),
                        m.restored_starts.to_string(),
                        format!("{:.2}%", 100.0 * m.restored_start_rate()),
                        format!("{:.1}", m.mean_restore_ms()),
                        m.freshens_on_restore.to_string(),
                    ]
                })
                .collect();
            print_table(
                &[
                    "variant",
                    "snapshots",
                    "restored",
                    "restore rate",
                    "restore ms",
                    "fr@restore",
                ],
                &rows,
            );
        }
        if self.windows {
            // Opt-in per-function telemetry windows (`--fn-windows`):
            // one table per cell, top functions by invocation volume.
            // All columns are integer-derived (obs/window.rs holds no
            // floats), so the table merges identically across shards.
            for r in &self.rows {
                let w = &r.metrics.fn_windows;
                if w.is_empty() {
                    continue;
                }
                println!(
                    "\n{} per-function windows ({} functions, {}s windows):",
                    r.label(with_policy, with_queue, with_placement, with_mitigation),
                    w.len(),
                    w.window_us / 1_000_000
                );
                let rows: Vec<Vec<String>> = w
                    .top_by_invocations(20)
                    .into_iter()
                    .map(|(f, fw)| {
                        let pm = fw.cold_per_mille();
                        vec![
                            f.to_string(),
                            fw.invocations.to_string(),
                            format!("{}.{}%", pm / 10, pm % 10),
                            fw.queue_wait.quantile_us(50).to_string(),
                            fw.queue_wait.quantile_us(99).to_string(),
                            fw.iat_drift_us().to_string(),
                            fw.wasted_freshens.to_string(),
                            fw.stale_aborts.to_string(),
                            fw.peak_window_invocations.to_string(),
                        ]
                    })
                    .collect();
                print_table(
                    &[
                        "function",
                        "inv",
                        "cold",
                        "qw p50 µs",
                        "qw p99 µs",
                        "iat drift µs",
                        "wasted",
                        "stale",
                        "peak/win",
                    ],
                    &rows,
                );
            }
        }
        if self.days > 1 {
            for r in &self.rows {
                let per: Vec<String> = r
                    .per_day
                    .iter()
                    .enumerate()
                    .map(|(d, m)| {
                        format!(
                            "d{d}: {} inv / {:.2}% cold / p99 {:.1}ms",
                            m.invocations,
                            100.0 * m.cold_start_rate(),
                            m.p99_ms()
                        )
                    })
                    .collect();
                println!("{} per-day: {}", r.label(with_policy, with_queue, with_placement, with_mitigation), per.join("; "));
            }
        }
        let demoted = self
            .rows
            .iter()
            .map(|r| r.metrics.chains_demoted)
            .max()
            .unwrap_or(0);
        if demoted > 0 {
            println!(
                "({demoted} apps had non-mirrored chain counts and replayed as \
                 independent rows)"
            );
        }
        // Speedups vs the baseline variant under the SAME keep-alive
        // policy, queue discipline and placement strategy (cross-axis
        // comparisons live in the tables themselves).
        for r in &self.rows {
            if r.variant == Variant::Baseline || r.metrics.p50_ms() == 0.0 {
                continue;
            }
            let Some(base) = self.rows.iter().find(|b| {
                b.variant == Variant::Baseline
                    && b.policy == r.policy
                    && b.queue == r.queue
                    && b.placement == r.placement
                    && b.mitigation == r.mitigation
            }) else {
                continue;
            };
            println!(
                "{}: p50 speedup {:.2}x, cold starts {} -> {}",
                r.label(with_policy, with_queue, with_placement, with_mitigation),
                base.metrics.p50_ms() / r.metrics.p50_ms(),
                base.metrics.cold_starts,
                r.metrics.cold_starts
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::macrotrace::synth::SynthTraceCfg;

    fn small_cfg() -> AzureMacroCfg {
        let mut cfg = AzureMacroCfg::new(TraceSource::Synth(SynthTraceCfg {
            apps: 24,
            minutes: 12,
            seed: 3,
            ..SynthTraceCfg::default()
        }));
        cfg.shards = 2;
        cfg.warmup_minutes = 3;
        cfg.variants = vec![Variant::Baseline, Variant::Both];
        cfg
    }

    #[test]
    fn baseline_never_freshens_and_full_system_does() {
        let r = run_multi(&small_cfg(), &[1], &SweepRunner::new(2)).unwrap();
        let base = &r.rows[0].metrics;
        let both = &r.rows[1].metrics;
        assert!(base.invocations > 0);
        assert_eq!(base.freshens_started, 0);
        assert!(both.freshens_started > 0);
        assert!(r.trace_rows > 0);
        // Every variant replays the same trace volume.
        assert_eq!(base.functions, both.functions);
        assert_eq!(base.apps, both.apps);
    }

    #[test]
    fn variant_parse_roundtrip() {
        for v in Variant::all() {
            assert_eq!(Variant::parse(v.as_str()), Some(v));
        }
        assert_eq!(Variant::parse("full"), Some(Variant::Both));
        assert_eq!(Variant::parse("bogus"), None);
    }

    #[test]
    fn multi_seed_pools_across_seeds() {
        let cfg = small_cfg();
        let one = run_multi(&cfg, &[1], &SweepRunner::new(1)).unwrap();
        let two = run_multi(&cfg, &[1, 2], &SweepRunner::new(4)).unwrap();
        assert!(
            two.rows[0].metrics.invocations > one.rows[0].metrics.invocations,
            "two seeds pool more invocations"
        );
        // Trace accounting is per pass, not per grid point.
        assert_eq!(one.trace_rows, two.trace_rows);
    }

    #[test]
    fn policy_axis_produces_one_row_per_cell() {
        let mut cfg = small_cfg();
        cfg.variants = vec![Variant::Baseline, Variant::Both];
        cfg.policies = vec![KeepAliveKind::FixedTtl, KeepAliveKind::LruPressure];
        let r = run_multi(&cfg, &[1], &SweepRunner::new(2)).unwrap();
        assert_eq!(r.rows.len(), 4);
        assert!(r.policy_axis());
        // Policy-major ordering, variants in request order within.
        assert_eq!(r.rows[0].policy, KeepAliveKind::FixedTtl);
        assert_eq!(r.rows[0].variant, Variant::Baseline);
        assert_eq!(r.rows[2].policy, KeepAliveKind::LruPressure);
        // Per-app worlds are so lightly loaded that keep-alive policy only
        // shows up in eviction counts, not volume.
        assert_eq!(
            r.rows[0].metrics.invocations,
            r.rows[2].metrics.invocations
        );
        assert!(r.digest().contains("baseline/fixed/legacy:"));
    }

    #[test]
    fn queue_axis_produces_queue_major_rows() {
        let mut cfg = small_cfg();
        cfg.variants = vec![Variant::Baseline];
        cfg.policies = vec![KeepAliveKind::FixedTtl, KeepAliveKind::LruPressure];
        cfg.queues = vec![QueueKind::LegacyOneShot, QueueKind::FifoFair];
        let r = run_multi(&cfg, &[1], &SweepRunner::new(2)).unwrap();
        assert_eq!(r.rows.len(), 4);
        assert!(r.queue_axis());
        // Queue-major ordering, then policy.
        assert_eq!(r.rows[0].queue, QueueKind::LegacyOneShot);
        assert_eq!(r.rows[0].policy, KeepAliveKind::FixedTtl);
        assert_eq!(r.rows[1].policy, KeepAliveKind::LruPressure);
        assert_eq!(r.rows[2].queue, QueueKind::FifoFair);
        assert!(r.digest().contains("baseline/fixed/fifo:"));
        // Lightly-loaded per-app worlds never queue, so disciplines agree
        // on volume.
        assert_eq!(
            r.rows[0].metrics.invocations,
            r.rows[2].metrics.invocations
        );
    }

    #[test]
    fn placement_axis_produces_placement_major_rows() {
        let mut cfg = small_cfg();
        cfg.variants = vec![Variant::Baseline];
        cfg.queues = vec![QueueKind::LegacyOneShot, QueueKind::FifoFair];
        cfg.placements = vec![PlacementKind::LeastLoadedMb, PlacementKind::RoundRobin];
        let r = run_multi(&cfg, &[1], &SweepRunner::new(2)).unwrap();
        assert_eq!(r.rows.len(), 4);
        assert!(r.placement_axis());
        // Placement-major ordering, then queue.
        assert_eq!(r.rows[0].placement, PlacementKind::LeastLoadedMb);
        assert_eq!(r.rows[0].queue, QueueKind::LegacyOneShot);
        assert_eq!(r.rows[1].queue, QueueKind::FifoFair);
        assert_eq!(r.rows[2].placement, PlacementKind::RoundRobin);
        // Fully-qualified four-segment digest labels on a placement grid.
        assert!(r.digest().contains("baseline/fixed/legacy/legacy:"));
        assert!(r.digest().contains("baseline/fixed/legacy/rr:"));
        // Lightly-loaded per-app worlds never fill a host, so placement
        // only moves containers around — volumes agree.
        assert_eq!(r.rows[0].metrics.invocations, r.rows[2].metrics.invocations);
    }

    #[test]
    fn legacy_grid_digest_labels_omit_the_placement_segment() {
        // No --placement axis → three-segment labels, byte-for-byte the
        // historical digest format (the pinned goldens depend on it).
        let r = run_multi(&small_cfg(), &[1], &SweepRunner::new(2)).unwrap();
        assert!(!r.placement_axis());
        for line in r.digest().lines() {
            let label = line.split(':').next().unwrap();
            assert_eq!(label.split('/').count(), 3, "label {label} gained a segment");
        }
        assert!(r.digest().contains("baseline/fixed/legacy:"));
    }

    #[test]
    fn heterogeneous_host_classes_flow_into_the_replay_worlds() {
        use crate::util::config::HostClass;
        let mut cfg = small_cfg();
        cfg.pool = PoolMode::Shared;
        cfg.variants = vec![Variant::Baseline];
        cfg.placements = vec![PlacementKind::LeastLoadedMb, PlacementKind::WarmAffinity];
        cfg.host_classes =
            HostClass::parse_list("cloud:2:4096:1000:local,edge:2:1024:1600:edge");
        assert!(cfg.host_classes.is_some());
        assert!(cfg.contended());
        let a = run_multi(&cfg, &[1], &SweepRunner::new(1)).unwrap();
        let b = run_multi(&cfg, &[1], &SweepRunner::new(4)).unwrap();
        assert_eq!(a.digest(), b.digest(), "parallel-invariant at fixed shards");
        for row in &a.rows {
            assert!(row.metrics.invocations > 0);
        }
    }

    #[test]
    fn mitigation_parse_roundtrip() {
        for m in Mitigation::all() {
            assert_eq!(Mitigation::parse(m.as_str()), Some(m));
        }
        assert_eq!(Mitigation::parse("keep-alive"), Some(Mitigation::Keepalive));
        assert_eq!(Mitigation::parse("snap"), Some(Mitigation::Snapshot));
        assert_eq!(Mitigation::parse("bogus"), None);
    }

    #[test]
    fn mitigation_axis_produces_mitigation_major_rows() {
        let mut cfg = small_cfg();
        cfg.variants = vec![Variant::Both];
        cfg.pool = PoolMode::Shared;
        cfg.mitigations = Some(Mitigation::all().to_vec());
        assert!(cfg.contended());
        let a = run_multi(&cfg, &[1], &SweepRunner::new(1)).unwrap();
        let b = run_multi(&cfg, &[1], &SweepRunner::new(4)).unwrap();
        assert_eq!(a.digest(), b.digest(), "parallel-invariant at fixed shards");
        assert_eq!(a.rows.len(), 4);
        assert!(a.mitigation_axis());
        assert_eq!(a.rows[0].mitigation, Some(Mitigation::Keepalive));
        assert_eq!(a.rows[1].mitigation, Some(Mitigation::Snapshot));
        assert_eq!(a.rows[3].mitigation, Some(Mitigation::Hybrid));
        // Labels (and digest lines) gain the trailing mitigation segment.
        assert!(a.digest().contains("both/fixed/legacy/keepalive:"));
        assert!(a.digest().contains("both/fixed/legacy/snapshot:"));
        // Every mitigation replays the identical arrival volume (the axis
        // flips only the freshen/snapshot switches, never the workload),
        // and the three start kinds partition completions everywhere.
        for r in &a.rows {
            let m = &r.metrics;
            assert_eq!(m.invocations, a.rows[0].metrics.invocations);
            assert_eq!(
                m.cold_starts + m.warm_starts + m.restored_starts,
                m.invocations
            );
        }
        let ka = &a.rows[0].metrics;
        let snap = &a.rows[1].metrics;
        let fresh = &a.rows[2].metrics;
        assert_eq!(ka.snapshots, 0, "keepalive cell never snapshots");
        assert_eq!(ka.restored_starts, 0);
        assert_eq!(ka.freshens_started, 0, "keepalive cell forces freshen off");
        assert_eq!(fresh.snapshots, 0, "freshen cell never snapshots");
        assert!(fresh.freshens_started > 0, "freshen cell keeps the variant's predictors");
        assert!(snap.snapshots > 0, "idle expiry demotes instead of evicting");
        assert_eq!(snap.freshens_started, 0, "snapshot cell forces freshen off");
        for line in a.digest().lines() {
            if line.starts_with("both/fixed/legacy/snapshot:") {
                assert!(line.contains(" sn="), "snapshot cell digest carries the suffix");
            }
            if line.starts_with("both/fixed/legacy/keepalive:") {
                assert!(!line.contains(" sn="), "keepalive cell keeps the legacy digest shape");
            }
        }
    }

    #[test]
    fn days_require_synth() {
        let mut cfg = small_cfg();
        cfg.source = TraceSource::Csv(std::path::PathBuf::from("/nonexistent.csv"));
        cfg.days = 3;
        assert!(run_multi(&cfg, &[1], &SweepRunner::new(1)).is_err());
    }

    #[test]
    fn shared_pool_with_days_reports_per_day_and_merges_deterministically() {
        let mut cfg = small_cfg();
        cfg.pool = PoolMode::Shared;
        cfg.days = 2;
        cfg.policies = vec![KeepAliveKind::FixedTtl, KeepAliveKind::HybridHistogram];
        let a = run_multi(&cfg, &[1], &SweepRunner::new(1)).unwrap();
        let b = run_multi(&cfg, &[1], &SweepRunner::new(4)).unwrap();
        assert_eq!(a.digest(), b.digest(), "parallel-invariant at fixed shards");
        for row in &a.rows {
            assert_eq!(row.per_day.len(), 2);
            let mut cum = MacroMetrics::default();
            for d in &row.per_day {
                cum.merge(d);
            }
            assert_eq!(cum, row.metrics, "cumulative equals merged days");
        }
        assert!(a.contended);
    }
}
