//! The §2 argument quantified: existing mechanisms vs freshen.
//!
//! "The Linux `tcp_no_metrics_save` capability allows metrics like RTT and
//! ssthresh to be cached between TCP connections to the same destination,
//! but does not apply to important parameters such as CWND. TCP Fast Open
//! requires sender/receiver support and limits the amount of data sent in
//! initial handshakes to small amounts. As a result, we believe several
//! inefficiencies remain, even with runtime reuse, that can be addressed
//! with freshen."
//!
//! Scenario: λ runs every `gap` seconds (long enough for RFC 2861 idle
//! decay and past the prefetch TTL), fetching a 5 MB object and writing a
//! 64 KB result. Mechanisms compared:
//!
//! | mechanism | connection | CWND at run | data at run |
//! |---|---|---|---|
//! | invocation-scoped  | re-established each run | initial | refetched |
//! | runtime reuse (§2) | reused (may be dead)    | decayed | refetched |
//! | + kernel metrics cache | reused/re-est. w/ ssthresh | decayed/initial | refetched |
//! | + TCP Fast Open    | 0-RTT re-establish      | initial | refetched |
//! | freshen (§3)       | kept alive + warmed     | warmed  | prefetched |

use crate::experiments::harness::SweepRunner;
use crate::experiments::{fmt_secs, print_table};
use crate::netsim::cc::CongestionControl;
use crate::netsim::link::Site;
use crate::netsim::metrics_cache::TcpMetricsCache;
use crate::netsim::tcp::{ConnState, Connection, TransferDirection};
use crate::netsim::warm::{warm_cwnd, CwndHistory, WarmPolicy};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::time::{SimDuration, SimTime};

/// The mechanisms compared.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mechanism {
    InvocationScoped,
    RuntimeReuse,
    RuntimeReuseMetricsCache,
    RuntimeReuseTfo,
    Freshen,
}

impl Mechanism {
    pub fn all() -> [Mechanism; 5] {
        [
            Mechanism::InvocationScoped,
            Mechanism::RuntimeReuse,
            Mechanism::RuntimeReuseMetricsCache,
            Mechanism::RuntimeReuseTfo,
            Mechanism::Freshen,
        ]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Mechanism::InvocationScoped => "invocation-scoped",
            Mechanism::RuntimeReuse => "runtime reuse",
            Mechanism::RuntimeReuseMetricsCache => "+ metrics cache",
            Mechanism::RuntimeReuseTfo => "+ TCP Fast Open",
            Mechanism::Freshen => "freshen",
        }
    }
}

#[derive(Debug, Clone)]
pub struct BaselineRow {
    pub mechanism: Mechanism,
    /// Per-invocation critical-path time (fetch + put), seconds.
    pub latency: Summary,
}

#[derive(Debug, Clone)]
pub struct Baselines {
    pub rows: Vec<BaselineRow>,
    pub gap_s: f64,
    pub fetch_bytes: f64,
    pub put_bytes: f64,
}

/// One `(mechanism, seed)` grid point: `iters` raw critical-path
/// latencies (seconds), mergeable across seeds.
fn mechanism_samples(
    mech: Mechanism,
    iters: usize,
    gap_s: f64,
    fetch_bytes: f64,
    put_bytes: f64,
    seed: u64,
) -> Vec<f64> {
    let mut link = Site::Remote.link();
    link.jitter_sigma = 0.02;
    let mut rng = Rng::new(seed);
    let mut kernel_cache = TcpMetricsCache::new();
    kernel_cache.tfo_enabled = mech == Mechanism::RuntimeReuseTfo;
    let mut history = CwndHistory::new();
    let dest = "store:443";

    // Short server idle timeout so runtime-scoped connections actually die
    // between far-apart invocations (the §2 failure mode).
    let idle_timeout = 60.0;
    let mut conn = Connection::new(link.clone(), CongestionControl::Cubic);
    conn.idle_timeout = idle_timeout;
    let mut samples = Vec::with_capacity(iters);
    let mut now = SimTime::ZERO;

    for _ in 0..iters {
        now += SimDuration::from_secs_f64(gap_s);
        // ---- freshen runs ahead of the invocation (off critical path).
        if mech == Mechanism::Freshen {
            let lead = SimDuration::from_secs(1);
            let f_at = SimTime(now.micros() - lead.micros());
            // EnsureConnection: keepalive or re-establish.
            let (_d, alive) = conn.keepalive(f_at, &mut rng);
            if !alive {
                conn.connect(f_at, &mut rng);
            }
            // WarmCwnd both directions.
            for dir in [TransferDirection::Download, TransferDirection::Upload] {
                warm_cwnd(
                    &mut conn,
                    dir,
                    fetch_bytes.max(put_bytes),
                    &WarmPolicy::default(),
                    &mut history,
                    f_at,
                    &mut rng,
                );
            }
        }

        // ---- the invocation's critical path.
        let mut t = 0.0;
        match mech {
            Mechanism::InvocationScoped => {
                // Fresh connection every run.
                conn = Connection::new(link.clone(), CongestionControl::Cubic);
                conn.idle_timeout = idle_timeout;
                t += conn.connect(now, &mut rng).as_secs_f64();
            }
            Mechanism::RuntimeReuse
            | Mechanism::RuntimeReuseMetricsCache
            | Mechanism::RuntimeReuseTfo
            | Mechanism::Freshen => {
                // Reused connection: discover death the hard way (RTO)
                // unless freshen already handled it.
                let dead = match conn.state {
                    ConnState::Established => {
                        if conn.idle_expired(now) {
                            conn.kill();
                            t += conn.rto();
                            true
                        } else {
                            false
                        }
                    }
                    _ => true,
                };
                if dead {
                    let ssthresh_hint = if mech == Mechanism::RuntimeReuseMetricsCache {
                        kernel_cache.ssthresh_hint(dest)
                    } else {
                        None
                    };
                    let fast_open = mech == Mechanism::RuntimeReuseTfo
                        && kernel_cache.can_fast_open(dest);
                    t += conn
                        .connect_with(now, &mut rng, ssthresh_hint, fast_open)
                        .as_secs_f64();
                    kernel_cache.grant_tfo_cookie(dest, now);
                }
            }
        }
        let t_start = now + SimDuration::from_secs_f64(t);
        // Freshen prefetched the data; everyone else fetches it now.
        if mech != Mechanism::Freshen {
            t += conn
                .request_response(t_start, &mut rng, 256.0, fetch_bytes, 1e-3)
                .as_secs_f64();
        }
        let t_put = now + SimDuration::from_secs_f64(t);
        t += conn
            .send_with_ack(t_put, &mut rng, put_bytes, 1e-3)
            .as_secs_f64();
        // Kernel caches metrics at "close"/quiesce.
        kernel_cache.record(dest, link.rtt, conn.cc_tx.ssthresh, now);
        samples.push(t);
    }
    samples
}

/// Single-seed convenience over [`run_multi`].
pub fn run(iters: usize, gap_s: f64, seed: u64) -> Baselines {
    run_multi(iters, gap_s, &[seed], &SweepRunner::new(1))
}

/// Multi-seed sweep: the `mechanisms × seeds` grid runs on `runner`;
/// per-mechanism latency samples pool in seed order before summarising,
/// so merged rows are deterministic for any `--parallel`.
pub fn run_multi(iters: usize, gap_s: f64, seeds: &[u64], runner: &SweepRunner) -> Baselines {
    assert!(!seeds.is_empty(), "baselines needs at least one seed");
    let fetch_bytes = 5e6;
    let put_bytes = 64.0 * 1024.0;
    let mechanisms = Mechanism::all();
    let rows = runner
        .run_grid(&mechanisms, seeds, |&m, seed| {
            mechanism_samples(m, iters, gap_s, fetch_bytes, put_bytes, seed)
        })
        .into_iter()
        .zip(mechanisms.iter())
        .map(|(per_seed, &mechanism)| {
            let mut samples = Vec::new();
            for s in per_seed {
                samples.extend(s);
            }
            BaselineRow {
                mechanism,
                latency: Summary::of(&samples).expect("non-empty"),
            }
        })
        .collect();
    Baselines {
        rows,
        gap_s,
        fetch_bytes,
        put_bytes,
    }
}

impl Baselines {
    pub fn freshen_speedup(&self) -> f64 {
        let freshen = self
            .rows
            .iter()
            .find(|r| r.mechanism == Mechanism::Freshen)
            .unwrap();
        let best_other = self
            .rows
            .iter()
            .filter(|r| r.mechanism != Mechanism::Freshen)
            .map(|r| r.latency.p50)
            .fold(f64::INFINITY, f64::min);
        best_other / freshen.latency.p50
    }

    pub fn print(&self) {
        println!(
            "\n== §2 baseline mechanisms vs freshen (λ every {:.0}s, {:.0}MB fetch + {:.0}KB put) ==",
            self.gap_s,
            self.fetch_bytes / 1e6,
            self.put_bytes / 1e3
        );
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.mechanism.as_str().to_string(),
                    fmt_secs(r.latency.p50),
                    fmt_secs(r.latency.p99),
                ]
            })
            .collect();
        print_table(&["mechanism", "p50", "p99"], &rows);
        println!(
            "freshen speedup over best existing mechanism: {:.2}x",
            self.freshen_speedup()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn existing_mechanisms_are_insufficient() {
        // The §2 claim: each mechanism helps a little, freshen wins big.
        let b = run(30, 120.0, 0xBA5E);
        let p50 = |m: Mechanism| {
            b.rows
                .iter()
                .find(|r| r.mechanism == m)
                .unwrap()
                .latency
                .p50
        };
        // Runtime reuse beats invocation-scoped... barely, at this gap the
        // connection died anyway and it pays death-detection; allow either
        // ordering but both must be slow.
        let inv = p50(Mechanism::InvocationScoped);
        let reuse = p50(Mechanism::RuntimeReuse);
        // Metrics cache ≤ plain reuse (ssthresh hint can only help).
        assert!(p50(Mechanism::RuntimeReuseMetricsCache) <= reuse * 1.05);
        // TFO saves the handshake RTT vs plain reuse.
        assert!(p50(Mechanism::RuntimeReuseTfo) <= reuse * 1.01);
        // Freshen dominates everything by a wide margin.
        let freshen = p50(Mechanism::Freshen);
        assert!(freshen < 0.5 * inv, "freshen {freshen} vs invocation {inv}");
        assert!(b.freshen_speedup() > 2.0, "speedup {}", b.freshen_speedup());
    }

    #[test]
    fn short_gaps_narrow_the_advantage() {
        // When invocations are frequent the connection stays warm and the
        // gap between mechanisms shrinks (freshen's prefetch still wins on
        // the 5MB fetch, but connection effects vanish).
        let frequent = run(30, 2.0, 0xBA5F);
        let sparse = run(30, 120.0, 0xBA5F);
        assert!(frequent.freshen_speedup() <= sparse.freshen_speedup() * 1.5);
    }

    #[test]
    fn multi_seed_sweep_is_identical_across_parallelism() {
        let seeds = [3u64, 4, 5];
        let seq = run_multi(12, 120.0, &seeds, &SweepRunner::new(1));
        let par = run_multi(12, 120.0, &seeds, &SweepRunner::new(4));
        assert_eq!(format!("{:?}", seq.rows), format!("{:?}", par.rows));
    }

    #[test]
    fn single_seed_multi_matches_legacy_entry_point() {
        let legacy = run(10, 60.0, 0xBA60);
        let multi = run_multi(10, 60.0, &[0xBA60], &SweepRunner::new(2));
        assert_eq!(format!("{:?}", legacy.rows), format!("{:?}", multi.rows));
    }
}
