//! Experiment harnesses: one module per paper table/figure, plus the
//! ablations DESIGN.md commits to.
//!
//! Every harness is a pure function from a seed/config to a structured
//! result with a `print()` that emits the same rows/series the paper
//! reports. Benches (`rust/benches/*`) and the CLI (`repro experiment
//! <id>`) both call through here, so the numbers in EXPERIMENTS.md are
//! regenerable from two entry points.
//!
//! | id      | paper artifact                                   |
//! |---------|--------------------------------------------------|
//! | fig2    | CDF of functions/app, orchestration vs all       |
//! | table1  | trigger-service delay medians                    |
//! | fig4    | file retrieval time vs size x location           |
//! | fig5    | warmed vs cold transfer, cloud link              |
//! | fig6    | warmed vs cold transfer, edge (~50 ms) link      |
//! | e2e     | chain workload, freshen on vs off (ours)         |
//! | abl-*   | lead-time, confidence-gating, TTL ablations      |
//! | azure-macro | Azure-trace macro benchmark (platform scale) |
//!
//! # Multi-seed sweeps
//!
//! [`harness::SweepRunner`] fans `(scenario, seed)` grids out over
//! `std::thread` workers; the `*_multi` entry points in `ablations`,
//! `prediction`, `fig4`, `fig5_6`, `table1`, `e2e` and `baselines` run
//! one independent simulation per grid point and merge the per-run
//! outputs deterministically:
//!
//! - the grid is ordered `params × seeds` (seeds innermost), and results
//!   are collected **by grid index, never by completion order**;
//! - per-point raw samples (latencies, transfer times) are pooled in grid
//!   order before summarising, and counters (hits, arrivals, GB-s) are
//!   summed, so a merged row over seeds `a..b` is byte-identical whether
//!   produced with `--parallel 1` or `--parallel N`.
//!
//! The CLI exposes this as `repro experiment <id> --seeds a..b
//! --parallel N`.
//!
//! [`azure_macro`] extends the contract from "across grid points" to
//! *within one trace*: a shard-major grid where each worker ingests its
//! hash-of-app slice once and replays it under every `(variant × seed)`,
//! merging integer-only metrics — so its output is byte-identical for any
//! `--shards` × `--parallel` combination.

pub mod ablations;
pub mod azure_macro;
pub mod baselines;
pub mod e2e;
pub mod fig2;
pub mod fig4;
pub mod fig5_6;
pub mod harness;
pub mod prediction;
pub mod table1;

pub use harness::SweepRunner;

/// Render a simple aligned table (used by every harness's `print`).
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut out = String::new();
        for (i, c) in cells.iter().enumerate() {
            out.push_str(&format!("{:>w$}  ", c, w = widths[i.min(widths.len() - 1)]));
        }
        println!("{}", out.trim_end());
    };
    line(headers.iter().map(|h| h.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

/// Format seconds adaptively (ms below 1s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fmt_secs_scales() {
        assert_eq!(super::fmt_secs(0.064), "64.0ms");
        assert_eq!(super::fmt_secs(1.282), "1.282s");
    }
}
