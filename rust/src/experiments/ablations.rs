//! Ablations over the design choices DESIGN.md calls out.
//!
//! - **Lead time** (Figure 3's timing axis): how early must freshen fire
//!   before the invocation to pay off? Sweeps the freshen lead from
//!   "after the invocation already started" to several seconds early.
//! - **Confidence gating** (§3.3 billing): with a controllable mispredict
//!   rate, what does gating save in wasted freshen spend?
//! - **Prefetch TTL** (§3.2 caching): network traffic vs staleness across
//!   TTLs under periodic re-invocation.

use crate::experiments::harness::SweepRunner;
use crate::experiments::print_table;
use crate::netsim::link::Site;
use crate::platform::endpoint::Endpoint;
use crate::platform::exec::{emit_prediction, invoke, start_freshen};
use crate::platform::function::FunctionSpec;
use crate::platform::world::{PlatformSim, World};
use crate::predict::{Prediction, PredictionSource};
use crate::simcore::Sim;
use crate::util::config::Config;
use crate::util::stats::Summary;
use crate::util::time::{SimDuration, SimTime};

fn lambda_world(seed: u64, freshen_enabled: bool) -> World {
    let mut cfg = Config::default();
    cfg.seed = seed;
    cfg.freshen.enabled = freshen_enabled;
    cfg.freshen.min_confidence = 0.0;
    let mut w = World::new(cfg);
    // Ablations control their own freshen/prediction schedules.
    w.auto_hist_predict = false;
    let mut ep = Endpoint::new("store", Site::Remote);
    ep.store.put("ID1", 5e6, SimTime::ZERO);
    w.add_endpoint(ep);
    w.deploy(FunctionSpec::paper_lambda(
        "lambda",
        "app",
        "store",
        SimDuration::from_millis(20),
    ));
    w
}

// ====================================================================
// Ablation A: freshen lead time
// ====================================================================

#[derive(Debug, Clone)]
pub struct LeadRow {
    /// Freshen start relative to invocation (negative = after).
    pub lead_ms: i64,
    pub latency: Summary,
    pub hit_rate: f64,
}

/// Raw output of one `(lead, seed)` run, mergeable across seeds.
struct LeadSample {
    latencies: Vec<SimDuration>,
    freshen_hits: u64,
    freshen_total: u64,
}

/// One `(lead, seed)` grid point: `iters` warm invocations 30 s apart
/// (past TTL and into idle decay), freshen firing `lead` before each.
fn lead_run(lead_ms: i64, iters: usize, seed: u64) -> LeadSample {
    let mut w = lambda_world(seed ^ lead_ms.unsigned_abs(), true);
    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 50_000_000;
    // Warm up the container.
    invoke(&mut sim, &mut w, "lambda");
    sim.run(&mut w);
    let mut t = sim.now() + SimDuration::from_secs(5);
    for _ in 0..iters {
        let invoke_at = t + SimDuration::from_secs(30);
        let freshen_at = if lead_ms >= 0 {
            SimTime(invoke_at.micros().saturating_sub(lead_ms as u64 * 1_000))
        } else {
            invoke_at + SimDuration::from_millis((-lead_ms) as u64)
        };
        sim.schedule_at(freshen_at, |sim, w| {
            start_freshen(sim, w, "lambda", None);
        });
        sim.schedule_at(invoke_at, |sim, w| {
            invoke(sim, w, "lambda");
        });
        t = invoke_at;
    }
    sim.run(&mut w);
    let latencies: Vec<SimDuration> = w
        .metrics
        .records()
        .iter()
        .skip(1) // warmup
        .map(|r| r.latency())
        .collect();
    let (freshen_hits, freshen_total) = w.metrics.freshen_hit_counts();
    LeadSample {
        latencies,
        freshen_hits,
        freshen_total,
    }
}

/// For each lead, run `iters` warm invocations 30 s apart (past TTL and
/// into idle decay), freshen firing `lead` before each. Single-seed
/// convenience over [`lead_time_multi`].
pub fn lead_time(leads_ms: &[i64], iters: usize, seed: u64) -> Vec<LeadRow> {
    lead_time_multi(leads_ms, iters, &[seed], &SweepRunner::new(1))
}

/// Multi-seed sweep of the lead-time ablation: the `leads × seeds` grid
/// runs on `runner`, and per-lead rows pool latency samples (in seed
/// order) and sum hit counters — deterministic regardless of parallelism.
pub fn lead_time_multi(
    leads_ms: &[i64],
    iters: usize,
    seeds: &[u64],
    runner: &SweepRunner,
) -> Vec<LeadRow> {
    assert!(!seeds.is_empty(), "lead_time_multi needs at least one seed");
    runner
        .run_grid(leads_ms, seeds, |&lead_ms, seed| {
            lead_run(lead_ms, iters, seed)
        })
        .into_iter()
        .zip(leads_ms.iter())
        .map(|(samples, &lead_ms)| {
            let mut latencies = Vec::new();
            let (mut hits, mut total) = (0u64, 0u64);
            for s in samples {
                latencies.extend(s.latencies);
                hits += s.freshen_hits;
                total += s.freshen_total;
            }
            LeadRow {
                lead_ms,
                latency: Summary::of_durations_ms(&latencies).expect("ran"),
                hit_rate: if total == 0 {
                    0.0
                } else {
                    hits as f64 / total as f64
                },
            }
        })
        .collect()
}

pub fn print_lead(rows: &[LeadRow]) {
    println!("\n== Ablation A: freshen lead time (invocations 30s apart) ==");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}ms", r.lead_ms),
                format!("{:.1}", r.latency.p50),
                format!("{:.1}", r.latency.p99),
                format!("{:.0}%", 100.0 * r.hit_rate),
            ]
        })
        .collect();
    print_table(&["lead", "p50 ms", "p99 ms", "hit rate"], &table);
}

// ====================================================================
// Ablation B: confidence gating under mispredictions
// ====================================================================

#[derive(Debug, Clone)]
pub struct ConfidenceRow {
    pub mispredict_rate: f64,
    pub gating: bool,
    pub latency_p50_ms: f64,
    pub wasted_gb_s: f64,
    pub useful_gb_s: f64,
    pub freshens: u64,
}

/// Raw output of one `(rate, gating, seed)` run.
struct ConfidenceSample {
    latencies: Vec<SimDuration>,
    wasted_gb_s: f64,
    useful_gb_s: f64,
    freshens: u64,
}

/// One `(rate, gating, seed)` grid point.
fn confidence_run(rate: f64, gating: bool, iters: usize, seed: u64) -> ConfidenceSample {
    let mut w = lambda_world(seed, true);
    // This ablation injects its own prediction stream; keep the
    // platform's automatic histogram predictions out of the way.
    w.auto_hist_predict = false;
    if !gating {
        // Ungated: admit everything the predictor emits, and
        // ignore the observed-accuracy feedback loop.
        w.gate.config.min_confidence = 0.0;
        w.gate.accuracy_gating = false;
    }
    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 50_000_000;
    invoke(&mut sim, &mut w, "lambda");
    sim.run(&mut w);
    let mut predict_rng = w.rng.fork(7);
    let mut t = sim.now() + SimDuration::from_secs(5);
    for _ in 0..iters {
        let expected = t + SimDuration::from_secs(30);
        let mispredict = predict_rng.bernoulli(rate);
        // Confidence reflects the true quality only when gating:
        // the gated platform learns from outcomes; ungated admits
        // high-confidence claims blindly.
        let pred = Prediction {
            function: "lambda".into(),
            expected_at: expected,
            confidence: 0.9,
            source: PredictionSource::Histogram,
        };
        sim.schedule_at(t + SimDuration::from_secs(29), move |sim, w| {
            emit_prediction(sim, w, pred.clone(), sim.now());
        });
        if !mispredict {
            sim.schedule_at(expected, |sim, w| {
                invoke(sim, w, "lambda");
            });
        }
        t = expected;
    }
    sim.run(&mut w);
    let acct = w.ledger.account("app");
    let latencies: Vec<SimDuration> = w
        .metrics
        .records()
        .iter()
        .skip(1)
        .map(|r| r.latency())
        .collect();
    ConfidenceSample {
        latencies,
        wasted_gb_s: acct.freshen_wasted_gb_s,
        useful_gb_s: acct.freshen_useful_gb_s,
        freshens: acct.freshens,
    }
}

/// Drive predictions with a known mispredict rate; compare gated (accuracy
/// feedback on) vs ungated (min_confidence 0, accuracy ignored -> we
/// emulate by feeding confident predictions regardless). Single-seed
/// convenience over [`confidence_multi`].
pub fn confidence(mispredict_rates: &[f64], iters: usize, seed: u64) -> Vec<ConfidenceRow> {
    confidence_multi(mispredict_rates, iters, &[seed], &SweepRunner::new(1))
}

/// Multi-seed sweep over the `(rate × mode) × seeds` grid. Latencies pool
/// in seed order; GB-s spend and freshen counts sum across seeds, so the
/// merged rows are deterministic for any `--parallel`.
pub fn confidence_multi(
    mispredict_rates: &[f64],
    iters: usize,
    seeds: &[u64],
    runner: &SweepRunner,
) -> Vec<ConfidenceRow> {
    assert!(!seeds.is_empty(), "confidence_multi needs at least one seed");
    let params: Vec<(f64, bool)> = mispredict_rates
        .iter()
        .flat_map(|&rate| [(rate, false), (rate, true)])
        .collect();
    runner
        .run_grid(&params, seeds, |&(rate, gating), seed| {
            confidence_run(rate, gating, iters, seed)
        })
        .into_iter()
        .zip(params.iter())
        .map(|(samples, &(rate, gating))| {
            let mut latencies = Vec::new();
            let (mut wasted, mut useful, mut freshens) = (0.0, 0.0, 0u64);
            for s in samples {
                latencies.extend(s.latencies);
                wasted += s.wasted_gb_s;
                useful += s.useful_gb_s;
                freshens += s.freshens;
            }
            ConfidenceRow {
                mispredict_rate: rate,
                gating,
                latency_p50_ms: Summary::of_durations_ms(&latencies)
                    .map(|s| s.p50)
                    .unwrap_or(0.0),
                wasted_gb_s: wasted,
                useful_gb_s: useful,
                freshens,
            }
        })
        .collect()
}

pub fn print_confidence(rows: &[ConfidenceRow]) {
    println!("\n== Ablation B: confidence gating vs mispredict rate ==");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.0}%", 100.0 * r.mispredict_rate),
                if r.gating { "gated" } else { "ungated" }.into(),
                format!("{:.1}", r.latency_p50_ms),
                format!("{:.4}", r.wasted_gb_s),
                format!("{:.4}", r.useful_gb_s),
                r.freshens.to_string(),
            ]
        })
        .collect();
    print_table(
        &["mispredict", "mode", "p50 ms", "wasted GB-s", "useful GB-s", "freshens"],
        &table,
    );
}

// ====================================================================
// Ablation C: prefetch TTL
// ====================================================================

#[derive(Debug, Clone)]
pub struct TtlRow {
    pub ttl_s: f64,
    pub latency_p50_ms: f64,
    pub network_mb: f64,
    pub saved_mb: f64,
    pub stale_serves: u64,
}

/// Raw output of one `(ttl, seed)` run.
struct TtlSample {
    latencies: Vec<SimDuration>,
    network_mb: f64,
    saved_mb: f64,
    stale_serves: u64,
}

/// One `(ttl, seed)` grid point.
fn ttl_run(ttl_s: f64, iters: usize, seed: u64) -> TtlSample {
    let mut w = lambda_world(seed, true);
    w.strict_versions = false; // pure TTL regime: count staleness
    {
        let mut spec = w.registry.function("lambda").unwrap().clone();
        spec.prefetch_ttl = Some(SimDuration::from_secs_f64(ttl_s));
        w.registry.deploy(spec, w.config.freshen.default_ttl);
    }
    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 50_000_000;
    invoke(&mut sim, &mut w, "lambda");
    sim.run(&mut w);
    let mut t = sim.now() + SimDuration::from_secs(2);
    for i in 0..iters {
        sim.schedule_at(t, |sim, w| {
            invoke(sim, w, "lambda");
        });
        if i % 12 == 11 {
            // External update every ~60s of invocations.
            sim.schedule_at(t + SimDuration::from_secs(1), |sim, w| {
                let now = sim.now();
                w.endpoints
                    .get_mut("store")
                    .unwrap()
                    .store
                    .external_update("ID1", 5e6, now);
            });
        }
        t = t + SimDuration::from_secs(5);
    }
    sim.run(&mut w);
    // Stale serves: fetch results whose version lagged the store.
    let stale_serves = w
        .containers
        .iter()
        .map(|c| c.runtime.cache.stats.version_stale)
        .sum::<u64>();
    let acct = w.ledger.account("app");
    let latencies: Vec<SimDuration> = w
        .metrics
        .records()
        .iter()
        .skip(1)
        .map(|r| r.latency())
        .collect();
    TtlSample {
        latencies,
        network_mb: acct.network_bytes / 1e6,
        saved_mb: acct.network_bytes_saved / 1e6,
        stale_serves,
    }
}

/// Periodic invocations (every 5 s) against an object that's externally
/// updated every 60 s; sweep the prefetch TTL. Small TTLs refetch often
/// (more traffic, never stale); large TTLs save traffic but risk staleness
/// — with strict version checking the staleness converts back into
/// refetch latency. Single-seed convenience over [`ttl_sweep_multi`].
pub fn ttl_sweep(ttls_s: &[f64], iters: usize, seed: u64) -> Vec<TtlRow> {
    ttl_sweep_multi(ttls_s, iters, &[seed], &SweepRunner::new(1))
}

/// Multi-seed sweep over the `ttls × seeds` grid: latencies pool in seed
/// order; traffic and staleness counters sum across seeds.
pub fn ttl_sweep_multi(
    ttls_s: &[f64],
    iters: usize,
    seeds: &[u64],
    runner: &SweepRunner,
) -> Vec<TtlRow> {
    assert!(!seeds.is_empty(), "ttl_sweep_multi needs at least one seed");
    runner
        .run_grid(ttls_s, seeds, |&ttl_s, seed| ttl_run(ttl_s, iters, seed))
        .into_iter()
        .zip(ttls_s.iter())
        .map(|(samples, &ttl_s)| {
            let mut latencies = Vec::new();
            let (mut network_mb, mut saved_mb, mut stale) = (0.0, 0.0, 0u64);
            for s in samples {
                latencies.extend(s.latencies);
                network_mb += s.network_mb;
                saved_mb += s.saved_mb;
                stale += s.stale_serves;
            }
            TtlRow {
                ttl_s,
                latency_p50_ms: Summary::of_durations_ms(&latencies)
                    .map(|s| s.p50)
                    .unwrap_or(0.0),
                network_mb,
                saved_mb,
                stale_serves: stale,
            }
        })
        .collect()
}

pub fn print_ttl(rows: &[TtlRow]) {
    println!("\n== Ablation C: prefetch TTL (invocations every 5s) ==");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.0}s", r.ttl_s),
                format!("{:.1}", r.latency_p50_ms),
                format!("{:.1}", r.network_mb),
                format!("{:.1}", r.saved_mb),
            ]
        })
        .collect();
    print_table(&["TTL", "p50 ms", "network MB", "saved MB"], &table);
}

#[cfg(test)]
mod tests {
    use crate::experiments::harness::SweepRunner;

    #[test]
    fn multi_seed_sweep_is_identical_across_parallelism() {
        // Acceptance: a >=4-seed sweep through SweepRunner merges to
        // byte-identical rows whether run on 1 worker or several.
        let leads = [0i64, 1000];
        let seeds = [11u64, 12, 13, 14];
        let seq = super::lead_time_multi(&leads, 6, &seeds, &SweepRunner::new(1));
        let par = super::lead_time_multi(&leads, 6, &seeds, &SweepRunner::new(4));
        assert_eq!(format!("{seq:?}"), format!("{par:?}"));
    }

    #[test]
    fn single_seed_multi_matches_legacy_entry_point() {
        let leads = [0i64, 500];
        let legacy = super::lead_time(&leads, 5, 0xA11);
        let multi =
            super::lead_time_multi(&leads, 5, &[0xA11], &SweepRunner::new(2));
        assert_eq!(format!("{legacy:?}"), format!("{multi:?}"));
    }

    #[test]
    fn earlier_freshen_is_better_or_equal() {
        let rows = super::lead_time(&[-100, 0, 500, 2000], 10, 0x1EAD);
        // Late freshen (after invocation) can't beat a 2s-early one.
        let late = rows.iter().find(|r| r.lead_ms == -100).unwrap();
        let early = rows.iter().find(|r| r.lead_ms == 2000).unwrap();
        assert!(
            early.latency.p50 <= late.latency.p50,
            "early {} vs late {}",
            early.latency.p50,
            late.latency.p50
        );
        assert!(early.hit_rate >= late.hit_rate);
    }

    #[test]
    fn gating_cuts_waste_under_mispredictions() {
        let rows = super::confidence(&[0.8], 40, 0xC0);
        let gated = rows.iter().find(|r| r.gating).unwrap();
        let ungated = rows.iter().find(|r| !r.gating).unwrap();
        assert!(
            gated.wasted_gb_s <= ungated.wasted_gb_s,
            "gated {} vs ungated {}",
            gated.wasted_gb_s,
            ungated.wasted_gb_s
        );
    }

    #[test]
    fn longer_ttl_saves_traffic() {
        let rows = super::ttl_sweep(&[1.0, 30.0], 24, 0x77);
        let short = &rows[0];
        let long = &rows[1];
        assert!(
            long.network_mb < short.network_mb,
            "long-TTL traffic {} should be below short-TTL {}",
            long.network_mb,
            short.network_mb
        );
    }
}
