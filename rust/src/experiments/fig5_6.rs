//! Figures 5 & 6 — warmed vs non-warmed TCP connections.
//!
//! Paper setup: an OpenWhisk function sends files of different sizes to a
//! server; measured from transfer initiation to the server's completion
//! response; warming emulated by "sending a large file before sending our
//! desired file size"; server on the same cloud (Figure 5) or at the edge
//! ~50 ms away (Figure 6); 20 iterations. "With smaller file sizes, the
//! performance of warmed and non-warmed is similar. As file sizes grow,
//! the benefit of warmed connection ranges from 51.22% to 71.94%. The edge
//! performance is better because network delay, and not system overheads,
//! dominate totals."

use crate::experiments::{fmt_secs, print_table};
use crate::netsim::cc::CongestionControl;
use crate::netsim::link::Link;
use crate::netsim::tcp::Connection;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::time::{SimDuration, SimTime};

/// Transfer sizes swept (bytes).
pub const SIZES: [f64; 6] = [1e3, 1e4, 1e5, 1e6, 5e6, 1e7];
pub const ITERATIONS: usize = 20;
/// The warming transfer the paper emulates freshen with.
pub const WARMING_BYTES: f64 = 2e7;

/// Which figure: the link placement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Figure 5: server on the same cloud (moderate RTT, fat pipe).
    Cloud,
    /// Figure 6: server at the edge, ~50 ms away.
    Edge50,
}

impl Placement {
    pub fn link(&self) -> Link {
        match self {
            // Same cloud: cross-zone path, ~4 ms RTT at 10 Gbps.
            Placement::Cloud => Link::new("cloud", 4e-3, 10e9 / 8.0),
            // The paper's "edge (~50ms away)" at 1 Gbps.
            Placement::Edge50 => Link::new("edge50", 50e-3, 1e9 / 8.0),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Placement::Cloud => "cloud (Figure 5)",
            Placement::Edge50 => "edge ~50ms (Figure 6)",
        }
    }
}

#[derive(Debug, Clone)]
pub struct WarmCell {
    pub size: f64,
    pub cold: Summary,
    pub warmed: Summary,
}

impl WarmCell {
    /// Median benefit of warming, as a fraction of the cold time.
    pub fn benefit(&self) -> f64 {
        1.0 - self.warmed.p50 / self.cold.p50
    }
}

#[derive(Debug, Clone)]
pub struct FigWarm {
    pub placement: Placement,
    pub cells: Vec<WarmCell>,
}

/// One cold send on an established-but-new connection.
fn cold_send_s(link: &Link, size: f64, rng: &mut Rng) -> f64 {
    let mut conn = Connection::new(link.clone(), CongestionControl::Cubic);
    let d = conn.connect(SimTime::ZERO, rng);
    conn.send_with_ack(SimTime::ZERO + d, rng, size, 1e-3).as_secs_f64()
}

/// One warmed send: a prior large transfer grows the window, then the
/// measured send happens immediately (no idle decay).
fn warmed_send_s(link: &Link, size: f64, rng: &mut Rng) -> f64 {
    let mut conn = Connection::new(link.clone(), CongestionControl::Cubic);
    let mut t = SimTime::ZERO + conn.connect(SimTime::ZERO, rng);
    t = t + conn.send_with_ack(t, rng, WARMING_BYTES, 1e-3);
    t = t + SimDuration::from_millis(10);
    conn.send_with_ack(t, rng, size, 1e-3).as_secs_f64()
}

/// Raw per-seed samples: `(size, cold, warmed)` per swept size, with the
/// rng stream threaded across cells exactly as the summarised run does.
fn run_samples(placement: Placement, seed: u64) -> Vec<(f64, Vec<f64>, Vec<f64>)> {
    let link = placement.link();
    let mut rng = Rng::new(seed);
    SIZES
        .iter()
        .map(|&size| {
            let cold: Vec<f64> = (0..ITERATIONS)
                .map(|_| cold_send_s(&link, size, &mut rng))
                .collect();
            let warmed: Vec<f64> = (0..ITERATIONS)
                .map(|_| warmed_send_s(&link, size, &mut rng))
                .collect();
            (size, cold, warmed)
        })
        .collect()
}

pub fn run(placement: Placement, seed: u64) -> FigWarm {
    run_multi(
        placement,
        &[seed],
        &crate::experiments::harness::SweepRunner::new(1),
    )
}

/// Multi-seed sweep: one independent transfer simulation per seed, cold
/// and warmed samples pooled per size in seed order before summarising.
pub fn run_multi(
    placement: Placement,
    seeds: &[u64],
    runner: &crate::experiments::harness::SweepRunner,
) -> FigWarm {
    assert!(!seeds.is_empty(), "fig5_6::run_multi needs at least one seed");
    let per_seed = runner.run(seeds, |_, &seed| run_samples(placement, seed));
    let cells = SIZES
        .iter()
        .enumerate()
        .map(|(i, &size)| {
            let mut cold = Vec::new();
            let mut warmed = Vec::new();
            for samples in &per_seed {
                cold.extend_from_slice(&samples[i].1);
                warmed.extend_from_slice(&samples[i].2);
            }
            WarmCell {
                size,
                cold: Summary::of(&cold).unwrap(),
                warmed: Summary::of(&warmed).unwrap(),
            }
        })
        .collect();
    FigWarm { placement, cells }
}

impl FigWarm {
    /// Benefit at the largest size (the paper's headline range).
    pub fn large_benefit(&self) -> f64 {
        self.cells.last().map(WarmCell::benefit).unwrap_or(0.0)
    }

    pub fn print(&self) {
        println!(
            "\n== {}: warmed vs non-warmed send, {} iterations ==",
            self.placement.as_str(),
            ITERATIONS
        );
        let rows: Vec<Vec<String>> = self
            .cells
            .iter()
            .map(|c| {
                vec![
                    crate::experiments::fig4::fmt_bytes(c.size),
                    fmt_secs(c.cold.p50),
                    fmt_secs(c.warmed.p50),
                    format!("{:+.1}%", 100.0 * c.benefit()),
                ]
            })
            .collect();
        print_table(&["size", "cold p50", "warmed p50", "benefit"], &rows);
        println!(
            "large-size benefit: {:.1}% (paper: 51.22%-71.94%)",
            100.0 * self.large_benefit()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sizes_similar_large_sizes_win_big() {
        for placement in [Placement::Cloud, Placement::Edge50] {
            let f = run(placement, 9);
            // Small files: warmed ~ cold (within 15%).
            let small = &f.cells[0];
            assert!(
                small.benefit().abs() < 0.15,
                "{placement:?}: small benefit {}",
                small.benefit()
            );
            // Largest files: benefit in/near the paper's 51-72% band.
            let large = f.large_benefit();
            assert!(
                (0.40..=0.90).contains(&large),
                "{placement:?}: large benefit {large}"
            );
            // Benefit grows (weakly) with size.
            let benefits: Vec<f64> = f.cells.iter().map(WarmCell::benefit).collect();
            assert!(
                benefits.last().unwrap() > benefits.first().unwrap(),
                "{placement:?}: {benefits:?}"
            );
        }
    }

    #[test]
    fn edge_benefit_exceeds_cloud_benefit() {
        // "The edge performance is better because network delay, and not
        // system overheads, dominate totals."
        let cloud = run(Placement::Cloud, 10).large_benefit();
        let edge = run(Placement::Edge50, 10).large_benefit();
        assert!(edge >= cloud * 0.9, "edge {edge} vs cloud {cloud}");
    }
}
