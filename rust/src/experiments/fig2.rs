//! Figure 2 — CDF of functions per application: orchestration apps vs all.
//!
//! Paper: "8 functions in the median Orchestration case versus 2 functions
//! in the median case of all", and the derived prediction window "~5.6s in
//! the extreme case of a linear chain" (8 x ~700 ms median runtime).
//!
//! Multi-seed: [`run_multi`] synthesizes one population per seed on a
//! [`SweepRunner`] and pools the per-app function-count samples in seed
//! order before computing the CDFs, so the merged figure is deterministic
//! for any `--parallel`.

use crate::experiments::harness::SweepRunner;
use crate::experiments::print_table;
use crate::util::rng::Rng;
use crate::util::stats::Cdf;
use crate::workload::azure::{
    figure2_series, linear_chain_window_from_counts, synthesize, AzurePopulationCfg,
};

/// The regenerated figure.
#[derive(Debug, Clone)]
pub struct Fig2 {
    /// CDF series over the grid: (x, F_all(x), F_orch(x)).
    pub series: Vec<(f64, f64, f64)>,
    pub median_all: f64,
    pub median_orch: f64,
    pub chain_window_s: f64,
    pub apps: usize,
}

/// Grid the CDF is evaluated on (functions per app).
pub const GRID: [f64; 12] = [
    1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0, 64.0,
];

pub fn run(seed: u64) -> Fig2 {
    run_multi(&[seed], &SweepRunner::new(1))
}

/// Multi-seed sweep: one synthesized population per seed, function-count
/// samples pooled in seed order. Single-seed output is identical to the
/// historical `run(seed)`.
pub fn run_multi(seeds: &[u64], runner: &SweepRunner) -> Fig2 {
    assert!(!seeds.is_empty(), "fig2 needs at least one seed");
    let cfg = AzurePopulationCfg::default();
    let per_seed = runner.run(seeds, |_, &seed| {
        let mut rng = Rng::new(seed);
        let apps = synthesize(&cfg, &mut rng);
        figure2_series(&apps)
    });
    let mut all = Vec::new();
    let mut orch = Vec::new();
    for (a, o) in per_seed {
        all.extend(a);
        orch.extend(o);
    }
    let cdf_all = Cdf::of(&all);
    let cdf_orch = Cdf::of(&orch);
    let series = GRID
        .iter()
        .map(|&x| (x, cdf_all.at(x), cdf_orch.at(x)))
        .collect();
    let chain_window_s = linear_chain_window_from_counts(&orch, cfg.median_runtime_s);
    Fig2 {
        series,
        median_all: cdf_all.quantile(50.0),
        median_orch: cdf_orch.quantile(50.0),
        chain_window_s,
        apps: all.len(),
    }
}

impl Fig2 {
    pub fn print(&self) {
        println!("\n== Figure 2: functions per application (CDF), {} apps ==", self.apps);
        let rows: Vec<Vec<String>> = self
            .series
            .iter()
            .map(|(x, a, o)| {
                vec![
                    format!("{x:.0}"),
                    format!("{:.3}", a),
                    format!("{:.3}", o),
                ]
            })
            .collect();
        print_table(&["#functions", "CDF(all)", "CDF(orchestration)"], &rows);
        println!(
            "medians: all={:.1} (paper: 2)  orchestration={:.1} (paper: 8)",
            self.median_all, self.median_orch
        );
        println!(
            "linear-chain prediction window: {:.1}s (paper: ~5.6s)",
            self.chain_window_s
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper() {
        let f = super::run(2020);
        assert!((1.0..=3.0).contains(&f.median_all));
        assert!((6.0..=10.0).contains(&f.median_orch));
        assert!((4.0..=7.5).contains(&f.chain_window_s));
        // CDFs are monotone and orchestration is stochastically larger.
        for w in f.series.windows(2) {
            assert!(w[0].1 <= w[1].1 && w[0].2 <= w[1].2);
        }
        let at2 = f.series.iter().find(|(x, _, _)| *x == 2.0).unwrap();
        assert!(at2.1 > at2.2, "all-apps CDF dominates at small counts");
    }

    #[test]
    fn multi_seed_is_identical_across_parallelism_and_pools_apps() {
        let seeds = [2020u64, 2021, 2022];
        let seq = run_multi(&seeds, &SweepRunner::new(1));
        let par = run_multi(&seeds, &SweepRunner::new(4));
        assert_eq!(format!("{seq:?}"), format!("{par:?}"));
        // Pooled population is seeds x the single-seed population.
        let single = super::run(2020);
        assert_eq!(seq.apps, seeds.len() * single.apps);
        assert!((6.0..=10.0).contains(&seq.median_orch));
    }
}
