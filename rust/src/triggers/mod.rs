//! Trigger-service simulators (Table 1).
//!
//! Functions are invoked through a trigger service, and each service adds a
//! delay between the *triggering* action and the *triggered* function's
//! start. The paper measured these medians over 20 k runs on AWS (cold
//! starts carefully avoided, timestamps taken just before the trigger and
//! at triggered-function start — methodology of Sequoia [12]):
//!
//! | Trigger service | Median delay |
//! |-----------------|--------------|
//! | Step Functions  | 0.064 s      |
//! | Direct (Boto3)  | 0.060 s      |
//! | SNS Pub/Sub     | 0.253 s      |
//! | S3 bucket       | 1.282 s      |
//!
//! These delays are the *prediction window* freshen exploits: the previous
//! function (or the provider) can call freshen on the next function in the
//! chain while the trigger is in flight.
//!
//! We model each service as a lognormal delay calibrated to the measured
//! median, with tail spread chosen per service class (queueing services
//! like SNS/S3 have heavier tails than direct RPC).

use crate::util::rng::Rng;
use crate::util::time::SimDuration;

/// The trigger services of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TriggerService {
    /// AWS Step Functions orchestration transition.
    StepFunctions,
    /// Direct invocation (Boto3 `Invoke`).
    Direct,
    /// SNS pub/sub fan-out.
    SnsPubSub,
    /// S3 bucket notification.
    S3Bucket,
}

impl TriggerService {
    pub fn all() -> [TriggerService; 4] {
        [
            TriggerService::StepFunctions,
            TriggerService::Direct,
            TriggerService::SnsPubSub,
            TriggerService::S3Bucket,
        ]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TriggerService::StepFunctions => "Step Functions",
            TriggerService::Direct => "Direct (Boto3)",
            TriggerService::SnsPubSub => "SNS Pub/Sub",
            TriggerService::S3Bucket => "S3 bucket",
        }
    }

    /// The paper's measured median delay in seconds (Table 1).
    pub fn paper_median(&self) -> f64 {
        match self {
            TriggerService::StepFunctions => 0.064,
            TriggerService::Direct => 0.060,
            TriggerService::SnsPubSub => 0.253,
            TriggerService::S3Bucket => 1.282,
        }
    }

    /// Lognormal sigma for the service's delay spread. Direct/StepFunctions
    /// are tight RPC paths; SNS and S3 ride internal queues and event
    /// scanners with heavier tails.
    fn sigma(&self) -> f64 {
        match self {
            TriggerService::StepFunctions => 0.25,
            TriggerService::Direct => 0.22,
            TriggerService::SnsPubSub => 0.45,
            TriggerService::S3Bucket => 0.55,
        }
    }

    /// Sample the trigger-to-start delay. Median of the sampled
    /// distribution equals `paper_median` (lognormal median = exp(mu)).
    pub fn sample_delay(&self, rng: &mut Rng) -> SimDuration {
        let mu = self.paper_median().ln();
        SimDuration::from_secs_f64(rng.lognormal(mu, self.sigma()))
    }

    /// The *prediction lead* this trigger affords: freshen can start as
    /// soon as the triggering side commits, so the expected lead equals the
    /// trigger delay itself.
    pub fn expected_lead(&self) -> SimDuration {
        SimDuration::from_secs_f64(self.paper_median())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::median;

    #[test]
    fn sampled_medians_match_table1() {
        let mut rng = Rng::new(0xAB);
        for svc in TriggerService::all() {
            let xs: Vec<f64> = (0..20_000)
                .map(|_| svc.sample_delay(&mut rng).as_secs_f64())
                .collect();
            let m = median(&xs);
            let target = svc.paper_median();
            assert!(
                (m - target).abs() / target < 0.03,
                "{}: median {m} vs paper {target}",
                svc.as_str()
            );
        }
    }

    #[test]
    fn ordering_matches_paper() {
        // Direct < StepFunctions < SNS < S3 in median delay.
        let meds: Vec<f64> = TriggerService::all()
            .iter()
            .map(|s| s.paper_median())
            .collect();
        assert!(meds[1] < meds[0]); // Direct < StepFunctions
        assert!(meds[0] < meds[2]); // StepFunctions < SNS
        assert!(meds[2] < meds[3]); // SNS < S3
    }

    #[test]
    fn delays_are_positive_and_tailed() {
        let mut rng = Rng::new(7);
        let svc = TriggerService::S3Bucket;
        let xs: Vec<f64> = (0..10_000)
            .map(|_| svc.sample_delay(&mut rng).as_secs_f64())
            .collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let m = median(&xs);
        let max = xs.iter().cloned().fold(0.0, f64::max);
        assert!(max > 2.0 * m, "expected a right tail: max {max} median {m}");
    }
}
