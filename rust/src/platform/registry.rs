//! Function, application and chain registry.
//!
//! The controller's view of what's deployed: function specs, the apps that
//! own them, explicit orchestration chains (Figure 1), and the freshen
//! hooks registered (or inferred) per function.
//!
//! Deploy is the interning boundary: tenant-qualified function and app
//! names intern once into the registry's [`Symbols`] table, and every
//! lookup the executor makes per event (`function_by_id`, `hook_by_id`,
//! `app_of_id`, `chain_next_id`) is an O(1) `FnId`-keyed map hit with no
//! string hashing. The `&str` entry points remain for the deploy/CLI/test
//! boundary and resolve through the table first.

use std::rc::Rc;

use crate::freshen::hooks::FreshenHook;
use crate::freshen::infer::infer_hook;
use crate::freshen::policy::validate_hook;
use crate::platform::function::{AppSpec, FunctionId, FunctionSpec};
use crate::platform::symbols::{FnId, Symbols};
use crate::util::fxhash::FxHashMap;
use crate::util::time::SimDuration;

/// Explicit chain: orchestration frameworks provide these (AWS Step
/// Functions); otherwise they can be derived via tracing [6]. Linear chains
/// for now; the predictor walks successor edges.
#[derive(Debug, Clone)]
pub struct ChainSpec {
    pub id: String,
    pub functions: Vec<FunctionId>,
}

/// The platform registry.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    /// Function/app name interning (shared namespace).
    pub symbols: Symbols,
    functions: FxHashMap<FnId, Rc<FunctionSpec>>,
    apps: FxHashMap<FnId, AppSpec>,
    chains: Vec<ChainSpec>,
    hooks: FxHashMap<FnId, FreshenHook>,
    /// function id → owning app id, precomputed at deploy (the executor
    /// used to re-derive this per charge via a spec lookup + String clone).
    app_of: FxHashMap<FnId, FnId>,
    /// function id → first-registered chain successor, precomputed at
    /// `register_chain` (first-match semantics of the legacy scan).
    chain_next: FxHashMap<FnId, FnId>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Deploy a function; creates its app on first reference and infers a
    /// freshen hook (provider-side code generation, §3.3) unless the
    /// developer registers their own afterwards. Interns both names.
    pub fn deploy(&mut self, spec: FunctionSpec, default_ttl: SimDuration) {
        let fid = self.symbols.intern(&spec.id);
        let aid = self.symbols.intern(&spec.app);
        let app = self
            .apps
            .entry(aid)
            .or_insert_with(|| AppSpec::new(&spec.app, false));
        if !app.functions.contains(&spec.id) {
            app.functions.push(spec.id.clone());
        }
        self.app_of.insert(fid, aid);
        let report = infer_hook(&spec, default_ttl);
        self.hooks.insert(fid, report.hook);
        self.functions.insert(fid, Rc::new(spec));
    }

    /// Register a developer-written freshen hook (validated per §3.3's
    /// abuse rules; replaces the inferred one on success).
    pub fn register_hook(
        &mut self,
        function: &str,
        hook: FreshenHook,
    ) -> Result<(), String> {
        let fid = self
            .symbols
            .lookup(function)
            .filter(|&f| self.functions.contains_key(&f))
            .ok_or_else(|| format!("unknown function '{function}'"))?;
        validate_hook(&hook)?;
        self.hooks.insert(fid, hook);
        Ok(())
    }

    /// Declare an orchestrated chain over already-deployed functions.
    pub fn register_chain(&mut self, id: &str, functions: Vec<FunctionId>) -> Result<(), String> {
        let mut fids = Vec::with_capacity(functions.len());
        for f in &functions {
            match self.symbols.lookup(f).filter(|&x| self.functions.contains_key(&x)) {
                Some(fid) => fids.push(fid),
                None => {
                    return Err(format!("chain '{id}' references unknown function '{f}'"));
                }
            }
        }
        // Mark all owning apps as orchestrated.
        for &fid in &fids {
            if let Some(&aid) = self.app_of.get(&fid) {
                if let Some(app) = self.apps.get_mut(&aid) {
                    app.orchestrated = true;
                }
            }
        }
        // Precompute successor edges; insert-if-absent replicates the
        // legacy first-match-across-chains scan order exactly.
        for pair in fids.windows(2) {
            self.chain_next.entry(pair[0]).or_insert(pair[1]);
        }
        self.chains.push(ChainSpec {
            id: id.to_string(),
            functions,
        });
        Ok(())
    }

    pub fn function(&self, id: &str) -> Option<&FunctionSpec> {
        self.function_by_id(self.symbols.lookup(id)?)
    }

    /// Hot-path lookup: O(1), no string hashing.
    pub fn function_by_id(&self, id: FnId) -> Option<&FunctionSpec> {
        self.functions.get(&id).map(Rc::as_ref)
    }

    /// Cheap shared handle for the executor's hot path (avoids cloning op
    /// payloads per step).
    pub fn function_rc(&self, id: &str) -> Option<Rc<FunctionSpec>> {
        self.function_rc_by_id(self.symbols.lookup(id)?)
    }

    pub fn function_rc_by_id(&self, id: FnId) -> Option<Rc<FunctionSpec>> {
        self.functions.get(&id).cloned()
    }

    pub fn app(&self, id: &str) -> Option<&AppSpec> {
        self.apps.get(&self.symbols.lookup(id)?)
    }

    pub fn app_of(&self, function: &str) -> Option<&AppSpec> {
        let fid = self.symbols.lookup(function)?;
        self.apps.get(self.app_of.get(&fid)?)
    }

    /// Owning app id of `function` ([`FnId::ANON`] if unknown — the
    /// legacy `""` app convention for charges on unknown functions).
    pub fn app_of_id(&self, function: FnId) -> FnId {
        self.app_of.get(&function).copied().unwrap_or(FnId::ANON)
    }

    pub fn hook(&self, function: &str) -> Option<&FreshenHook> {
        self.hook_by_id(self.symbols.lookup(function)?)
    }

    pub fn hook_by_id(&self, function: FnId) -> Option<&FreshenHook> {
        self.hooks.get(&function)
    }

    pub fn chains(&self) -> &[ChainSpec] {
        &self.chains
    }

    /// Successor of `function` in any registered chain (first match) —
    /// the explicit-chain prediction signal of §2.
    pub fn chain_successor(&self, function: &str) -> Option<&FunctionId> {
        for chain in &self.chains {
            if let Some(pos) = chain.functions.iter().position(|f| f == function) {
                if pos + 1 < chain.functions.len() {
                    return Some(&chain.functions[pos + 1]);
                }
            }
        }
        None
    }

    /// Hot-path successor lookup (precomputed at registration).
    pub fn chain_next_id(&self, function: FnId) -> Option<FnId> {
        self.chain_next.get(&function).copied()
    }

    pub fn function_count(&self) -> usize {
        self.functions.len()
    }

    pub fn function_ids(&self) -> Vec<FunctionId> {
        let mut ids: Vec<FunctionId> = self
            .functions
            .keys()
            .map(|&f| self.symbols.resolve(f).to_string())
            .collect();
        ids.sort();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::freshen::hooks::{FreshenAction, HookOrigin};
    use crate::util::time::SimDuration;

    fn ttl() -> SimDuration {
        SimDuration::from_secs(10)
    }

    fn lambda(id: &str, app: &str) -> FunctionSpec {
        FunctionSpec::paper_lambda(id, app, "store", SimDuration::from_millis(10))
    }

    #[test]
    fn deploy_infers_hook_and_creates_app() {
        let mut r = Registry::new();
        r.deploy(lambda("f1", "appA"), ttl());
        assert!(r.function("f1").is_some());
        assert!(r.app("appA").is_some());
        assert!(!r.hook("f1").unwrap().is_empty());
        assert!(!r.app("appA").unwrap().orchestrated);
    }

    #[test]
    fn developer_hook_replaces_inferred() {
        let mut r = Registry::new();
        r.deploy(lambda("f1", "a"), ttl());
        let mut custom = FreshenHook::new(HookOrigin::Developer, 2);
        custom.push(
            0,
            FreshenAction::EnsureConnection {
                endpoint: "store".into(),
            },
        );
        r.register_hook("f1", custom.clone()).unwrap();
        assert_eq!(r.hook("f1").unwrap().len(), 1);
        assert_eq!(r.hook("f1").unwrap().origin, HookOrigin::Developer);
        assert!(r.register_hook("ghost", custom).is_err());
    }

    #[test]
    fn chain_registration_and_successor() {
        let mut r = Registry::new();
        for f in ["a", "b", "c"] {
            r.deploy(lambda(f, "pipeline"), ttl());
        }
        r.register_chain("main", vec!["a".into(), "b".into(), "c".into()])
            .unwrap();
        assert_eq!(r.chain_successor("a"), Some(&"b".to_string()));
        assert_eq!(r.chain_successor("b"), Some(&"c".to_string()));
        assert_eq!(r.chain_successor("c"), None);
        assert!(r.app("pipeline").unwrap().orchestrated);
        assert!(r
            .register_chain("bad", vec!["a".into(), "ghost".into()])
            .is_err());
    }

    #[test]
    fn id_lookups_match_string_lookups() {
        let mut r = Registry::new();
        for f in ["a", "b", "c"] {
            r.deploy(lambda(f, "pipeline"), ttl());
        }
        r.register_chain("main", vec!["a".into(), "b".into(), "c".into()])
            .unwrap();
        let a = r.symbols.lookup("a").unwrap();
        let b = r.symbols.lookup("b").unwrap();
        let app = r.symbols.lookup("pipeline").unwrap();
        assert_eq!(r.function_by_id(a).unwrap().id, "a");
        assert_eq!(r.app_of_id(a), app);
        assert_eq!(r.app_of_id(FnId::ANON), FnId::ANON);
        assert_eq!(r.chain_next_id(a), Some(b));
        assert_eq!(r.chain_next_id(r.symbols.lookup("c").unwrap()), None);
        assert!(r.hook_by_id(a).is_some());
        assert_eq!(r.function_ids(), vec!["a", "b", "c"]);
    }
}
