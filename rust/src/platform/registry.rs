//! Function, application and chain registry.
//!
//! The controller's view of what's deployed: function specs, the apps that
//! own them, explicit orchestration chains (Figure 1), and the freshen
//! hooks registered (or inferred) per function.

use std::rc::Rc;

use crate::freshen::hooks::FreshenHook;
use crate::freshen::infer::infer_hook;
use crate::freshen::policy::validate_hook;
use crate::platform::function::{AppSpec, FunctionId, FunctionSpec};
use crate::util::fxhash::FxHashMap;
use crate::util::time::SimDuration;

/// Explicit chain: orchestration frameworks provide these (AWS Step
/// Functions); otherwise they can be derived via tracing [6]. Linear chains
/// for now; the predictor walks successor edges.
#[derive(Debug, Clone)]
pub struct ChainSpec {
    pub id: String,
    pub functions: Vec<FunctionId>,
}

/// The platform registry.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    functions: FxHashMap<FunctionId, Rc<FunctionSpec>>,
    apps: FxHashMap<String, AppSpec>,
    chains: Vec<ChainSpec>,
    hooks: FxHashMap<FunctionId, FreshenHook>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Deploy a function; creates its app on first reference and infers a
    /// freshen hook (provider-side code generation, §3.3) unless the
    /// developer registers their own afterwards.
    pub fn deploy(&mut self, spec: FunctionSpec, default_ttl: SimDuration) {
        let app = self
            .apps
            .entry(spec.app.clone())
            .or_insert_with(|| AppSpec::new(&spec.app, false));
        if !app.functions.contains(&spec.id) {
            app.functions.push(spec.id.clone());
        }
        let report = infer_hook(&spec, default_ttl);
        self.hooks.insert(spec.id.clone(), report.hook);
        self.functions.insert(spec.id.clone(), Rc::new(spec));
    }

    /// Register a developer-written freshen hook (validated per §3.3's
    /// abuse rules; replaces the inferred one on success).
    pub fn register_hook(
        &mut self,
        function: &str,
        hook: FreshenHook,
    ) -> Result<(), String> {
        if !self.functions.contains_key(function) {
            return Err(format!("unknown function '{function}'"));
        }
        validate_hook(&hook)?;
        self.hooks.insert(function.to_string(), hook);
        Ok(())
    }

    /// Declare an orchestrated chain over already-deployed functions.
    pub fn register_chain(&mut self, id: &str, functions: Vec<FunctionId>) -> Result<(), String> {
        for f in &functions {
            if !self.functions.contains_key(f) {
                return Err(format!("chain '{id}' references unknown function '{f}'"));
            }
        }
        // Mark all owning apps as orchestrated.
        for f in &functions {
            let app_id = self.functions[f].app.clone();
            if let Some(app) = self.apps.get_mut(&app_id) {
                app.orchestrated = true;
            }
        }
        self.chains.push(ChainSpec {
            id: id.to_string(),
            functions,
        });
        Ok(())
    }

    pub fn function(&self, id: &str) -> Option<&FunctionSpec> {
        self.functions.get(id).map(Rc::as_ref)
    }

    /// Cheap shared handle for the executor's hot path (avoids cloning op
    /// payloads per step).
    pub fn function_rc(&self, id: &str) -> Option<Rc<FunctionSpec>> {
        self.functions.get(id).cloned()
    }

    pub fn app(&self, id: &str) -> Option<&AppSpec> {
        self.apps.get(id)
    }

    pub fn app_of(&self, function: &str) -> Option<&AppSpec> {
        self.function(function).and_then(|f| self.apps.get(&f.app))
    }

    pub fn hook(&self, function: &str) -> Option<&FreshenHook> {
        self.hooks.get(function)
    }

    pub fn chains(&self) -> &[ChainSpec] {
        &self.chains
    }

    /// Successor of `function` in any registered chain (first match) —
    /// the explicit-chain prediction signal of §2.
    pub fn chain_successor(&self, function: &str) -> Option<&FunctionId> {
        for chain in &self.chains {
            if let Some(pos) = chain.functions.iter().position(|f| f == function) {
                if pos + 1 < chain.functions.len() {
                    return Some(&chain.functions[pos + 1]);
                }
            }
        }
        None
    }

    pub fn function_count(&self) -> usize {
        self.functions.len()
    }

    pub fn function_ids(&self) -> Vec<FunctionId> {
        let mut ids: Vec<FunctionId> = self.functions.keys().cloned().collect();
        ids.sort();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::freshen::hooks::{FreshenAction, HookOrigin};
    use crate::util::time::SimDuration;

    fn ttl() -> SimDuration {
        SimDuration::from_secs(10)
    }

    fn lambda(id: &str, app: &str) -> FunctionSpec {
        FunctionSpec::paper_lambda(id, app, "store", SimDuration::from_millis(10))
    }

    #[test]
    fn deploy_infers_hook_and_creates_app() {
        let mut r = Registry::new();
        r.deploy(lambda("f1", "appA"), ttl());
        assert!(r.function("f1").is_some());
        assert!(r.app("appA").is_some());
        assert!(!r.hook("f1").unwrap().is_empty());
        assert!(!r.app("appA").unwrap().orchestrated);
    }

    #[test]
    fn developer_hook_replaces_inferred() {
        let mut r = Registry::new();
        r.deploy(lambda("f1", "a"), ttl());
        let mut custom = FreshenHook::new(HookOrigin::Developer, 2);
        custom.push(
            0,
            FreshenAction::EnsureConnection {
                endpoint: "store".into(),
            },
        );
        r.register_hook("f1", custom.clone()).unwrap();
        assert_eq!(r.hook("f1").unwrap().len(), 1);
        assert_eq!(r.hook("f1").unwrap().origin, HookOrigin::Developer);
        assert!(r.register_hook("ghost", custom).is_err());
    }

    #[test]
    fn chain_registration_and_successor() {
        let mut r = Registry::new();
        for f in ["a", "b", "c"] {
            r.deploy(lambda(f, "pipeline"), ttl());
        }
        r.register_chain("main", vec!["a".into(), "b".into(), "c".into()])
            .unwrap();
        assert_eq!(r.chain_successor("a"), Some(&"b".to_string()));
        assert_eq!(r.chain_successor("b"), Some(&"c".to_string()));
        assert_eq!(r.chain_successor("c"), None);
        assert!(r.app("pipeline").unwrap().orchestrated);
        assert!(r
            .register_chain("bad", vec!["a".into(), "ghost".into()])
            .is_err());
    }
}
