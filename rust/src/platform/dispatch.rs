//! Pluggable dispatch queueing: who waits, and in what order, when the
//! cluster is memory-full.
//!
//! The executor used to hard-code one answer — a per-function
//! `FxHashMap<FunctionId, VecDeque>` living on the `World`, drained one
//! invocation per eviction in hash-map iteration order. Under a contended
//! shared pool that is neither fair (hash order is arbitrary) nor
//! memory-efficient (one retry per eviction leaves freed memory idle).
//! [`QueueDiscipline`] extracts the three decision points behind a trait:
//!
//! - **enqueue**: a dispatch found no memory anywhere; the invocation
//!   waits ([`QueueDiscipline::enqueue`]). Retries that fail again
//!   re-enqueue with their original arrival stamp, so seniority is stable.
//! - **same-function drain**: a container just released and its function
//!   has queued work — every discipline hands over the *oldest* queued
//!   invocation of that function ([`QueueDiscipline::take_for_function`]);
//!   warm reuse is the platform's cheapest move and jumping the global
//!   order for it is the historical (and universal) fast path.
//! - **capacity drain**: memory was freed (an eviction, or a release
//!   under a pressure-only policy); the discipline picks which waiting
//!   invocation(s) to retry ([`QueueDiscipline::next_candidate`]) and how
//!   far to push ([`QueueDiscipline::drains_until_full`],
//!   [`QueueDiscipline::retries_past_failure`]).
//!
//! Three implementations span the fairness/efficiency design space:
//!
//! - [`LegacyOneShot`] — the pre-extraction behavior, kept byte-identical:
//!   per-function queues, ONE retry per drain, candidate = front of the
//!   first non-empty queue in hash-map iteration order. This is the
//!   default ([`QueueKind::LegacyOneShot`]), so every historical digest
//!   holds.
//! - [`FifoFair`] — one global arrival-order FIFO. A drain retries the
//!   head, then the next head, until a retry fails to place (the freed
//!   memory is exhausted). Strict head-of-line: nothing ever overtakes an
//!   older invocation, which bounds every function's time-in-queue by the
//!   queue's total service time.
//! - [`MemoryAware`] — smallest-memory-charge-first: a drain resumes as
//!   many invocations per freed MB as possible. An aging bound
//!   ([`MemoryAware::aging_bound`]) promotes the oldest entry once it has
//!   waited too long, so a large-memory function is guaranteed retry
//!   priority instead of starving behind an endless stream of small ones;
//!   a failed aged head falls back to the smallest candidate (one skip)
//!   so the promotion never livelocks the drain.
//!
//! Determinism: every discipline is a deterministic function of the
//! enqueue/drain call sequence. `LegacyOneShot` iterates an `FxHashMap`
//! whose key-insertion history is replay-deterministic (same trace, same
//! order), `FifoFair` orders by the dense arrival-ordered invocation id,
//! and `MemoryAware` breaks charge ties by that same id — no ambient
//! hashing, no wall-clock.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::platform::function::FunctionId;
use crate::platform::world::InvocationId;
use crate::util::config::QueueKind;
use crate::util::fxhash::FxHashMap;
use crate::util::time::{SimDuration, SimTime};

/// One waiting invocation, as the discipline sees it.
#[derive(Debug, Clone)]
pub struct Waiting {
    pub inv: InvocationId,
    pub function: FunctionId,
    /// MB the invocation's cold start would charge (fixed at first
    /// enqueue; the accounting mode never changes mid-run).
    pub charge_mb: u32,
    /// Arrival stamp — re-enqueues after a failed retry carry the
    /// original one, so seniority survives retries.
    pub enqueued_at: SimTime,
}

/// A dispatch queue discipline (see module docs).
pub trait QueueDiscipline {
    /// Stable identifier (reports, CLI echo).
    fn name(&self) -> &'static str;

    /// Add a waiting invocation (fresh arrival or failed retry).
    fn enqueue(&mut self, w: Waiting);

    /// The oldest waiting invocation of `function`, if any (same-function
    /// warm drain on container release).
    fn take_for_function(&mut self, function: &str) -> Option<InvocationId>;

    /// The next invocation to retry now that capacity freed, skipping
    /// the ones that already failed this drain round. `now` drives aging.
    fn next_candidate(&mut self, now: SimTime, skip: &[InvocationId]) -> Option<InvocationId>;

    /// Keep retrying further candidates after a successful placement?
    /// (`false` = the historical one-retry-per-drain behavior.)
    fn drains_until_full(&self) -> bool;

    /// Keep offering candidates after `failures` retries failed to place
    /// this drain round? Strict-FIFO head-of-line blocking says no;
    /// `MemoryAware` allows one skip past a failed aged head.
    fn retries_past_failure(&self, failures: usize) -> bool;

    /// Waiting invocations.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Build the discipline a [`QueueKind`] names. `aging_bound` is
/// [`MemoryAware`]'s anti-starvation promotion threshold
/// (`Config::queue_aging_bound`; the other disciplines ignore it).
pub fn build(kind: QueueKind, aging_bound: SimDuration) -> Box<dyn QueueDiscipline> {
    match kind {
        QueueKind::LegacyOneShot => Box::new(LegacyOneShot::default()),
        QueueKind::FifoFair => Box::new(FifoFair::default()),
        QueueKind::MemoryAware => Box::new(MemoryAware::with_aging_bound(aging_bound)),
    }
}

// ====================================================================
// LegacyOneShot
// ====================================================================

/// The pre-extraction inline behavior, byte-identical: per-function
/// `VecDeque`s in an `FxHashMap`, retries exactly one invocation per
/// drain, chosen as the front of the first non-empty queue in hash-map
/// iteration order. Failed retries push to the BACK of their function's
/// queue (the historical re-queue), and emptied queues keep their map
/// entry — both details matter for iteration-order identity.
#[derive(Default)]
pub struct LegacyOneShot {
    queues: FxHashMap<FunctionId, VecDeque<Waiting>>,
    len: usize,
}

impl LegacyOneShot {
    /// The cached `len` counter must always equal the per-function queue
    /// totals — a divergence means a discipline method lost or double
    /// counted a waiter.
    #[inline]
    fn debug_check_len(&self) {
        debug_assert_eq!(
            self.len,
            self.queues.values().map(VecDeque::len).sum::<usize>(),
            "legacy queue len counter diverged from its per-function queues"
        );
    }
}

impl QueueDiscipline for LegacyOneShot {
    fn name(&self) -> &'static str {
        "legacy"
    }

    fn enqueue(&mut self, w: Waiting) {
        self.queues.entry(w.function.clone()).or_default().push_back(w);
        self.len += 1;
        self.debug_check_len();
    }

    fn take_for_function(&mut self, function: &str) -> Option<InvocationId> {
        let w = self.queues.get_mut(function).and_then(|q| q.pop_front())?;
        self.len -= 1;
        self.debug_check_len();
        Some(w.inv)
    }

    fn next_candidate(&mut self, _now: SimTime, _skip: &[InvocationId]) -> Option<InvocationId> {
        let key = self
            .queues
            .iter()
            .find(|(_, q)| !q.is_empty())
            .map(|(k, _)| k.clone())?;
        let w = self.queues.get_mut(&key).and_then(|q| q.pop_front())?;
        self.len -= 1;
        self.debug_check_len();
        Some(w.inv)
    }

    fn drains_until_full(&self) -> bool {
        false
    }

    fn retries_past_failure(&self, _failures: usize) -> bool {
        false
    }

    fn len(&self) -> usize {
        self.len
    }
}

// ====================================================================
// FifoFair
// ====================================================================

/// One global FIFO in arrival order (invocation ids are dense and
/// arrival-ordered, so ordering by id IS arrival order). Drains head by
/// head until a placement fails: strict head-of-line, so the maximum
/// time-in-queue of ANY function is bounded by the backlog ahead of it.
/// (The one sanctioned overtake is the same-function warm fast path —
/// it consumes no memory the head could have used.)
///
/// Internally an id-keyed `BTreeMap` backbone (key order IS arrival
/// order) plus a per-function id index, so the same-function drain is
/// O(log n) instead of the old front-to-back scan — deep shared-pool
/// backlogs used to pay O(queue-depth) per completion. Pop order is
/// pinned unchanged by the module tests and the replay digests.
#[derive(Default)]
pub struct FifoFair {
    /// Arrival-ordered backbone: first key = oldest waiter.
    q: BTreeMap<InvocationId, Waiting>,
    /// Ids of each function's waiters, id-ordered (first = oldest). Keyed
    /// lookups only — never iterated — so the hash map stays inert to
    /// ordering.
    by_fn: FxHashMap<FunctionId, BTreeSet<InvocationId>>,
}

impl FifoFair {
    fn insert(&mut self, w: Waiting) {
        self.by_fn.entry(w.function.clone()).or_default().insert(w.inv);
        self.q.insert(w.inv, w);
        self.debug_check_index();
    }

    fn remove(&mut self, id: InvocationId) -> Option<Waiting> {
        let w = self.q.remove(&id)?;
        if let Some(set) = self.by_fn.get_mut(&w.function) {
            set.remove(&id);
            if set.is_empty() {
                self.by_fn.remove(&w.function);
            }
        }
        self.debug_check_index();
        Some(w)
    }

    fn oldest_of(&self, function: &str) -> Option<InvocationId> {
        self.by_fn.get(function)?.iter().next().copied()
    }

    /// The per-function index must partition the backbone exactly — a
    /// divergence means an insert/remove pair went through one structure
    /// but not the other.
    #[inline]
    fn debug_check_index(&self) {
        debug_assert_eq!(
            self.q.len(),
            self.by_fn.values().map(BTreeSet::len).sum::<usize>(),
            "fifo per-function index diverged from the queue backbone"
        );
    }
}

impl QueueDiscipline for FifoFair {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn enqueue(&mut self, w: Waiting) {
        self.insert(w);
    }

    fn take_for_function(&mut self, function: &str) -> Option<InvocationId> {
        let id = self.oldest_of(function)?;
        self.remove(id).map(|w| w.inv)
    }

    fn next_candidate(&mut self, _now: SimTime, skip: &[InvocationId]) -> Option<InvocationId> {
        // skip holds at most this round's failures (bounded by the
        // retries_past_failure cap), so the find is O(skip), not O(n).
        let id = *self.q.keys().find(|id| !skip.contains(id))?;
        self.remove(id).map(|w| w.inv)
    }

    fn drains_until_full(&self) -> bool {
        true
    }

    fn retries_past_failure(&self, _failures: usize) -> bool {
        false
    }

    fn len(&self) -> usize {
        self.q.len()
    }
}

// ====================================================================
// MemoryAware
// ====================================================================

/// Smallest-charge-first drain: each freed chunk of memory resumes as
/// many waiting invocations as it can hold. Ties break by arrival order
/// (lowest id). The aging bound keeps it starvation-free: once the
/// oldest entry has waited `aging_bound`, it is offered FIRST regardless
/// of size; if that aged retry fails to place, the drain falls back to
/// the smallest candidate (one skip) so small work keeps flowing while
/// the aged entry retains its priority for every later drain.
///
/// Same indexed backbone as [`FifoFair`] plus a `(charge, id)`-ordered
/// selection index, so the per-completion smallest-charge pick is
/// O(log n) instead of the old full-queue `min_by_key` scan. The index's
/// iteration order — smallest charge first, ties to the lowest id — is
/// exactly the old scan's first-minimum order, so pop order is
/// unchanged (pinned by the module tests and the replay digests).
pub struct MemoryAware {
    /// Arrival-ordered backbone: first key = oldest waiter (the aging
    /// probe).
    q: BTreeMap<InvocationId, Waiting>,
    /// Ids of each function's waiters, id-ordered. Keyed lookups only.
    by_fn: FxHashMap<FunctionId, BTreeSet<InvocationId>>,
    /// Charge-ordered selection index: first entry = smallest charge,
    /// ties to the oldest (lowest id).
    by_charge: BTreeSet<(u32, InvocationId)>,
    /// Queue wait after which the oldest entry outranks smaller charges.
    pub aging_bound: SimDuration,
    /// Was the most recent candidate an aged-head promotion? Only then is
    /// a post-failure retry worth anything: if the SMALLEST charge failed
    /// to place, every other candidate fails too.
    last_was_aged: bool,
}

/// Default promotion threshold: long enough that smallest-first wins the
/// common case, short enough that a heavy function waits seconds — not a
/// trace horizon — under sustained small-function pressure.
pub const MEMAWARE_AGING_BOUND: SimDuration = SimDuration(30_000_000); // 30 s

impl Default for MemoryAware {
    fn default() -> MemoryAware {
        MemoryAware::with_aging_bound(MEMAWARE_AGING_BOUND)
    }
}

impl MemoryAware {
    /// An empty queue with a custom promotion threshold (tests and
    /// ablations; the platform default is [`MEMAWARE_AGING_BOUND`]).
    pub fn with_aging_bound(aging_bound: SimDuration) -> MemoryAware {
        MemoryAware {
            q: BTreeMap::new(),
            by_fn: FxHashMap::default(),
            by_charge: BTreeSet::new(),
            aging_bound,
            last_was_aged: false,
        }
    }

    fn insert(&mut self, w: Waiting) {
        self.by_fn.entry(w.function.clone()).or_default().insert(w.inv);
        self.by_charge.insert((w.charge_mb, w.inv));
        self.q.insert(w.inv, w);
        self.debug_check_index();
    }

    fn remove(&mut self, id: InvocationId) -> Option<Waiting> {
        let w = self.q.remove(&id)?;
        self.by_charge.remove(&(w.charge_mb, w.inv));
        if let Some(set) = self.by_fn.get_mut(&w.function) {
            set.remove(&id);
            if set.is_empty() {
                self.by_fn.remove(&w.function);
            }
        }
        self.debug_check_index();
        Some(w)
    }

    /// Both indexes must partition the backbone exactly.
    #[inline]
    fn debug_check_index(&self) {
        debug_assert_eq!(
            self.q.len(),
            self.by_fn.values().map(BTreeSet::len).sum::<usize>(),
            "memaware per-function index diverged from the queue backbone"
        );
        debug_assert_eq!(
            self.q.len(),
            self.by_charge.len(),
            "memaware charge index diverged from the queue backbone"
        );
    }
}

impl QueueDiscipline for MemoryAware {
    fn name(&self) -> &'static str {
        "memaware"
    }

    fn enqueue(&mut self, w: Waiting) {
        // Same arrival-ordered backbone as FifoFair: the first key is
        // always the oldest entry (the aging probe), selection goes
        // through the charge index.
        self.insert(w);
    }

    fn take_for_function(&mut self, function: &str) -> Option<InvocationId> {
        let id = self.by_fn.get(function)?.iter().next().copied()?;
        self.remove(id).map(|w| w.inv)
    }

    fn next_candidate(&mut self, now: SimTime, skip: &[InvocationId]) -> Option<InvocationId> {
        // Aged head first — but only as the round's FIRST candidate: once
        // anything failed this round (the aged head included), the drain
        // falls back to smallest-charge so small work keeps flowing
        // instead of burning the round on further aged heavyweights.
        if skip.is_empty() {
            let (&id, front) = self.q.iter().next()?;
            if now.since(front.enqueued_at) >= self.aging_bound {
                // The backbone is id-keyed, so the promoted first entry
                // is by construction the globally most-senior waiter —
                // promotion never jumps a younger entry over an older
                // one.
                self.last_was_aged = true;
                return self.remove(id).map(|w| w.inv);
            }
        }
        // The smallest charge, ties to the oldest (lowest id): the
        // (charge, id) index iterates in exactly that order, so the first
        // non-skipped entry is the old scan's first minimum. skip is at
        // most one entry (see retries_past_failure), so this is O(skip).
        let id = self
            .by_charge
            .iter()
            .find(|(_, id)| !skip.contains(id))
            .map(|&(_, id)| id)?;
        self.last_was_aged = false;
        self.remove(id).map(|w| w.inv)
    }

    fn drains_until_full(&self) -> bool {
        true
    }

    fn retries_past_failure(&self, failures: usize) -> bool {
        // One skip, and only past a failed AGED head: it must not
        // head-of-line-block the small work that still fits. If the
        // smallest candidate was the one that failed, no other candidate
        // can place either — stop.
        failures < 2 && self.last_was_aged
    }

    fn len(&self) -> usize {
        self.q.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(inv: InvocationId, function: &str, mb: u32, at_s: u64) -> Waiting {
        Waiting {
            inv,
            function: function.to_string(),
            charge_mb: mb,
            enqueued_at: SimTime(at_s * 1_000_000),
        }
    }

    fn t(s: u64) -> SimTime {
        SimTime(s * 1_000_000)
    }

    #[test]
    fn build_maps_kinds_to_disciplines() {
        for kind in QueueKind::all() {
            let d = build(kind, MEMAWARE_AGING_BOUND);
            assert_eq!(d.name(), kind.as_str());
            assert!(d.is_empty());
        }
    }

    #[test]
    fn build_threads_the_aging_bound_through() {
        let mut d = build(QueueKind::MemoryAware, SimDuration::from_secs(5));
        d.enqueue(w(0, "big", 2048, 0));
        d.enqueue(w(1, "small", 128, 1));
        // At t=6 s the oldest entry has waited past the 5 s bound, so it
        // is promoted over the smaller charge — proving the custom bound
        // (not the 30 s default) is in effect.
        assert_eq!(d.next_candidate(t(6), &[]), Some(0));
        // With the default bound the same drain picks the smallest.
        let mut d = build(QueueKind::MemoryAware, MEMAWARE_AGING_BOUND);
        d.enqueue(w(0, "big", 2048, 0));
        d.enqueue(w(1, "small", 128, 1));
        assert_eq!(d.next_candidate(t(6), &[]), Some(1));
    }

    #[test]
    fn legacy_is_per_function_fifo_with_one_shot_drain() {
        let mut d = LegacyOneShot::default();
        d.enqueue(w(0, "f", 256, 0));
        d.enqueue(w(1, "g", 256, 1));
        d.enqueue(w(2, "f", 256, 2));
        assert_eq!(d.len(), 3);
        // Same-function drain is per-function FIFO.
        assert_eq!(d.take_for_function("f"), Some(0));
        assert_eq!(d.take_for_function("f"), Some(2));
        assert_eq!(d.take_for_function("f"), None);
        assert_eq!(d.len(), 1);
        // One-shot drain: a single candidate per round, never more.
        assert!(!d.drains_until_full());
        assert!(!d.retries_past_failure(0));
        assert_eq!(d.next_candidate(t(10), &[]), Some(1));
        assert_eq!(d.next_candidate(t(10), &[]), None);
        assert!(d.is_empty());
    }

    #[test]
    fn legacy_candidate_follows_hash_map_iteration_order() {
        // The candidate must be the front of the FIRST non-empty queue in
        // FxHashMap iteration order — whatever that order is, it must
        // match an identically-built map (the byte-identity property the
        // executor relies on).
        let mut d = LegacyOneShot::default();
        let mut reference: FxHashMap<FunctionId, VecDeque<InvocationId>> = FxHashMap::default();
        for (i, f) in ["a", "b", "c", "d", "e"].iter().enumerate() {
            d.enqueue(w(i, f, 256, 0));
            reference.entry(f.to_string()).or_default().push_back(i);
        }
        let expected = reference
            .iter()
            .find(|(_, q)| !q.is_empty())
            .map(|(_, q)| q[0])
            .unwrap();
        assert_eq!(d.next_candidate(t(0), &[]), Some(expected));
    }

    #[test]
    fn fifo_orders_globally_by_arrival_and_reinserts_at_seniority() {
        let mut d = FifoFair::default();
        d.enqueue(w(3, "a", 256, 3));
        d.enqueue(w(5, "b", 512, 5));
        assert_eq!(d.next_candidate(t(9), &[]), Some(3));
        // Failed retry: re-enqueue with the original stamp → back to the
        // head, ahead of the younger entry.
        d.enqueue(w(3, "a", 256, 3));
        assert_eq!(d.next_candidate(t(9), &[]), Some(3));
        d.enqueue(w(3, "a", 256, 3));
        // A failed head is skipped for the rest of the drain round.
        assert_eq!(d.next_candidate(t(9), &[3]), Some(5), "skip honors the failed head");
        d.enqueue(w(7, "a", 256, 7));
        d.enqueue(w(8, "a", 128, 8));
        // Same-function drain hands over the oldest of that function.
        assert_eq!(d.take_for_function("a"), Some(3));
        assert_eq!(d.take_for_function("a"), Some(7));
        assert_eq!(d.take_for_function("b"), None, "5 was drained above");
        assert_eq!(d.len(), 1);
        assert!(d.drains_until_full());
        assert!(!d.retries_past_failure(1), "strict head-of-line");
    }

    #[test]
    fn memaware_picks_smallest_charge_until_the_aging_bound_promotes() {
        let mut d = MemoryAware::default();
        d.enqueue(w(0, "big", 2048, 0));
        d.enqueue(w(1, "small", 128, 1));
        d.enqueue(w(2, "mid", 512, 2));
        // Under the bound: smallest charge wins.
        assert_eq!(d.next_candidate(t(5), &[]), Some(1));
        d.enqueue(w(1, "small", 128, 1));
        // Ties break to the oldest entry.
        d.enqueue(w(3, "small2", 128, 3));
        assert_eq!(d.next_candidate(t(5), &[]), Some(1));
        // A failed smallest pick ends the round: nothing larger could
        // place where it failed.
        assert!(!d.retries_past_failure(1), "failed smallest stops the drain");
        // Past the bound, the oldest entry outranks everything. (At
        // t=31 s entry 0 has waited 31 s ≥ the 30 s bound; entry 2 only
        // 29 s.)
        assert_eq!(d.next_candidate(t(31), &[]), Some(0), "aged head promoted");
        // A failed AGED head is worth one skip — the smallest flows again.
        assert!(d.retries_past_failure(1), "one skip past a failed aged head");
        assert!(!d.retries_past_failure(2), "then stop");
        d.enqueue(w(0, "big", 2048, 0));
        assert_eq!(d.next_candidate(t(31), &[0]), Some(3));
        assert!(
            !d.retries_past_failure(1),
            "the fallback pick was the smallest: a failure is terminal"
        );
        assert_eq!(d.take_for_function("mid"), Some(2));
        assert_eq!(d.len(), 1);
    }

    /// The indexed FifoFair/MemoryAware must pop in EXACTLY the order of
    /// the pre-index O(n)-scan implementations: drive both against
    /// reference models (the old `VecDeque` scans, verbatim) through a
    /// long seeded op mix and pin every returned id. A divergence here
    /// would shift replay digests, which the azure-macro goldens forbid.
    #[test]
    fn indexed_disciplines_match_the_reference_scan_order() {
        use crate::util::rng::Rng;

        // The old arrival-ordered VecDeque backbone, verbatim.
        fn insert_ordered(q: &mut VecDeque<Waiting>, w: Waiting) {
            let pos = q.partition_point(|e| e.inv < w.inv);
            q.insert(pos, w);
        }

        struct RefModel {
            q: VecDeque<Waiting>,
            memaware: bool,
            aging_bound: SimDuration,
        }

        impl RefModel {
            fn take_for_function(&mut self, function: &str) -> Option<InvocationId> {
                let idx = self.q.iter().position(|e| e.function == function)?;
                self.q.remove(idx).map(|w| w.inv)
            }

            fn next_candidate(&mut self, now: SimTime, skip: &[InvocationId]) -> Option<InvocationId> {
                if self.memaware {
                    if skip.is_empty() {
                        let front = self.q.front()?;
                        if now.since(front.enqueued_at) >= self.aging_bound {
                            return self.q.pop_front().map(|w| w.inv);
                        }
                    }
                    let idx = self
                        .q
                        .iter()
                        .enumerate()
                        .filter(|(_, e)| !skip.contains(&e.inv))
                        .min_by_key(|(_, e)| e.charge_mb)
                        .map(|(i, _)| i)?;
                    self.q.remove(idx).map(|w| w.inv)
                } else {
                    let idx = self.q.iter().position(|e| !skip.contains(&e.inv))?;
                    self.q.remove(idx).map(|w| w.inv)
                }
            }
        }

        let bound = SimDuration::from_secs(20);
        for (kind, memaware) in [(QueueKind::FifoFair, false), (QueueKind::MemoryAware, true)] {
            let mut indexed = build(kind, bound);
            let mut model = RefModel { q: VecDeque::new(), memaware, aging_bound: bound };
            let mut rng = Rng::new(0xD15B_A7C4 ^ memaware as u64);
            let functions = ["a", "b", "c", "d"];
            let charges = [128u32, 256, 256, 512, 2048];
            let mut next_id: InvocationId = 0;
            let mut last_popped: Option<InvocationId> = None;
            for step in 0..2_000u64 {
                // Sim time advances with the op index so the aging bound
                // fires on some drains and not others.
                let now = SimTime(step * 100_000);
                match rng.below(10) {
                    // Fresh arrival (ids stay dense and arrival-ordered).
                    0..=4 => {
                        let f = functions[rng.below(functions.len() as u64) as usize];
                        let mb = charges[rng.below(charges.len() as u64) as usize];
                        let wait = w(next_id, f, mb, step / 10);
                        indexed.enqueue(wait.clone());
                        insert_ordered(&mut model.q, wait);
                        next_id += 1;
                    }
                    // Same-function drain.
                    5..=6 => {
                        let f = functions[rng.below(functions.len() as u64) as usize];
                        let got = indexed.take_for_function(f);
                        assert_eq!(got, model.take_for_function(f), "step {step}: take({f})");
                        last_popped = None;
                    }
                    // Capacity drain, clean round. Remember the pop so a
                    // later op can replay it as a failed retry.
                    7..=8 => {
                        let got = indexed.next_candidate(now, &[]);
                        assert_eq!(got, model.next_candidate(now, &[]), "step {step}: drain");
                        last_popped = got;
                    }
                    // Failed retry: re-enqueue the last pop at its original
                    // seniority, then drain again skipping it.
                    _ => {
                        if let Some(prev) = last_popped.take() {
                            let f = functions[rng.below(functions.len() as u64) as usize];
                            let mb = charges[rng.below(charges.len() as u64) as usize];
                            let back = w(prev, f, mb, step / 10);
                            indexed.enqueue(back.clone());
                            insert_ordered(&mut model.q, back);
                            let skip = [prev];
                            let got = indexed.next_candidate(now, &skip);
                            assert_eq!(got, model.next_candidate(now, &skip), "step {step}: skip drain");
                        }
                    }
                }
                assert_eq!(indexed.len(), model.q.len(), "step {step}: length");
            }
            // Full drain at the end: every remaining pop must agree too.
            loop {
                let got = indexed.next_candidate(SimTime(u64::MAX / 2), &[]);
                assert_eq!(got, model.next_candidate(SimTime(u64::MAX / 2), &[]), "final drain");
                if got.is_none() {
                    break;
                }
            }
            assert!(indexed.is_empty());
        }
    }
}
