//! Pluggable dispatch queueing: who waits, and in what order, when the
//! cluster is memory-full.
//!
//! The executor used to hard-code one answer — a per-function
//! `FxHashMap<FunctionId, VecDeque>` living on the `World`, drained one
//! invocation per eviction in hash-map iteration order. Under a contended
//! shared pool that is neither fair (hash order is arbitrary) nor
//! memory-efficient (one retry per eviction leaves freed memory idle).
//! [`QueueDiscipline`] extracts the three decision points behind a trait:
//!
//! - **enqueue**: a dispatch found no memory anywhere; the invocation
//!   waits ([`QueueDiscipline::enqueue`]). Retries that fail again
//!   re-enqueue with their original arrival stamp, so seniority is stable.
//! - **same-function drain**: a container just released and its function
//!   has queued work — every discipline hands over the *oldest* queued
//!   invocation of that function ([`QueueDiscipline::take_for_function`]);
//!   warm reuse is the platform's cheapest move and jumping the global
//!   order for it is the historical (and universal) fast path.
//! - **capacity drain**: memory was freed (an eviction, or a release
//!   under a pressure-only policy); the discipline picks which waiting
//!   invocation(s) to retry ([`QueueDiscipline::next_candidate`]) and how
//!   far to push ([`QueueDiscipline::drains_until_full`],
//!   [`QueueDiscipline::retries_past_failure`]).
//!
//! Waiters carry the interned [`FnId`] plus their dense arrival `seq`
//! (the legacy invocation id); enqueue/take resolve names through the
//! world's [`Symbols`] table only where a discipline is genuinely
//! string-keyed, so the hot path hashes 4-byte ids, not tenant-qualified
//! name strings.
//!
//! Three implementations span the fairness/efficiency design space:
//!
//! - [`LegacyOneShot`] — the pre-extraction behavior, kept byte-identical:
//!   per-function queues, ONE retry per drain, candidate = front of the
//!   first non-empty queue in hash-map iteration order. This is the
//!   default ([`QueueKind::LegacyOneShot`]), so every historical digest
//!   holds. The map is keyed by the interned `Rc<str>` name (refcount
//!   bump per enqueue, no allocation): `Rc<str>` hashes byte-identically
//!   to the `String` it replaced under Fx (pinned by a `symbols` test),
//!   and the key-insertion sequence is unchanged, so iteration order —
//!   and with it the drain order and every digest — is unchanged.
//! - [`FifoFair`] — one global arrival-order FIFO. A drain retries the
//!   head, then the next head, until a retry fails to place (the freed
//!   memory is exhausted). Strict head-of-line: nothing ever overtakes an
//!   older invocation, which bounds every function's time-in-queue by the
//!   queue's total service time.
//! - [`MemoryAware`] — smallest-memory-charge-first: a drain resumes as
//!   many invocations per freed MB as possible. An aging bound
//!   ([`MemoryAware::aging_bound`]) promotes the oldest entry once it has
//!   waited too long, so a large-memory function is guaranteed retry
//!   priority instead of starving behind an endless stream of small ones;
//!   a failed aged head falls back to the smallest candidate (one skip)
//!   so the promotion never livelocks the drain.
//!
//! Determinism: every discipline is a deterministic function of the
//! enqueue/drain call sequence. `LegacyOneShot` iterates an `FxHashMap`
//! whose key-insertion history is replay-deterministic (same trace, same
//! order), `FifoFair` orders by the dense arrival `seq`, and
//! `MemoryAware` breaks charge ties by that same `seq` — no ambient
//! hashing, no wall-clock.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::rc::Rc;

use crate::platform::slab::InvocationId;
use crate::platform::symbols::{FnId, Symbols};
use crate::util::config::QueueKind;
use crate::util::fxhash::FxHashMap;
use crate::util::time::{SimDuration, SimTime};

/// One waiting invocation, as the discipline sees it.
#[derive(Debug, Clone)]
pub struct Waiting {
    pub inv: InvocationId,
    /// Dense arrival sequence number of the invocation (the legacy id);
    /// the global ordering key of every arrival-ordered discipline.
    pub seq: u64,
    pub function: FnId,
    /// MB the invocation's cold start would charge (fixed at first
    /// enqueue; the accounting mode never changes mid-run).
    pub charge_mb: u32,
    /// Arrival stamp — re-enqueues after a failed retry carry the
    /// original one, so seniority survives retries.
    pub enqueued_at: SimTime,
}

/// A dispatch queue discipline (see module docs).
pub trait QueueDiscipline {
    /// Stable identifier (reports, CLI echo).
    fn name(&self) -> &'static str;

    /// Add a waiting invocation (fresh arrival or failed retry). `syms`
    /// resolves the interned function id for string-keyed disciplines.
    fn enqueue(&mut self, w: Waiting, syms: &Symbols);

    /// The oldest waiting invocation of `function`, if any (same-function
    /// warm drain on container release).
    fn take_for_function(&mut self, function: FnId, syms: &Symbols) -> Option<InvocationId>;

    /// The next invocation to retry now that capacity freed, skipping
    /// the ones that already failed this drain round. `now` drives aging.
    fn next_candidate(&mut self, now: SimTime, skip: &[InvocationId]) -> Option<InvocationId>;

    /// Keep retrying further candidates after a successful placement?
    /// (`false` = the historical one-retry-per-drain behavior.)
    fn drains_until_full(&self) -> bool;

    /// Keep offering candidates after `failures` retries failed to place
    /// this drain round? Strict-FIFO head-of-line blocking says no;
    /// `MemoryAware` allows one skip past a failed aged head.
    fn retries_past_failure(&self, failures: usize) -> bool;

    /// Waiting invocations.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Build the discipline a [`QueueKind`] names. `aging_bound` is
/// [`MemoryAware`]'s anti-starvation promotion threshold
/// (`Config::queue_aging_bound`; the other disciplines ignore it).
pub fn build(kind: QueueKind, aging_bound: SimDuration) -> Box<dyn QueueDiscipline> {
    match kind {
        QueueKind::LegacyOneShot => Box::new(LegacyOneShot::default()),
        QueueKind::FifoFair => Box::new(FifoFair::default()),
        QueueKind::MemoryAware => Box::new(MemoryAware::with_aging_bound(aging_bound)),
    }
}

// ====================================================================
// LegacyOneShot
// ====================================================================

/// The pre-extraction inline behavior, byte-identical: per-function
/// `VecDeque`s in an `FxHashMap`, retries exactly one invocation per
/// drain, chosen as the front of the first non-empty queue in hash-map
/// iteration order. Failed retries push to the BACK of their function's
/// queue (the historical re-queue), and emptied queues keep their map
/// entry — both details matter for iteration-order identity. Keys are the
/// interned `Rc<str>` names (Fx-hash-identical to the `String`s they
/// replaced; see module docs).
#[derive(Default)]
pub struct LegacyOneShot {
    queues: FxHashMap<Rc<str>, VecDeque<Waiting>>,
    len: usize,
}

impl LegacyOneShot {
    /// The cached `len` counter must always equal the per-function queue
    /// totals — a divergence means a discipline method lost or double
    /// counted a waiter.
    #[inline]
    fn debug_check_len(&self) {
        debug_assert_eq!(
            self.len,
            self.queues.values().map(VecDeque::len).sum::<usize>(),
            "legacy queue len counter diverged from its per-function queues"
        );
    }
}

impl QueueDiscipline for LegacyOneShot {
    fn name(&self) -> &'static str {
        "legacy"
    }

    fn enqueue(&mut self, w: Waiting, syms: &Symbols) {
        self.queues.entry(syms.rc(w.function)).or_default().push_back(w);
        self.len += 1;
        self.debug_check_len();
    }

    fn take_for_function(&mut self, function: FnId, syms: &Symbols) -> Option<InvocationId> {
        let w = self
            .queues
            .get_mut(syms.resolve(function))
            .and_then(|q| q.pop_front())?;
        self.len -= 1;
        self.debug_check_len();
        Some(w.inv)
    }

    fn next_candidate(&mut self, _now: SimTime, _skip: &[InvocationId]) -> Option<InvocationId> {
        let key = self
            .queues
            .iter()
            .find(|(_, q)| !q.is_empty())
            .map(|(k, _)| Rc::clone(k))?;
        let w = self.queues.get_mut(&key).and_then(|q| q.pop_front())?;
        self.len -= 1;
        self.debug_check_len();
        Some(w.inv)
    }

    fn drains_until_full(&self) -> bool {
        false
    }

    fn retries_past_failure(&self, _failures: usize) -> bool {
        false
    }

    fn len(&self) -> usize {
        self.len
    }
}

// ====================================================================
// FifoFair
// ====================================================================

/// One global FIFO in arrival order (arrival `seq`s are dense and
/// arrival-ordered by construction, so ordering by seq IS arrival
/// order). Drains head by head until a placement fails: strict
/// head-of-line, so the maximum time-in-queue of ANY function is bounded
/// by the backlog ahead of it. (The one sanctioned overtake is the
/// same-function warm fast path — it consumes no memory the head could
/// have used.)
///
/// Internally a seq-keyed `BTreeMap` backbone (key order IS arrival
/// order) plus a per-function seq index, so the same-function drain is
/// O(log n) instead of the old front-to-back scan — deep shared-pool
/// backlogs used to pay O(queue-depth) per completion. Pop order is
/// pinned unchanged by the module tests and the replay digests.
#[derive(Default)]
pub struct FifoFair {
    /// Arrival-ordered backbone: first key = oldest waiter.
    q: BTreeMap<u64, Waiting>,
    /// Seqs of each function's waiters, seq-ordered (first = oldest).
    /// Keyed lookups only — never iterated — so the hash map stays inert
    /// to ordering.
    by_fn: FxHashMap<FnId, BTreeSet<u64>>,
}

impl FifoFair {
    fn insert(&mut self, w: Waiting) {
        self.by_fn.entry(w.function).or_default().insert(w.seq);
        self.q.insert(w.seq, w);
        self.debug_check_index();
    }

    fn remove(&mut self, seq: u64) -> Option<Waiting> {
        let w = self.q.remove(&seq)?;
        if let Some(set) = self.by_fn.get_mut(&w.function) {
            set.remove(&seq);
            if set.is_empty() {
                self.by_fn.remove(&w.function);
            }
        }
        self.debug_check_index();
        Some(w)
    }

    fn oldest_of(&self, function: FnId) -> Option<u64> {
        self.by_fn.get(&function)?.iter().next().copied()
    }

    /// The per-function index must partition the backbone exactly — a
    /// divergence means an insert/remove pair went through one structure
    /// but not the other.
    #[inline]
    fn debug_check_index(&self) {
        debug_assert_eq!(
            self.q.len(),
            self.by_fn.values().map(BTreeSet::len).sum::<usize>(),
            "fifo per-function index diverged from the queue backbone"
        );
    }
}

impl QueueDiscipline for FifoFair {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn enqueue(&mut self, w: Waiting, _syms: &Symbols) {
        self.insert(w);
    }

    fn take_for_function(&mut self, function: FnId, _syms: &Symbols) -> Option<InvocationId> {
        let seq = self.oldest_of(function)?;
        self.remove(seq).map(|w| w.inv)
    }

    fn next_candidate(&mut self, _now: SimTime, skip: &[InvocationId]) -> Option<InvocationId> {
        // skip holds at most this round's failures (bounded by the
        // retries_past_failure cap), so the find is O(skip), not O(n).
        let seq = self
            .q
            .iter()
            .find(|(_, w)| !skip.contains(&w.inv))
            .map(|(&s, _)| s)?;
        self.remove(seq).map(|w| w.inv)
    }

    fn drains_until_full(&self) -> bool {
        true
    }

    fn retries_past_failure(&self, _failures: usize) -> bool {
        false
    }

    fn len(&self) -> usize {
        self.q.len()
    }
}

// ====================================================================
// MemoryAware
// ====================================================================

/// Smallest-charge-first drain: each freed chunk of memory resumes as
/// many waiting invocations as it can hold. Ties break by arrival order
/// (lowest seq). The aging bound keeps it starvation-free: once the
/// oldest entry has waited `aging_bound`, it is offered FIRST regardless
/// of size; if that aged retry fails to place, the drain falls back to
/// the smallest candidate (one skip) so small work keeps flowing while
/// the aged entry retains its priority for every later drain.
///
/// Same indexed backbone as [`FifoFair`] plus a `(charge, seq)`-ordered
/// selection index, so the per-completion smallest-charge pick is
/// O(log n) instead of the old full-queue `min_by_key` scan. The index's
/// iteration order — smallest charge first, ties to the lowest seq — is
/// exactly the old scan's first-minimum order, so pop order is
/// unchanged (pinned by the module tests and the replay digests).
pub struct MemoryAware {
    /// Arrival-ordered backbone: first key = oldest waiter (the aging
    /// probe).
    q: BTreeMap<u64, Waiting>,
    /// Seqs of each function's waiters, seq-ordered. Keyed lookups only.
    by_fn: FxHashMap<FnId, BTreeSet<u64>>,
    /// Charge-ordered selection index: first entry = smallest charge,
    /// ties to the oldest (lowest seq).
    by_charge: BTreeSet<(u32, u64)>,
    /// Queue wait after which the oldest entry outranks smaller charges.
    pub aging_bound: SimDuration,
    /// Was the most recent candidate an aged-head promotion? Only then is
    /// a post-failure retry worth anything: if the SMALLEST charge failed
    /// to place, every other candidate fails too.
    last_was_aged: bool,
}

/// Default promotion threshold: long enough that smallest-first wins the
/// common case, short enough that a heavy function waits seconds — not a
/// trace horizon — under sustained small-function pressure.
pub const MEMAWARE_AGING_BOUND: SimDuration = SimDuration(30_000_000); // 30 s

impl Default for MemoryAware {
    fn default() -> MemoryAware {
        MemoryAware::with_aging_bound(MEMAWARE_AGING_BOUND)
    }
}

impl MemoryAware {
    /// An empty queue with a custom promotion threshold (tests and
    /// ablations; the platform default is [`MEMAWARE_AGING_BOUND`]).
    pub fn with_aging_bound(aging_bound: SimDuration) -> MemoryAware {
        MemoryAware {
            q: BTreeMap::new(),
            by_fn: FxHashMap::default(),
            by_charge: BTreeSet::new(),
            aging_bound,
            last_was_aged: false,
        }
    }

    fn insert(&mut self, w: Waiting) {
        self.by_fn.entry(w.function).or_default().insert(w.seq);
        self.by_charge.insert((w.charge_mb, w.seq));
        self.q.insert(w.seq, w);
        self.debug_check_index();
    }

    fn remove(&mut self, seq: u64) -> Option<Waiting> {
        let w = self.q.remove(&seq)?;
        self.by_charge.remove(&(w.charge_mb, w.seq));
        if let Some(set) = self.by_fn.get_mut(&w.function) {
            set.remove(&seq);
            if set.is_empty() {
                self.by_fn.remove(&w.function);
            }
        }
        self.debug_check_index();
        Some(w)
    }

    /// Both indexes must partition the backbone exactly.
    #[inline]
    fn debug_check_index(&self) {
        debug_assert_eq!(
            self.q.len(),
            self.by_fn.values().map(BTreeSet::len).sum::<usize>(),
            "memaware per-function index diverged from the queue backbone"
        );
        debug_assert_eq!(
            self.q.len(),
            self.by_charge.len(),
            "memaware charge index diverged from the queue backbone"
        );
    }
}

impl QueueDiscipline for MemoryAware {
    fn name(&self) -> &'static str {
        "memaware"
    }

    fn enqueue(&mut self, w: Waiting, _syms: &Symbols) {
        // Same arrival-ordered backbone as FifoFair: the first key is
        // always the oldest entry (the aging probe), selection goes
        // through the charge index.
        self.insert(w);
    }

    fn take_for_function(&mut self, function: FnId, _syms: &Symbols) -> Option<InvocationId> {
        let seq = self.by_fn.get(&function)?.iter().next().copied()?;
        self.remove(seq).map(|w| w.inv)
    }

    fn next_candidate(&mut self, now: SimTime, skip: &[InvocationId]) -> Option<InvocationId> {
        // Aged head first — but only as the round's FIRST candidate: once
        // anything failed this round (the aged head included), the drain
        // falls back to smallest-charge so small work keeps flowing
        // instead of burning the round on further aged heavyweights.
        if skip.is_empty() {
            let (&seq, front) = self.q.iter().next()?;
            if now.since(front.enqueued_at) >= self.aging_bound {
                // The backbone is seq-keyed, so the promoted first entry
                // is by construction the globally most-senior waiter —
                // promotion never jumps a younger entry over an older
                // one.
                self.last_was_aged = true;
                return self.remove(seq).map(|w| w.inv);
            }
        }
        // The smallest charge, ties to the oldest (lowest seq): the
        // (charge, seq) index iterates in exactly that order, so the
        // first non-skipped entry is the old scan's first minimum. skip
        // is at most one entry (see retries_past_failure), so this is
        // O(skip).
        let seq = self
            .by_charge
            .iter()
            .find(|&&(_, seq)| !skip.contains(&self.q[&seq].inv))
            .map(|&(_, seq)| seq)?;
        self.last_was_aged = false;
        self.remove(seq).map(|w| w.inv)
    }

    fn drains_until_full(&self) -> bool {
        true
    }

    fn retries_past_failure(&self, failures: usize) -> bool {
        // One skip, and only past a failed AGED head: it must not
        // head-of-line-block the small work that still fits. If the
        // smallest candidate was the one that failed, no other candidate
        // can place either — stop.
        failures < 2 && self.last_was_aged
    }

    fn len(&self) -> usize {
        self.q.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::slab::InvocationSlab;

    /// Mint `n` live handles with dense seqs 0..n (append-only slab, so
    /// handle i carries seq i — the legacy dense-id regime).
    fn mint(n: usize) -> Vec<InvocationId> {
        let mut slab: InvocationSlab<()> = InvocationSlab::new();
        (0..n).map(|_| slab.insert_with(|_, _| ())).collect()
    }

    struct Names {
        syms: Symbols,
    }

    impl Names {
        fn new(names: &[&str]) -> Names {
            let mut syms = Symbols::new();
            for n in names {
                syms.intern(n);
            }
            Names { syms }
        }

        fn id(&self, name: &str) -> FnId {
            self.syms.lookup(name).unwrap()
        }
    }

    fn w(ids: &[InvocationId], seq: usize, function: FnId, mb: u32, at_s: u64) -> Waiting {
        Waiting {
            inv: ids[seq],
            seq: seq as u64,
            function,
            charge_mb: mb,
            enqueued_at: SimTime(at_s * 1_000_000),
        }
    }

    fn t(s: u64) -> SimTime {
        SimTime(s * 1_000_000)
    }

    #[test]
    fn build_maps_kinds_to_disciplines() {
        for kind in QueueKind::all() {
            let d = build(kind, MEMAWARE_AGING_BOUND);
            assert_eq!(d.name(), kind.as_str());
            assert!(d.is_empty());
        }
    }

    #[test]
    fn build_threads_the_aging_bound_through() {
        let ids = mint(2);
        let names = Names::new(&["big", "small"]);
        let (big, small) = (names.id("big"), names.id("small"));
        let mut d = build(QueueKind::MemoryAware, SimDuration::from_secs(5));
        d.enqueue(w(&ids, 0, big, 2048, 0), &names.syms);
        d.enqueue(w(&ids, 1, small, 128, 1), &names.syms);
        // At t=6 s the oldest entry has waited past the 5 s bound, so it
        // is promoted over the smaller charge — proving the custom bound
        // (not the 30 s default) is in effect.
        assert_eq!(d.next_candidate(t(6), &[]), Some(ids[0]));
        // With the default bound the same drain picks the smallest.
        let mut d = build(QueueKind::MemoryAware, MEMAWARE_AGING_BOUND);
        d.enqueue(w(&ids, 0, big, 2048, 0), &names.syms);
        d.enqueue(w(&ids, 1, small, 128, 1), &names.syms);
        assert_eq!(d.next_candidate(t(6), &[]), Some(ids[1]));
    }

    #[test]
    fn legacy_is_per_function_fifo_with_one_shot_drain() {
        let ids = mint(3);
        let names = Names::new(&["f", "g"]);
        let (f, g) = (names.id("f"), names.id("g"));
        let mut d = LegacyOneShot::default();
        d.enqueue(w(&ids, 0, f, 256, 0), &names.syms);
        d.enqueue(w(&ids, 1, g, 256, 1), &names.syms);
        d.enqueue(w(&ids, 2, f, 256, 2), &names.syms);
        assert_eq!(d.len(), 3);
        // Same-function drain is per-function FIFO.
        assert_eq!(d.take_for_function(f, &names.syms), Some(ids[0]));
        assert_eq!(d.take_for_function(f, &names.syms), Some(ids[2]));
        assert_eq!(d.take_for_function(f, &names.syms), None);
        assert_eq!(d.len(), 1);
        // One-shot drain: a single candidate per round, never more.
        assert!(!d.drains_until_full());
        assert!(!d.retries_past_failure(0));
        assert_eq!(d.next_candidate(t(10), &[]), Some(ids[1]));
        assert_eq!(d.next_candidate(t(10), &[]), None);
        assert!(d.is_empty());
    }

    #[test]
    fn legacy_candidate_follows_hash_map_iteration_order() {
        // The candidate must be the front of the FIRST non-empty queue in
        // FxHashMap iteration order — and that order, over the interned
        // Rc<str> keys, must match an identically-built String-keyed map
        // (the byte-identity property the executor relies on).
        let ids = mint(5);
        let fnames = ["a", "b", "c", "d", "e"];
        let names = Names::new(&fnames);
        let mut d = LegacyOneShot::default();
        let mut reference: FxHashMap<String, VecDeque<InvocationId>> = FxHashMap::default();
        for (i, f) in fnames.iter().enumerate() {
            d.enqueue(w(&ids, i, names.id(f), 256, 0), &names.syms);
            reference.entry(f.to_string()).or_default().push_back(ids[i]);
        }
        let expected = reference
            .iter()
            .find(|(_, q)| !q.is_empty())
            .map(|(_, q)| q[0])
            .unwrap();
        assert_eq!(d.next_candidate(t(0), &[]), Some(expected));
    }

    #[test]
    fn fifo_orders_globally_by_arrival_and_reinserts_at_seniority() {
        let ids = mint(9);
        let names = Names::new(&["a", "b"]);
        let (a, b) = (names.id("a"), names.id("b"));
        let mut d = FifoFair::default();
        d.enqueue(w(&ids, 3, a, 256, 3), &names.syms);
        d.enqueue(w(&ids, 5, b, 512, 5), &names.syms);
        assert_eq!(d.next_candidate(t(9), &[]), Some(ids[3]));
        // Failed retry: re-enqueue with the original stamp → back to the
        // head, ahead of the younger entry.
        d.enqueue(w(&ids, 3, a, 256, 3), &names.syms);
        assert_eq!(d.next_candidate(t(9), &[]), Some(ids[3]));
        d.enqueue(w(&ids, 3, a, 256, 3), &names.syms);
        // A failed head is skipped for the rest of the drain round.
        assert_eq!(
            d.next_candidate(t(9), &[ids[3]]),
            Some(ids[5]),
            "skip honors the failed head"
        );
        d.enqueue(w(&ids, 7, a, 256, 7), &names.syms);
        d.enqueue(w(&ids, 8, a, 128, 8), &names.syms);
        // Same-function drain hands over the oldest of that function.
        assert_eq!(d.take_for_function(a, &names.syms), Some(ids[3]));
        assert_eq!(d.take_for_function(a, &names.syms), Some(ids[7]));
        assert_eq!(d.take_for_function(b, &names.syms), None, "5 was drained above");
        assert_eq!(d.len(), 1);
        assert!(d.drains_until_full());
        assert!(!d.retries_past_failure(1), "strict head-of-line");
    }

    #[test]
    fn memaware_picks_smallest_charge_until_the_aging_bound_promotes() {
        let ids = mint(4);
        let names = Names::new(&["big", "small", "mid", "small2"]);
        let (big, small, mid, small2) = (
            names.id("big"),
            names.id("small"),
            names.id("mid"),
            names.id("small2"),
        );
        let mut d = MemoryAware::default();
        d.enqueue(w(&ids, 0, big, 2048, 0), &names.syms);
        d.enqueue(w(&ids, 1, small, 128, 1), &names.syms);
        d.enqueue(w(&ids, 2, mid, 512, 2), &names.syms);
        // Under the bound: smallest charge wins.
        assert_eq!(d.next_candidate(t(5), &[]), Some(ids[1]));
        d.enqueue(w(&ids, 1, small, 128, 1), &names.syms);
        // Ties break to the oldest entry.
        d.enqueue(w(&ids, 3, small2, 128, 3), &names.syms);
        assert_eq!(d.next_candidate(t(5), &[]), Some(ids[1]));
        // A failed smallest pick ends the round: nothing larger could
        // place where it failed.
        assert!(!d.retries_past_failure(1), "failed smallest stops the drain");
        // Past the bound, the oldest entry outranks everything. (At
        // t=31 s entry 0 has waited 31 s ≥ the 30 s bound; entry 2 only
        // 29 s.)
        assert_eq!(d.next_candidate(t(31), &[]), Some(ids[0]), "aged head promoted");
        // A failed AGED head is worth one skip — the smallest flows again.
        assert!(d.retries_past_failure(1), "one skip past a failed aged head");
        assert!(!d.retries_past_failure(2), "then stop");
        d.enqueue(w(&ids, 0, big, 2048, 0), &names.syms);
        assert_eq!(d.next_candidate(t(31), &[ids[0]]), Some(ids[3]));
        assert!(
            !d.retries_past_failure(1),
            "the fallback pick was the smallest: a failure is terminal"
        );
        assert_eq!(d.take_for_function(mid, &names.syms), Some(ids[2]));
        assert_eq!(d.len(), 1);
    }

    /// The indexed FifoFair/MemoryAware must pop in EXACTLY the order of
    /// the pre-index O(n)-scan implementations: drive both against
    /// reference models (the old `VecDeque` scans, verbatim) through a
    /// long seeded op mix and pin every returned id. A divergence here
    /// would shift replay digests, which the azure-macro goldens forbid.
    #[test]
    fn indexed_disciplines_match_the_reference_scan_order() {
        use crate::util::rng::Rng;

        // The old arrival(seq)-ordered VecDeque backbone, verbatim.
        fn insert_ordered(q: &mut VecDeque<Waiting>, w: Waiting) {
            let pos = q.partition_point(|e| e.seq < w.seq);
            q.insert(pos, w);
        }

        struct RefModel {
            q: VecDeque<Waiting>,
            memaware: bool,
            aging_bound: SimDuration,
        }

        impl RefModel {
            fn take_for_function(&mut self, function: FnId) -> Option<InvocationId> {
                let idx = self.q.iter().position(|e| e.function == function)?;
                self.q.remove(idx).map(|w| w.inv)
            }

            fn next_candidate(&mut self, now: SimTime, skip: &[InvocationId]) -> Option<InvocationId> {
                if self.memaware {
                    if skip.is_empty() {
                        let front = self.q.front()?;
                        if now.since(front.enqueued_at) >= self.aging_bound {
                            return self.q.pop_front().map(|w| w.inv);
                        }
                    }
                    let idx = self
                        .q
                        .iter()
                        .enumerate()
                        .filter(|(_, e)| !skip.contains(&e.inv))
                        .min_by_key(|(_, e)| e.charge_mb)
                        .map(|(i, _)| i)?;
                    self.q.remove(idx).map(|w| w.inv)
                } else {
                    let idx = self.q.iter().position(|e| !skip.contains(&e.inv))?;
                    self.q.remove(idx).map(|w| w.inv)
                }
            }
        }

        let bound = SimDuration::from_secs(20);
        let ids = mint(2_000);
        let fnames = ["a", "b", "c", "d"];
        let names = Names::new(&fnames);
        for (kind, memaware) in [(QueueKind::FifoFair, false), (QueueKind::MemoryAware, true)] {
            let mut indexed = build(kind, bound);
            let mut model = RefModel { q: VecDeque::new(), memaware, aging_bound: bound };
            let mut rng = Rng::new(0xD15B_A7C4 ^ memaware as u64);
            let charges = [128u32, 256, 256, 512, 2048];
            let mut next_seq: usize = 0;
            // Track the seq of the last clean-round pop so a later op can
            // replay it as a failed retry (slot == seq in the append-only
            // mint slab).
            let mut last_popped: Option<usize> = None;
            for step in 0..2_000u64 {
                // Sim time advances with the op index so the aging bound
                // fires on some drains and not others.
                let now = SimTime(step * 100_000);
                match rng.below(10) {
                    // Fresh arrival (seqs stay dense and arrival-ordered).
                    0..=4 => {
                        let f = names.id(fnames[rng.below(fnames.len() as u64) as usize]);
                        let mb = charges[rng.below(charges.len() as u64) as usize];
                        let wait = w(&ids, next_seq, f, mb, step / 10);
                        indexed.enqueue(wait.clone(), &names.syms);
                        insert_ordered(&mut model.q, wait);
                        next_seq += 1;
                    }
                    // Same-function drain.
                    5..=6 => {
                        let f = names.id(fnames[rng.below(fnames.len() as u64) as usize]);
                        let got = indexed.take_for_function(f, &names.syms);
                        assert_eq!(got, model.take_for_function(f), "step {step}: take");
                        last_popped = None;
                    }
                    // Capacity drain, clean round. Remember the pop so a
                    // later op can replay it as a failed retry.
                    7..=8 => {
                        let got = indexed.next_candidate(now, &[]);
                        assert_eq!(got, model.next_candidate(now, &[]), "step {step}: drain");
                        last_popped = got.map(|id| id.slot() as usize);
                    }
                    // Failed retry: re-enqueue the last pop at its original
                    // seniority, then drain again skipping it.
                    _ => {
                        if let Some(prev) = last_popped.take() {
                            let f = names.id(fnames[rng.below(fnames.len() as u64) as usize]);
                            let mb = charges[rng.below(charges.len() as u64) as usize];
                            let back = w(&ids, prev, f, mb, step / 10);
                            indexed.enqueue(back.clone(), &names.syms);
                            insert_ordered(&mut model.q, back);
                            let skip = [ids[prev]];
                            let got = indexed.next_candidate(now, &skip);
                            assert_eq!(got, model.next_candidate(now, &skip), "step {step}: skip drain");
                        }
                    }
                }
                assert_eq!(indexed.len(), model.q.len(), "step {step}: length");
            }
            // Full drain at the end: every remaining pop must agree too.
            loop {
                let got = indexed.next_candidate(SimTime(u64::MAX / 2), &[]);
                assert_eq!(got, model.next_candidate(SimTime(u64::MAX / 2), &[]), "final drain");
                if got.is_none() {
                    break;
                }
            }
            assert!(indexed.is_empty());
        }
    }
}
