//! Function-id interning (§Perf: hot-path overhaul).
//!
//! Every tenant-qualified function and app name is interned exactly once —
//! at deploy or first reference — into a per-world [`Symbols`] table that
//! maps `str → FnId(u32)` and back. The hot paths (dispatch indexes,
//! keep-alive checks, placement, container matching, freshen caches, span
//! recording) then carry and compare the 4-byte `Copy` id instead of
//! hashing and cloning owned `String`s per event.
//!
//! Digest contract: ids never appear in output. Display, export, and
//! digest paths resolve back through the table (`resolve`/`rc`), so every
//! byte of existing output is unchanged. Where the *iteration order* of a
//! legacy `FxHashMap<String, _>` is digest-pinned (the `LegacyOneShot`
//! queue discipline), the interned build keys that map by `Rc<str>` from
//! this table: `Rc<str>` hashes via `str::hash` exactly as `String` does,
//! so the same insertion sequence produces the same bucket order.

use std::rc::Rc;

use crate::util::fxhash::FxHashMap;

/// An interned function (or app) name. 4 bytes, `Copy`, order-stable:
/// ids are assigned densely in interning order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FnId(u32);

impl FnId {
    /// The "no function" sentinel (used where legacy code passed `""`).
    pub const ANON: FnId = FnId(u32::MAX);

    pub fn is_anon(self) -> bool {
        self == FnId::ANON
    }

    /// Dense index for side tables (`Vec<T>` keyed by id).
    pub fn index(self) -> u32 {
        self.0
    }
}

/// The per-world intern table. Apps and functions share one namespace
/// (names are tenant-qualified and distinct in practice; sharing keeps
/// `app_of` an id→id map).
#[derive(Clone)]
pub struct Symbols {
    /// id → name, dense.
    names: Vec<Rc<str>>,
    /// name → id. Keys are the same `Rc<str>` allocations as `names`.
    ids: FxHashMap<Rc<str>, FnId>,
    /// Cached `""` so resolving [`FnId::ANON`] (or an unknown id) never
    /// allocates — legacy charge paths for unknown functions expect `""`.
    empty: Rc<str>,
}

impl std::fmt::Debug for Symbols {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Symbols")
            .field("len", &self.names.len())
            .finish()
    }
}

impl Default for Symbols {
    fn default() -> Self {
        Symbols::new()
    }
}

impl Symbols {
    pub fn new() -> Symbols {
        Symbols {
            names: Vec::new(),
            ids: FxHashMap::default(),
            empty: Rc::from(""),
        }
    }

    /// Get-or-insert: returns the existing id for `name`, or assigns the
    /// next dense one. `""` always interns to [`FnId::ANON`].
    pub fn intern(&mut self, name: &str) -> FnId {
        if name.is_empty() {
            return FnId::ANON;
        }
        if let Some(&id) = self.ids.get(name) {
            return id;
        }
        assert!(
            self.names.len() < u32::MAX as usize,
            "symbol table overflow"
        );
        let id = FnId(self.names.len() as u32);
        let rc: Rc<str> = Rc::from(name);
        self.names.push(rc.clone());
        self.ids.insert(rc, id);
        id
    }

    /// Id for an already-interned name (`None` if never interned; `""`
    /// maps to `Some(ANON)`).
    pub fn lookup(&self, name: &str) -> Option<FnId> {
        if name.is_empty() {
            return Some(FnId::ANON);
        }
        self.ids.get(name).copied()
    }

    /// Resolve an id to its name. ANON and unknown ids resolve to `""`
    /// (the legacy empty-function convention).
    pub fn resolve(&self, id: FnId) -> &str {
        self.names
            .get(id.0 as usize)
            .map(|rc| &**rc)
            .unwrap_or("")
    }

    /// Resolve to a shared `Rc<str>` (refcount bump, no allocation).
    /// ANON and unknown ids yield the cached `""`.
    pub fn rc(&self, id: FnId) -> Rc<str> {
        self.names
            .get(id.0 as usize)
            .cloned()
            .unwrap_or_else(|| self.empty.clone())
    }

    /// Number of interned names.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_resolve_round_trips() {
        let mut s = Symbols::new();
        let a = s.intern("app/alpha");
        let b = s.intern("app/beta");
        assert_ne!(a, b);
        assert_eq!(s.resolve(a), "app/alpha");
        assert_eq!(s.resolve(b), "app/beta");
        assert_eq!(s.rc(a).as_ref(), "app/alpha");
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn duplicate_interns_return_the_same_id() {
        let mut s = Symbols::new();
        let a1 = s.intern("f");
        let a2 = s.intern("f");
        assert_eq!(a1, a2);
        assert_eq!(s.len(), 1);
        assert_eq!(s.lookup("f"), Some(a1));
        assert_eq!(s.lookup("g"), None);
    }

    #[test]
    fn ids_are_dense_in_interning_order() {
        let mut s = Symbols::new();
        for (i, name) in ["x", "y", "z"].iter().enumerate() {
            assert_eq!(s.intern(name).index(), i as u32);
        }
    }

    #[test]
    fn anon_is_the_empty_name_and_never_allocates_storage() {
        let mut s = Symbols::new();
        assert_eq!(s.intern(""), FnId::ANON);
        assert!(FnId::ANON.is_anon());
        assert_eq!(s.len(), 0);
        assert_eq!(s.resolve(FnId::ANON), "");
        assert_eq!(s.rc(FnId::ANON).as_ref(), "");
        assert_eq!(s.lookup(""), Some(FnId::ANON));
    }

    #[test]
    fn rc_str_hashes_like_string_under_fx() {
        // The LegacyOneShot digest contract: FxHashMap<Rc<str>, _> must
        // bucket exactly like FxHashMap<String, _> for the same keys.
        use crate::util::fxhash::FxBuildHasher;
        use std::hash::{BuildHasher, Hash, Hasher};
        let bh = FxBuildHasher::default();
        for name in ["", "f", "app/fn-17", "a-much-longer-function-name"] {
            let mut h1 = bh.build_hasher();
            name.to_string().hash(&mut h1);
            let mut h2 = bh.build_hasher();
            let rc: Rc<str> = Rc::from(name);
            rc.hash(&mut h2);
            assert_eq!(h1.finish(), h2.finish(), "hash diverged for {name:?}");
        }
    }
}
