//! Pluggable placement: which invoker host a cold start lands on.
//!
//! Mirrors the `QueueDiscipline`/`KeepAlivePolicy` extractions: the
//! historical inline host scan in `World::acquire_slot` becomes the
//! [`LeastLoadedMb`] strategy (byte-identical, digest-pinned default),
//! and alternatives slot in behind the same [`Placement`] trait —
//! spreading baselines ([`RandomUniform`], [`RoundRobin`]), warm-state
//! locality ([`WarmAffinity`]), and label-constrained scheduling over
//! heterogeneous host classes ([`Constrained`], after edgeless-orc's
//! deployment requirements). Strategies are pure decision procedures over
//! a read-only [`PlaceCtx`] snapshot: they never mutate pool state and
//! never consume the world's main RNG stream, so the default axis stays
//! byte-identical and every strategy inherits the shard×parallel
//! determinism contract for free.

use crate::platform::container::{Container, ContainerId, ContainerState};
use crate::platform::invoker::Invoker;
use crate::platform::symbols::FnId;
use crate::util::config::{HostClass, PlacementKind};
use crate::util::rng::Rng;

/// What a strategy decided: recycle a parked (evicted) container slot, or
/// create a fresh container on a chosen host. The world applies the
/// decision (allocation + memory charge) so strategies stay read-only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Recycle this evicted container in place (keeps its id and host).
    Reuse(ContainerId),
    /// Create a new container on this invoker host.
    Create(usize),
}

/// Read-only placement context: the pool snapshot plus the charge and the
/// function's deployment labels. Borrowed field-disjoint from the world
/// so a decision can be taken while the placement RNG is held mutably.
pub struct PlaceCtx<'a> {
    /// Function being placed ([`FnId::ANON`] for anonymous/test
    /// acquisitions). Interned: strategies compare ids, never strings.
    pub function: FnId,
    /// Memory the new container will charge its host, MB.
    pub charge_mb: u64,
    pub containers: &'a [Container],
    pub invokers: &'a [Invoker],
    /// Declared host classes; empty on a homogeneous cluster.
    pub classes: &'a [HostClass],
    /// The function's affinity labels (host-class names; empty = any).
    pub affinity: &'a [String],
    /// The function's anti-affinity labels.
    pub anti_affinity: &'a [String],
}

impl PlaceCtx<'_> {
    /// Can `host` take this charge right now?
    pub fn has_room(&self, host: usize) -> bool {
        self.invokers[host].has_room(self.charge_mb)
    }

    /// Do the function's labels admit `host`? On a homogeneous cluster
    /// there are no class names to match: unconstrained functions go
    /// anywhere, while a non-empty affinity list can match nothing (the
    /// deployment asked for a class the cluster doesn't declare).
    pub fn labels_admit(&self, host: usize) -> bool {
        if self.classes.is_empty() {
            return self.affinity.is_empty();
        }
        let name = &self.classes[self.invokers[host].class].name;
        (self.affinity.is_empty() || self.affinity.iter().any(|l| l == name))
            && !self.anti_affinity.iter().any(|l| l == name)
    }

    /// Settle onto a chosen host: recycle its lowest-id parked slot if it
    /// has one, else create. (The legacy strategy instead scans parked
    /// slots globally — see [`legacy_place`].)
    pub fn settle_on(&self, host: usize) -> Decision {
        match self
            .containers
            .iter()
            .find(|c| c.state == ContainerState::Evicted && c.invoker == host)
        {
            Some(c) => Decision::Reuse(c.id),
            None => Decision::Create(host),
        }
    }

    /// Hosts able to take the charge, id order.
    fn hosts_with_room(&self) -> Vec<usize> {
        self.invokers
            .iter()
            .filter(|i| i.has_room(self.charge_mb))
            .map(|i| i.id)
            .collect()
    }
}

/// The historical inline scan from `World::acquire_slot`, verbatim:
/// recycle the first (lowest-id) parked container anywhere whose host has
/// room, else create on the least-loaded host (ties: lowest id; Rust's
/// `min_by_key` keeps the first minimum). Kept as a free function so
/// [`WarmAffinity`] can fall back to the exact same order.
pub fn legacy_place(ctx: &PlaceCtx) -> Option<Decision> {
    if let Some(cid) = ctx
        .containers
        .iter()
        .find(|c| {
            c.state == ContainerState::Evicted && ctx.invokers[c.invoker].has_room(ctx.charge_mb)
        })
        .map(|c| c.id)
    {
        return Some(Decision::Reuse(cid));
    }
    ctx.invokers
        .iter()
        .filter(|i| i.has_room(ctx.charge_mb))
        .min_by_key(|i| i.used_mb)
        .map(|i| Decision::Create(i.id))
}

/// A placement strategy. `place` returns `None` when no host can take the
/// charge (the cluster is full for this function — the caller falls back
/// to pressure eviction or queues). `admits` is the label-feasibility
/// gate the executor's drop/evict paths consult; only [`Constrained`]
/// restricts it.
pub trait Placement {
    fn name(&self) -> &'static str;

    /// Choose where the next container for `ctx.function` goes. `rng` is
    /// the world's dedicated placement stream (forked from the seed, never
    /// the main simulation stream); deterministic strategies must not
    /// draw from it.
    fn place(&mut self, ctx: &PlaceCtx, rng: &mut Rng) -> Option<Decision>;

    /// May `ctx.function` ever run on `host`? Gates the infeasible-drop
    /// check and pressure-eviction host filter.
    fn admits(&self, ctx: &PlaceCtx, host: usize) -> bool {
        let _ = (ctx, host);
        true
    }
}

/// Legacy: global parked-slot recycle, else least-loaded host.
#[derive(Debug, Default)]
pub struct LeastLoadedMb;

impl Placement for LeastLoadedMb {
    fn name(&self) -> &'static str {
        "legacy"
    }

    fn place(&mut self, ctx: &PlaceCtx, _rng: &mut Rng) -> Option<Decision> {
        legacy_place(ctx)
    }
}

/// Uniformly random host among those with room.
#[derive(Debug, Default)]
pub struct RandomUniform;

impl Placement for RandomUniform {
    fn name(&self) -> &'static str {
        "random"
    }

    fn place(&mut self, ctx: &PlaceCtx, rng: &mut Rng) -> Option<Decision> {
        let hosts = ctx.hosts_with_room();
        if hosts.is_empty() {
            return None;
        }
        let host = hosts[rng.below(hosts.len() as u64) as usize];
        Some(ctx.settle_on(host))
    }
}

/// Rotate a cursor over the hosts, skipping full ones.
#[derive(Debug, Default)]
pub struct RoundRobin {
    cursor: usize,
}

impl Placement for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn place(&mut self, ctx: &PlaceCtx, _rng: &mut Rng) -> Option<Decision> {
        let n = ctx.invokers.len();
        for step in 0..n {
            let host = (self.cursor + step) % n;
            if ctx.has_room(host) {
                self.cursor = (host + 1) % n;
                return Some(ctx.settle_on(host));
            }
        }
        None
    }
}

/// Prefer hosts already holding live (non-evicted) containers of the
/// function — a freshened or warm container next door is what placement
/// can exploit — least-loaded among them; fall back to the exact legacy
/// scan when no such host has room.
#[derive(Debug, Default)]
pub struct WarmAffinity;

impl Placement for WarmAffinity {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn place(&mut self, ctx: &PlaceCtx, _rng: &mut Rng) -> Option<Decision> {
        let holding = ctx
            .containers
            .iter()
            .filter(|c| {
                c.state != ContainerState::Evicted
                    && c.function == Some(ctx.function)
                    && !ctx.function.is_anon()
            })
            .map(|c| c.invoker);
        let mut marked = vec![false; ctx.invokers.len()];
        for host in holding {
            marked[host] = true;
        }
        let preferred = ctx
            .invokers
            .iter()
            .filter(|i| marked[i.id] && i.has_room(ctx.charge_mb))
            .min_by_key(|i| i.used_mb)
            .map(|i| i.id);
        match preferred {
            Some(host) => Some(ctx.settle_on(host)),
            None => legacy_place(ctx),
        }
    }
}

/// Affinity/anti-affinity label matching against host-class names,
/// least-loaded among the admitted hosts. A function whose labels admit
/// no host is infeasible for the whole cluster (`place` and `admits`
/// agree, so such invocations drop rather than queue forever).
#[derive(Debug, Default)]
pub struct Constrained;

impl Placement for Constrained {
    fn name(&self) -> &'static str {
        "constrained"
    }

    fn place(&mut self, ctx: &PlaceCtx, _rng: &mut Rng) -> Option<Decision> {
        ctx.invokers
            .iter()
            .filter(|i| ctx.labels_admit(i.id) && i.has_room(ctx.charge_mb))
            .min_by_key(|i| i.used_mb)
            .map(|i| ctx.settle_on(i.id))
    }

    fn admits(&self, ctx: &PlaceCtx, host: usize) -> bool {
        ctx.labels_admit(host)
    }
}

/// Build the configured strategy.
pub fn build(kind: PlacementKind) -> Box<dyn Placement> {
    match kind {
        PlacementKind::LeastLoadedMb => Box::new(LeastLoadedMb),
        PlacementKind::RandomUniform => Box::new(RandomUniform),
        PlacementKind::RoundRobin => Box::new(RoundRobin::default()),
        PlacementKind::WarmAffinity => Box::new(WarmAffinity),
        PlacementKind::Constrained => Box::new(Constrained),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::symbols::Symbols;
    use crate::util::time::SimTime;

    /// Shared interned ids for the test functions "f" and "g".
    fn fg() -> (FnId, FnId) {
        let mut syms = Symbols::new();
        (syms.intern("f"), syms.intern("g"))
    }

    fn cluster(caps: &[u64]) -> Vec<Invoker> {
        caps.iter()
            .enumerate()
            .map(|(i, &c)| Invoker::new(i, c))
            .collect()
    }

    fn ctx<'a>(
        function: FnId,
        charge_mb: u64,
        containers: &'a [Container],
        invokers: &'a [Invoker],
    ) -> PlaceCtx<'a> {
        PlaceCtx {
            function,
            charge_mb,
            containers,
            invokers,
            classes: &[],
            affinity: &[],
            anti_affinity: &[],
        }
    }

    /// A live container of `function` parked on `host` (for affinity and
    /// reuse scans). `evicted` parks it instead.
    fn seeded_container(id: usize, host: usize, function: FnId, evicted: bool) -> Container {
        let mut c = Container::new(id, host, SimTime::ZERO);
        if !evicted {
            c.begin_cold_start(function, SimTime::ZERO);
        }
        c
    }

    #[test]
    fn legacy_reuses_lowest_id_parked_slot_globally() {
        let (f, _) = fg();
        let mut invokers = cluster(&[512, 512]);
        invokers[0].charge(512); // host 0 full: its parked slot is skipped
        let containers = vec![
            seeded_container(0, 0, f, true),
            seeded_container(1, 1, f, true),
        ];
        let c = ctx(f, 256, &containers, &invokers);
        assert_eq!(legacy_place(&c), Some(Decision::Reuse(1)));
    }

    #[test]
    fn legacy_creates_on_least_loaded_with_lowest_id_ties() {
        let (f, _) = fg();
        let mut invokers = cluster(&[512, 512, 512]);
        invokers[0].charge(256);
        let containers = Vec::new();
        let c = ctx(f, 256, &containers, &invokers);
        // Hosts 1 and 2 tie at 0 used: first minimum wins (host 1).
        assert_eq!(legacy_place(&c), Some(Decision::Create(1)));
        let full = ctx(f, 1024, &containers, &invokers);
        assert_eq!(legacy_place(&full), None);
    }

    #[test]
    fn least_loaded_strategy_is_the_legacy_scan() {
        let (f, _) = fg();
        let mut s = LeastLoadedMb;
        let mut rng = Rng::new(1);
        let invokers = cluster(&[512, 512]);
        let containers = vec![seeded_container(0, 1, f, true)];
        let c = ctx(f, 256, &containers, &invokers);
        assert_eq!(s.place(&c, &mut rng), legacy_place(&c));
        assert_eq!(s.name(), "legacy");
    }

    #[test]
    fn random_only_picks_hosts_with_room() {
        let (f, _) = fg();
        let mut s = RandomUniform;
        let mut rng = Rng::new(7);
        let mut invokers = cluster(&[512, 512, 512]);
        invokers[0].charge(512);
        invokers[2].charge(512);
        let containers = Vec::new();
        let c = ctx(f, 256, &containers, &invokers);
        for _ in 0..32 {
            // Only host 1 has room: every draw must land there.
            assert_eq!(s.place(&c, &mut rng), Some(Decision::Create(1)));
        }
        let full = ctx(f, 1024, &containers, &invokers);
        assert_eq!(s.place(&full, &mut rng), None);
    }

    #[test]
    fn round_robin_rotates_and_skips_full_hosts() {
        let (f, _) = fg();
        let mut s = RoundRobin::default();
        let mut rng = Rng::new(1);
        let mut invokers = cluster(&[512, 512, 512]);
        invokers[1].charge(512);
        let containers = Vec::new();
        let c = ctx(f, 256, &containers, &invokers);
        assert_eq!(s.place(&c, &mut rng), Some(Decision::Create(0)));
        // Host 1 is full: the cursor skips to 2, then wraps to 0.
        assert_eq!(s.place(&c, &mut rng), Some(Decision::Create(2)));
        assert_eq!(s.place(&c, &mut rng), Some(Decision::Create(0)));
        let full = ctx(f, 1024, &containers, &invokers);
        assert_eq!(s.place(&full, &mut rng), None);
    }

    #[test]
    fn round_robin_settles_on_parked_slots() {
        let (f, _) = fg();
        let mut s = RoundRobin::default();
        let mut rng = Rng::new(1);
        let invokers = cluster(&[512, 512]);
        let containers = vec![seeded_container(0, 0, f, true)];
        let c = ctx(f, 256, &containers, &invokers);
        assert_eq!(s.place(&c, &mut rng), Some(Decision::Reuse(0)));
        assert_eq!(s.place(&c, &mut rng), Some(Decision::Create(1)));
    }

    #[test]
    fn warm_affinity_lands_next_to_live_containers() {
        let (f, g_fn) = fg();
        let mut s = WarmAffinity;
        let mut rng = Rng::new(1);
        let mut invokers = cluster(&[1024, 1024, 1024]);
        invokers[2].charge(256);
        let containers = vec![seeded_container(0, 2, f, false)];
        let c = ctx(f, 256, &containers, &invokers);
        // Host 2 holds f's live container: preferred despite more load.
        assert_eq!(s.place(&c, &mut rng), Some(Decision::Create(2)));
        // A different function sees no warm host: legacy least-loaded.
        let g = ctx(g_fn, 256, &containers, &invokers);
        assert_eq!(s.place(&g, &mut rng), legacy_place(&g));
    }

    #[test]
    fn warm_affinity_falls_back_to_legacy_when_warm_host_is_full() {
        let (f, _) = fg();
        let mut s = WarmAffinity;
        let mut rng = Rng::new(1);
        let mut invokers = cluster(&[512, 512]);
        invokers[1].charge(512);
        let containers = vec![seeded_container(0, 1, f, false)];
        let c = ctx(f, 256, &containers, &invokers);
        assert_eq!(s.place(&c, &mut rng), legacy_place(&c));
        assert_eq!(s.place(&c, &mut rng), Some(Decision::Create(0)));
    }

    /// The warm-hit locality probe: with warm state parked on one host,
    /// affinity placement lands every subsequent container of the
    /// function next to it (structural: the host always has room here),
    /// while uniform-random placement spreads across the cluster. 60
    /// draws over 4 roomy hosts all landing on one host has probability
    /// 4^-60 — the assertion is deterministic for any real RNG stream.
    #[test]
    fn warm_affinity_beats_random_on_locality() {
        let (f, _) = fg();
        let invokers = cluster(&[1 << 30, 1 << 30, 1 << 30, 1 << 30]);
        let containers = vec![seeded_container(0, 2, f, false)];
        let c = ctx(f, 256, &containers, &invokers);
        let mut affinity_hits = 0;
        let mut random_hits = 0;
        let mut total = 0;
        for seed in [11u64, 22, 33] {
            let mut rng = Rng::new(seed);
            let mut aff = WarmAffinity;
            let mut rand = RandomUniform;
            for _ in 0..20 {
                total += 1;
                if aff.place(&c, &mut rng) == Some(Decision::Create(2)) {
                    affinity_hits += 1;
                }
                if rand.place(&c, &mut rng) == Some(Decision::Create(2)) {
                    random_hits += 1;
                }
            }
        }
        assert_eq!(affinity_hits, total, "affinity always lands by the warm state");
        assert!(
            random_hits < total,
            "random placement must spread ({random_hits}/{total} on the warm host)"
        );
    }

    #[test]
    fn constrained_matches_labels_against_class_names() {
        let classes = crate::util::config::HostClass::parse_list(
            "cloud:2:4096:1000:local,edge:2:1024:1600:edge",
        )
        .unwrap();
        let mut invokers: Vec<Invoker> = Vec::new();
        for (id, (class, cap)) in [(0usize, 4096u64), (0, 4096), (1, 1024), (1, 1024)]
            .into_iter()
            .enumerate()
        {
            invokers.push(Invoker::new_in_class(id, class, cap));
        }
        invokers[2].charge(512);
        let containers = Vec::new();
        let mut rng = Rng::new(1);
        let mut s = Constrained;
        let edge_only = vec!["edge".to_string()];
        let not_edge = vec!["edge".to_string()];
        let nowhere = vec!["gpu".to_string()];
        // Affinity to edge: least-loaded edge host (3, host 2 is loaded).
        let (f, _) = fg();
        let c = PlaceCtx {
            function: f,
            charge_mb: 256,
            containers: &containers,
            invokers: &invokers,
            classes: &classes,
            affinity: &edge_only,
            anti_affinity: &[],
        };
        assert_eq!(s.place(&c, &mut rng), Some(Decision::Create(3)));
        assert!(s.admits(&c, 2) && s.admits(&c, 3));
        assert!(!s.admits(&c, 0) && !s.admits(&c, 1));
        // Anti-affinity to edge: cloud hosts only.
        let c = PlaceCtx {
            anti_affinity: &not_edge,
            affinity: &[],
            ..c
        };
        assert_eq!(s.place(&c, &mut rng), Some(Decision::Create(0)));
        assert!(s.admits(&c, 0) && !s.admits(&c, 3));
        // Labels matching no declared class: infeasible everywhere.
        let c = PlaceCtx {
            affinity: &nowhere,
            anti_affinity: &[],
            ..c
        };
        assert_eq!(s.place(&c, &mut rng), None);
        assert!(!s.admits(&c, 0));
        // Unconstrained functions go anywhere, least-loaded first.
        let c = PlaceCtx {
            affinity: &[],
            ..c
        };
        assert_eq!(s.place(&c, &mut rng), Some(Decision::Create(0)));
    }

    #[test]
    fn homogeneous_cluster_admits_only_unlabelled_functions() {
        let (f, _) = fg();
        let invokers = cluster(&[512]);
        let containers = Vec::new();
        let labels = vec!["edge".to_string()];
        let open = ctx(f, 256, &containers, &invokers);
        assert!(open.labels_admit(0));
        let closed = PlaceCtx {
            affinity: &labels,
            ..ctx(f, 256, &containers, &invokers)
        };
        assert!(!closed.labels_admit(0));
    }

    #[test]
    fn build_covers_every_kind() {
        for kind in PlacementKind::all() {
            let strategy = build(kind);
            assert_eq!(strategy.name(), kind.as_str());
        }
    }
}
