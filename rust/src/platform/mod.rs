//! The OpenWhisk-like serverless platform (§2 "Serverless runtime reuse").
//!
//! The paper's mechanism lives inside a provider's platform: Docker-style
//! containers host a persistent language runtime; the `init` hook loads the
//! function, the `run` hook executes it, and (our addition) the `freshen`
//! hook runs proactive work. This module is that platform, built for the
//! deterministic simulator substrate ([`crate::simcore`]); the real-time
//! serving engine ([`crate::serve`]) reuses the same specs and runtime
//! environment types.
//!
//! - [`function`] — function specs and the op DSL static analysis works on.
//! - [`registry`] — functions, apps, chains.
//! - [`datastore`] — versioned S3-like object store.
//! - [`endpoint`] — remote services (store/file/model servers) behind links.
//! - [`container`] — container lifecycle + the in-container runtime env.
//! - [`invoker`] — per-host container pools.
//! - [`symbols`] — per-world function/app name interning (`str → FnId`).
//! - [`slab`] — generation-stamped free-list slab for invocation contexts.
//! - [`world`] — the composed simulation world.
//! - [`dispatch`] — pluggable queue disciplines for invocations waiting
//!   on cluster memory (legacy one-shot / FIFO-fair / memory-aware).
//! - [`placement`] — pluggable placement strategies choosing the invoker
//!   host a cold start lands on (legacy least-loaded / random /
//!   round-robin / warm-affinity / label-constrained), over optionally
//!   heterogeneous host classes.
//! - [`snapshot`] — snapshot/restore cost model: the rival cold-start
//!   mitigation (discounted parked charge, base + working-set page-in
//!   restore, REAP-style prefetch variant).
//! - [`exec`] — the event-driven op executor (function *and* freshen),
//!   including the controller's dispatch/queue/eviction policies.

pub mod container;
pub mod datastore;
pub mod dispatch;
pub mod endpoint;
pub mod exec;
pub mod function;
pub mod invoker;
pub mod keepalive;
pub mod placement;
pub mod registry;
pub mod slab;
pub mod snapshot;
pub mod symbols;
pub mod world;

pub use container::{Container, ContainerId, ContainerState, RuntimeEnv};
pub use datastore::ObjectStore;
pub use endpoint::Endpoint;
pub use function::{AppSpec, Arg, FunctionId, FunctionSpec, Op};
pub use registry::Registry;
pub use symbols::{FnId, Symbols};
pub use world::World;
