//! Function specifications and the operation DSL.
//!
//! Serverless functions in this platform are expressed as a small sequence
//! of operations rather than opaque code. This mirrors what the paper's
//! §3.3 inference relies on: "source code is available for static analysis
//! for such tasks as identification of read-only data fetched using
//! constant parameters". An [`Op`]'s arguments are explicitly [`Arg::Const`]
//! (runtime constants, like the paper's `CREDS`, `ID1`, `ID2`) or
//! [`Arg::Param`] (derived from invocation arguments) — the distinction the
//! freshen inference engine keys on.

use crate::util::config::ServiceCategory;
use crate::util::time::SimDuration;

/// Function identifier (unique within the platform).
pub type FunctionId = String;

/// An operation argument: compile-time constant or invocation-derived.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Arg {
    /// A runtime constant (e.g. `CREDS`, `ID1` in Algorithm 1).
    Const(String),
    /// Derived from the invocation's arguments; unknown before `run`.
    Param(String),
}

impl Arg {
    pub fn is_const(&self) -> bool {
        matches!(self, Arg::Const(_))
    }

    /// The constant value, if this is a constant.
    pub fn const_value(&self) -> Option<&str> {
        match self {
            Arg::Const(v) => Some(v),
            Arg::Param(_) => None,
        }
    }
}

/// One step of a function body.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Fetch an object over the endpoint's connection (Algorithm 1 line 3).
    DataGet {
        endpoint: String,
        creds: Arg,
        object_id: Arg,
    },
    /// Write a result over the endpoint's connection (Algorithm 1 line 7).
    /// `bytes` is the typical payload size (from traces/annotations).
    DataPut {
        endpoint: String,
        creds: Arg,
        object_id: Arg,
        bytes: f64,
    },
    /// Pure computation for a fixed duration (the `...` of Algorithm 1).
    Compute { duration: SimDuration },
    /// Run the AOT-compiled model on the fetched data (the intro's λ1:
    /// "analyzes an input image"). In the simulator this costs the
    /// calibrated inference latency; in the serving engine it executes the
    /// real PJRT artifact.
    Infer { model: String, input_bytes: f64 },
    /// Trigger the next function in a chain through a trigger service
    /// (Figure 1); fires as the function completes.
    InvokeNext {
        function: FunctionId,
        trigger: crate::triggers::TriggerService,
    },
    /// Non-deterministic chain step (§6 "Prediction success must be
    /// additionally quantified, especially in the case of
    /// non-deterministic function chains"): choose one successor by
    /// weight, possibly none (weights may sum to < 1; the remainder is
    /// "chain ends here"). The chain predictor observes which branch ran
    /// and discounts its confidence accordingly.
    InvokeBranch {
        branches: Vec<(FunctionId, f64)>,
        trigger: crate::triggers::TriggerService,
    },
}

impl Op {
    /// Does this op access a remote resource through a connection?
    pub fn endpoint(&self) -> Option<&str> {
        match self {
            Op::DataGet { endpoint, .. } | Op::DataPut { endpoint, .. } => Some(endpoint),
            _ => None,
        }
    }

    /// Successor functions this op may trigger (chain edges).
    pub fn successors(&self) -> Vec<&FunctionId> {
        match self {
            Op::InvokeNext { function, .. } => vec![function],
            Op::InvokeBranch { branches, .. } => branches.iter().map(|(f, _)| f).collect(),
            _ => Vec::new(),
        }
    }

    /// Are all of this op's arguments constants (freshen-inferrable)?
    pub fn all_const(&self) -> bool {
        match self {
            Op::DataGet {
                creds, object_id, ..
            } => creds.is_const() && object_id.is_const(),
            Op::DataPut {
                creds, object_id, ..
            } => creds.is_const() && object_id.is_const(),
            _ => false,
        }
    }
}

/// A deployed serverless function.
#[derive(Debug, Clone)]
pub struct FunctionSpec {
    pub id: FunctionId,
    /// Owning application (billing + Figure 2 population unit).
    pub app: String,
    pub ops: Vec<Op>,
    pub memory_mb: u32,
    pub category: ServiceCategory,
    /// Per-function TTL override for prefetched data (None = platform
    /// default) — §3.2: "the TTL could be set ... by freshen configuration
    /// values specified by the function developer".
    pub prefetch_ttl: Option<SimDuration>,
    /// Host-class names this function may run on (deployment requirement,
    /// edgeless-orc style). Empty = any host. Only consulted by the
    /// `Constrained` placement strategy on a heterogeneous cluster.
    pub affinity: Vec<String>,
    /// Host-class names this function must NOT run on. Same scope as
    /// [`FunctionSpec::affinity`].
    pub anti_affinity: Vec<String>,
}

impl FunctionSpec {
    pub fn new(id: &str, app: &str, ops: Vec<Op>) -> FunctionSpec {
        FunctionSpec {
            id: id.to_string(),
            app: app.to_string(),
            ops,
            memory_mb: 256,
            category: ServiceCategory::Standard,
            prefetch_ttl: None,
            affinity: Vec::new(),
            anti_affinity: Vec::new(),
        }
    }

    /// Number of freshen resources = number of connection-touching ops,
    /// in program order (DataGet -> 0, DataPut -> 1 for the paper's λ).
    pub fn resource_count(&self) -> usize {
        self.ops.iter().filter(|op| op.endpoint().is_some()).count()
    }

    /// Map op index -> freshen resource index (None for non-resource ops).
    pub fn resource_indices(&self) -> Vec<Option<usize>> {
        let mut next = 0;
        self.ops
            .iter()
            .map(|op| {
                if op.endpoint().is_some() {
                    let idx = next;
                    next += 1;
                    Some(idx)
                } else {
                    None
                }
            })
            .collect()
    }

    /// Endpoints this function touches, deduplicated, program order.
    pub fn endpoints(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for op in &self.ops {
            if let Some(e) = op.endpoint() {
                if !out.contains(&e) {
                    out.push(e);
                }
            }
        }
        out
    }

    /// Construct the paper's λ (Algorithm 1): DataGet, Compute, DataPut —
    /// all constant arguments. Used pervasively by tests and benches.
    pub fn paper_lambda(id: &str, app: &str, endpoint: &str, compute: SimDuration) -> FunctionSpec {
        FunctionSpec::new(
            id,
            app,
            vec![
                Op::DataGet {
                    endpoint: endpoint.to_string(),
                    creds: Arg::Const("CREDS".into()),
                    object_id: Arg::Const("ID1".into()),
                },
                Op::Compute { duration: compute },
                Op::DataPut {
                    endpoint: endpoint.to_string(),
                    creds: Arg::Const("CREDS".into()),
                    object_id: Arg::Const("ID2".into()),
                    bytes: 64.0 * 1024.0,
                },
            ],
        )
    }
}

/// A serverless application: a set of functions, possibly chained through
/// an orchestration framework (Figure 2's population unit).
#[derive(Debug, Clone)]
pub struct AppSpec {
    pub id: String,
    pub functions: Vec<FunctionId>,
    /// Is this app managed by an orchestration framework (Step-Functions-
    /// like)? Orchestrated apps expose explicit chains the predictor uses.
    pub orchestrated: bool,
    pub category: ServiceCategory,
}

impl AppSpec {
    pub fn new(id: &str, orchestrated: bool) -> AppSpec {
        AppSpec {
            id: id.to_string(),
            functions: Vec::new(),
            orchestrated,
            category: ServiceCategory::Standard,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::triggers::TriggerService;

    #[test]
    fn paper_lambda_shape() {
        let f = FunctionSpec::paper_lambda("l1", "app", "store", SimDuration::from_millis(50));
        assert_eq!(f.ops.len(), 3);
        assert_eq!(f.resource_count(), 2);
        assert_eq!(f.resource_indices(), vec![Some(0), None, Some(1)]);
        assert_eq!(f.endpoints(), vec!["store"]);
        assert!(f.ops[0].all_const());
        assert!(f.ops[2].all_const());
        assert!(!f.ops[1].all_const());
    }

    #[test]
    fn param_args_are_not_const() {
        let op = Op::DataGet {
            endpoint: "store".into(),
            creds: Arg::Const("CREDS".into()),
            object_id: Arg::Param("user_key".into()),
        };
        assert!(!op.all_const());
        assert_eq!(op.endpoint(), Some("store"));
    }

    #[test]
    fn invoke_next_has_no_endpoint() {
        let op = Op::InvokeNext {
            function: "f2".into(),
            trigger: TriggerService::Direct,
        };
        assert_eq!(op.endpoint(), None);
        assert!(!op.all_const());
    }

    #[test]
    fn arg_accessors() {
        assert_eq!(Arg::Const("x".into()).const_value(), Some("x"));
        assert_eq!(Arg::Param("y".into()).const_value(), None);
    }
}
