//! The composed simulation world.
//!
//! [`World`] owns every mutable piece of platform state; discrete-event
//! handlers receive `(&mut PlatformSim, &mut World)` and the borrow
//! discipline is "disjoint fields": helpers take the specific fields they
//! need (`&world.endpoints`, `&mut world.rng`, `&mut world.containers[c]`)
//! so network, container and predictor state can be touched in one event.
//!
//! Hot-path identity: function and app names are interned at deploy into
//! `registry.symbols` ([`crate::platform::symbols::Symbols`]); everything
//! per-event carries the 4-byte [`FnId`]. Invocation contexts live in a
//! generation-stamped [`InvocationSlab`] (recycling is opt-in, used by the
//! macro replay) and are addressed by [`InvocationId`] handles; each ctx
//! also carries a dense arrival `seq` equal to the legacy Vec index, and
//! all output derives from `seq`, never from slab slot numbers.

use std::rc::Rc;

use crate::util::fxhash::FxHashMap;

use crate::billing::Ledger;
use crate::freshen::policy::FreshenGate;
use crate::metrics::{EvictionCause, MetricsHub, StartKind};
use crate::netsim::link::Site;
use crate::platform::container::{Container, ContainerId, ContainerState};
use crate::platform::dispatch::{self, QueueDiscipline};
use crate::platform::endpoint::Endpoint;
use crate::platform::exec::PlatformEvent;
use crate::platform::invoker::Invoker;
use crate::platform::keepalive::{self, KeepAlivePolicy};
use crate::platform::placement::{self, Decision, PlaceCtx, Placement};
use crate::platform::registry::Registry;
use crate::platform::symbols::FnId;
use crate::predict::chain::ChainPredictor;
use crate::predict::confidence::PredictionTracker;
use crate::predict::histogram::HistogramPredictor;
use crate::predict::learned::LearnedScorer;
use crate::simcore::waitlist::WaitList;
use crate::simcore::Sim;
use crate::util::config::{Config, MemoryAccounting, UNIFORM_SLOT_MB};
use crate::util::rng::{mix64, Rng};
use crate::util::time::{SimDuration, SimTime};

pub use crate::platform::slab::{InvocationId, InvocationSlab};

/// Stream tag forking the placement RNG off the world seed: random
/// placement draws never perturb the main simulation stream, so the
/// default (legacy, draw-free) axis stays byte-identical.
const PLACEMENT_STREAM: u64 = 0x9C7A_CE00;

/// Stream tag for inter-node network jitter on cross-node chain edges.
const NET_STREAM: u64 = 0x0E79_E700;

/// Per-invocation execution context (the state machine the executor walks).
#[derive(Debug, Clone)]
pub struct InvocationCtx {
    /// Slab handle of this context (generation-stamped).
    pub id: InvocationId,
    /// Dense arrival sequence number — identical to the legacy append-only
    /// Vec index. Every externally visible artifact (span `inv` fields,
    /// run params, dispatch ordering) uses `seq`; slab slots never leak.
    pub seq: u64,
    /// Interned function id (resolve via `registry.symbols` for display).
    pub function: FnId,
    pub container: Option<ContainerId>,
    pub enqueued_at: SimTime,
    pub started_at: SimTime,
    /// Index of the op about to execute.
    pub op_idx: usize,
    pub start_kind: StartKind,
    pub freshen_hits: u32,
    pub freshen_misses: u32,
    /// Ever held by the dispatch queue (drives the distinct-queued
    /// counter; re-enqueues after failed retries don't recount).
    pub queued: bool,
    pub done: bool,
}

/// An in-flight freshen run on a container.
#[derive(Debug, Clone)]
pub struct FreshenRunCtx {
    pub id: usize,
    pub function: FnId,
    pub container: ContainerId,
    /// The container incarnation this run launched against. When
    /// `Config::freshen_incarnation_guard` is on, a step that finds the
    /// container reclaimed (incarnation moved on) aborts instead of
    /// touching the recycled slot.
    pub incarnation: u64,
    pub action_idx: usize,
    pub started_at: SimTime,
    /// Prediction that admitted this run (billing resolution).
    pub prediction_id: Option<u64>,
    pub done: bool,
}

/// Deferred freshen charge awaiting prediction resolution.
#[derive(Debug, Clone)]
pub struct PendingFreshenCharge {
    pub prediction_id: u64,
    /// Interned app id (resolved back to its name at ledger settlement).
    pub app: FnId,
    pub memory_mb: u32,
    pub duration: SimDuration,
}

/// The simulation world.
pub struct World {
    pub config: Config,
    pub rng: Rng,
    pub registry: Registry,
    pub containers: Vec<Container>,
    pub invokers: Vec<Invoker>,
    // Deploy/ingest boundary: endpoints are registered once at setup and
    // looked up per network op by id string.
    // simlint: allow(D007, endpoint registration is a setup-time boundary)
    pub endpoints: FxHashMap<String, Endpoint>,
    pub metrics: MetricsHub,
    pub ledger: Ledger,
    pub gate: FreshenGate,
    pub chain_pred: ChainPredictor,
    pub hist_pred: HistogramPredictor,
    pub tracker: PredictionTracker,
    pub scorer: LearnedScorer,
    /// Invocation contexts: a generation-stamped free-list slab. Recycling
    /// is opt-in (`invocations.set_recycle(true)`, replay only); off, the
    /// slab is append-only like the legacy Vec and completed contexts stay
    /// inspectable for tests.
    pub invocations: InvocationSlab<InvocationCtx>,
    pub freshen_runs: Vec<FreshenRunCtx>,
    /// Invocations waiting for cluster memory, behind the configured
    /// queue discipline (built from `config.queue`; swappable for tests).
    pub dispatch: Box<dyn QueueDiscipline>,
    /// Placement strategy choosing the invoker host for cold starts
    /// (built from `config.placement`; swappable for tests).
    pub placement: Box<dyn Placement>,
    /// Dedicated RNG stream for randomized placement (forked from the
    /// seed; deterministic strategies never draw from it).
    pub placement_rng: Rng,
    /// Dedicated RNG stream for inter-node latency jitter on cross-node
    /// chain edges (homogeneous clusters never draw from it).
    pub net_rng: Rng,
    /// `FrWait` parking: one wait list per (container, resource index).
    pub fr_waiters: FxHashMap<(ContainerId, usize), WaitList<World, PlatformEvent>>,
    /// Freshen charges awaiting hit/miss resolution.
    pub pending_charges: Vec<PendingFreshenCharge>,
    /// Calibrated inference latency per model (simulator stand-in for the
    /// PJRT execution the serving engine performs for real; can be
    /// overwritten from measured artifact timings).
    // simlint: allow(D007, model calibration is a setup-time boundary)
    pub model_latencies: FxHashMap<String, SimDuration>,
    /// Strict version checking for prefetched data (§3.2 version numbers).
    pub strict_versions: bool,
    /// Emit histogram-based predictions automatically after each completed
    /// invocation (the standalone-function path). Ablations that inject
    /// their own prediction streams turn this off to avoid contamination.
    pub auto_hist_predict: bool,
    /// The container keep-alive policy (built from `config.keep_alive`;
    /// swappable for tests/ablations). Shared by every decision site.
    pub keep_alive: Rc<dyn KeepAlivePolicy>,
    /// Lifecycle span recorder (disabled by default; a replay turns it on
    /// via `ReplayCfg::trace_spans` / `--span-log`). Lives on the world so
    /// every executor event can record without threading a handle.
    pub obs: crate::obs::Tracer,
    /// Total memory currently charged by live containers, MB (exact
    /// integer mirror of the invokers' `used_mb` sums).
    pub resident_mb: u64,
    /// When `resident_mb` last changed (drives the MB·µs integral in
    /// `metrics.resident_mb_us`).
    resident_last_change: SimTime,
}

/// The simulator type every experiment drives: enum-coded platform events
/// ([`PlatformEvent`]) on the wheel, closures as the escape hatch.
pub type PlatformSim = Sim<World, PlatformEvent>;

impl World {
    pub fn new(config: Config) -> World {
        let rng = Rng::new(config.seed);
        let placement_rng = Rng::new(mix64(config.seed, PLACEMENT_STREAM));
        let net_rng = Rng::new(mix64(config.seed, NET_STREAM));
        let gate = FreshenGate::new(config.freshen.clone());
        let invokers = config
            .host_layout()
            .into_iter()
            .enumerate()
            .map(|(i, (class, capacity_mb))| Invoker::new_in_class(i, class, capacity_mb))
            .collect();
        let keep_alive = keepalive::build(config.keep_alive);
        let dispatch = dispatch::build(config.queue, config.queue_aging_bound);
        let placement = placement::build(config.placement);
        World {
            dispatch,
            placement,
            placement_rng,
            net_rng,
            rng,
            gate,
            invokers,
            keep_alive,
            obs: crate::obs::Tracer::disabled(),
            resident_mb: 0,
            resident_last_change: SimTime::ZERO,
            registry: Registry::new(),
            containers: Vec::new(),
            endpoints: FxHashMap::default(),
            metrics: MetricsHub::new(),
            ledger: Ledger::new(),
            chain_pred: ChainPredictor::new(),
            hist_pred: HistogramPredictor::new(),
            tracker: PredictionTracker::new(),
            scorer: LearnedScorer::default(),
            invocations: InvocationSlab::new(),
            freshen_runs: Vec::new(),
            fr_waiters: FxHashMap::default(),
            pending_charges: Vec::new(),
            model_latencies: FxHashMap::default(),
            strict_versions: true,
            auto_hist_predict: true,
            config,
        }
    }

    /// Add a remote endpoint.
    pub fn add_endpoint(&mut self, endpoint: Endpoint) {
        self.endpoints.insert(endpoint.id.clone(), endpoint);
    }

    /// Deploy a function spec (infers its freshen hook; interns its name).
    pub fn deploy(&mut self, spec: crate::platform::function::FunctionSpec) {
        self.registry.deploy(spec, self.config.freshen.default_ttl);
    }

    /// Intern (or look up) a function/app name — the string→id boundary
    /// for callers holding a name (CLI, experiments, tests).
    pub fn fid(&mut self, name: &str) -> FnId {
        self.registry.symbols.intern(name)
    }

    /// Default simulated latency for `Op::Infer` when no calibration is set.
    pub fn model_latency(&self, model: &str) -> SimDuration {
        self.model_latencies
            .get(model)
            .copied()
            .unwrap_or(SimDuration::from_millis(5))
    }

    // ---- container pool (memory-accounted) -----------------------------

    /// Find a warm container for `function`.
    pub fn find_warm(&self, function: FnId) -> Option<ContainerId> {
        self.containers
            .iter()
            .find(|c| c.warm_for(function))
            .map(|c| c.id)
    }

    /// Find a snapshotted container holding `function`'s image (the
    /// restore path's lookup, checked after [`World::find_warm`] misses).
    pub fn find_snapshot(&self, function: FnId) -> Option<ContainerId> {
        self.containers
            .iter()
            .find(|c| c.snapshot_for(function))
            .map(|c| c.id)
    }

    /// The MB a container hosting `function` charges its invoker:
    /// one uniform 256 MB slot, or the function's declared `memory_mb`
    /// under per-function accounting.
    pub fn charge_for_function_id(&self, function: FnId) -> u32 {
        match self.config.memory_accounting {
            MemoryAccounting::UniformSlot => UNIFORM_SLOT_MB,
            MemoryAccounting::FunctionMb => self
                .registry
                .function_by_id(function)
                .map(|f| f.memory_mb.max(1))
                .unwrap_or(UNIFORM_SLOT_MB),
        }
    }

    /// Name-keyed convenience wrapper over [`World::charge_for_function_id`].
    pub fn charge_for_function(&self, function: &str) -> u32 {
        match self.registry.symbols.lookup(function) {
            Some(f) => self.charge_for_function_id(f),
            None => match self.config.memory_accounting {
                MemoryAccounting::UniformSlot | MemoryAccounting::FunctionMb => UNIFORM_SLOT_MB,
            },
        }
    }

    /// Find a container slot with `memory_mb` of host memory behind it
    /// for an anonymous acquisition (no function identity: placement sees
    /// no warm state and no labels). Equivalent to
    /// [`World::acquire_slot_for`] with [`FnId::ANON`].
    pub fn acquire_slot(&mut self, now: SimTime, memory_mb: u32) -> Option<ContainerId> {
        self.acquire_slot_for(now, memory_mb, FnId::ANON)
    }

    /// Find a container slot with `memory_mb` of host memory behind it —
    /// where the charge lands is the configured [`Placement`] strategy's
    /// decision (the default [`placement::LeastLoadedMb`] reproduces the
    /// historical inline scan byte-for-byte: recycle the first evicted
    /// container on a host with room, else create on the freest host) —
    /// and charge the memory. Returns `None` when no host the strategy
    /// admits can take the charge (the cluster is memory-full, or the
    /// function's labels exclude every host with room).
    ///
    /// Under uniform accounting the default admits byte-identically to
    /// the old count-bounded pool: an evicted slot's host always has a
    /// free slot's worth of memory (its eviction released it), and
    /// "freest host" is "least-occupied host" when every charge is equal.
    pub fn acquire_slot_for(
        &mut self,
        now: SimTime,
        memory_mb: u32,
        function: FnId,
    ) -> Option<ContainerId> {
        let decision = {
            let (affinity, anti_affinity) = self
                .registry
                .function_by_id(function)
                .map(|f| (f.affinity.as_slice(), f.anti_affinity.as_slice()))
                .unwrap_or((&[], &[]));
            let ctx = PlaceCtx {
                function,
                charge_mb: memory_mb as u64,
                containers: &self.containers,
                invokers: &self.invokers,
                classes: &self.config.host_classes,
                affinity,
                anti_affinity,
            };
            self.placement.place(&ctx, &mut self.placement_rng)?
        };
        let cid = match decision {
            Decision::Reuse(cid) => cid,
            Decision::Create(host) => {
                let id = self.containers.len();
                self.invokers[host].containers.push(id);
                self.containers.push(Container::new(id, host, now));
                id
            }
        };
        self.charge_container(cid, memory_mb, now);
        self.debug_check_memory_accounting();
        Some(cid)
    }

    /// May `function` ever run on `host` under the configured placement
    /// strategy? Only [`placement::Constrained`] restricts this (label
    /// matching); the executor's infeasible-drop check and the pressure
    /// path's host filter both consult it so label-excluded functions
    /// drop instead of queueing or stealing memory they cannot use.
    pub fn placement_admits(&self, function: FnId, host: usize) -> bool {
        let (affinity, anti_affinity) = self
            .registry
            .function_by_id(function)
            .map(|f| (f.affinity.as_slice(), f.anti_affinity.as_slice()))
            .unwrap_or((&[], &[]));
        let ctx = PlaceCtx {
            function,
            charge_mb: 0,
            containers: &self.containers,
            invokers: &self.invokers,
            classes: &self.config.host_classes,
            affinity,
            anti_affinity,
        };
        self.placement.admits(&ctx, host)
    }

    /// The cold-start cost of provisioning `cid` on its host: the
    /// configured base cost scaled by the host class's permille
    /// multiplier. Homogeneous clusters (and the 1000-permille identity)
    /// return the base duration untouched, keeping legacy digests exact.
    pub fn cold_start_on(&self, cid: ContainerId) -> SimDuration {
        let base = self.config.cold_start;
        if self.config.host_classes.is_empty() {
            return base;
        }
        let class = self.invokers[self.containers[cid].invoker].class;
        let permille = self.config.host_classes[class].cold_start_mult_permille;
        if permille == 1000 {
            return base;
        }
        SimDuration(base.0.saturating_mul(permille as u64) / 1000)
    }

    /// Inter-node latency charged on a chain edge leaving `cid`'s host:
    /// a jittered RTT sample from the host class's network profile.
    /// Homogeneous clusters and on-host ([`Site::Local`]) classes charge
    /// nothing and draw nothing, so legacy runs never touch `net_rng`.
    pub fn chain_edge_delay(&mut self, cid: ContainerId) -> SimDuration {
        if self.config.host_classes.is_empty() {
            return SimDuration::ZERO;
        }
        let class = self.invokers[self.containers[cid].invoker].class;
        let site = self.config.host_classes[class].net_profile;
        if site == Site::Local {
            return SimDuration::ZERO;
        }
        SimDuration::from_secs_f64(site.link().sample_rtt(&mut self.net_rng))
    }

    /// Evict a container: release its memory charge, count the eviction
    /// by cause, and destroy its runtime state. Idempotent on an already-
    /// evicted container (no double release, no double count).
    pub fn evict_container(&mut self, cid: ContainerId, cause: EvictionCause, now: SimTime) {
        if self.containers[cid].state != ContainerState::Evicted {
            let mb = self.containers[cid].charged_mb;
            let inv = self.containers[cid].invoker;
            debug_assert!(
                self.invokers[inv].used_mb >= mb as u64,
                "evicting container {cid} would release {mb} MB from invoker {inv} \
                 holding only {} MB (double release?)",
                self.invokers[inv].used_mb
            );
            self.invokers[inv].release(mb as u64);
            self.note_resident_delta(now, -(mb as i64));
            self.metrics.evictions += 1;
            match cause {
                EvictionCause::Idle => self.metrics.evictions_idle += 1,
                EvictionCause::Pressure => {
                    self.metrics.evictions_pressure += 1;
                    // Reclaiming a parked snapshot is not a warm kill:
                    // the state it destroys costs a restore to re-pay,
                    // not a full cold start.
                    if self.containers[cid].state != ContainerState::Snapshotted
                        && self.containers[cid].runtime.invocations > 0
                    {
                        self.metrics.warm_kills += 1;
                    }
                }
            }
            if self.obs.is_enabled() {
                let kind = match cause {
                    EvictionCause::Idle => crate::obs::SpanKind::EvictionIdle,
                    EvictionCause::Pressure => crate::obs::SpanKind::EvictionPressure,
                };
                let warm_kill = matches!(cause, EvictionCause::Pressure)
                    && self.containers[cid].state != ContainerState::Snapshotted
                    && self.containers[cid].runtime.invocations > 0;
                let f = self.containers[cid].function.unwrap_or(FnId::ANON);
                self.obs.record(
                    &self.registry.symbols,
                    kind,
                    f,
                    cid as u64,
                    now,
                    SimDuration::ZERO,
                    mb as u64,
                    warm_kill as u64,
                );
            }
        }
        self.containers[cid].evict();
        self.debug_check_memory_accounting();
    }

    /// Demote a warm idle container to the snapshotted state: serialize
    /// its sandbox, release the difference between the warm footprint and
    /// the discounted snapshot charge, and park it for a later restore.
    /// The keep-alive policies' [`keepalive::IdleVerdict::Snapshot`]
    /// verdict lands here. The freed memory may admit queued work — the
    /// executor redispatches after calling this, exactly like an eviction.
    pub fn demote_to_snapshot(&mut self, cid: ContainerId, now: SimTime) {
        let warm_mb = self.containers[cid].charged_mb;
        let snap_mb = crate::platform::snapshot::snapshot_charge_mb(
            warm_mb,
            self.config.snapshot.charge_permille,
        )
        .min(warm_mb);
        let freed = warm_mb - snap_mb;
        let inv = self.containers[cid].invoker;
        self.invokers[inv].release(freed as u64);
        self.note_resident_delta(now, -(freed as i64));
        self.containers[cid].charged_mb = snap_mb;
        self.containers[cid].snapshot(now);
        self.metrics.snapshots_created += 1;
        if self.metrics.windows.enabled {
            if let Some(f) = self.containers[cid].function {
                let name = self.registry.symbols.resolve(f).to_string();
                self.metrics.windows.on_snapshot(&name);
            }
        }
        if self.obs.is_enabled() {
            let f = self.containers[cid].function.unwrap_or(FnId::ANON);
            self.obs.record(
                &self.registry.symbols,
                crate::obs::SpanKind::SnapshotCreate,
                f,
                cid as u64,
                now,
                SimDuration::ZERO,
                warm_mb as u64,
                snap_mb as u64,
            );
        }
        self.debug_check_memory_accounting();
    }

    /// Begin restoring a snapshotted container for a fresh arrival:
    /// re-charge the delta back up to the full warm footprint `full_mb`
    /// and flip the container to Initializing (the restore completes
    /// through the ordinary `finish_init`). Returns the restore latency
    /// (base + working-set page-in, prefetch-scaled), or `None` when the
    /// host lacks room for the re-charge — the caller falls through to
    /// the normal cold-start path and the snapshot stays parked.
    pub fn begin_restore(
        &mut self,
        cid: ContainerId,
        full_mb: u32,
        now: SimTime,
    ) -> Option<SimDuration> {
        let snap_mb = self.containers[cid].charged_mb;
        let full_mb = full_mb.max(snap_mb);
        let delta = full_mb - snap_mb;
        let inv = self.containers[cid].invoker;
        if !self.invokers[inv].has_room(delta as u64) {
            return None;
        }
        self.invokers[inv].charge(delta as u64);
        self.note_resident_delta(now, delta as i64);
        self.containers[cid].charged_mb = full_mb;
        self.containers[cid].begin_restore(now);
        let cost = crate::platform::snapshot::restore_cost(&self.config.snapshot, full_mb);
        self.metrics.restore_us += cost.micros();
        if self.obs.is_enabled() {
            let f = self.containers[cid].function.unwrap_or(FnId::ANON);
            self.obs.record(
                &self.registry.symbols,
                crate::obs::SpanKind::Restore,
                f,
                cid as u64,
                now,
                cost,
                full_mb as u64,
                snap_mb as u64,
            );
        }
        self.debug_check_memory_accounting();
        Some(cost)
    }

    /// Re-point a live container's memory charge at a different function
    /// (per-app re-init). Under uniform accounting this is a no-op; under
    /// per-function accounting the host may transiently exceed capacity
    /// when the sibling is heavier — re-init trades that slack for the
    /// kept runtime state.
    pub fn recharge_container(&mut self, cid: ContainerId, memory_mb: u32, now: SimTime) {
        let old = self.containers[cid].charged_mb;
        if old == memory_mb {
            return;
        }
        let inv = self.containers[cid].invoker;
        self.invokers[inv].release(old as u64);
        self.invokers[inv].charge(memory_mb as u64);
        self.containers[cid].charged_mb = memory_mb;
        self.note_resident_delta(now, memory_mb as i64 - old as i64);
        self.debug_check_memory_accounting();
    }

    fn charge_container(&mut self, cid: ContainerId, memory_mb: u32, now: SimTime) {
        let inv = self.containers[cid].invoker;
        self.invokers[inv].charge(memory_mb as u64);
        self.containers[cid].charged_mb = memory_mb;
        self.note_resident_delta(now, memory_mb as i64);
    }

    /// Advance the resident-memory integral to `now` and apply a change.
    ///
    /// Negative deltas use checked subtraction: a release exceeding the
    /// resident total clamps at zero AND counts in
    /// `metrics.accounting_clamps` instead of wrapping (the old
    /// `as i64 … max(0)` cast also clamped, but silently, and a charge
    /// stream past `i64::MAX` MB would have wrapped the cast itself).
    /// The counter is zero in every correctly paired charge/release
    /// stream; nonzero flags a mis-paired release that debug builds catch
    /// via `debug_check_memory_accounting` but release builds previously
    /// swallowed.
    fn note_resident_delta(&mut self, now: SimTime, delta_mb: i64) {
        let dt = now.since(self.resident_last_change).micros();
        self.metrics.resident_mb_us = self
            .metrics
            .resident_mb_us
            .saturating_add(self.resident_mb.saturating_mul(dt));
        self.resident_last_change = now;
        if delta_mb >= 0 {
            self.resident_mb = self.resident_mb.saturating_add(delta_mb as u64);
        } else {
            self.resident_mb = match self.resident_mb.checked_sub(delta_mb.unsigned_abs()) {
                Some(left) => left,
                None => {
                    self.metrics.accounting_clamps += 1;
                    0
                }
            };
        }
        self.metrics.peak_resident_mb = self.metrics.peak_resident_mb.max(self.resident_mb);
    }

    /// Flush the resident-memory integral up to `now` (call once before
    /// reading `metrics.resident_mb_us` at the end of a run).
    pub fn seal_resident_accounting(&mut self, now: SimTime) {
        self.note_resident_delta(now, 0);
    }

    /// Debug-build cross-check of the memory-accounting invariant: the sum
    /// of container charges on each host equals that invoker's `used_mb`,
    /// and the grand total equals `resident_mb` — i.e. memory is never
    /// double-charged, double-released, or driven negative. Containers keep
    /// `charged_mb == 0` while evicted, so summing every slot is exact even
    /// in the acquire-before-cold-start window. Runs after every charge /
    /// release / recharge in debug builds (the tier-1 test profile); compiles
    /// to nothing in release, keeping the replay hot path untouched.
    #[inline]
    pub fn debug_check_memory_accounting(&self) {
        #[cfg(debug_assertions)]
        {
            let mut per_inv = vec![0u64; self.invokers.len()];
            for c in &self.containers {
                per_inv[c.invoker] += c.charged_mb as u64;
            }
            let mut total = 0u64;
            for (inv, want) in self.invokers.iter().zip(&per_inv) {
                debug_assert_eq!(
                    inv.used_mb, *want,
                    "invoker {} used_mb diverged from its containers' charges",
                    inv.id
                );
                total += *want;
            }
            debug_assert_eq!(
                self.resident_mb, total,
                "resident_mb diverged from the per-invoker charge total"
            );
        }
    }

    /// Total warm containers (reporting).
    pub fn warm_count(&self) -> usize {
        self.containers
            .iter()
            .filter(|c| c.state == ContainerState::Warm)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::Site;
    use crate::platform::function::FunctionSpec;

    #[test]
    fn world_setup() {
        let mut w = World::new(Config::default());
        w.add_endpoint(Endpoint::new("store", Site::Edge));
        w.deploy(FunctionSpec::paper_lambda(
            "f1",
            "app",
            "store",
            SimDuration::from_millis(10),
        ));
        assert!(w.registry.function("f1").is_some());
        assert!(w.registry.hook("f1").is_some());
        assert_eq!(w.invokers.len(), Config::default().invokers);
    }

    #[test]
    fn acquire_slot_reuses_evicted_then_creates() {
        let mut cfg = Config::default();
        cfg.invokers = 1;
        cfg.containers_per_invoker = 2;
        let mut w = World::new(cfg);
        let (f, g) = (w.fid("f"), w.fid("g"));
        let a = w.acquire_slot(SimTime::ZERO, UNIFORM_SLOT_MB).unwrap();
        w.containers[a].begin_cold_start(f, SimTime::ZERO);
        let b = w.acquire_slot(SimTime::ZERO, UNIFORM_SLOT_MB).unwrap();
        assert_ne!(a, b);
        w.containers[b].begin_cold_start(g, SimTime::ZERO);
        // Pool is full now (2 uniform slots = 512 MB charged).
        assert_eq!(w.resident_mb, 2 * UNIFORM_SLOT_MB as u64);
        assert!(w.acquire_slot(SimTime::ZERO, UNIFORM_SLOT_MB).is_none());
        // Evicting releases the memory and frees the slot for reuse
        // (same id).
        w.evict_container(a, EvictionCause::Idle, SimTime::ZERO);
        assert_eq!(w.metrics.evictions_idle, 1);
        assert_eq!(w.resident_mb, UNIFORM_SLOT_MB as u64);
        assert_eq!(
            w.acquire_slot(SimTime::ZERO, UNIFORM_SLOT_MB),
            Some(a)
        );
    }

    #[test]
    fn function_mb_accounting_crowds_out_heavy_functions() {
        let mut cfg = Config::default();
        cfg.invokers = 1;
        cfg.invoker_memory_mb = Some(1024);
        cfg.memory_accounting = MemoryAccounting::FunctionMb;
        let mut w = World::new(cfg);
        // Three light containers fit; the 512 MB one then doesn't.
        for f in ["a", "b", "c"] {
            let fid = w.fid(f);
            let cid = w.acquire_slot(SimTime::ZERO, 256).unwrap();
            w.containers[cid].begin_cold_start(fid, SimTime::ZERO);
        }
        assert_eq!(w.invokers[0].free_mb(), 256);
        assert!(w.acquire_slot(SimTime::ZERO, 512).is_none());
        // A 256 MB one still fits.
        assert!(w.acquire_slot(SimTime::ZERO, 256).is_some());
        assert_eq!(w.invokers[0].free_mb(), 0);
        assert_eq!(w.metrics.peak_resident_mb, 1024);
    }

    #[test]
    fn resident_integral_accumulates_mb_time() {
        let mut cfg = Config::default();
        cfg.invokers = 1;
        let mut w = World::new(cfg);
        let f = w.fid("f");
        let a = w.acquire_slot(SimTime::ZERO, 256).unwrap();
        w.containers[a].begin_cold_start(f, SimTime::ZERO);
        // 256 MB resident for 2 simulated seconds.
        w.evict_container(a, EvictionCause::Pressure, SimTime(2_000_000));
        w.seal_resident_accounting(SimTime(5_000_000));
        assert_eq!(w.metrics.resident_mb_us, 256 * 2_000_000);
        assert_eq!(w.metrics.evictions_pressure, 1);
        // Never ran an invocation: a cold kill, not a warm kill.
        assert_eq!(w.metrics.warm_kills, 0);
        // Double eviction neither double-releases nor double-counts.
        w.evict_container(a, EvictionCause::Pressure, SimTime(6_000_000));
        assert_eq!(w.metrics.evictions, 1);
        assert_eq!(w.resident_mb, 0);
    }

    #[test]
    fn charge_for_function_follows_the_accounting_mode() {
        let mut w = World::new(Config::default());
        let mut spec = FunctionSpec::paper_lambda(
            "big",
            "app",
            "store",
            SimDuration::from_millis(10),
        );
        spec.memory_mb = 2048;
        w.deploy(spec);
        assert_eq!(w.charge_for_function("big"), UNIFORM_SLOT_MB);
        assert_eq!(w.charge_for_function("ghost"), UNIFORM_SLOT_MB);
        w.config.memory_accounting = MemoryAccounting::FunctionMb;
        assert_eq!(w.charge_for_function("big"), 2048);
        assert_eq!(w.charge_for_function("ghost"), UNIFORM_SLOT_MB);
        // Id-keyed variant agrees.
        let big = w.fid("big");
        assert_eq!(w.charge_for_function_id(big), 2048);
        assert_eq!(w.charge_for_function_id(FnId::ANON), UNIFORM_SLOT_MB);
    }

    #[test]
    fn model_latency_defaults() {
        let w = World::new(Config::default());
        assert_eq!(w.model_latency("unknown"), SimDuration::from_millis(5));
    }

    /// Satellite bugfix: a mis-paired release clamps `resident_mb` at
    /// zero AND counts in `accounting_clamps` instead of silently casting
    /// through `i64`; paired streams never touch the counter.
    #[test]
    fn mispaired_release_clamps_and_counts() {
        let mut w = World::new(Config::default());
        w.note_resident_delta(SimTime::ZERO, 100);
        w.note_resident_delta(SimTime(1_000_000), -60);
        assert_eq!(w.resident_mb, 40);
        assert_eq!(w.metrics.accounting_clamps, 0, "paired stream never clamps");
        // Release more than is resident: clamp, count, keep going.
        w.note_resident_delta(SimTime(2_000_000), -50);
        assert_eq!(w.resident_mb, 0);
        assert_eq!(w.metrics.accounting_clamps, 1);
        // The integral accumulated the pre-clamp occupancy exactly.
        assert_eq!(w.metrics.resident_mb_us, 100 * 1_000_000 + 40 * 1_000_000);
        // Accounting continues to work after the clamp.
        w.note_resident_delta(SimTime(3_000_000), 8);
        assert_eq!(w.resident_mb, 8);
        assert_eq!(w.metrics.accounting_clamps, 1);
    }

    /// Snapshot demote/restore accounting: the demote releases exactly
    /// the non-discounted fraction, the restore re-charges it, and the
    /// per-invoker / resident mirrors stay exact throughout.
    #[test]
    fn snapshot_demote_and_restore_keep_accounting_exact() {
        let mut cfg = Config::default();
        cfg.invokers = 1;
        cfg.snapshot.enabled = true;
        cfg.snapshot.charge_permille = 250;
        cfg.snapshot.restore_base = SimDuration::from_millis(25);
        cfg.snapshot.page_in_us_per_mb = 150;
        let mut w = World::new(cfg);
        let f = w.fid("f");
        let cid = w.acquire_slot(SimTime::ZERO, 256).unwrap();
        w.containers[cid].begin_cold_start(f, SimTime::ZERO);
        w.containers[cid].finish_init(SimTime::ZERO);
        assert_eq!(w.resident_mb, 256);

        w.demote_to_snapshot(cid, SimTime(1_000_000));
        assert_eq!(w.containers[cid].state, ContainerState::Snapshotted);
        assert_eq!(w.containers[cid].charged_mb, 64, "256 MB at 250 permille");
        assert_eq!(w.resident_mb, 64);
        assert_eq!(w.invokers[0].used_mb, 64);
        assert_eq!(w.metrics.snapshots_created, 1);
        assert_eq!(w.find_snapshot(f), Some(cid));
        // A snapshot is not a warm container.
        assert_eq!(w.find_warm(f), None);

        let cost = w.begin_restore(cid, 256, SimTime(2_000_000)).unwrap();
        assert_eq!(cost, SimDuration(25_000 + 256 * 150));
        assert_eq!(w.containers[cid].state, ContainerState::Initializing);
        assert_eq!(w.resident_mb, 256);
        assert_eq!(w.metrics.restore_us, cost.micros());
        w.containers[cid].finish_init(SimTime(2_000_000) + cost);
        assert_eq!(w.find_warm(f), Some(cid));
        assert_eq!(w.metrics.accounting_clamps, 0);
    }

    /// A restore whose re-charge delta exceeds the host's free memory is
    /// refused: the snapshot stays parked and nothing is charged.
    #[test]
    fn restore_refused_when_host_is_full() {
        let mut cfg = Config::default();
        cfg.invokers = 1;
        cfg.invoker_memory_mb = Some(300);
        cfg.memory_accounting = MemoryAccounting::FunctionMb;
        cfg.snapshot.enabled = true;
        let mut w = World::new(cfg);
        let (f, g) = (w.fid("f"), w.fid("g"));
        let a = w.acquire_slot(SimTime::ZERO, 256).unwrap();
        w.containers[a].begin_cold_start(f, SimTime::ZERO);
        w.containers[a].finish_init(SimTime::ZERO);
        w.demote_to_snapshot(a, SimTime::ZERO); // parks at 64 MB
        // A sibling fills the host: 64 + 200 leaves only 36 MB free.
        let b = w.acquire_slot(SimTime::ZERO, 200).unwrap();
        w.containers[b].begin_cold_start(g, SimTime::ZERO);
        assert!(w.begin_restore(a, 256, SimTime(1_000_000)).is_none());
        assert_eq!(w.containers[a].state, ContainerState::Snapshotted);
        assert_eq!(w.containers[a].charged_mb, 64);
        assert_eq!(w.resident_mb, 264);
        assert_eq!(w.metrics.restore_us, 0);
    }

    #[test]
    fn heterogeneous_classes_build_the_cluster_and_scale_costs() {
        let mut cfg = Config::default();
        cfg.host_classes = crate::util::config::HostClass::parse_list(
            "cloud:2:4096:1000:local,edge:1:1024:1600:edge",
        )
        .unwrap();
        let mut w = World::new(cfg);
        assert_eq!(w.invokers.len(), 3, "classes replace the invokers count");
        assert_eq!(w.invokers[0].capacity_mb, 4096);
        assert_eq!(w.invokers[2].capacity_mb, 1024);
        assert_eq!(w.invokers[2].class, 1);
        // Force a container onto each class and compare scaled costs.
        w.config.placement = crate::util::config::PlacementKind::RoundRobin;
        w.placement = crate::platform::placement::build(w.config.placement);
        let a = w.acquire_slot(SimTime::ZERO, 256).unwrap(); // host 0: cloud
        let b = w.acquire_slot(SimTime::ZERO, 256).unwrap(); // host 1: cloud
        let c = w.acquire_slot(SimTime::ZERO, 256).unwrap(); // host 2: edge
        assert_eq!(w.containers[c].invoker, 2);
        assert_eq!(w.cold_start_on(a), w.config.cold_start);
        assert_eq!(w.cold_start_on(b), w.config.cold_start);
        // 1600 permille of the 500 ms default = 800 ms, exact.
        assert_eq!(
            w.cold_start_on(c),
            SimDuration(w.config.cold_start.0 * 1600 / 1000)
        );
        // Chain edges off a local-profile host are free and draw-free;
        // off the edge-profile host they pay a jittered positive RTT.
        assert_eq!(w.chain_edge_delay(a), SimDuration::ZERO);
        assert!(w.chain_edge_delay(c) > SimDuration::ZERO);
    }

    #[test]
    fn homogeneous_default_charges_no_cross_node_costs() {
        let mut w = World::new(Config::default());
        let anything = w.fid("anything");
        let a = w.acquire_slot(SimTime::ZERO, 256).unwrap();
        assert_eq!(w.cold_start_on(a), w.config.cold_start);
        assert_eq!(w.chain_edge_delay(a), SimDuration::ZERO);
        assert!(w.placement_admits(anything, 0));
    }
}
