//! The composed simulation world.
//!
//! [`World`] owns every mutable piece of platform state; discrete-event
//! closures receive `(&mut Sim<World>, &mut World)` and the borrow
//! discipline is "disjoint fields": helpers take the specific fields they
//! need (`&world.endpoints`, `&mut world.rng`, `&mut world.containers[c]`)
//! so network, container and predictor state can be touched in one event.

use std::collections::{HashMap, VecDeque};

use crate::util::fxhash::FxHashMap;

use crate::billing::Ledger;
use crate::freshen::policy::FreshenGate;
use crate::metrics::{MetricsHub, StartKind};
use crate::platform::container::{Container, ContainerId};
use crate::platform::endpoint::Endpoint;
use crate::platform::function::FunctionId;
use crate::platform::invoker::Invoker;
use crate::platform::registry::Registry;
use crate::predict::chain::ChainPredictor;
use crate::predict::confidence::PredictionTracker;
use crate::predict::histogram::HistogramPredictor;
use crate::predict::learned::LearnedScorer;
use crate::simcore::waitlist::WaitList;
use crate::simcore::Sim;
use crate::util::config::Config;
use crate::util::rng::Rng;
use crate::util::time::{SimDuration, SimTime};

/// Dense invocation identifier (index into `World::invocations`).
pub type InvocationId = usize;

/// Per-invocation execution context (the state machine the executor walks).
#[derive(Debug, Clone)]
pub struct InvocationCtx {
    pub id: InvocationId,
    pub function: FunctionId,
    pub container: Option<ContainerId>,
    pub enqueued_at: SimTime,
    pub started_at: SimTime,
    /// Index of the op about to execute.
    pub op_idx: usize,
    pub start_kind: StartKind,
    pub freshen_hits: u32,
    pub freshen_misses: u32,
    pub done: bool,
}

/// An in-flight freshen run on a container.
#[derive(Debug, Clone)]
pub struct FreshenRunCtx {
    pub id: usize,
    pub function: FunctionId,
    pub container: ContainerId,
    pub action_idx: usize,
    pub started_at: SimTime,
    /// Prediction that admitted this run (billing resolution).
    pub prediction_id: Option<u64>,
    pub done: bool,
}

/// Deferred freshen charge awaiting prediction resolution.
#[derive(Debug, Clone)]
pub struct PendingFreshenCharge {
    pub prediction_id: u64,
    pub app: String,
    pub memory_mb: u32,
    pub duration: SimDuration,
}

/// The simulation world.
pub struct World {
    pub config: Config,
    pub rng: Rng,
    pub registry: Registry,
    pub containers: Vec<Container>,
    pub invokers: Vec<Invoker>,
    pub endpoints: FxHashMap<String, Endpoint>,
    pub metrics: MetricsHub,
    pub ledger: Ledger,
    pub gate: FreshenGate,
    pub chain_pred: ChainPredictor,
    pub hist_pred: HistogramPredictor,
    pub tracker: PredictionTracker,
    pub scorer: LearnedScorer,
    /// Active + completed invocation contexts (slab; completed stay for
    /// inspection in tests, metrics copy what reports need).
    pub invocations: Vec<InvocationCtx>,
    pub freshen_runs: Vec<FreshenRunCtx>,
    /// Per-function queues when no container is available.
    pub queues: FxHashMap<FunctionId, VecDeque<InvocationId>>,
    /// `FrWait` parking: one wait list per (container, resource index).
    pub fr_waiters: FxHashMap<(ContainerId, usize), WaitList<World>>,
    /// Freshen charges awaiting hit/miss resolution.
    pub pending_charges: Vec<PendingFreshenCharge>,
    /// Calibrated inference latency per model (simulator stand-in for the
    /// PJRT execution the serving engine performs for real; can be
    /// overwritten from measured artifact timings).
    pub model_latencies: HashMap<String, SimDuration>,
    /// Strict version checking for prefetched data (§3.2 version numbers).
    pub strict_versions: bool,
    /// Emit histogram-based predictions automatically after each completed
    /// invocation (the standalone-function path). Ablations that inject
    /// their own prediction streams turn this off to avoid contamination.
    pub auto_hist_predict: bool,
}

/// The simulator type every experiment drives.
pub type PlatformSim = Sim<World>;

impl World {
    pub fn new(config: Config) -> World {
        let rng = Rng::new(config.seed);
        let gate = FreshenGate::new(config.freshen.clone());
        let invokers = (0..config.invokers)
            .map(|i| Invoker::new(i, config.containers_per_invoker))
            .collect();
        World {
            rng,
            gate,
            invokers,
            registry: Registry::new(),
            containers: Vec::new(),
            endpoints: FxHashMap::default(),
            metrics: MetricsHub::new(),
            ledger: Ledger::new(),
            chain_pred: ChainPredictor::new(),
            hist_pred: HistogramPredictor::new(),
            tracker: PredictionTracker::new(),
            scorer: LearnedScorer::default(),
            invocations: Vec::new(),
            freshen_runs: Vec::new(),
            queues: FxHashMap::default(),
            fr_waiters: FxHashMap::default(),
            pending_charges: Vec::new(),
            model_latencies: HashMap::new(),
            strict_versions: true,
            auto_hist_predict: true,
            config,
        }
    }

    /// Add a remote endpoint.
    pub fn add_endpoint(&mut self, endpoint: Endpoint) {
        self.endpoints.insert(endpoint.id.clone(), endpoint);
    }

    /// Deploy a function spec (infers its freshen hook).
    pub fn deploy(&mut self, spec: crate::platform::function::FunctionSpec) {
        self.registry.deploy(spec, self.config.freshen.default_ttl);
    }

    /// Default simulated latency for `Op::Infer` when no calibration is set.
    pub fn model_latency(&self, model: &str) -> SimDuration {
        self.model_latencies
            .get(model)
            .copied()
            .unwrap_or(SimDuration::from_millis(5))
    }

    // ---- container pool -----------------------------------------------

    /// Find a warm container for `function`.
    pub fn find_warm(&self, function: &str) -> Option<ContainerId> {
        self.containers
            .iter()
            .find(|c| c.warm_for(function))
            .map(|c| c.id)
    }

    /// Find (or create) a free container slot: an evicted container, or a
    /// new slot on an invoker with capacity. Returns `None` when the
    /// cluster is full.
    pub fn acquire_slot(&mut self, now: SimTime) -> Option<ContainerId> {
        if let Some(c) = self
            .containers
            .iter()
            .find(|c| c.state == crate::platform::container::ContainerState::Evicted)
        {
            return Some(c.id);
        }
        // Create a new container on the least-occupied invoker.
        let inv = self
            .invokers
            .iter_mut()
            .filter(|i| i.has_capacity())
            .min_by_key(|i| i.occupancy())?;
        let id = self.containers.len();
        inv.containers.push(id);
        let invoker_id = inv.id;
        self.containers.push(Container::new(id, invoker_id, now));
        Some(id)
    }

    /// Total warm containers (reporting).
    pub fn warm_count(&self) -> usize {
        self.containers
            .iter()
            .filter(|c| c.state == crate::platform::container::ContainerState::Warm)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::Site;
    use crate::platform::function::FunctionSpec;

    #[test]
    fn world_setup() {
        let mut w = World::new(Config::default());
        w.add_endpoint(Endpoint::new("store", Site::Edge));
        w.deploy(FunctionSpec::paper_lambda(
            "f1",
            "app",
            "store",
            SimDuration::from_millis(10),
        ));
        assert!(w.registry.function("f1").is_some());
        assert!(w.registry.hook("f1").is_some());
        assert_eq!(w.invokers.len(), Config::default().invokers);
    }

    #[test]
    fn acquire_slot_reuses_evicted_then_creates() {
        let mut cfg = Config::default();
        cfg.invokers = 1;
        cfg.containers_per_invoker = 2;
        let mut w = World::new(cfg);
        let a = w.acquire_slot(SimTime::ZERO).unwrap();
        w.containers[a].begin_cold_start("f", SimTime::ZERO);
        let b = w.acquire_slot(SimTime::ZERO).unwrap();
        assert_ne!(a, b);
        w.containers[b].begin_cold_start("g", SimTime::ZERO);
        // Pool is full now.
        assert!(w.acquire_slot(SimTime::ZERO).is_none());
        // Evicting frees the slot for reuse (same id).
        w.containers[a].evict();
        assert_eq!(w.acquire_slot(SimTime::ZERO), Some(a));
    }

    #[test]
    fn model_latency_defaults() {
        let w = World::new(Config::default());
        assert_eq!(w.model_latency("unknown"), SimDuration::from_millis(5));
    }
}
