//! Versioned object store (the S3-like datastore behind `DataGet`/`DataPut`).
//!
//! Objects carry a monotonically-increasing version and a size; the version
//! is what the freshen cache compares against to detect staleness ("an
//! object stored within the runtime may need to be retrieved from a
//! datastore because a newer version is available", §2).

use crate::util::fxhash::FxHashMap;
use crate::util::time::SimTime;

/// One stored object's metadata (we simulate payloads by size only).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StoredObject {
    pub version: u64,
    pub bytes: f64,
    pub modified: SimTime,
}

/// A named object store.
#[derive(Debug, Clone, Default)]
pub struct ObjectStore {
    objects: FxHashMap<String, StoredObject>,
    /// Operation counters (metrics / billing).
    pub gets: u64,
    pub puts: u64,
    pub heads: u64,
}

impl ObjectStore {
    pub fn new() -> ObjectStore {
        ObjectStore::default()
    }

    /// Create or overwrite an object; bumps the version.
    pub fn put(&mut self, id: &str, bytes: f64, now: SimTime) -> u64 {
        self.puts += 1;
        let entry = self.objects.entry(id.to_string()).or_insert(StoredObject {
            version: 0,
            bytes,
            modified: now,
        });
        entry.version += 1;
        entry.bytes = bytes;
        entry.modified = now;
        entry.version
    }

    /// Full fetch: returns the object (None if missing).
    pub fn get(&mut self, id: &str) -> Option<StoredObject> {
        self.gets += 1;
        self.objects.get(id).copied()
    }

    /// Metadata-only check (a HEAD request): cheap version probe used by
    /// freshen to validate cached copies.
    pub fn head(&mut self, id: &str) -> Option<u64> {
        self.heads += 1;
        self.objects.get(id).map(|o| o.version)
    }

    /// Read without counting (test/assert helper).
    pub fn peek(&self, id: &str) -> Option<StoredObject> {
        self.objects.get(id).copied()
    }

    /// Simulate an external writer updating the object out-of-band — the
    /// staleness scenario of §2.
    pub fn external_update(&mut self, id: &str, bytes: f64, now: SimTime) -> u64 {
        let entry = self.objects.entry(id.to_string()).or_insert(StoredObject {
            version: 0,
            bytes,
            modified: now,
        });
        entry.version += 1;
        entry.bytes = bytes;
        entry.modified = now;
        entry.version
    }

    pub fn len(&self) -> usize {
        self.objects.len()
    }

    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_bumps_version() {
        let mut s = ObjectStore::new();
        let v1 = s.put("model", 5e6, SimTime(0));
        let v2 = s.put("model", 6e6, SimTime(1));
        assert_eq!((v1, v2), (1, 2));
        let obj = s.get("model").unwrap();
        assert_eq!(obj.version, 2);
        assert_eq!(obj.bytes, 6e6);
    }

    #[test]
    fn head_is_cheap_version_probe() {
        let mut s = ObjectStore::new();
        s.put("a", 1.0, SimTime(0));
        assert_eq!(s.head("a"), Some(1));
        assert_eq!(s.head("zzz"), None);
        assert_eq!(s.heads, 2);
        assert_eq!(s.gets, 0);
    }

    #[test]
    fn missing_object_is_none() {
        let mut s = ObjectStore::new();
        assert!(s.get("nope").is_none());
        assert_eq!(s.gets, 1);
    }

    #[test]
    fn external_update_invalidates_cached_versions() {
        let mut s = ObjectStore::new();
        s.put("m", 1.0, SimTime(0));
        let cached_version = s.peek("m").unwrap().version;
        s.external_update("m", 2.0, SimTime(5));
        assert!(s.peek("m").unwrap().version > cached_version);
    }
}
