//! Generation-stamped free-list slab for invocation contexts.
//!
//! The shared-pool macro replay used to push every `InvocationCtx` onto a
//! `Vec` that only ever grew — >1M contexts resident for a >1M-invocation
//! day even though almost all were done. The slab reuses completed slots
//! via a LIFO free list, so resident contexts track the *in-flight*
//! population instead of the cumulative one.
//!
//! Handles are [`InvocationId`]: a `(slot, generation)` pair. Releasing a
//! slot bumps its generation, so a stale handle held across a reuse
//! mismatches and is caught by a `debug_assertions` check on every access
//! — the same belt-and-braces style as the container incarnation guard.
//!
//! Digest contract: recycling is *opt-in* (`set_recycle(true)`, used by
//! the replay path). Off — the default — `release` is a no-op, slots are
//! never reused, and `slot` numbers coincide with the legacy dense Vec
//! indexes; invariants and tests that iterate completed contexts keep
//! working. Independently of recycling, every context receives a dense
//! arrival sequence number (`seq`, see [`InvocationSlab::insert_with`])
//! identical to the legacy Vec index, and *all* output (spans, params,
//! dispatch order) derives from `seq`, never from slot numbers — which is
//! why reusing slots cannot move a byte of output.

/// Handle to a slab-resident invocation context.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct InvocationId {
    slot: u32,
    gen: u32,
}

impl InvocationId {
    /// Slot index (for debug display; output must use the ctx `seq`).
    pub fn slot(self) -> u32 {
        self.slot
    }
}

struct Slot<T> {
    /// Bumped on every release; a handle is live iff its `gen` matches.
    gen: u32,
    body: Option<T>,
}

/// The slab. `T` is the context type (generic to keep this module free of
/// platform dependencies and independently testable).
pub struct InvocationSlab<T> {
    slots: Vec<Slot<T>>,
    /// LIFO free list of released slot indexes (only populated when
    /// `recycle` is on).
    free: Vec<u32>,
    /// When off (default), `release` is a no-op and the slab behaves as
    /// an append-only Vec (legacy semantics).
    recycle: bool,
    /// Dense arrival counter; the next context's `seq`.
    next_seq: u64,
    /// Number of occupied slots.
    live: usize,
}

impl<T> Default for InvocationSlab<T> {
    fn default() -> Self {
        InvocationSlab::new()
    }
}

impl<T> InvocationSlab<T> {
    pub fn new() -> InvocationSlab<T> {
        InvocationSlab {
            slots: Vec::new(),
            free: Vec::new(),
            recycle: false,
            next_seq: 0,
            live: 0,
        }
    }

    /// Opt in to slot reuse (the replay hot path). Must be set before the
    /// first insert; flipping it mid-run would mix index regimes.
    pub fn set_recycle(&mut self, on: bool) {
        debug_assert!(
            self.slots.is_empty(),
            "set_recycle must precede the first insert"
        );
        self.recycle = on;
    }

    /// Insert a context built by `make`, which receives the assigned
    /// handle and the dense arrival sequence number (equal to the legacy
    /// `Vec` index: 0, 1, 2, … in arrival order, never reused).
    pub fn insert_with(&mut self, make: impl FnOnce(InvocationId, u64) -> T) -> InvocationId {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.live += 1;
        if let Some(slot) = self.free.pop() {
            let s = &mut self.slots[slot as usize];
            debug_assert!(s.body.is_none(), "free-list slot still occupied");
            let id = InvocationId { slot, gen: s.gen };
            s.body = Some(make(id, seq));
            return id;
        }
        assert!(self.slots.len() < u32::MAX as usize, "slab overflow");
        let id = InvocationId {
            slot: self.slots.len() as u32,
            gen: 0,
        };
        let body = make(id, seq);
        self.slots.push(Slot {
            gen: 0,
            body: Some(body),
        });
        id
    }

    /// Mark a context's slot reusable. No-op unless recycling is on; the
    /// handle must be live (checked under `debug_assertions`).
    pub fn release(&mut self, id: InvocationId) {
        if !self.recycle {
            return;
        }
        let s = &mut self.slots[id.slot as usize];
        debug_assert_eq!(s.gen, id.gen, "release of a stale InvocationId");
        if s.body.take().is_some() {
            s.gen = s.gen.wrapping_add(1);
            self.free.push(id.slot);
            self.live -= 1;
        }
    }

    /// Total contexts ever inserted (== the next `seq`).
    pub fn total(&self) -> u64 {
        self.next_seq
    }

    /// Currently occupied slots.
    pub fn live(&self) -> usize {
        self.live
    }

    /// Allocated slot capacity (the resident high-water mark).
    pub fn slots_allocated(&self) -> usize {
        self.slots.len()
    }

    pub fn get(&self, id: InvocationId) -> Option<&T> {
        let s = self.slots.get(id.slot as usize)?;
        if s.gen != id.gen {
            return None;
        }
        s.body.as_ref()
    }

    /// Iterate occupied contexts in slot order. With recycling off this
    /// is exactly arrival (`seq`) order, matching the legacy Vec.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.slots.iter().filter_map(|s| s.body.as_ref())
    }
}

impl<T> std::ops::Index<InvocationId> for InvocationSlab<T> {
    type Output = T;

    fn index(&self, id: InvocationId) -> &T {
        let s = &self.slots[id.slot as usize];
        debug_assert_eq!(
            s.gen, id.gen,
            "stale InvocationId: slot {} was recycled",
            id.slot
        );
        s.body.as_ref().expect("released InvocationId")
    }
}

impl<T> std::ops::IndexMut<InvocationId> for InvocationSlab<T> {
    fn index_mut(&mut self, id: InvocationId) -> &mut T {
        let s = &mut self.slots[id.slot as usize];
        debug_assert_eq!(
            s.gen, id.gen,
            "stale InvocationId: slot {} was recycled",
            id.slot
        );
        s.body.as_mut().expect("released InvocationId")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_only_by_default_with_dense_seqs() {
        let mut slab: InvocationSlab<u64> = InvocationSlab::new();
        let ids: Vec<InvocationId> = (0..5)
            .map(|_| slab.insert_with(|_id, seq| seq * 10))
            .collect();
        // release is a no-op with recycling off
        slab.release(ids[2]);
        assert_eq!(slab.live(), 5);
        assert_eq!(slab.slots_allocated(), 5);
        assert_eq!(slab.total(), 5);
        let seqs: Vec<u64> = slab.iter().copied().collect();
        assert_eq!(seqs, vec![0, 10, 20, 30, 40]);
        assert_eq!(slab[ids[2]], 20);
    }

    #[test]
    fn recycling_reuses_slots_lifo_and_keeps_seq_dense() {
        let mut slab: InvocationSlab<u64> = InvocationSlab::new();
        slab.set_recycle(true);
        let a = slab.insert_with(|_, seq| seq);
        let b = slab.insert_with(|_, seq| seq);
        let c = slab.insert_with(|_, seq| seq);
        assert_eq!((slab[a], slab[b], slab[c]), (0, 1, 2));
        slab.release(b);
        assert_eq!(slab.live(), 2);
        // The freed slot is reused; the seq keeps counting densely.
        let d = slab.insert_with(|_, seq| seq);
        assert_eq!(d.slot(), b.slot(), "LIFO slot reuse");
        assert_ne!(d, b, "generation differs");
        assert_eq!(slab[d], 3, "seq is dense across reuse");
        assert_eq!(slab.slots_allocated(), 3, "no new slot allocated");
        assert_eq!(slab.total(), 4);
    }

    #[test]
    fn bounded_residency_under_churn() {
        // The point of the slab: 10k inserted, never more than 2 resident.
        let mut slab: InvocationSlab<u64> = InvocationSlab::new();
        slab.set_recycle(true);
        let mut prev: Option<InvocationId> = None;
        for _ in 0..10_000 {
            let id = slab.insert_with(|_, seq| seq);
            if let Some(p) = prev.take() {
                slab.release(p);
            }
            prev = Some(id);
        }
        assert_eq!(slab.total(), 10_000);
        assert!(slab.slots_allocated() <= 2, "residency must stay bounded");
    }

    #[test]
    fn get_on_stale_handle_is_none() {
        let mut slab: InvocationSlab<u64> = InvocationSlab::new();
        slab.set_recycle(true);
        let a = slab.insert_with(|_, seq| seq);
        slab.release(a);
        assert!(slab.get(a).is_none());
        let b = slab.insert_with(|_, seq| seq);
        assert_eq!(b.slot(), a.slot());
        assert!(slab.get(a).is_none(), "old generation stays dead");
        assert_eq!(slab.get(b), Some(&1));
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "gen check is debug-only")]
    #[should_panic(expected = "stale InvocationId")]
    fn stale_handle_access_panics_in_debug() {
        let mut slab: InvocationSlab<u64> = InvocationSlab::new();
        slab.set_recycle(true);
        let a = slab.insert_with(|_, seq| seq);
        slab.release(a);
        let _b = slab.insert_with(|_, seq| seq); // recycles a's slot
        let _ = slab[a]; // stale generation → panic
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "gen check is debug-only")]
    #[should_panic(expected = "stale InvocationId")]
    fn double_release_then_access_panics_in_debug() {
        let mut slab: InvocationSlab<u64> = InvocationSlab::new();
        slab.set_recycle(true);
        let a = slab.insert_with(|_, seq| seq);
        slab.release(a);
        let _ = slab[a];
    }
}
