//! Remote service endpoints.
//!
//! An [`Endpoint`] is a named remote service — the file/model server or
//! datastore the paper's functions talk to — placed behind a network link
//! ([`crate::netsim::link`]) at one of the evaluation's sites (local /
//! edge / remote) with a versioned [`ObjectStore`] and a per-request server
//! processing time.

use crate::netsim::cc::CongestionControl;
use crate::netsim::link::{Link, Site};
use crate::netsim::tcp::Connection;
use crate::netsim::tls::{TlsSession, TlsVersion};
use crate::netsim::warm::CwndHistory;
use crate::platform::datastore::ObjectStore;

/// A remote service the platform's functions use.
#[derive(Debug, Clone)]
pub struct Endpoint {
    pub id: String,
    pub link: Link,
    pub store: ObjectStore,
    /// Per-request server processing time, seconds.
    pub server_time: f64,
    /// Whether connections to this endpoint use TLS (and which version).
    pub tls: Option<TlsVersion>,
    /// Server-side idle timeout in seconds (connections idle longer die).
    pub idle_timeout: f64,
    /// Host-wide history of window sizes toward this endpoint (feeds
    /// `warm_cwnd`'s recent-connection estimate).
    pub cwnd_history: CwndHistory,
    /// Congestion control used for connections to this endpoint.
    pub cc: CongestionControl,
}

impl Endpoint {
    pub fn new(id: &str, site: Site) -> Endpoint {
        Endpoint {
            id: id.to_string(),
            link: site.link(),
            store: ObjectStore::new(),
            server_time: 1.0e-3,
            tls: None,
            idle_timeout: crate::netsim::tcp::DEFAULT_IDLE_TIMEOUT,
            cwnd_history: CwndHistory::new(),
            cc: CongestionControl::Cubic,
        }
    }

    pub fn with_tls(mut self, version: TlsVersion) -> Endpoint {
        self.tls = Some(version);
        self
    }

    pub fn with_link(mut self, link: Link) -> Endpoint {
        self.link = link;
        self
    }

    pub fn with_server_time(mut self, seconds: f64) -> Endpoint {
        self.server_time = seconds;
        self
    }

    /// Build a fresh (closed) connection object toward this endpoint.
    pub fn new_connection(&self) -> Connection {
        let mut c = Connection::new(self.link.clone(), self.cc);
        c.idle_timeout = self.idle_timeout;
        c
    }

    /// Build the TLS session object if this endpoint uses TLS.
    pub fn new_tls_session(&self) -> Option<TlsSession> {
        self.tls.map(TlsSession::new)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_builders() {
        let e = Endpoint::new("store", Site::Remote)
            .with_tls(TlsVersion::Tls13)
            .with_server_time(0.002);
        assert_eq!(e.id, "store");
        assert_eq!(e.server_time, 0.002);
        assert!(e.new_tls_session().is_some());
        let plain = Endpoint::new("s2", Site::Local);
        assert!(plain.new_tls_session().is_none());
    }

    #[test]
    fn connections_inherit_endpoint_settings() {
        let mut e = Endpoint::new("store", Site::Edge);
        e.idle_timeout = 42.0;
        let c = e.new_connection();
        assert_eq!(c.idle_timeout, 42.0);
        assert_eq!(c.link.name, "edge");
    }
}
