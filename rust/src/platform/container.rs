//! Containers and the in-container language runtime.
//!
//! OpenWhisk semantics (§2): a Docker container hosts a persistent language
//! runtime listening for hooks. `init` loads the function code; `run`
//! executes an invocation; our added `freshen` hook runs proactive work.
//! State held in [`RuntimeEnv`] is **runtime-scoped** — it survives across
//! invocations in the same container (connections, prefetched data,
//! `fr_state`) and is destroyed on eviction.

use crate::util::fxhash::FxHashMap;

use crate::freshen::cache::FreshenCache;
use crate::freshen::state::FrState;
use crate::netsim::tcp::Connection;
use crate::netsim::tls::TlsSession;
use crate::platform::symbols::FnId;
use crate::simcore::EventId;
use crate::util::time::SimTime;

/// Dense container identifier (index into the world's container table).
pub type ContainerId = usize;

/// Container lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerState {
    /// Being provisioned + `init` (a cold start in progress).
    Initializing,
    /// Runtime is live and idle; a `run` dispatch is a warm start.
    Warm,
    /// Currently executing an invocation.
    Busy,
    /// Serialized to a host-local snapshot image: not dispatchable, but
    /// parked at a discounted memory charge and restorable at a fraction
    /// of a cold start (see [`crate::platform::snapshot`]).
    Snapshotted,
    /// Torn down; slot reusable.
    Evicted,
}

/// Runtime-scoped state: everything the language runtime keeps alive
/// between invocations (§2 "runtime-scoped variables").
#[derive(Debug, Clone, Default)]
pub struct RuntimeEnv {
    /// Persistent connections per endpoint (the paper's canonical use of
    /// runtime scoping).
    // simlint: allow(D007, keyed by endpoint registration name, not per-event function id)
    pub connections: FxHashMap<String, Connection>,
    /// TLS sessions per endpoint (tickets survive reconnects).
    // simlint: allow(D007, keyed by endpoint registration name, not per-event function id)
    pub tls: FxHashMap<String, TlsSession>,
    /// The freshen resource list shared by hook and wrappers.
    pub fr_state: FrState,
    /// The freshen prefetch cache.
    pub cache: FreshenCache,
    /// Count of invocations served by this runtime.
    pub invocations: u64,
}

impl RuntimeEnv {
    pub fn new() -> RuntimeEnv {
        RuntimeEnv::default()
    }

    /// Wipe everything (container recycled / evicted).
    pub fn reset(&mut self) {
        self.connections.clear();
        self.tls.clear();
        self.fr_state = FrState::new();
        self.cache.clear();
        self.invocations = 0;
    }
}

/// A container slot on an invoker host.
#[derive(Debug, Clone)]
pub struct Container {
    pub id: ContainerId,
    /// Host this container lives on.
    pub invoker: usize,
    /// Function whose code was `init`ed into the runtime (interned id;
    /// resolve through the world's `Symbols` for display). Containers are
    /// per-function unless the platform allows sharing (§2, [13]).
    pub function: Option<FnId>,
    /// Owning application (set at cold start; under per-app isolation a
    /// warm container may be re-inited for any sibling function).
    pub app: Option<FnId>,
    pub state: ContainerState,
    pub runtime: RuntimeEnv,
    pub created_at: SimTime,
    pub last_used: SimTime,
    /// Memory (MB) this container currently charges its invoker host
    /// (0 while evicted). Set by the world at slot acquisition.
    pub charged_mb: u32,
    /// Reuse generation: bumped whenever the container leaves the idle
    /// Warm state (dispatch, cold start, eviction). An idle-eviction
    /// check scheduled for generation g is stale — and must skip — once
    /// the generation moves on.
    pub reuse_gen: u64,
    /// Incarnation: bumped only when the slot is RECLAIMED — evicted, or
    /// re-inited for a sibling function (both destroy/repoint the state a
    /// freshen run works against) — so it names one hosted-function
    /// lifetime of this slot (coarser than `reuse_gen`, which also moves
    /// on every dispatch). A freshen run stamped with incarnation i is
    /// stale once the slot is reclaimed, and the incarnation guard aborts
    /// it.
    pub incarnation: u64,
    /// The pending idle-eviction check, if any, so a re-release can
    /// cancel it instead of piling up one no-op wheel event per release.
    pub idle_timer: Option<EventId>,
    /// Statistics.
    pub cold_starts: u32,
    pub warm_starts: u32,
    /// Freshen runs executed in this container.
    pub freshen_runs: u32,
}

impl Container {
    pub fn new(id: ContainerId, invoker: usize, now: SimTime) -> Container {
        Container {
            id,
            invoker,
            function: None,
            app: None,
            state: ContainerState::Evicted,
            runtime: RuntimeEnv::new(),
            created_at: now,
            last_used: now,
            charged_mb: 0,
            reuse_gen: 0,
            incarnation: 0,
            idle_timer: None,
            cold_starts: 0,
            warm_starts: 0,
            freshen_runs: 0,
        }
    }

    /// Begin a cold start for `function` of `app` (provision + `init`).
    pub fn begin_cold_start(&mut self, function: FnId, now: SimTime) {
        self.begin_cold_start_for_app(function, None, now)
    }

    /// Cold start with explicit app attribution (per-app isolation needs
    /// the app on the container).
    pub fn begin_cold_start_for_app(&mut self, function: FnId, app: Option<FnId>, now: SimTime) {
        debug_assert_eq!(self.state, ContainerState::Evicted);
        self.runtime.reset();
        self.function = Some(function);
        self.app = app.filter(|a| !a.is_anon());
        self.state = ContainerState::Initializing;
        self.created_at = now;
        self.last_used = now;
        self.reuse_gen += 1;
        self.cold_starts += 1;
    }

    /// `init` finished: the runtime is live.
    pub fn finish_init(&mut self, now: SimTime) {
        debug_assert_eq!(self.state, ContainerState::Initializing);
        self.state = ContainerState::Warm;
        self.last_used = now;
    }

    /// Dispatch an invocation (warm start).
    pub fn begin_run(&mut self, now: SimTime) {
        debug_assert_eq!(self.state, ContainerState::Warm);
        self.state = ContainerState::Busy;
        self.warm_starts += 1;
        self.last_used = now;
        self.reuse_gen += 1;
        self.runtime.invocations += 1;
    }

    /// Invocation complete: back to warm.
    pub fn finish_run(&mut self, now: SimTime) {
        debug_assert_eq!(self.state, ContainerState::Busy);
        self.state = ContainerState::Warm;
        self.last_used = now;
    }

    /// Demote a warm idle container to a snapshot: the sandbox is
    /// serialized to a host-local image and the slot parks at a
    /// discounted charge (the world adjusts `charged_mb` and the invoker
    /// ledger; this is the state transition only). Runtime-scoped state
    /// is preserved IN the image — it comes back on restore — but the
    /// incarnation does not move: a snapshot is suspension, not reclaim.
    pub fn snapshot(&mut self, now: SimTime) {
        debug_assert_eq!(self.state, ContainerState::Warm);
        self.state = ContainerState::Snapshotted;
        self.last_used = now;
        // Leaving the idle Warm state invalidates pending idle checks.
        self.reuse_gen += 1;
        self.idle_timer = None;
    }

    /// Begin restoring a snapshot (base latency + working-set page-in;
    /// the world schedules the completion event). Sockets do not survive
    /// serialization, so live connections and TLS sessions are dropped —
    /// the freshen cache and `fr_state` page back in with the image.
    pub fn begin_restore(&mut self, now: SimTime) {
        debug_assert_eq!(self.state, ContainerState::Snapshotted);
        self.state = ContainerState::Initializing;
        self.last_used = now;
        self.reuse_gen += 1;
        self.runtime.connections.clear();
        self.runtime.tls.clear();
    }

    /// Is this container a parked snapshot of `function`?
    pub fn snapshot_for(&self, function: FnId) -> bool {
        self.state == ContainerState::Snapshotted && self.function == Some(function)
    }

    /// Evict: destroy runtime-scoped state. Memory release against the
    /// invoker is the world's job (`World::evict_container`); this only
    /// clears the container-side charge record.
    pub fn evict(&mut self) {
        self.state = ContainerState::Evicted;
        self.function = None;
        self.app = None;
        self.charged_mb = 0;
        self.reuse_gen += 1;
        self.incarnation += 1;
        self.idle_timer = None;
        self.runtime.reset();
    }

    /// Per-app isolation (§6): swap which sibling function's code the live
    /// runtime hosts. Keeps connections and the freshen cache (shared
    /// runtime scope); clears `fr_state` (its indices are positional per
    /// function body). A reclaim from any in-flight freshen run's point
    /// of view, so the incarnation moves on.
    pub fn reinit_for(&mut self, function: FnId, now: SimTime) {
        debug_assert_eq!(self.state, ContainerState::Warm);
        self.function = Some(function);
        self.runtime.fr_state = crate::freshen::state::FrState::new();
        self.incarnation += 1;
        self.last_used = now;
    }

    /// Is this container warm and owned by `app` (any function)?
    pub fn warm_for_app(&self, app: FnId) -> bool {
        self.state == ContainerState::Warm && self.app == Some(app)
    }

    /// Can this container serve `function` warm right now?
    pub fn warm_for(&self, function: FnId) -> bool {
        self.state == ContainerState::Warm && self.function == Some(function)
    }

    /// Idle duration (only meaningful for warm containers).
    pub fn idle_for(&self, now: SimTime) -> crate::util::time::SimDuration {
        now.since(self.last_used)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::symbols::Symbols;
    use crate::util::time::SimDuration;

    fn t(s: u64) -> SimTime {
        SimTime(s * 1_000_000)
    }

    fn ids(names: &[&str]) -> Vec<FnId> {
        let mut syms = Symbols::new();
        names.iter().map(|n| syms.intern(n)).collect()
    }

    #[test]
    fn lifecycle() {
        let [f1, f2] = ids(&["f1", "f2"])[..] else {
            unreachable!()
        };
        let mut c = Container::new(0, 0, t(0));
        assert_eq!(c.state, ContainerState::Evicted);
        c.begin_cold_start(f1, t(0));
        assert_eq!(c.state, ContainerState::Initializing);
        assert!(!c.warm_for(f1));
        c.finish_init(t(1));
        assert!(c.warm_for(f1));
        assert!(!c.warm_for(f2));
        c.begin_run(t(2));
        assert_eq!(c.state, ContainerState::Busy);
        c.finish_run(t(3));
        assert!(c.warm_for(f1));
        assert_eq!(c.cold_starts, 1);
        assert_eq!(c.warm_starts, 1);
        assert_eq!(c.runtime.invocations, 1);
    }

    #[test]
    fn eviction_destroys_runtime_state() {
        let [f1] = ids(&["f1"])[..] else {
            unreachable!()
        };
        let mut c = Container::new(0, 0, t(0));
        c.begin_cold_start(f1, t(0));
        c.finish_init(t(1));
        c.runtime.cache.put(
            "store",
            "m",
            1,
            100.0,
            SimDuration::from_secs(60),
            t(1),
        );
        assert_eq!(c.runtime.cache.len(), 1);
        c.evict();
        assert_eq!(c.state, ContainerState::Evicted);
        assert!(c.function.is_none());
        assert_eq!(c.runtime.cache.len(), 0);
    }

    #[test]
    fn reuse_generation_tracks_idle_exits() {
        let [f] = ids(&["f"])[..] else { unreachable!() };
        let mut c = Container::new(0, 0, t(0));
        let g0 = c.reuse_gen;
        c.begin_cold_start(f, t(0));
        c.finish_init(t(1));
        let g1 = c.reuse_gen;
        assert!(g1 > g0, "cold start leaves a new generation");
        c.begin_run(t(2));
        assert!(c.reuse_gen > g1, "dispatch invalidates pending idle checks");
        c.finish_run(t(3));
        let g2 = c.reuse_gen;
        c.evict();
        assert!(c.reuse_gen > g2, "eviction invalidates pending idle checks");
        assert_eq!(c.charged_mb, 0);
        assert!(c.idle_timer.is_none());
    }

    #[test]
    fn incarnation_moves_only_on_reclaim() {
        let [f, f2, g] = ids(&["f", "f2", "g"])[..] else {
            unreachable!()
        };
        let mut c = Container::new(0, 0, t(0));
        assert_eq!(c.incarnation, 0);
        c.begin_cold_start(f, t(0));
        c.finish_init(t(1));
        c.begin_run(t(2));
        c.finish_run(t(3));
        assert_eq!(c.incarnation, 0, "dispatch never changes the incarnation");
        // A per-app re-init repoints the slot at a sibling function —
        // a reclaim from a freshen run's point of view.
        c.reinit_for(f2, t(4));
        assert_eq!(c.incarnation, 1);
        c.evict();
        assert_eq!(c.incarnation, 2);
        // A recycled slot is a NEW incarnation: anything stamped with the
        // old one (an in-flight freshen run) is recognizably stale.
        c.begin_cold_start(g, t(5));
        assert_eq!(c.incarnation, 2);
        c.evict();
        assert_eq!(c.incarnation, 3);
    }

    #[test]
    fn snapshot_restore_lifecycle() {
        let [f] = ids(&["f"])[..] else { unreachable!() };
        let mut c = Container::new(0, 0, t(0));
        c.begin_cold_start(f, t(0));
        c.finish_init(t(1));
        c.runtime
            .cache
            .put("store", "m", 1, 100.0, SimDuration::from_secs(60), t(1));
        let inc = c.incarnation;
        let g = c.reuse_gen;
        c.snapshot(t(2));
        assert_eq!(c.state, ContainerState::Snapshotted);
        assert!(c.snapshot_for(f));
        assert!(!c.warm_for(f), "a snapshot is not dispatchable");
        assert!(c.reuse_gen > g, "demotion invalidates pending idle checks");
        assert_eq!(c.incarnation, inc, "a snapshot is suspension, not reclaim");
        c.begin_restore(t(3));
        assert_eq!(c.state, ContainerState::Initializing);
        assert!(c.runtime.connections.is_empty(), "sockets die across a snapshot");
        c.finish_init(t(4));
        assert!(c.warm_for(f));
        assert_eq!(c.runtime.cache.len(), 1, "cached state pages back in");
        assert_eq!(c.incarnation, inc, "restore keeps the incarnation");
        // A parked snapshot is still pressure-evictable.
        c.snapshot(t(5));
        c.evict();
        assert_eq!(c.state, ContainerState::Evicted);
        assert!(c.incarnation > inc, "eviction is the reclaim");
    }

    #[test]
    fn idle_tracking() {
        let [f] = ids(&["f"])[..] else { unreachable!() };
        let mut c = Container::new(0, 0, t(0));
        c.begin_cold_start(f, t(0));
        c.finish_init(t(1));
        assert_eq!(c.idle_for(t(11)), SimDuration::from_secs(10));
    }
}
