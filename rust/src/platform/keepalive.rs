//! Pluggable container keep-alive: who decides when warm state dies.
//!
//! The executor used to hard-code one answer — a fixed idle TTL scheduled
//! inline in `exec.rs`, plus an LRU steal when container sharing was on.
//! [`KeepAlivePolicy`] extracts both decision points behind a trait:
//!
//! - **idle**: a container just went Warm with an empty queue. The policy
//!   says when to check on it ([`KeepAlivePolicy::idle_check_after`]) and,
//!   when the check fires, whether to evict, keep, or re-check later
//!   ([`KeepAlivePolicy::idle_verdict`]).
//! - **pressure**: a cold start found no free memory. The policy says
//!   whether reclaiming warm containers is allowed at all
//!   ([`KeepAlivePolicy::evicts_under_pressure`]) and which victim dies
//!   ([`KeepAlivePolicy::pressure_victim`]).
//!
//! Three implementations reproduce the design space the lifecycle-control
//! literature compares (SPES, slot-survival prediction):
//!
//! - [`FixedTtl`] — evict after `config.idle_eviction` of idleness;
//!   pressure reclaim only when `allow_container_sharing` is on. This is
//!   byte-identical to the historical inline behavior and is the default.
//! - [`LruPressure`] — never evict on idle; reclaim the LRU warm
//!   container only when memory pressure demands it.
//! - [`HybridHistogram`] — per-function keep-alive windows derived from
//!   the IAT [`HistogramPredictor`]: predictable functions stay warm
//!   until just past their predicted next arrival (even beyond the fixed
//!   TTL), unpredictable ones are retired after a short fallback TTL,
//!   and pressure reclaims LRU. Pre-warming ahead of the predicted
//!   arrival rides the existing freshen/prediction path; this policy
//!   contributes the survival half of the window.
//!
//! Policies are stateless (per-function state lives in the predictor),
//! so the world holds one `Rc<dyn KeepAlivePolicy>` shared by every
//! decision site.
//!
//! A policy only decides WHO dies; what happens to the memory it frees —
//! which queued invocation(s) get retried, and in what order — is the
//! dispatch subsystem's job ([`crate::platform::dispatch`]).

use std::rc::Rc;

use crate::platform::container::{Container, ContainerId, ContainerState};
use crate::platform::symbols::Symbols;
use crate::predict::histogram::HistogramPredictor;
use crate::util::config::{Config, KeepAliveKind};
use crate::util::time::{SimDuration, SimTime};

/// Everything an idle decision may consult. Narrow borrows (not
/// `&World`) so the executor can hold the policy and the context at once.
pub struct IdleCtx<'a> {
    pub now: SimTime,
    pub container: &'a Container,
    pub config: &'a Config,
    pub hist_pred: &'a HistogramPredictor,
    /// Resolves the container's interned function id back to its name for
    /// the (name-keyed) predictor.
    pub symbols: &'a Symbols,
}

/// Outcome of a fired idle check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IdleVerdict {
    /// Retire the container now.
    Evict,
    /// Leave it warm with no further checks (a later release re-arms).
    Keep,
    /// Leave it warm and check again after this delay.
    Recheck(SimDuration),
    /// Demote the container to the snapshotted state instead of killing
    /// it: its memory charge drops to the discounted snapshot fraction
    /// and the next arrival restores it (base + page-in) instead of
    /// paying a full cold start. Only issued when
    /// `Config::snapshot.enabled`; with the axis off every policy falls
    /// back to [`IdleVerdict::Evict`] and legacy behavior is untouched.
    Snapshot,
}

/// The verdict for a container whose keep-alive window has closed: evict
/// when the snapshot mitigation is off (legacy behavior, byte-identical),
/// demote to a snapshot when it is on. Shared by every policy so the
/// gate lives in exactly one place.
fn retire_verdict(ctx: &IdleCtx) -> IdleVerdict {
    if ctx.config.snapshot.enabled {
        IdleVerdict::Snapshot
    } else {
        IdleVerdict::Evict
    }
}

/// A container keep-alive policy (see module docs).
pub trait KeepAlivePolicy {
    /// Stable identifier (reports, CLI echo).
    fn name(&self) -> &'static str;

    /// Delay until the idle check for a container that just went idle;
    /// `None` schedules no check (the container lives until pressure).
    fn idle_check_after(&self, ctx: &IdleCtx) -> Option<SimDuration>;

    /// Decide the fate of a still-idle container when its check fires.
    fn idle_verdict(&self, ctx: &IdleCtx) -> IdleVerdict;

    /// May a failed admission reclaim warm containers?
    fn evicts_under_pressure(&self, config: &Config) -> bool;

    /// Pick the pressure victim among resident containers whose host can
    /// still make room (`host_ok[invoker]`); default: LRU warm — §2
    /// [13]'s repurposing rule. Under uniform accounting every host with
    /// a warm container is eligible, so this matches the historical
    /// global-LRU steal exactly.
    fn pressure_victim(
        &self,
        containers: &[Container],
        host_ok: &[bool],
    ) -> Option<ContainerId> {
        lru_warm_victim(containers, host_ok)
    }
}

/// The least-recently-used warm container on an eligible host, if any.
/// `last_used` ties break on container id as an EXPLICIT secondary key:
/// the historical scan got lowest-id-wins implicitly from `min_by_key`'s
/// first-minimum rule over the container vec, but that coupling would
/// silently depend on allocation order the moment anything (heterogeneous
/// host classes, a future slab re-layout) reorders the vec.
pub fn lru_warm_victim(containers: &[Container], host_ok: &[bool]) -> Option<ContainerId> {
    containers
        .iter()
        .filter(|c| {
            c.state == ContainerState::Warm && host_ok.get(c.invoker).copied().unwrap_or(false)
        })
        .min_by_key(|c| (c.last_used, c.id))
        .map(|c| c.id)
}

/// The least-recently-used SNAPSHOTTED container on an eligible host, if
/// any — the pressure path's preferred victim when the snapshot axis is
/// on: a parked image's restore is far cheaper to re-pay than the full
/// cold start a warm kill forces, so snapshots are the cheapest memory
/// on the cluster. Same explicit `(last_used, id)` tie-break as
/// [`lru_warm_victim`]. Legacy runs hold no snapshotted containers, so
/// this is `None` and the policy's warm choice is untouched.
pub fn snapshot_lru_victim(containers: &[Container], host_ok: &[bool]) -> Option<ContainerId> {
    containers
        .iter()
        .filter(|c| {
            c.state == ContainerState::Snapshotted
                && host_ok.get(c.invoker).copied().unwrap_or(false)
        })
        .min_by_key(|c| (c.last_used, c.id))
        .map(|c| c.id)
}

/// Build the policy a [`KeepAliveKind`] names.
pub fn build(kind: KeepAliveKind) -> Rc<dyn KeepAlivePolicy> {
    match kind {
        KeepAliveKind::FixedTtl => Rc::new(FixedTtl),
        KeepAliveKind::LruPressure => Rc::new(LruPressure),
        KeepAliveKind::HybridHistogram => Rc::new(HybridHistogram::default()),
    }
}

// ====================================================================
// FixedTtl
// ====================================================================

/// Evict after a fixed idle TTL (`config.idle_eviction`); reclaim under
/// pressure only when the platform allows container sharing. Byte-
/// identical to the pre-trait inline executor logic (regression-tested in
/// `tests/keepalive_policies.rs`).
#[derive(Debug, Clone, Copy, Default)]
pub struct FixedTtl;

impl KeepAlivePolicy for FixedTtl {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn idle_check_after(&self, ctx: &IdleCtx) -> Option<SimDuration> {
        Some(ctx.config.idle_eviction)
    }

    fn idle_verdict(&self, ctx: &IdleCtx) -> IdleVerdict {
        if ctx.container.idle_for(ctx.now) >= ctx.config.idle_eviction {
            retire_verdict(ctx)
        } else {
            IdleVerdict::Keep
        }
    }

    fn evicts_under_pressure(&self, config: &Config) -> bool {
        config.allow_container_sharing
    }
}

// ====================================================================
// LruPressure
// ====================================================================

/// Keep warm containers forever; evict the LRU one only when a cold
/// start needs the memory. Maximizes warm hits at low load, pays the
/// warm-kill cost only when the cluster is genuinely full.
#[derive(Debug, Clone, Copy, Default)]
pub struct LruPressure;

impl KeepAlivePolicy for LruPressure {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn idle_check_after(&self, _ctx: &IdleCtx) -> Option<SimDuration> {
        None
    }

    fn idle_verdict(&self, _ctx: &IdleCtx) -> IdleVerdict {
        IdleVerdict::Keep
    }

    fn evicts_under_pressure(&self, _config: &Config) -> bool {
        true
    }
}

// ====================================================================
// HybridHistogram
// ====================================================================

/// Prediction-driven keep-alive windows (slot-survival style): keep a
/// container warm until just past its function's predicted next arrival;
/// fall back to a short TTL when the IAT history is absent or too
/// scattered to trust. Pressure reclaims LRU.
#[derive(Debug, Clone, Copy)]
pub struct HybridHistogram {
    /// Minimum predictor confidence to trust a window.
    pub min_confidence: f64,
    /// Slack past the predicted arrival before declaring it missed.
    pub grace: SimDuration,
    /// TTL for functions without a trustworthy prediction.
    pub fallback_ttl: SimDuration,
    /// Hard cap on any single keep-alive window.
    pub max_window: SimDuration,
}

impl Default for HybridHistogram {
    fn default() -> HybridHistogram {
        HybridHistogram {
            min_confidence: 0.2,
            grace: SimDuration::from_secs(10),
            fallback_ttl: SimDuration::from_secs(60),
            // The IAT histogram spans an hour; windows never exceed it.
            max_window: SimDuration::from_secs(3600),
        }
    }
}

impl HybridHistogram {
    /// The keep-alive window for the container's function as seen from
    /// `ctx.now`: predicted-IAT remainder + grace, or the fallback TTL.
    /// `None` means the prediction window has already closed.
    fn window(&self, ctx: &IdleCtx) -> Option<SimDuration> {
        let function = ctx.container.function?;
        match ctx.hist_pred.predict_next(ctx.symbols.resolve(function), ctx.now) {
            Some(p) if p.confidence >= self.min_confidence => {
                if p.expected_at > ctx.now {
                    Some((p.expected_at.since(ctx.now) + self.grace).min(self.max_window))
                } else {
                    // The modal arrival is already due ("imminent"); the
                    // grace we would grant has effectively been spent by
                    // the time a verdict fires, so the window is closed.
                    None
                }
            }
            _ => Some(self.fallback_ttl),
        }
    }
}

impl KeepAlivePolicy for HybridHistogram {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn idle_check_after(&self, ctx: &IdleCtx) -> Option<SimDuration> {
        // At release time even a closed window gets the grace period: the
        // predicted arrival may be microseconds away.
        Some(self.window(ctx).unwrap_or(self.grace).max(SimDuration::from_secs(1)))
    }

    fn idle_verdict(&self, ctx: &IdleCtx) -> IdleVerdict {
        match self.window(ctx) {
            // A live prediction window extends the container's life —
            // re-check at its end rather than holding the TTL fixed.
            Some(w) if ctx.container.idle_for(ctx.now) < w => {
                IdleVerdict::Recheck(w.max(SimDuration::from_secs(1)))
            }
            _ => retire_verdict(ctx),
        }
    }

    fn evicts_under_pressure(&self, _config: &Config) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: u64) -> SimTime {
        SimTime(s * 1_000_000)
    }

    /// One shared intern table per test; names interned on demand.
    fn warm_container(
        syms: &mut Symbols,
        id: ContainerId,
        function: &str,
        last_used: SimTime,
    ) -> Container {
        let f = syms.intern(function);
        let mut c = Container::new(id, 0, SimTime::ZERO);
        c.begin_cold_start(f, SimTime::ZERO);
        c.finish_init(SimTime::ZERO);
        c.last_used = last_used;
        c
    }

    fn ctx<'a>(
        now: SimTime,
        container: &'a Container,
        config: &'a Config,
        hist: &'a HistogramPredictor,
        syms: &'a Symbols,
    ) -> IdleCtx<'a> {
        IdleCtx {
            now,
            container,
            config,
            hist_pred: hist,
            symbols: syms,
        }
    }

    #[test]
    fn fixed_ttl_matches_legacy_constants() {
        let cfg = Config::default();
        let hist = HistogramPredictor::new();
        let mut syms = Symbols::new();
        let c = warm_container(&mut syms, 0, "f", t(0));
        let p = FixedTtl;
        let cx = ctx(t(0), &c, &cfg, &hist, &syms);
        assert_eq!(p.idle_check_after(&cx), Some(cfg.idle_eviction));
        // Exactly at the TTL: evict (the legacy closure used `>=`).
        let cx = ctx(SimTime::ZERO + cfg.idle_eviction, &c, &cfg, &hist, &syms);
        assert_eq!(p.idle_verdict(&cx), IdleVerdict::Evict);
        // A container reused since the check was scheduled is kept.
        let cx = ctx(t(1), &c, &cfg, &hist, &syms);
        assert_eq!(p.idle_verdict(&cx), IdleVerdict::Keep);
        // Pressure reclaim is gated on the sharing switch, like the old
        // `steal_lru_warm` call site.
        assert!(!p.evicts_under_pressure(&cfg));
        let mut sharing = cfg.clone();
        sharing.allow_container_sharing = true;
        assert!(p.evicts_under_pressure(&sharing));
    }

    #[test]
    fn pressure_victim_is_lru_warm_with_stable_ties() {
        let ok = [true];
        let mut syms = Symbols::new();
        let a = warm_container(&mut syms, 0, "a", t(30));
        let b = warm_container(&mut syms, 1, "b", t(10));
        let mut busy = warm_container(&mut syms, 2, "c", t(1));
        busy.begin_run(t(40)); // busy containers are never victims
        let d = warm_container(&mut syms, 3, "d", t(10)); // ties with b -> lower id wins
        let pool = vec![a, b, busy, d];
        assert_eq!(lru_warm_victim(&pool, &ok), Some(1));
        // Hosts that cannot make room are excluded entirely.
        assert_eq!(lru_warm_victim(&pool, &[false]), None);
        // All-busy pools have no victim.
        let mut all_busy = pool;
        for c in &mut all_busy {
            if c.state == ContainerState::Warm {
                c.begin_run(t(50));
            }
        }
        assert_eq!(lru_warm_victim(&all_busy, &ok), None);
    }

    #[test]
    fn lru_pressure_never_times_out_but_always_reclaims() {
        let cfg = Config::default();
        let hist = HistogramPredictor::new();
        let mut syms = Symbols::new();
        let c = warm_container(&mut syms, 0, "f", t(0));
        let p = LruPressure;
        let cx = ctx(t(100_000), &c, &cfg, &hist, &syms);
        assert_eq!(p.idle_check_after(&cx), None);
        assert_eq!(p.idle_verdict(&cx), IdleVerdict::Keep);
        assert!(p.evicts_under_pressure(&cfg), "pressure reclaim is unconditional");
    }

    #[test]
    fn hybrid_window_tracks_the_predictor() {
        let cfg = Config::default();
        let p = HybridHistogram::default();
        // Periodic function: 20 arrivals every 60 s.
        let mut hist = HistogramPredictor::new();
        for i in 0..20 {
            hist.observe("cron", t(i * 60));
        }
        let mut syms = Symbols::new();
        let c = warm_container(&mut syms, 0, "cron", t(19 * 60));
        let cx = ctx(t(19 * 60), &c, &cfg, &hist, &syms);
        let w = p.idle_check_after(&cx).unwrap();
        // Window ~= modal IAT (60 s +/- half a 15 s bin) + 10 s grace.
        assert!(
            w >= SimDuration::from_secs(55) && w <= SimDuration::from_secs(85),
            "window {w}"
        );
        // While the window is open the verdict extends, after it closes
        // (prediction missed) the verdict evicts.
        assert!(matches!(p.idle_verdict(&cx), IdleVerdict::Recheck(_)));
        let cx = ctx(t(19 * 60 + 120), &c, &cfg, &hist, &syms);
        assert_eq!(p.idle_verdict(&cx), IdleVerdict::Evict);
        // Unknown functions get the short fallback TTL, far below the
        // fixed policy's 600 s.
        let unknown = warm_container(&mut syms, 1, "ghost", t(0));
        let cx = ctx(t(0), &unknown, &cfg, &hist, &syms);
        assert_eq!(p.idle_check_after(&cx), Some(p.fallback_ttl));
        assert!(p.fallback_ttl < cfg.idle_eviction);
    }

    #[test]
    fn build_maps_kinds_to_policies() {
        for kind in KeepAliveKind::all() {
            let policy = build(kind);
            assert_eq!(policy.name(), kind.as_str());
        }
    }

    /// With `snapshot.enabled` every retire-the-container verdict becomes
    /// Snapshot; Keep/Recheck verdicts are untouched, and with the axis
    /// off the verdicts are the legacy Evict — the mitigation flips
    /// exactly one decision.
    #[test]
    fn snapshot_axis_turns_evictions_into_demotions() {
        let mut cfg = Config::default();
        let hist = HistogramPredictor::new();
        let mut syms = Symbols::new();
        let c = warm_container(&mut syms, 0, "f", t(0));

        let fixed = FixedTtl;
        let expired = SimTime::ZERO + cfg.idle_eviction;
        let cx = ctx(expired, &c, &cfg, &hist, &syms);
        assert_eq!(fixed.idle_verdict(&cx), IdleVerdict::Evict);
        cfg.snapshot.enabled = true;
        let cx = ctx(expired, &c, &cfg, &hist, &syms);
        assert_eq!(fixed.idle_verdict(&cx), IdleVerdict::Snapshot);
        // A recently-used container is still kept, not snapshotted.
        let cx = ctx(t(1), &c, &cfg, &hist, &syms);
        assert_eq!(fixed.idle_verdict(&cx), IdleVerdict::Keep);

        // Hybrid: a closed prediction window demotes instead of evicting.
        let hybrid = HybridHistogram::default();
        let late = SimTime::ZERO + hybrid.fallback_ttl + SimDuration::from_secs(1);
        let cx = ctx(late, &c, &cfg, &hist, &syms);
        assert_eq!(hybrid.idle_verdict(&cx), IdleVerdict::Snapshot);
        cfg.snapshot.enabled = false;
        let cx = ctx(late, &c, &cfg, &hist, &syms);
        assert_eq!(hybrid.idle_verdict(&cx), IdleVerdict::Evict);

        // LruPressure never idle-retires, so the axis changes nothing.
        cfg.snapshot.enabled = true;
        let cx = ctx(t(100_000), &c, &cfg, &hist, &syms);
        assert_eq!(LruPressure.idle_verdict(&cx), IdleVerdict::Keep);
    }
}
