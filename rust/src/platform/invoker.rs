//! Invoker hosts: per-host container pools.
//!
//! OpenWhisk's controller dispatches activations to *invokers*, each of
//! which manages a bounded pool of containers. We model the pool bound
//! (memory pressure is the reason container resources are limited and
//! sharing policies matter, §2 [13]).

use crate::platform::container::ContainerId;

/// One invoker host.
#[derive(Debug, Clone)]
pub struct Invoker {
    pub id: usize,
    /// Containers resident on this host (indices into the world table).
    pub containers: Vec<ContainerId>,
    /// Maximum resident containers.
    pub capacity: usize,
}

impl Invoker {
    pub fn new(id: usize, capacity: usize) -> Invoker {
        Invoker {
            id,
            containers: Vec::new(),
            capacity,
        }
    }

    pub fn has_capacity(&self) -> bool {
        self.containers.len() < self.capacity
    }

    pub fn occupancy(&self) -> usize {
        self.containers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_accounting() {
        let mut inv = Invoker::new(0, 2);
        assert!(inv.has_capacity());
        inv.containers.push(0);
        inv.containers.push(1);
        assert!(!inv.has_capacity());
        assert_eq!(inv.occupancy(), 2);
    }
}
