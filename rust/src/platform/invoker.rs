//! Invoker hosts: per-host, memory-accounted container pools.
//!
//! OpenWhisk's controller dispatches activations to *invokers*, each of
//! which manages a bounded pool of containers. The bound is **memory**:
//! a host has `capacity_mb` of container memory, and every resident
//! container charges it (memory pressure is the reason container
//! resources are limited and sharing policies matter, §2 [13]).
//!
//! Under [`MemoryAccounting::UniformSlot`] every container charges one
//! uniform 256 MB slot, which makes the MB bound arithmetically identical
//! to the historical `containers_per_invoker` count bound. Under
//! [`MemoryAccounting::FunctionMb`] a container charges its function's
//! declared `memory_mb`, so a 4 GB model server really does displace
//! sixteen 256 MB lambdas.
//!
//! [`MemoryAccounting::UniformSlot`]: crate::util::config::MemoryAccounting
//! [`MemoryAccounting::FunctionMb`]: crate::util::config::MemoryAccounting

use crate::platform::container::ContainerId;

/// One invoker host.
#[derive(Debug, Clone)]
pub struct Invoker {
    pub id: usize,
    /// Index into `Config::host_classes` (0 on a homogeneous cluster,
    /// where no classes are declared). Drives per-class cold-start
    /// multipliers, network profiles, and label-constrained placement.
    pub class: usize,
    /// Containers resident on this host (indices into the world table).
    pub containers: Vec<ContainerId>,
    /// Memory capacity, MB.
    pub capacity_mb: u64,
    /// Memory charged by live (non-evicted) containers, MB.
    pub used_mb: u64,
}

impl Invoker {
    pub fn new(id: usize, capacity_mb: u64) -> Invoker {
        Invoker::new_in_class(id, 0, capacity_mb)
    }

    pub fn new_in_class(id: usize, class: usize, capacity_mb: u64) -> Invoker {
        Invoker {
            id,
            class,
            containers: Vec::new(),
            capacity_mb,
            used_mb: 0,
        }
    }

    /// Free memory, MB.
    pub fn free_mb(&self) -> u64 {
        self.capacity_mb.saturating_sub(self.used_mb)
    }

    /// Can this host charge another `mb` of container memory?
    pub fn has_room(&self, mb: u64) -> bool {
        self.free_mb() >= mb
    }

    /// Could this host EVER admit `mb`, were every container evicted?
    /// The pressure path refuses requests no host can satisfy (they
    /// queue instead of cannibalising warm state they can't use).
    pub fn feasible(&self, mb: u64) -> bool {
        self.capacity_mb >= mb
    }

    /// Charge `mb` against the host (a container cold-starting here).
    /// May transiently exceed capacity only through re-init recharges;
    /// plain admission always checks [`Invoker::has_room`] first.
    pub fn charge(&mut self, mb: u64) {
        self.used_mb = self.used_mb.saturating_add(mb);
    }

    /// Release `mb` back to the host (a container evicted). Releasing more
    /// than is charged is always a caller bug (a double release, or a
    /// charge/release pairing gone wrong): debug builds fail loudly; release
    /// builds saturate to zero so accounting can never go negative.
    pub fn release(&mut self, mb: u64) {
        debug_assert!(
            mb <= self.used_mb,
            "invoker {}: releasing {mb} MB with only {} MB charged (double release?)",
            self.id,
            self.used_mb
        );
        self.used_mb = self.used_mb.saturating_sub(mb);
    }

    /// Container slots ever created on this host (live + evicted).
    pub fn occupancy(&self) -> usize {
        self.containers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_accounting() {
        let mut inv = Invoker::new(0, 512);
        assert!(inv.has_room(512));
        inv.charge(256);
        assert_eq!(inv.free_mb(), 256);
        assert!(inv.has_room(256));
        assert!(!inv.has_room(257));
        inv.charge(256);
        assert!(!inv.has_room(1));
        inv.release(256);
        assert!(inv.has_room(256));
        // Exact charge/release pairing returns the host to empty.
        inv.release(256);
        assert_eq!(inv.used_mb, 0);
        assert_eq!(inv.free_mb(), 512);
        // Feasibility is about capacity, not current occupancy.
        inv.charge(512);
        assert!(inv.feasible(512));
        assert!(!inv.feasible(513));
    }

    /// The no-negative-accounting invariant: over-releasing is a caller bug
    /// and debug builds (the test profile) must refuse it loudly.
    #[test]
    #[should_panic(expected = "double release")]
    #[cfg(debug_assertions)]
    fn over_release_panics_in_debug() {
        let mut inv = Invoker::new(0, 512);
        inv.charge(256);
        inv.release(10_000);
    }
}
