//! The event-driven executor: function bodies, the freshen hook, and their
//! coordination through `fr_state`.
//!
//! This is where the paper's Figure 3 plays out. An invocation walks its
//! ops one event at a time; a freshen run walks its hook's actions
//! concurrently on the same container. Both sides use the wrapper decision
//! logic of Algorithms 4/5 ([`crate::freshen::wrappers`]): whoever touches
//! a resource first claims it (`Running`), the other side waits on the
//! resource's wait list or consumes the finished result.
//!
//! Entry points:
//! - [`invoke`] — submit an invocation (records arrival for predictors).
//! - [`start_freshen`] — launch a freshen run on a function's container
//!   (used by prediction admission, or directly by tests/examples).
//! - [`emit_prediction`] — gate a prediction and, if admitted, schedule
//!   the freshen and its accuracy-resolution bookkeeping.
//!
//! # Hot path
//!
//! The recurring timer shapes of the platform are enum-coded
//! ([`PlatformEvent`]): an op continuation, a body start, an idle check or
//! a freshen step is a small `Copy`-field variant stored inline on the
//! timing wheel — no `Box`, no vtable — while irregular shapes (network
//! completions with payloads, workload-layer events) keep the boxed-closure
//! escape hatch. Function names never travel on this path either: contexts,
//! events and spans carry interned [`FnId`]s, resolved back to names only
//! at observation boundaries (`registry.symbols`).

use crate::freshen::hooks::FreshenAction;
use crate::freshen::state::{Completer, FrResult};
use crate::freshen::wrappers::{fr_fetch_decision, fr_warm_decision, WrapperDecision};
use crate::metrics::{EvictionCause, InvocationRecord, StartKind};
use crate::obs::SpanKind;
use crate::netsim::tcp::{ConnState, TransferDirection};
use crate::netsim::warm::{warm_cwnd, WarmPolicy};
use crate::platform::container::{ContainerId, ContainerState, RuntimeEnv};
use crate::platform::dispatch::Waiting;
use crate::platform::endpoint::Endpoint;
use crate::platform::function::Op;
use crate::platform::keepalive::{IdleCtx, IdleVerdict};
use crate::platform::symbols::FnId;
use crate::platform::world::{
    FreshenRunCtx, InvocationCtx, InvocationId, PendingFreshenCharge, PlatformSim, World,
};
use crate::predict::confidence::DEFAULT_MATCH_WINDOW;
use crate::predict::Prediction;
use crate::simcore::{EventBody, EventFn, Sim};
use crate::util::rng::Rng;
use crate::util::time::{SimDuration, SimTime};

use crate::util::fxhash::FxHashMap;

/// Local (in-runtime) access to already-present data, e.g. a prefetched
/// object handed to the function: sub-millisecond runtime overhead.
const LOCAL_ACCESS: SimDuration = SimDuration(50);
/// Cost of committing a trigger request from inside a function.
const TRIGGER_COMMIT: SimDuration = SimDuration(2_000);
/// Request payload size for a `DataGet`.
const REQUEST_BYTES: f64 = 256.0;
/// Lead before a histogram-predicted invocation at which freshen starts.
const HIST_LEAD: SimDuration = SimDuration(500_000); // 500 ms

// ====================================================================
// Platform events
// ====================================================================

/// The platform's enum-coded event type.
///
/// Every recurring timer shape on the replay hot path is a plain variant —
/// stored inline on the timing wheel, zero heap allocations per event —
/// dispatched here in one `match`. Irregular shapes (transfer completions
/// carrying an [`FrResult`], wait-list wakeups, workload-layer snapshots)
/// go through [`PlatformEvent::Closure`], which `Sim::schedule` wraps via
/// [`EventBody::from_closure`], so closure call sites compile unchanged.
///
/// Firing order is pinned against the all-closures reference model by
/// `Sim::force_closures` (see the equivalence tests): both paths consume
/// one sequence number per schedule, so `(timestamp, seq)` order — and
/// therefore every digest — is identical.
pub enum PlatformEvent {
    /// A committed trigger fires: submit an invocation of `function`.
    Invoke { function: FnId },
    /// An op's latency elapsed: advance the invocation to its next op.
    Advance { inv: InvocationId },
    /// Dispatch cost paid: the runtime's `run` hook fires on `cid`.
    BeginBody {
        inv: InvocationId,
        cid: ContainerId,
        kind: StartKind,
    },
    /// Cold start finished: the container inits, then the body begins.
    ColdStartDone { inv: InvocationId, cid: ContainerId },
    /// Snapshot restore finished (base + page-in elapsed): the container
    /// re-inits and the body begins as a [`StartKind::Restored`] start.
    RestoreDone { inv: InvocationId, cid: ContainerId },
    /// Keep-alive idle check, stamped with the container's reuse
    /// generation at arm time (stale checks no-op).
    IdleCheck { cid: ContainerId, gen: u64 },
    /// Continue a freshen run at its (already-advanced) action cursor.
    FreshenStep { run: usize },
    /// A pre-provisioned freshen container finished its cold start.
    FreshenColdDone {
        function: FnId,
        cid: ContainerId,
        prediction_id: Option<u64>,
    },
    /// Trigger commit elapsed: gate the prediction and maybe freshen.
    EmitPrediction { pred: Prediction },
    /// Prediction deadline: resolve hit/miss, settle deferred charges.
    ResolvePrediction { pid: u64, function: FnId },
    /// Freshen lead time reached: launch the admitted run.
    StartFreshen {
        function: FnId,
        prediction_id: Option<u64>,
    },
    /// Escape hatch for irregular shapes (one boxed closure per event).
    Closure(EventFn<World, PlatformEvent>),
}

impl EventBody<World> for PlatformEvent {
    fn fire(self, sim: &mut Sim<World, PlatformEvent>, world: &mut World) {
        match self {
            PlatformEvent::Invoke { function } => {
                invoke_id(sim, world, function);
            }
            PlatformEvent::Advance { inv } => advance(sim, world, inv),
            PlatformEvent::BeginBody { inv, cid, kind } => begin_body(sim, world, inv, cid, kind),
            PlatformEvent::ColdStartDone { inv, cid } => {
                world.containers[cid].finish_init(sim.now());
                world.containers[cid].begin_run(sim.now());
                begin_body(sim, world, inv, cid, StartKind::Cold);
            }
            PlatformEvent::RestoreDone { inv, cid } => restore_done(sim, world, inv, cid),
            PlatformEvent::IdleCheck { cid, gen } => idle_check_fired(sim, world, cid, gen),
            PlatformEvent::FreshenStep { run } => step_freshen(sim, world, run),
            PlatformEvent::FreshenColdDone {
                function,
                cid,
                prediction_id,
            } => {
                world.containers[cid].finish_init(sim.now());
                let _ = launch_freshen_on(sim, world, function, cid, prediction_id);
            }
            PlatformEvent::EmitPrediction { pred } => {
                let now = sim.now();
                emit_prediction(sim, world, pred, now);
            }
            PlatformEvent::ResolvePrediction { pid, function } => {
                let now = sim.now();
                resolve_prediction(world, pid, function, now);
            }
            PlatformEvent::StartFreshen {
                function,
                prediction_id,
            } => {
                let _ = start_freshen_id(sim, world, function, prediction_id);
            }
            PlatformEvent::Closure(f) => f(sim, world),
        }
    }

    fn from_closure(f: EventFn<World, PlatformEvent>) -> PlatformEvent {
        PlatformEvent::Closure(f)
    }
}

// ====================================================================
// Invocation path
// ====================================================================

/// Submit an invocation of `function` now. Returns its id.
///
/// Name-keyed boundary: interns the name and delegates to [`invoke_id`]
/// (replay loops that pre-intern their trace's names skip this hash).
pub fn invoke(sim: &mut PlatformSim, world: &mut World, function: &str) -> InvocationId {
    let f = world.registry.symbols.intern(function);
    invoke_id(sim, world, f)
}

/// Submit an invocation of interned `function` now. Returns its id.
pub fn invoke_id(sim: &mut PlatformSim, world: &mut World, function: FnId) -> InvocationId {
    let now = sim.now();
    debug_assert!(
        world.registry.function_by_id(function).is_some(),
        "invoke of unknown function '{}'",
        world.registry.symbols.resolve(function)
    );
    // Arrival is a predictor observation and may confirm a prediction.
    // (The predictors are name-keyed observation boundaries: resolve is
    // an index into the intern table, not a hash.)
    world
        .hist_pred
        .observe(world.registry.symbols.resolve(function), now);
    world
        .tracker
        .on_arrival(world.registry.symbols.resolve(function), now);

    let id = world.invocations.insert_with(|id, seq| InvocationCtx {
        id,
        seq,
        function,
        container: None,
        enqueued_at: now,
        started_at: now,
        op_idx: 0,
        start_kind: StartKind::Warm,
        freshen_hits: 0,
        freshen_misses: 0,
        queued: false,
        done: false,
    });
    let seq = world.invocations[id].seq;
    world.obs.record(
        &world.registry.symbols,
        SpanKind::Arrival,
        function,
        seq,
        now,
        SimDuration::ZERO,
        0,
        0,
    );
    if world.metrics.windows.enabled {
        world
            .metrics
            .windows
            .on_arrival(world.registry.symbols.resolve(function), now.micros());
    }
    dispatch(sim, world, id);
    id
}

/// Route the invocation to a container (or queue it). Returns whether it
/// was placed (`false` = handed to the dispatch queue), so capacity
/// drains know when the freed memory is exhausted.
fn dispatch(sim: &mut PlatformSim, world: &mut World, inv: InvocationId) -> bool {
    let now = sim.now();
    let (function, seq) = {
        let ctx = &world.invocations[inv];
        (ctx.function, ctx.seq)
    };

    if let Some(cid) = world.find_warm(function) {
        // Warm start: reserve immediately, body begins after dispatch cost.
        note_queue_wait(world, inv, now);
        cancel_idle_timer(sim, world, cid);
        world.containers[cid].begin_run(now);
        let delay = world.config.warm_start;
        world.obs.record(
            &world.registry.symbols,
            SpanKind::WarmStart,
            function,
            seq,
            now,
            delay,
            cid as u64,
            0,
        );
        sim.schedule_event(
            delay,
            PlatformEvent::BeginBody {
                inv,
                cid,
                kind: StartKind::Warm,
            },
        );
        return true;
    }

    // Snapshot restore: a parked image of this exact function beats both
    // the sibling re-init (which keeps only app-scoped state) and the
    // full cold start. The re-charge back to the warm footprint must fit
    // the snapshot's host; when it doesn't, the snapshot stays parked and
    // the arrival falls through to the ordinary paths below. Gated on the
    // axis, so legacy runs never even scan for snapshots.
    if world.config.snapshot.enabled {
        if let Some(cid) = world.find_snapshot(function) {
            let full_mb = world.charge_for_function_id(function);
            if let Some(cost) = world.begin_restore(cid, full_mb, now) {
                note_queue_wait(world, inv, now);
                sim.schedule_event(cost, PlatformEvent::RestoreDone { inv, cid });
                return true;
            }
        }
    }

    // Per-app isolation (§6): a warm sibling container can be re-inited
    // for this function at a fraction of a cold start, keeping its
    // runtime-scoped connections and freshen cache.
    if world.config.isolation == crate::util::config::IsolationScope::PerApp {
        let app = world.registry.app_of_id(function);
        let sibling = world
            .containers
            .iter()
            .filter(|c| c.warm_for_app(app))
            .max_by_key(|c| c.last_used)
            .map(|c| c.id);
        if let Some(cid) = sibling {
            note_queue_wait(world, inv, now);
            cancel_idle_timer(sim, world, cid);
            world.containers[cid].reinit_for(function, now);
            let mb = world.charge_for_function_id(function);
            world.recharge_container(cid, mb, now);
            world.containers[cid].begin_run(now);
            world.metrics.reinits += 1;
            let delay = world.config.warm_start + world.config.cold_start.mul_f64(0.25);
            world.obs.record(
                &world.registry.symbols,
                SpanKind::Reinit,
                function,
                seq,
                now,
                delay,
                cid as u64,
                mb as u64,
            );
            sim.schedule_event(
                delay,
                PlatformEvent::BeginBody {
                    inv,
                    cid,
                    kind: StartKind::Warm,
                },
            );
            return true;
        }
    }

    // Cold start: charge the function's memory against the cluster; where
    // it lands is the placement strategy's call; when the cluster is
    // full, the keep-alive policy may reclaim warm containers.
    let mb = world.charge_for_function_id(function);
    let slot = world
        .acquire_slot_for(now, mb, function)
        .or_else(|| evict_for_pressure(sim, world, mb, now, function));

    if let Some(cid) = slot {
        note_queue_wait(world, inv, now);
        let app = world.registry.app_of_id(function);
        world.containers[cid].begin_cold_start_for_app(function, Some(app), now);
        let delay = world.cold_start_on(cid);
        world.obs.record(
            &world.registry.symbols,
            SpanKind::ColdStart,
            function,
            seq,
            now,
            delay,
            cid as u64,
            mb as u64,
        );
        sim.schedule_event(delay, PlatformEvent::ColdStartDone { inv, cid });
        return true;
    }

    // A charge NO host could ever admit must not queue: it would strand
    // forever (and under strict-FIFO drain head-of-line-block everything
    // behind it), so it is dropped explicitly and counted. "Admit" covers
    // both memory capacity and placement labels — a function whose
    // affinity labels exclude every capable host is just as stranded. The
    // legacy path let such requests queue silently; the drop only fires
    // where that path would have hung, so feasible workloads — including
    // every pinned digest — are byte-identical.
    if !world
        .invokers
        .iter()
        .any(|i| i.feasible(mb as u64) && world.placement_admits(function, i.id))
    {
        world.invocations[inv].done = true;
        world.metrics.dropped_infeasible += 1;
        world.obs.record(
            &world.registry.symbols,
            SpanKind::Drop,
            function,
            seq,
            now,
            SimDuration::ZERO,
            mb as u64,
            0,
        );
        return true; // terminally handled: nothing to retry later
    }

    // Cluster full: hand the invocation to the queue discipline. Failed
    // retries land here too, carrying their original arrival stamp so
    // seniority survives. Drained on container release / eviction.
    if !world.invocations[inv].queued {
        world.invocations[inv].queued = true;
        world.metrics.queued_total += 1;
    }
    let enqueued_at = world.invocations[inv].enqueued_at;
    world.dispatch.enqueue(
        Waiting {
            inv,
            seq,
            function,
            charge_mb: mb,
            enqueued_at,
        },
        &world.registry.symbols,
    );
    let depth = world.dispatch.len() as u64;
    world.metrics.queue_peak_depth = world.metrics.queue_peak_depth.max(depth);
    false
}

/// Record the queue wait an invocation paid, at placement time. Fresh
/// arrivals dispatch in their arrival event (zero wait); only retries of
/// queued work observe `now` past the arrival stamp.
fn note_queue_wait(world: &mut World, inv: InvocationId, now: SimTime) {
    let (seq, function, enqueued_at, queued) = {
        let ctx = &world.invocations[inv];
        (ctx.seq, ctx.function, ctx.enqueued_at, ctx.queued)
    };
    debug_assert!(
        now >= enqueued_at,
        "invocation {seq} placed before its arrival stamp (queue wait would underflow)"
    );
    let waited = now.since(enqueued_at).micros();
    if queued && waited > 0 {
        world.metrics.queue_wait_us = world.metrics.queue_wait_us.saturating_add(waited);
        world.metrics.queue_wait_max_us = world.metrics.queue_wait_max_us.max(waited);
        world.obs.record(
            &world.registry.symbols,
            SpanKind::Queue,
            function,
            seq,
            enqueued_at,
            SimDuration(waited),
            0,
            0,
        );
        if world.metrics.windows.enabled {
            world
                .metrics
                .windows
                .on_queue_wait(world.registry.symbols.resolve(function), waited);
        }
    }
}

/// Memory pressure: ask the keep-alive policy for warm victims until the
/// `mb` charge fits (one eviction per 256 MB slot under uniform
/// accounting — the historical LRU steal — possibly several small
/// containers for one heavy function under per-function accounting).
/// Victims only come from hosts that can actually make room (free +
/// reclaimable-warm memory covers the charge), so an oversized request
/// never cannibalises warm state it can't use; under uniform accounting
/// every warm container's host qualifies, preserving the legacy global
/// LRU choice. Returns the acquired slot, or `None` when the policy
/// forbids pressure eviction or no host can be made to fit.
///
/// NOTE: by default an in-flight freshen run on a reclaimed container
/// keeps stepping against the recycled slot (legacy semantics, kept for
/// the byte-identical default-path guarantee); prefetch staleness is
/// bounded by the version checks in `fr_fetch_decision`. Switching on
/// `Config::freshen_incarnation_guard` aborts such runs instead (see
/// [`abort_if_stale_freshen`]).
fn evict_for_pressure(
    sim: &mut PlatformSim,
    world: &mut World,
    mb: u32,
    now: SimTime,
    function: FnId,
) -> Option<ContainerId> {
    let policy = world.keep_alive.clone();
    if !policy.evicts_under_pressure(&world.config) {
        return None;
    }
    // Once a victim's host is chosen, later rounds stay on it while it
    // can still make room: the evictions then pay off on the host that
    // receives the container instead of scattering warm kills across the
    // cluster. (The first pick is still the policy's global choice, so
    // the uniform-slot steal — which always admits after one eviction —
    // is byte-identical to the historical global LRU.)
    let mut target: Option<usize> = None;
    loop {
        // Recompute host feasibility each round: a host qualifies if its
        // capacity admits the charge at all and evicting warm state could
        // actually free enough memory on it.
        let mut reclaimable = vec![0u64; world.invokers.len()];
        for c in &world.containers {
            if matches!(c.state, ContainerState::Warm | ContainerState::Snapshotted) {
                reclaimable[c.invoker] += c.charged_mb as u64;
            }
        }
        let host_ok: Vec<bool> = world
            .invokers
            .iter()
            .map(|inv| {
                inv.feasible(mb as u64)
                    && inv.free_mb() + reclaimable[inv.id] >= mb as u64
                    && world.placement_admits(function, inv.id)
            })
            .collect();
        let masked: Vec<bool> = match target {
            Some(t) if host_ok[t] => host_ok
                .iter()
                .enumerate()
                .map(|(i, &ok)| ok && i == t)
                .collect(),
            _ => {
                target = None;
                host_ok
            }
        };
        // Parked snapshots die before warm state: their restore is far
        // cheaper to re-pay than a full cold start, so they are the
        // cheapest memory on the cluster. No snapshots (every legacy
        // run) means this is a pure fall-through to the policy's choice.
        let victim = match crate::platform::keepalive::snapshot_lru_victim(
            &world.containers,
            &masked,
        )
        .or_else(|| policy.pressure_victim(&world.containers, &masked))
        {
            Some(v) => v,
            // The locked host ran dry without fitting: fall back to the
            // full feasible set next round.
            None if target.is_some() => {
                target = None;
                continue;
            }
            None => return None,
        };
        target = Some(world.containers[victim].invoker);
        cancel_idle_timer(sim, world, victim);
        world.evict_container(victim, EvictionCause::Pressure, now);
        if let Some(cid) = world.acquire_slot_for(now, mb, function) {
            return Some(cid);
        }
    }
}

/// Restore latency elapsed: the container re-inits (through the ordinary
/// `finish_init`) and the invocation's body begins as a Restored start.
/// The hybrid mitigation additionally launches the paper's freshen pass
/// on the freshly restored container: its connections died with the
/// snapshot (`begin_restore` cleared them) and its cached state may be
/// stale, which is exactly what the freshen hook repairs. The run is
/// launched like a developer-invoked freshen (no prediction to resolve)
/// and is incarnation-guard aware like every other run.
fn restore_done(sim: &mut PlatformSim, world: &mut World, inv: InvocationId, cid: ContainerId) {
    let now = sim.now();
    world.containers[cid].finish_init(now);
    world.containers[cid].begin_run(now);
    let function = world.invocations[inv].function;
    if world.config.snapshot.freshen_on_restore
        && world.config.freshen.enabled
        && !world
            .registry
            .hook_by_id(function)
            .map_or(true, |h| h.is_empty())
        && launch_freshen_on(sim, world, function, cid, None).is_some()
    {
        world.metrics.freshens_on_restore += 1;
    }
    begin_body(sim, world, inv, cid, StartKind::Restored);
}

/// The container is ours and the runtime's `run` hook fired: walk the ops.
fn begin_body(
    sim: &mut PlatformSim,
    world: &mut World,
    inv: InvocationId,
    cid: ContainerId,
    kind: StartKind,
) {
    let now = sim.now();
    let (function, seq) = {
        let ctx = &world.invocations[inv];
        (ctx.function, ctx.seq)
    };
    let (resource_count, prefetch_ttl) = {
        let spec = world.registry.function_by_id(function).expect("deployed");
        (
            spec.resource_count(),
            spec.prefetch_ttl.unwrap_or(world.config.freshen.default_ttl),
        )
    };
    {
        let ctx = &mut world.invocations[inv];
        ctx.container = Some(cid);
        ctx.started_at = now;
        ctx.start_kind = kind;
    }
    if world.obs.is_enabled() {
        // Host id in the low bits, placement-strategy code in the high
        // byte (legacy's code is 0, so default-axis spans are untouched).
        let host = world.containers[cid].invoker as u64
            | (world.config.placement.code() << 56);
        let charge = world.containers[cid].charged_mb as u64;
        world.obs.record(
            &world.registry.symbols,
            SpanKind::Placement,
            function,
            seq,
            now,
            SimDuration::ZERO,
            host,
            charge,
        );
    }
    // (Re)build fr_state for this cycle, keeping still-fresh results.
    world.containers[cid]
        .runtime
        .fr_state
        .ensure_len(resource_count, prefetch_ttl, now);
    step_op(sim, world, inv);
}

/// Execute the invocation's current op; schedules its own continuation.
fn step_op(sim: &mut PlatformSim, world: &mut World, inv: InvocationId) {
    let now = sim.now();
    let (function, seq, op_idx, cid) = {
        let ctx = &world.invocations[inv];
        (
            ctx.function,
            ctx.seq,
            ctx.op_idx,
            ctx.container.expect("dispatched"),
        )
    };
    // Rc handle: no per-step clone of op payloads (hot path; see §Perf).
    let spec = world.registry.function_rc_by_id(function).expect("deployed");
    if op_idx >= spec.ops.len() {
        finish_invocation(sim, world, inv);
        return;
    }
    // Freshen-resource index of the current op, allocation-free.
    let resource = if spec.ops[op_idx].endpoint().is_some() {
        Some(
            spec.ops[..op_idx]
                .iter()
                .filter(|o| o.endpoint().is_some())
                .count(),
        )
    } else {
        None
    };

    match &spec.ops[op_idx] {
        Op::Compute { duration } => {
            sim.schedule_event(*duration, PlatformEvent::Advance { inv });
        }
        Op::Infer { model, .. } => {
            let d = world.model_latency(model);
            sim.schedule_event(d, PlatformEvent::Advance { inv });
        }
        Op::InvokeNext { function: next, trigger } => {
            let trigger = *trigger;
            // Commit the trigger: the next function starts after the
            // trigger service's delay (Table 1), plus the inter-node hop
            // off this container's host (zero on homogeneous clusters)...
            let delay = trigger.sample_delay(&mut world.rng);
            let hop = world.chain_edge_delay(cid);
            let next_id = world.registry.symbols.intern(next);
            sim.schedule_event(
                TRIGGER_COMMIT + delay + hop,
                PlatformEvent::Invoke { function: next_id },
            );
            world.obs.record(
                &world.registry.symbols,
                SpanKind::ChainEdge,
                next_id,
                seq,
                now,
                TRIGGER_COMMIT + delay + hop,
                0,
                0,
            );
            // A deterministic edge: record follow-through for the
            // predictor's confidence model.
            world
                .chain_pred
                .observe_edge(world.registry.symbols.resolve(function), next, true);
            // ...and that same delay is freshen's prediction window: the
            // platform knows `next` is imminent the moment the trigger
            // commits (Figure 1).
            let pred = world.chain_pred.predict_successor(
                world.registry.symbols.resolve(function),
                next,
                trigger,
                now + TRIGGER_COMMIT,
            );
            sim.schedule_event(TRIGGER_COMMIT, PlatformEvent::EmitPrediction { pred });
            sim.schedule_event(TRIGGER_COMMIT, PlatformEvent::Advance { inv });
        }
        Op::InvokeBranch { branches, trigger } => {
            let trigger = *trigger;
            // Non-deterministic chain (§6): sample the successor (or no
            // successor when weights sum below 1). The platform does NOT
            // know the outcome ahead of time — it predicts from observed
            // branch frequencies, so some freshens are mispredictions the
            // owner pays for (the billing story of §3.3).
            let total: f64 = branches.iter().map(|(_, p)| *p).sum();
            let roll = world.rng.f64();
            let mut acc = 0.0;
            // Borrow the sampled name out of the spec (an owned `Rc`
            // handle) instead of cloning it per branch roll.
            let mut taken: Option<&str> = None;
            for (f, p) in branches.iter() {
                acc += p;
                if roll < acc {
                    taken = Some(f.as_str());
                    break;
                }
            }
            debug_assert!(total <= 1.0 + 1e-9, "branch weights exceed 1");
            // Observe every edge's follow-through.
            for (f, _) in branches.iter() {
                world.chain_pred.observe_edge(
                    world.registry.symbols.resolve(function),
                    f,
                    taken == Some(f.as_str()),
                );
            }
            if let Some(next) = taken {
                let delay = trigger.sample_delay(&mut world.rng);
                let hop = world.chain_edge_delay(cid);
                let next_id = world.registry.symbols.intern(next);
                sim.schedule_event(
                    TRIGGER_COMMIT + delay + hop,
                    PlatformEvent::Invoke { function: next_id },
                );
                world.obs.record(
                    &world.registry.symbols,
                    SpanKind::ChainEdge,
                    next_id,
                    seq,
                    now,
                    TRIGGER_COMMIT + delay + hop,
                    0,
                    0,
                );
            }
            // Predict (and maybe freshen) every plausible branch — the
            // learned branch confidence gates which ones are worth it.
            for (f, _) in branches.iter() {
                let pred = world.chain_pred.predict_successor(
                    world.registry.symbols.resolve(function),
                    f,
                    trigger,
                    now + TRIGGER_COMMIT,
                );
                sim.schedule_event(TRIGGER_COMMIT, PlatformEvent::EmitPrediction { pred });
            }
            sim.schedule_event(TRIGGER_COMMIT, PlatformEvent::Advance { inv });
        }
        Op::DataGet {
            endpoint,
            object_id,
            ..
        } => {
            let r = resource.expect("DataGet is a resource op");
            let obj = object_id
                .const_value()
                .map(str::to_string)
                // Param-derived ids resolve at run time; simulate with a
                // per-invocation unique key (never prefetchable). `seq`
                // is the legacy dense id, so the key bytes are unchanged.
                .unwrap_or_else(|| format!("param:{seq}"));
            exec_data_get(sim, world, inv, cid, r, endpoint.clone(), obj);
        }
        Op::DataPut {
            endpoint,
            object_id,
            bytes,
            ..
        } => {
            let r = resource.expect("DataPut is a resource op");
            let obj = object_id
                .const_value()
                .map(str::to_string)
                .unwrap_or_else(|| format!("param:{seq}"));
            exec_data_put(sim, world, inv, cid, r, endpoint.clone(), obj, *bytes);
        }
    }
}

fn advance(sim: &mut PlatformSim, world: &mut World, inv: InvocationId) {
    world.invocations[inv].op_idx += 1;
    step_op(sim, world, inv);
}

/// `FrFetch(r, DataGet(...))` — Algorithm 4 over the simulator substrate.
fn exec_data_get(
    sim: &mut PlatformSim,
    world: &mut World,
    inv: InvocationId,
    cid: ContainerId,
    r: usize,
    endpoint: String,
    object_id: String,
) {
    let now = sim.now();
    let live_version = if world.strict_versions {
        world
            .endpoints
            .get(&endpoint)
            .and_then(|e| e.store.peek(&object_id))
            .map(|o| o.version)
    } else {
        None
    };
    let entry = world.containers[cid]
        .runtime
        .fr_state
        .get_mut(r)
        .expect("fr_state sized in begin_body");
    match fr_fetch_decision(entry, now, live_version) {
        WrapperDecision::UseResult(FrResult::Data { bytes, .. }) => {
            // Freshen already fetched it: local handoff only.
            world.invocations[inv].freshen_hits += 1;
            let app = world
                .registry
                .app_of_id(world.invocations[inv].function);
            world
                .ledger
                .credit_network_saved(world.registry.symbols.resolve(app), bytes);
            sim.schedule_event(LOCAL_ACCESS, PlatformEvent::Advance { inv });
        }
        WrapperDecision::UseResult(_) => {
            // Defensive: a fetch resource finished without data (a
            // mis-authored developer hook could do this). The connection
            // may be warm but the data must still be fetched — do it,
            // without touching the entry.
            world.invocations[inv].freshen_misses += 1;
            let (d, result) = do_fetch(
                &mut world.endpoints,
                &mut world.rng,
                &mut world.containers[cid].runtime,
                &endpoint,
                &object_id,
                now,
            );
            charge_transfer(world, inv, &result);
            sim.schedule_event(d, PlatformEvent::Advance { inv });
        }
        WrapperDecision::Wait => {
            // FrWait: park until the freshen thread finishes this resource.
            world
                .fr_waiters
                .entry((cid, r))
                .or_default()
                .wait(move |sim, w| exec_retry_get(sim, w, inv));
        }
        WrapperDecision::DoItYourself => {
            world.invocations[inv].freshen_misses += 1;
            // Check the cross-invocation freshen cache before the network.
            let ttl = prefetch_ttl(world, inv);
            let cache_hit = world.containers[cid].runtime.cache.get(
                &endpoint,
                &object_id,
                now,
                live_version,
            );
            if let Some(cached) = cache_hit {
                let result = FrResult::Data {
                    object_id: object_id.clone(),
                    version: cached.version,
                    bytes: cached.bytes,
                };
                sim.schedule(LOCAL_ACCESS, move |sim, w| {
                    finish_resource(sim, w, cid, r, result.clone(), Completer::Function);
                    advance(sim, w, inv)
                });
                return;
            }
            // Real fetch over the (possibly cold/dead) connection.
            let (d, result) = do_fetch(
                &mut world.endpoints,
                &mut world.rng,
                &mut world.containers[cid].runtime,
                &endpoint,
                &object_id,
                now,
            );
            charge_transfer(world, inv, &result);
            let ep = endpoint.clone();
            sim.schedule(d, move |sim, w| {
                if let FrResult::Data { version, bytes, .. } = &result {
                    w.containers[cid].runtime.cache.put(
                        &ep, &object_id, *version, *bytes, ttl, sim.now(),
                    );
                }
                finish_resource(sim, w, cid, r, result.clone(), Completer::Function);
                advance(sim, w, inv)
            });
        }
    }
}

/// Re-entry after an `FrWait` on a fetch resource: the entry is now
/// finished; consume it (or redo on failure).
fn exec_retry_get(sim: &mut PlatformSim, world: &mut World, inv: InvocationId) {
    // Re-run the decision from scratch; the entry is Finished now, so this
    // lands in UseResult (or DoItYourself if the freshen failed).
    step_op(sim, world, inv);
}

/// `FrWarm(r, DataPut(...))` — Algorithm 5. The put itself always runs;
/// what freshen buys is a live, cwnd-warmed connection.
#[allow(clippy::too_many_arguments)]
fn exec_data_put(
    sim: &mut PlatformSim,
    world: &mut World,
    inv: InvocationId,
    cid: ContainerId,
    r: usize,
    endpoint: String,
    object_id: String,
    bytes: f64,
) {
    let now = sim.now();
    let entry = world.containers[cid]
        .runtime
        .fr_state
        .get_mut(r)
        .expect("fr_state sized");
    match fr_warm_decision(entry, now) {
        WrapperDecision::UseResult(_) => {
            world.invocations[inv].freshen_hits += 1;
            // Connection is live and warm: straight to the transfer.
            let d = do_put(
                &mut world.endpoints,
                &mut world.rng,
                &mut world.containers[cid].runtime,
                &endpoint,
                &object_id,
                bytes,
                now,
            );
            charge_bytes(world, inv, bytes);
            sim.schedule_event(d, PlatformEvent::Advance { inv });
        }
        WrapperDecision::Wait => {
            world
                .fr_waiters
                .entry((cid, r))
                .or_default()
                .wait(move |sim, w| step_op(sim, w, inv));
        }
        WrapperDecision::DoItYourself => {
            world.invocations[inv].freshen_misses += 1;
            let d = do_put(
                &mut world.endpoints,
                &mut world.rng,
                &mut world.containers[cid].runtime,
                &endpoint,
                &object_id,
                bytes,
                now,
            );
            charge_bytes(world, inv, bytes);
            sim.schedule(d, move |sim, w| {
                finish_resource(sim, w, cid, r, FrResult::Warmed, Completer::Function);
                advance(sim, w, inv)
            });
        }
    }
}

/// Complete `fr_state[(cid, r)]` and wake any parked waiters.
fn finish_resource(
    sim: &mut PlatformSim,
    world: &mut World,
    cid: ContainerId,
    r: usize,
    result: FrResult,
    by: Completer,
) {
    let now = sim.now();
    if let Some(entry) = world.containers[cid].runtime.fr_state.get_mut(r) {
        entry.finish(result, now, by);
    }
    if let Some(mut list) = world.fr_waiters.remove(&(cid, r)) {
        list.wake_all(sim);
    }
}

/// Invocation complete: metrics, billing, container release, queue drain
/// (the same-function fast path here; cross-function drains go through
/// [`redispatch_pending`] and the configured queue discipline).
fn finish_invocation(sim: &mut PlatformSim, world: &mut World, inv: InvocationId) {
    let now = sim.now();
    let (function, cid) = {
        let ctx = &mut world.invocations[inv];
        ctx.done = true;
        (ctx.function, ctx.container.expect("dispatched"))
    };
    let ctx = world.invocations[inv].clone();
    world.metrics.record(InvocationRecord {
        function: world.registry.symbols.resolve(function).to_string(),
        enqueued_at: ctx.enqueued_at,
        started_at: ctx.started_at,
        finished_at: now,
        start_kind: ctx.start_kind,
        freshen_hits: ctx.freshen_hits,
        freshen_misses: ctx.freshen_misses,
    });
    let cold = matches!(ctx.start_kind, StartKind::Cold);
    if world.obs.is_enabled() {
        world.obs.record(
            &world.registry.symbols,
            SpanKind::Exec,
            function,
            ctx.seq,
            ctx.started_at,
            now.since(ctx.started_at),
            ctx.freshen_hits as u64,
            ctx.freshen_misses as u64,
        );
        world.obs.record(
            &world.registry.symbols,
            SpanKind::Complete,
            function,
            ctx.seq,
            now,
            SimDuration::ZERO,
            now.since(ctx.enqueued_at).micros(),
            cold as u64,
        );
    }
    if world.metrics.windows.enabled {
        world.metrics.windows.on_complete(
            world.registry.symbols.resolve(function),
            cold,
            now.micros(),
        );
        if matches!(ctx.start_kind, StartKind::Restored) {
            world
                .metrics
                .windows
                .on_restore(world.registry.symbols.resolve(function));
        }
    }
    let (app, memory_mb) = {
        let spec = world.registry.function_by_id(function).expect("deployed");
        (world.registry.app_of_id(function), spec.memory_mb)
    };
    world.ledger.charge_execution(
        world.registry.symbols.resolve(app),
        memory_mb,
        now.since(ctx.started_at),
    );
    world.containers[cid].finish_run(now);
    // Terminal: no event references this handle anymore (continuations
    // are consumed, the queue never held a dispatched invocation). Under
    // recycling (replay) the slot returns to the free list; otherwise
    // this is a no-op and the context stays inspectable.
    world.invocations.release(inv);

    // Standalone-function prediction: after each completed invocation,
    // consult the IAT histogram and (if confident) pre-arm a freshen just
    // before the expected next arrival.
    if world.auto_hist_predict {
        if let Some(pred) = world
            .hist_pred
            .predict_next(world.registry.symbols.resolve(function), now)
        {
            let start_at =
                SimTime(pred.expected_at.micros().saturating_sub(HIST_LEAD.micros())).max(now);
            emit_prediction(sim, world, pred, start_at);
        }
    }

    // Drain this function's queue onto the now-warm container (every
    // discipline hands over its oldest queued invocation of `function`).
    if let Some(next) = world
        .dispatch
        .take_for_function(function, &world.registry.symbols)
    {
        note_queue_wait(world, next, now);
        cancel_idle_timer(sim, world, cid);
        world.containers[cid].begin_run(now);
        let delay = world.config.warm_start;
        sim.schedule_event(
            delay,
            PlatformEvent::BeginBody {
                inv: next,
                cid,
                kind: StartKind::Warm,
            },
        );
        return;
    }
    // Otherwise hand the idle container to the keep-alive policy. A
    // pressure-only policy arms no timer — and therefore would never
    // reach `redispatch_pending` through an idle eviction — so it gives
    // queued work of other functions its chance right now: the idle
    // container is exactly the reclaimable memory a queued cold start
    // needs. (Timer-based policies keep the historical behavior: queued
    // work waits for the eviction.)
    if !schedule_idle_check(sim, world, cid) {
        redispatch_pending(sim, world);
    }
}

// ====================================================================
// Keep-alive: policy-driven idle eviction
// ====================================================================

/// Cancel the container's pending idle check, if any. Called whenever
/// the container leaves the idle Warm state, so a hot container never
/// accumulates superseded no-op wheel events (it used to gather one per
/// release).
fn cancel_idle_timer(sim: &mut PlatformSim, world: &mut World, cid: ContainerId) {
    if let Some(ev) = world.containers[cid].idle_timer.take() {
        sim.cancel(ev);
    }
}

/// Ask the policy when to check on a container that just went idle, and
/// arm (or replace) its idle timer. The check event is stamped with the
/// container's reuse generation: a dispatch or eviction in the meantime
/// bumps the generation, turning any timer that escaped cancellation into
/// a guaranteed no-op. Returns whether a timer was armed (`false` for
/// pressure-only policies).
fn schedule_idle_check(sim: &mut PlatformSim, world: &mut World, cid: ContainerId) -> bool {
    let policy = world.keep_alive.clone();
    let delay = {
        let ctx = IdleCtx {
            now: sim.now(),
            container: &world.containers[cid],
            config: &world.config,
            hist_pred: &world.hist_pred,
            symbols: &world.registry.symbols,
        };
        policy.idle_check_after(&ctx)
    };
    let Some(delay) = delay else {
        return false; // pressure-only policy: no timer at all
    };
    cancel_idle_timer(sim, world, cid);
    arm_idle_check(sim, world, cid, delay);
    true
}

fn arm_idle_check(
    sim: &mut PlatformSim,
    world: &mut World,
    cid: ContainerId,
    delay: SimDuration,
) {
    let gen = world.containers[cid].reuse_gen;
    let ev = sim.schedule_event(delay, PlatformEvent::IdleCheck { cid, gen });
    world.containers[cid].idle_timer = Some(ev);
}

fn idle_check_fired(sim: &mut PlatformSim, world: &mut World, cid: ContainerId, gen: u64) {
    let now = sim.now();
    {
        let c = &mut world.containers[cid];
        // Stale: the container was dispatched, recycled or evicted since
        // this check was armed.
        if c.reuse_gen != gen || c.state != ContainerState::Warm {
            return;
        }
        c.idle_timer = None;
    }
    let policy = world.keep_alive.clone();
    let verdict = {
        let ctx = IdleCtx {
            now,
            container: &world.containers[cid],
            config: &world.config,
            hist_pred: &world.hist_pred,
            symbols: &world.registry.symbols,
        };
        policy.idle_verdict(&ctx)
    };
    match verdict {
        IdleVerdict::Evict => {
            world.evict_container(cid, EvictionCause::Idle, now);
            // The freed memory may unblock a queued invocation of another
            // function.
            redispatch_pending(sim, world);
        }
        IdleVerdict::Snapshot => {
            // Evict-to-snapshot: park the container at its discounted
            // charge. The released fraction is freed memory like any
            // eviction's, so queued work gets its retry.
            world.demote_to_snapshot(cid, now);
            redispatch_pending(sim, world);
        }
        IdleVerdict::Recheck(delay) => arm_idle_check(sim, world, cid, delay),
        IdleVerdict::Keep => {}
    }
}

/// Retry queued invocations now that capacity freed (an eviction, or a
/// release under a pressure-only policy). The discipline drives the
/// drain: `LegacyOneShot` retries exactly one candidate (the historical
/// behavior), `FifoFair`/`MemoryAware` keep going until a retry fails to
/// place — the freed memory is exhausted — or the queue empties. A failed
/// retry re-queues with its original seniority and is skipped for the
/// rest of the round, so the loop never spins: every iteration either
/// permanently removes a queue entry or grows the skip list, and the
/// discipline caps how many failures it tolerates.
fn redispatch_pending(sim: &mut PlatformSim, world: &mut World) {
    let mut failed: Vec<InvocationId> = Vec::new();
    loop {
        let Some(inv) = world.dispatch.next_candidate(sim.now(), &failed) else {
            return;
        };
        let placed = dispatch(sim, world, inv);
        if !world.dispatch.drains_until_full() {
            return;
        }
        if !placed {
            failed.push(inv);
            if !world.dispatch.retries_past_failure(failed.len()) {
                return;
            }
        }
    }
}

// ====================================================================
// Freshen path
// ====================================================================

/// Gate a prediction; when admitted, register it with the tracker (for
/// hit/miss billing) and schedule the freshen run at `start_at`.
pub fn emit_prediction(
    sim: &mut PlatformSim,
    world: &mut World,
    pred: Prediction,
    start_at: SimTime,
) {
    let now = sim.now();
    // A prediction names a deployed function, whose name was interned at
    // deploy: lookup (not intern) keeps stray predictions out of the table.
    let Some(function) = world.registry.symbols.lookup(&pred.function) else {
        return;
    };
    let Some(spec) = world.registry.function_by_id(function) else {
        return;
    };
    let category = spec.category;
    let app = world.registry.app_of_id(function);
    let decision = world.gate.should_freshen(
        world.registry.symbols.resolve(app),
        pred.confidence,
        category,
        now,
    );
    if !decision.admitted() {
        return;
    }
    let (pid, deadline) = world.tracker.register(
        &pred.function,
        world.registry.symbols.resolve(app),
        pred.expected_at,
        DEFAULT_MATCH_WINDOW,
    );
    if world.obs.is_enabled() {
        let lead = pred.expected_at.since(now);
        let conf_pm = (pred.confidence.clamp(0.0, 1.0) * 1000.0) as u64;
        world.obs.record(
            &world.registry.symbols,
            SpanKind::Prediction,
            function,
            pid,
            now,
            lead,
            conf_pm,
            0,
        );
    }
    if world.metrics.windows.enabled {
        world
            .metrics
            .windows
            .note_prediction(&pred.function, pred.expected_at.micros());
    }
    // Expiry resolution: hit/miss -> gate feedback + deferred billing.
    sim.schedule_event_at(deadline, PlatformEvent::ResolvePrediction { pid, function });
    let delay = start_at.since(now);
    sim.schedule_event(
        delay,
        PlatformEvent::StartFreshen {
            function,
            prediction_id: Some(pid),
        },
    );
    world.metrics.freshens_started += 1;
}

fn resolve_prediction(world: &mut World, pid: u64, function: FnId, now: SimTime) {
    let Some((app, hit)) = world.tracker.expire(pid) else {
        return;
    };
    world.gate.record_outcome(&app, hit);
    if !hit {
        world.metrics.freshens_wasted += 1;
        world.obs.record(
            &world.registry.symbols,
            SpanKind::FreshenWasted,
            function,
            pid,
            now,
            SimDuration::ZERO,
            0,
            0,
        );
        if world.metrics.windows.enabled {
            world
                .metrics
                .windows
                .on_wasted_freshen(world.registry.symbols.resolve(function));
        }
    }
    // Settle deferred freshen charges for this prediction.
    let mut settled = Vec::new();
    world.pending_charges.retain(|c| {
        if c.prediction_id == pid {
            settled.push(c.clone());
            false
        } else {
            true
        }
    });
    for c in settled {
        world.ledger.charge_freshen(
            world.registry.symbols.resolve(c.app),
            c.memory_mb,
            c.duration,
            hit,
        );
    }
}

/// Launch a freshen run for `function`. Picks a container holding the
/// function's runtime (warm or busy — the hook runs on a separate runtime
/// thread, §3.1); optionally pre-provisions one when none exists.
/// Returns the run id, or `None` when no container could be found/made.
///
/// Name-keyed boundary over [`start_freshen_id`].
pub fn start_freshen(
    sim: &mut PlatformSim,
    world: &mut World,
    function: &str,
    prediction_id: Option<u64>,
) -> Option<usize> {
    let f = world.registry.symbols.lookup(function)?;
    start_freshen_id(sim, world, f, prediction_id)
}

/// Launch a freshen run for interned `function` (see [`start_freshen`]).
pub fn start_freshen_id(
    sim: &mut PlatformSim,
    world: &mut World,
    function: FnId,
    prediction_id: Option<u64>,
) -> Option<usize> {
    let now = sim.now();
    if world
        .registry
        .hook_by_id(function)
        .map_or(true, |h| h.is_empty())
    {
        return None; // nothing to do (not inferrable — not fatal, §3.3)
    }
    // A container whose runtime holds this function, live or about to be.
    let existing = world
        .containers
        .iter()
        .find(|c| {
            c.function == Some(function)
                && matches!(c.state, ContainerState::Warm | ContainerState::Busy)
        })
        .map(|c| c.id);
    let cid = match existing {
        Some(cid) => cid,
        None => {
            // Pre-provision: freshen composes with cold-start avoidance.
            // (It never evicts anyone for the privilege — speculative work
            // only uses genuinely free memory.)
            let mb = world.charge_for_function_id(function);
            let cid = world.acquire_slot_for(now, mb, function)?;
            let app = world.registry.app_of_id(function);
            world.containers[cid].begin_cold_start_for_app(function, Some(app), now);
            let cold = world.cold_start_on(cid);
            sim.schedule_event(
                cold,
                PlatformEvent::FreshenColdDone {
                    function,
                    cid,
                    prediction_id,
                },
            );
            return Some(usize::MAX); // run id assigned at launch
        }
    };
    launch_freshen_on(sim, world, function, cid, prediction_id)
}

fn launch_freshen_on(
    sim: &mut PlatformSim,
    world: &mut World,
    function: FnId,
    cid: ContainerId,
    prediction_id: Option<u64>,
) -> Option<usize> {
    let now = sim.now();
    let resource_count = world.registry.function_by_id(function)?.resource_count();
    let ttl = prefetch_ttl_of(world, function);
    world.containers[cid]
        .runtime
        .fr_state
        .ensure_len(resource_count, ttl, now);
    let id = world.freshen_runs.len();
    world.freshen_runs.push(FreshenRunCtx {
        id,
        function,
        container: cid,
        incarnation: world.containers[cid].incarnation,
        action_idx: 0,
        started_at: now,
        prediction_id,
        done: false,
    });
    world.containers[cid].freshen_runs += 1;
    step_freshen(sim, world, id);
    Some(id)
}

/// Incarnation guard (`Config::freshen_incarnation_guard`): a freshen
/// run whose container was reclaimed since launch — the slot's
/// incarnation moved on — aborts instead of stepping against recycled
/// state. The aborted run bills nothing and completes nothing; the
/// prediction that admitted it still resolves on its own schedule.
/// Returns whether the run was aborted. With the guard off (the
/// default), stale runs keep the legacy keep-stepping semantics and
/// every historical digest holds.
fn abort_if_stale_freshen(world: &mut World, run: usize) -> bool {
    // Incarnations only move forward (evict/reinit bump the counter): a
    // slot observed at an OLDER incarnation than a run's launch stamp
    // means the monotone guard itself is broken.
    debug_assert!(
        world.containers[world.freshen_runs[run].container].incarnation
            >= world.freshen_runs[run].incarnation,
        "container incarnation moved backwards under freshen run {run}"
    );
    if !world.config.freshen_incarnation_guard {
        return false;
    }
    let ctx = &world.freshen_runs[run];
    if ctx.done || world.containers[ctx.container].incarnation == ctx.incarnation {
        return false;
    }
    world.freshen_runs[run].done = true;
    world.metrics.stale_freshen_aborts += 1;
    if world.obs.is_enabled() || world.metrics.windows.enabled {
        // No sim handle here: stamp the abort with the run's launch time
        // (the abort itself fires at an interior event of the run).
        let f = world.freshen_runs[run].function;
        let started = world.freshen_runs[run].started_at;
        let cid = world.freshen_runs[run].container as u64;
        world.obs.record(
            &world.registry.symbols,
            SpanKind::StaleAbort,
            f,
            run as u64,
            started,
            SimDuration::ZERO,
            cid,
            0,
        );
        if world.metrics.windows.enabled {
            world
                .metrics
                .windows
                .on_stale_abort(world.registry.symbols.resolve(f));
        }
    }
    true
}

/// Execute the freshen run's current action (Algorithm 2's body, one
/// action per event).
fn step_freshen(sim: &mut PlatformSim, world: &mut World, run: usize) {
    if abort_if_stale_freshen(world, run) {
        return;
    }
    let now = sim.now();
    let (function, cid, action_idx) = {
        let ctx = &world.freshen_runs[run];
        (ctx.function, ctx.container, ctx.action_idx)
    };
    let hook = world
        .registry
        .hook_by_id(function)
        .expect("hook exists")
        .clone();
    if action_idx >= hook.actions.len() {
        finish_freshen(sim, world, run);
        return;
    }
    let (r, action) = hook.actions[action_idx].clone();

    // `EnsureConnection` is a *preparatory* action: the connection object
    // itself carries the outcome (its liveness/state), and the same
    // resource index usually has a terminal action (Prefetch/WarmCwnd)
    // following it. It therefore must not claim or finish the fr_state
    // entry — doing so would mark a fetch resource "done" without data.
    if let FreshenAction::EnsureConnection { endpoint } = &action {
        let d = ensure_connection(
            &mut world.endpoints,
            &mut world.rng,
            &mut world.containers[cid].runtime,
            endpoint,
            now,
        );
        // Advance the cursor at schedule time: nothing reads it between
        // here and the step firing (the abort guard keys on done /
        // incarnation only), so the pre-bump is order-equivalent to the
        // old in-event bump — and the continuation is a plain variant.
        world.freshen_runs[run].action_idx += 1;
        sim.schedule_event(d, PlatformEvent::FreshenStep { run });
        return;
    }

    // Terminal actions claim the resource; if the function already claimed
    // or completed it (freshen is late — Figure 3 right), skip.
    let claimed = world.containers[cid]
        .runtime
        .fr_state
        .get_mut(r)
        .map(|e| e.try_start(now))
        .unwrap_or(false);
    if !claimed {
        world.freshen_runs[run].action_idx += 1;
        sim.schedule_event(SimDuration::ZERO, PlatformEvent::FreshenStep { run });
        return;
    }

    match action {
        FreshenAction::EnsureConnection { .. } => unreachable!("handled above"),
        FreshenAction::WarmCwnd {
            endpoint,
            direction,
            anticipated_bytes,
        } => {
            let d = do_warm_cwnd(
                &mut world.endpoints,
                &mut world.rng,
                &mut world.containers[cid].runtime,
                &endpoint,
                direction,
                anticipated_bytes,
                now,
            );
            sim.schedule(d, move |sim, w| {
                if abort_if_stale_freshen(w, run) {
                    return;
                }
                finish_resource(sim, w, cid, r, FrResult::Warmed, Completer::Freshen);
                w.freshen_runs[run].action_idx += 1;
                step_freshen(sim, w, run)
            });
        }
        FreshenAction::Prefetch {
            endpoint,
            object_id,
            ttl,
        } => {
            // Skip the network when the cache already holds a fresh copy
            // ("fetch once every n seconds", §3.2).
            if world.containers[cid]
                .runtime
                .cache
                .peek_fresh(&endpoint, &object_id, now)
            {
                let cached = world.containers[cid]
                    .runtime
                    .cache
                    .get(&endpoint, &object_id, now, None)
                    .expect("peeked fresh");
                let result = FrResult::Data {
                    object_id: object_id.clone(),
                    version: cached.version,
                    bytes: cached.bytes,
                };
                sim.schedule(LOCAL_ACCESS, move |sim, w| {
                    if abort_if_stale_freshen(w, run) {
                        return;
                    }
                    finish_resource(sim, w, cid, r, result.clone(), Completer::Freshen);
                    w.freshen_runs[run].action_idx += 1;
                    step_freshen(sim, w, run)
                });
                return;
            }
            let (d, result) = do_fetch(
                &mut world.endpoints,
                &mut world.rng,
                &mut world.containers[cid].runtime,
                &endpoint,
                &object_id,
                now,
            );
            // Freshen's network use bills to the app owner too.
            if let FrResult::Data { bytes, .. } = &result {
                let app = app_of(world, function);
                world
                    .ledger
                    .charge_network(world.registry.symbols.resolve(app), *bytes);
            }
            sim.schedule(d, move |sim, w| {
                if abort_if_stale_freshen(w, run) {
                    return;
                }
                if let FrResult::Data { version, bytes, .. } = &result {
                    w.containers[cid].runtime.cache.put(
                        &endpoint, &object_id, *version, *bytes, ttl, sim.now(),
                    );
                }
                finish_resource(sim, w, cid, r, result.clone(), Completer::Freshen);
                w.freshen_runs[run].action_idx += 1;
                step_freshen(sim, w, run)
            });
        }
    }
}

fn finish_freshen(sim: &mut PlatformSim, world: &mut World, run: usize) {
    let now = sim.now();
    let ctx = &mut world.freshen_runs[run];
    ctx.done = true;
    let duration = now.since(ctx.started_at);
    let started_at = ctx.started_at;
    let function = ctx.function;
    let prediction_id = ctx.prediction_id;
    let cid = ctx.container;
    world.metrics.freshens_completed += 1;
    world.obs.record(
        &world.registry.symbols,
        SpanKind::FreshenRun,
        function,
        prediction_id.unwrap_or(u64::MAX),
        started_at,
        duration,
        cid as u64,
        0,
    );
    let app = app_of(world, function);
    let memory_mb = world
        .registry
        .function_by_id(function)
        .map(|f| f.memory_mb)
        .unwrap_or(256);
    match prediction_id {
        // Deferred: usefulness known when the prediction resolves.
        Some(pid) => world.pending_charges.push(PendingFreshenCharge {
            prediction_id: pid,
            app,
            memory_mb,
            duration,
        }),
        // Developer-invoked freshen bills immediately as useful.
        None => world.ledger.charge_freshen(
            world.registry.symbols.resolve(app),
            memory_mb,
            duration,
            true,
        ),
    }
    let _ = sim;
}

// ====================================================================
// Network helpers (disjoint-field borrows)
// ====================================================================

/// Make the runtime's connection to `endpoint` live, paying whatever it
/// costs from its current state: keepalive probe, death detection,
/// (re-)establishment, TLS. Returns the total duration.
pub fn ensure_connection(
    // simlint: allow(D007, keyed by endpoint registration name, not per-event function id)
    endpoints: &mut FxHashMap<String, Endpoint>,
    rng: &mut Rng,
    env: &mut RuntimeEnv,
    endpoint: &str,
    now: SimTime,
) -> SimDuration {
    let Some(ep) = endpoints.get_mut(endpoint) else {
        return LOCAL_ACCESS; // unknown endpoint: fail fast
    };
    let conn = env
        .connections
        .entry(endpoint.to_string())
        .or_insert_with(|| ep.new_connection());
    let mut t = SimDuration::ZERO;
    let mut need_connect = false;
    match conn.state {
        ConnState::Established => {
            let (d, alive) = conn.keepalive(now, rng);
            t += d;
            if !alive {
                need_connect = true;
            }
        }
        ConnState::Closed | ConnState::Dead => need_connect = true,
    }
    if need_connect {
        t += conn.connect(now + t, rng);
        // TLS on top when the endpoint requires it.
        if let Some(version) = ep.tls {
            let sess = env
                .tls
                .entry(endpoint.to_string())
                .or_insert_with(|| crate::netsim::tls::TlsSession::new(version));
            sess.invalidate();
            t += sess.establish(&ep.link, rng);
        }
    }
    t
}

/// The function-side variant: using a connection without a prior liveness
/// check. A silently-dead connection costs a full RTO of detection before
/// re-establishment — the overhead freshen's `EnsureConnection` removes.
fn usable_connection(
    // simlint: allow(D007, keyed by endpoint registration name, not per-event function id)
    endpoints: &mut FxHashMap<String, Endpoint>,
    rng: &mut Rng,
    env: &mut RuntimeEnv,
    endpoint: &str,
    now: SimTime,
) -> SimDuration {
    let Some(ep) = endpoints.get_mut(endpoint) else {
        return LOCAL_ACCESS;
    };
    let conn = env
        .connections
        .entry(endpoint.to_string())
        .or_insert_with(|| ep.new_connection());
    let mut t = SimDuration::ZERO;
    let dead = match conn.state {
        ConnState::Established => {
            if conn.idle_expired(now) {
                // Discover the death the hard way: wait out an RTO.
                conn.kill();
                t += SimDuration::from_secs_f64(conn.rto());
                true
            } else {
                false
            }
        }
        ConnState::Closed | ConnState::Dead => true,
    };
    if dead {
        t += conn.connect(now + t, rng);
        if let Some(version) = ep.tls {
            let sess = env
                .tls
                .entry(endpoint.to_string())
                .or_insert_with(|| crate::netsim::tls::TlsSession::new(version));
            sess.invalidate();
            t += sess.establish(&ep.link, rng);
        }
    }
    t
}

/// Fetch `object_id` from `endpoint` over the runtime's connection.
/// Returns `(duration, result)`.
pub fn do_fetch(
    // simlint: allow(D007, keyed by endpoint registration name, not per-event function id)
    endpoints: &mut FxHashMap<String, Endpoint>,
    rng: &mut Rng,
    env: &mut RuntimeEnv,
    endpoint: &str,
    object_id: &str,
    now: SimTime,
) -> (SimDuration, FrResult) {
    let mut t = usable_connection(endpoints, rng, env, endpoint, now);
    let Some(ep) = endpoints.get_mut(endpoint) else {
        return (t, FrResult::Failed);
    };
    let conn = env.connections.get_mut(endpoint).expect("ensured");
    match ep.store.get(object_id) {
        None => {
            // 404: a small request/response round.
            t += conn.request_response(now + t, rng, REQUEST_BYTES, 256.0, ep.server_time);
            (t, FrResult::Failed)
        }
        Some(obj) => {
            t += conn.request_response(now + t, rng, REQUEST_BYTES, obj.bytes, ep.server_time);
            // Download grew the server->client window; feed the history
            // that `warm_cwnd` estimates from.
            ep.cwnd_history
                .record(now + t, conn.cwnd(TransferDirection::Download));
            (
                t,
                FrResult::Data {
                    object_id: object_id.to_string(),
                    version: obj.version,
                    bytes: obj.bytes,
                },
            )
        }
    }
}

/// Write `bytes` as `object_id` to `endpoint` over the runtime's connection.
pub fn do_put(
    // simlint: allow(D007, keyed by endpoint registration name, not per-event function id)
    endpoints: &mut FxHashMap<String, Endpoint>,
    rng: &mut Rng,
    env: &mut RuntimeEnv,
    endpoint: &str,
    object_id: &str,
    bytes: f64,
    now: SimTime,
) -> SimDuration {
    let mut t = usable_connection(endpoints, rng, env, endpoint, now);
    let Some(ep) = endpoints.get_mut(endpoint) else {
        return t;
    };
    let conn = env.connections.get_mut(endpoint).expect("ensured");
    t += conn.send_with_ack(now + t, rng, bytes, ep.server_time);
    ep.store.put(object_id, bytes, now + t);
    ep.cwnd_history
        .record(now + t, conn.cwnd(TransferDirection::Upload));
    t
}

/// Warm the congestion window (establishing the connection first if
/// needed) via the provider-mediated `warm_cwnd` syscall.
fn do_warm_cwnd(
    // simlint: allow(D007, keyed by endpoint registration name, not per-event function id)
    endpoints: &mut FxHashMap<String, Endpoint>,
    rng: &mut Rng,
    env: &mut RuntimeEnv,
    endpoint: &str,
    direction: TransferDirection,
    anticipated_bytes: f64,
    now: SimTime,
) -> SimDuration {
    let mut t = ensure_connection(endpoints, rng, env, endpoint, now);
    let Some(ep) = endpoints.get_mut(endpoint) else {
        return t;
    };
    let conn = env.connections.get_mut(endpoint).expect("ensured");
    let (_outcome, probe) = warm_cwnd(
        conn,
        direction,
        anticipated_bytes,
        &WarmPolicy::default(),
        &mut ep.cwnd_history,
        now + t,
        rng,
    );
    t += probe;
    t
}

// ---- small lookups --------------------------------------------------

/// Owning app of `function` (ANON when unknown): a 4-byte id copy, where
/// this helper used to allocate a fresh `String` on every billing call.
fn app_of(world: &World, function: FnId) -> FnId {
    world.registry.app_of_id(function)
}

fn prefetch_ttl(world: &World, inv: InvocationId) -> SimDuration {
    let f = world.invocations[inv].function;
    prefetch_ttl_of(world, f)
}

fn prefetch_ttl_of(world: &World, function: FnId) -> SimDuration {
    world
        .registry
        .function_by_id(function)
        .and_then(|f| f.prefetch_ttl)
        .unwrap_or(world.config.freshen.default_ttl)
}

fn charge_transfer(world: &mut World, inv: InvocationId, result: &FrResult) {
    if let FrResult::Data { bytes, .. } = result {
        let app = app_of(world, world.invocations[inv].function);
        world
            .ledger
            .charge_network(world.registry.symbols.resolve(app), *bytes);
    }
}

fn charge_bytes(world: &mut World, inv: InvocationId, bytes: f64) {
    let app = app_of(world, world.invocations[inv].function);
    world
        .ledger
        .charge_network(world.registry.symbols.resolve(app), bytes);
}
