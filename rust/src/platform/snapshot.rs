//! Snapshot/restore: the rival cold-start mitigation.
//!
//! Instead of keeping an idle container warm (full memory charge) or
//! killing it (full cold start on the next arrival), the platform can
//! *snapshot* it: serialize the sandbox to a host-local image and park it
//! as a third lifecycle state ([`ContainerState::Snapshotted`]) that
//! charges its invoker only a discounted fraction of the warm footprint.
//! The next arrival *restores* the snapshot — paying a fixed base latency
//! plus a working-set page-in term — instead of paying a full cold start
//! (Ustiugov et al., "Benchmarking, Analysis, and Optimization of
//! Serverless Function Snapshots").
//!
//! Two cost-model refinements from that literature are modeled:
//!
//! - **REAP-style prefetch** ([`SnapshotConfig::prefetch`]): recording the
//!   stable working set and bulk-loading it on restore shrinks the
//!   demand-paging term to `prefetch_permille`/1000 of its vanilla cost.
//! - **Freshen-on-restore** ([`SnapshotConfig::freshen_on_restore`]): a
//!   restored runtime's connections are dead (sockets do not survive a
//!   snapshot) and its cached state may be stale; the hybrid mitigation
//!   runs the paper's freshen pass on the freshly restored container to
//!   re-warm it (wired in [`crate::platform::exec`], incarnation-guard
//!   aware like every other freshen run).
//!
//! All arithmetic here is integer-exact (permille scaling, µs-per-MB
//! terms) so restore costs and discounted charges merge digest-stably.
//!
//! [`ContainerState::Snapshotted`]: crate::platform::container::ContainerState

use crate::util::config::SnapshotConfig;
use crate::util::time::SimDuration;

/// Memory (MB) a snapshotted container charges its host: the warm charge
/// scaled to `charge_permille`/1000, floor division (a 256 MB container
/// at the default 250‰ parks at exactly 64 MB).
pub fn snapshot_charge_mb(warm_mb: u32, charge_permille: u32) -> u32 {
    (warm_mb as u64 * charge_permille as u64 / 1000) as u32
}

/// The working-set page-in term of a restore, in sim-µs: `warm_mb ×
/// page_in_us_per_mb`, scaled to `prefetch_permille`/1000 when the
/// REAP-style prefetch variant is on. Exact integer arithmetic.
pub fn page_in_us(cfg: &SnapshotConfig, warm_mb: u32) -> u64 {
    let demand = cfg.page_in_us_per_mb * warm_mb as u64;
    if cfg.prefetch {
        demand * cfg.prefetch_permille as u64 / 1000
    } else {
        demand
    }
}

/// Total restore latency: the fixed base (descriptor load + sandbox
/// rebuild) plus the page-in term.
pub fn restore_cost(cfg: &SnapshotConfig, warm_mb: u32) -> SimDuration {
    SimDuration(cfg.restore_base.micros() + page_in_us(cfg, warm_mb))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discounted_charge_is_exact_floor_permille() {
        assert_eq!(snapshot_charge_mb(256, 250), 64);
        assert_eq!(snapshot_charge_mb(100, 250), 25);
        // Floor division: 3 × 250 / 1000 = 0 — a tiny container's
        // snapshot can round to a zero charge, which accounting accepts.
        assert_eq!(snapshot_charge_mb(3, 250), 0);
        assert_eq!(snapshot_charge_mb(1024, 125), 128);
        assert_eq!(snapshot_charge_mb(0, 500), 0);
        // 1000‰ is a full-price snapshot; 0‰ is free.
        assert_eq!(snapshot_charge_mb(777, 1000), 777);
        assert_eq!(snapshot_charge_mb(777, 0), 0);
    }

    /// The satellite's pinned restore-cost arithmetic: base + page-in +
    /// prefetch as exact integers, no rounding surprises.
    #[test]
    fn restore_cost_pins_base_plus_page_in_plus_prefetch() {
        let mut cfg = SnapshotConfig::default();
        cfg.restore_base = SimDuration::from_millis(25); // 25_000 µs
        cfg.page_in_us_per_mb = 150;
        cfg.prefetch = false;
        cfg.prefetch_permille = 300;
        // Vanilla: 25_000 + 256 × 150 = 63_400 µs.
        assert_eq!(page_in_us(&cfg, 256), 38_400);
        assert_eq!(restore_cost(&cfg, 256), SimDuration(63_400));
        // Prefetch: page-in shrinks to 38_400 × 300 / 1000 = 11_520 µs.
        cfg.prefetch = true;
        assert_eq!(page_in_us(&cfg, 256), 11_520);
        assert_eq!(restore_cost(&cfg, 256), SimDuration(36_520));
        // Permille scaling floors: 7 MB × 150 = 1050; × 300 / 1000 = 315.
        assert_eq!(page_in_us(&cfg, 7), 315);
        // A zero-MB working set still pays the base.
        assert_eq!(restore_cost(&cfg, 0), SimDuration(25_000));
    }

    #[test]
    fn prefetch_never_exceeds_vanilla() {
        let mut cfg = SnapshotConfig::default();
        for mb in [0u32, 1, 64, 256, 4096] {
            cfg.prefetch = false;
            let vanilla = restore_cost(&cfg, mb);
            cfg.prefetch = true;
            assert!(restore_cost(&cfg, mb) <= vanilla);
        }
    }
}
