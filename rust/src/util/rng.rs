//! Deterministic pseudo-random numbers and distributions.
//!
//! The offline toolchain has no `rand` crate, so this is a small, fully
//! deterministic replacement built on **xoshiro256++** seeded through
//! **SplitMix64** (the reference seeding procedure). Every experiment in the
//! repo takes an explicit seed, so results are reproducible bit-for-bit.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// Mix two 64-bit values into one well-dispersed seed (murmur3-style
/// finalizer). This is how derived streams are keyed off a root seed plus
/// a stable identity — e.g. the macro-trace replay seeds each app's world
/// from `mix64(run_seed, app_hash)` and the synthesizer keys app `i`'s
/// stream from `mix64(trace_seed, i)` — so the same pair always yields the
/// same stream, independent of generation order.
pub fn mix64(a: u64, b: u64) -> u64 {
    let mut x = a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    x ^= x >> 33;
    x
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream. Used to give each simulated
    /// component its own generator so adding draws in one component does not
    /// perturb another (critical for A/B experiment comparability).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`, 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Debiased via Lemire's method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)` (half-open).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "range({lo}, {hi})");
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (single-value variant; simple and
    /// plenty fast for our workloads).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std_dev: f64) -> f64 {
        mean + std_dev * self.normal()
    }

    /// Log-normal parameterised by the *underlying* normal's mu/sigma.
    /// Used for trigger-service delays (long-tailed, strictly positive).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given rate (mean `1/rate`). Poisson inter-arrivals.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Pareto (Lomax-style, `x_min * U^(-1/alpha)`); heavy-tailed sizes.
    pub fn pareto(&mut self, x_min: f64, alpha: f64) -> f64 {
        assert!(x_min > 0.0 && alpha > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        x_min * u.powf(-1.0 / alpha)
    }

    /// Sample an index in `[0, n)` from a Zipf distribution with exponent `s`
    /// (rank 0 is most popular). Linear-scan inversion over precomputed
    /// weights would be faster for hot use; callers with hot loops should use
    /// [`ZipfSampler`].
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        ZipfSampler::new(n, s).sample(self)
    }

    /// Pick a uniformly random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with all-zero weights");
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed-CDF Zipf sampler (binary-search inversion), for hot loops.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, s: f64) -> ZipfSampler {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        ZipfSampler { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).expect("NaN in zipf cdf"))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn mix64_is_deterministic_and_disperses() {
        assert_eq!(mix64(1, 2), mix64(1, 2));
        assert_ne!(mix64(1, 2), mix64(2, 1));
        assert_ne!(mix64(1, 2), mix64(1, 3));
        // Nearby keys land far apart (no low-bit correlation).
        let a = mix64(7, 100);
        let b = mix64(7, 101);
        assert!((a ^ b).count_ones() > 8, "poor dispersion: {a:x} vs {b:x}");
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Rng::new(7);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        // Streams differ from each other and from the parent.
        let a: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(1);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[rng.below(10) as usize] += 1;
        }
        for &c in &counts {
            // expected 10_000 each; allow 5% deviation
            assert!((9_500..10_500).contains(&c), "count {c} out of band");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(4);
        let n = 200_000;
        let mean = (0..n).map(|_| rng.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.005, "mean {mean}");
    }

    #[test]
    fn lognormal_median() {
        // Median of lognormal(mu, sigma) is exp(mu).
        let mut rng = Rng::new(5);
        let mut xs: Vec<f64> = (0..50_001).map(|_| rng.lognormal(0.5, 0.8)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        assert!((median - 0.5f64.exp()).abs() < 0.05, "median {median}");
    }

    #[test]
    fn zipf_rank0_most_popular() {
        let mut rng = Rng::new(6);
        let sampler = ZipfSampler::new(50, 1.1);
        let mut counts = vec![0u32; 50];
        for _ in 0..50_000 {
            counts[sampler.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[49]);
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut rng = Rng::new(7);
        for _ in 0..1_000 {
            let i = rng.weighted(&[0.0, 3.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }
}
