//! Platform configuration.
//!
//! All knobs a deployment would set live here: container pool sizing,
//! cold-start costs, network site parameters, freshen policy defaults.
//! Configs load from JSON (see `Config::from_json`) so examples and the CLI
//! can share experiment setups; every field has a sensible default drawn
//! from the paper (or from the OpenWhisk defaults the paper builds on).

use crate::netsim::link::Site;
use crate::util::json::Json;
use crate::util::time::SimDuration;

/// The uniform container slot size (MB) used by legacy count-bounded
/// pools: under [`MemoryAccounting::UniformSlot`] every container charges
/// exactly this much, so "capacity = N slots" and "capacity = N × 256 MB"
/// admit byte-identically.
pub const UNIFORM_SLOT_MB: u32 = 256;

/// Top-level platform configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of invoker hosts in the cluster.
    pub invokers: usize,
    /// Legacy pool-sizing knob: when [`Config::invoker_memory_mb`] is
    /// unset, each host's memory capacity is `containers_per_invoker`
    /// uniform 256 MB slots (exactly the old count-bounded pool).
    pub containers_per_invoker: usize,
    /// Memory capacity per invoker host in MB. `None` (default) derives
    /// the capacity from `containers_per_invoker` (see above).
    pub invoker_memory_mb: Option<u64>,
    /// How a container charges its host's memory capacity.
    pub memory_accounting: MemoryAccounting,
    /// Keep-alive / eviction policy for idle warm containers.
    pub keep_alive: KeepAliveKind,
    /// Queue discipline for invocations waiting on cluster memory
    /// (the implementations live in [`crate::platform::dispatch`]).
    pub queue: QueueKind,
    /// Placement strategy: which invoker host a cold start lands on
    /// (the implementations live in [`crate::platform::placement`]).
    pub placement: PlacementKind,
    /// Heterogeneous host classes (cloud vs edge). Empty (the default)
    /// keeps the homogeneous cluster: `invokers` identical hosts of
    /// [`Config::invoker_capacity_mb`] each. Non-empty REPLACES the
    /// `invokers`/`invoker_memory_mb` sizing: the cluster is the classes
    /// expanded in order (see [`Config::host_layout`]).
    pub host_classes: Vec<HostClass>,
    /// Anti-starvation aging bound for [`QueueKind::MemoryAware`]: once
    /// the oldest queued invocation has waited this long, it is promoted
    /// ahead of the smallest-charge order. The 30 s default pins the
    /// discipline's historical digests.
    pub queue_aging_bound: SimDuration,
    /// Abort in-flight freshen runs whose container was reclaimed
    /// (pressure-evicted and possibly recycled) since the run launched.
    /// Off by default: the legacy semantics let a stale run keep stepping
    /// against the recycled slot, and the default replay digests pin that
    /// behavior byte-for-byte.
    pub freshen_incarnation_guard: bool,
    /// Cold-start cost: container provision + runtime `init` hook.
    pub cold_start: SimDuration,
    /// Warm-start dispatch overhead (`run` hook on a live runtime).
    pub warm_start: SimDuration,
    /// Idle duration after which a warm container is evicted
    /// (OpenWhisk's default stem-cell keep-alive is 10 minutes).
    pub idle_eviction: SimDuration,
    /// Whether different functions may share a warmed container
    /// (the paper cites [13]: most providers disallow it).
    pub allow_container_sharing: bool,
    /// Isolation scope (§6: "integrating freshen into serverless
    /// architectures that provide different isolation scopes" — Azure
    /// offers chain-level isolation). Under [`IsolationScope::PerApp`], a
    /// warm container of the same app can be re-inited for a sibling
    /// function at a fraction of a cold start, *keeping its runtime-scoped
    /// connections and freshen cache* — so freshen benefits compound
    /// across a chain's stages.
    pub isolation: IsolationScope,
    /// Freshen policy knobs.
    pub freshen: FreshenConfig,
    /// Snapshot/restore cold-start mitigation knobs (the rival to
    /// freshen; implementations live in [`crate::platform::snapshot`]).
    pub snapshot: SnapshotConfig,
    /// Default TTL for entries in the freshen prefetch cache.
    pub seed: u64,
}

/// Freshen policy configuration (§3.3 billing/abuse controls).
#[derive(Debug, Clone)]
pub struct FreshenConfig {
    /// Master switch; `false` reproduces the vanilla-platform baselines.
    pub enabled: bool,
    /// Minimum prediction confidence required to launch a freshen
    /// (mispredicted freshens bill the app owner, so providers gate).
    pub min_confidence: f64,
    /// Default TTL for prefetched data in the freshen cache.
    pub default_ttl: SimDuration,
    /// Per-app cap on freshen invocations per minute (abuse guard).
    pub max_freshens_per_min: u32,
    /// Service category: aggressive freshen for latency-sensitive apps.
    pub category: ServiceCategory,
}

/// Snapshot/restore mitigation configuration (Ustiugov et al.,
/// "Benchmarking, Analysis, and Optimization of Serverless Function
/// Snapshots"). A snapshotted container parks its state on the host at a
/// discounted memory charge; restoring it costs a base latency plus a
/// working-set page-in term. All cost knobs are integers (permille /
/// µs-per-MB) so restore arithmetic is exact and digest-stable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SnapshotConfig {
    /// Master switch; `false` (the default) keeps every legacy digest and
    /// stdout byte pinned: no demotions, no restores, no new states.
    pub enabled: bool,
    /// Memory charge of a snapshotted container, in permille of its warm
    /// charge (250 = the snapshot holds 25% of the warm footprint).
    pub charge_permille: u32,
    /// Fixed restore cost: load the snapshot descriptor + rebuild the
    /// sandbox, before any working-set page faults.
    pub restore_base: SimDuration,
    /// Working-set page-in cost per MB of the container's warm charge, in
    /// sim-µs (the demand-paging term a vanilla snapshot restore pays).
    pub page_in_us_per_mb: u64,
    /// REAP-style working-set prefetch: record the stable working set and
    /// bulk-load it on restore, shrinking the page-in term.
    pub prefetch: bool,
    /// Page-in cost remaining under prefetch, permille (300 = prefetch
    /// eliminates 70% of the demand-paging cost).
    pub prefetch_permille: u32,
    /// Hybrid mitigation: run a freshen pass on the restored container to
    /// re-warm stale runtime state (connections die across a snapshot).
    /// Only meaningful when `freshen.enabled` is also set.
    pub freshen_on_restore: bool,
}

impl Default for SnapshotConfig {
    fn default() -> SnapshotConfig {
        SnapshotConfig {
            enabled: false,
            charge_permille: 250,
            restore_base: SimDuration::from_millis(25),
            page_in_us_per_mb: 150,
            prefetch: false,
            prefetch_permille: 300,
            freshen_on_restore: false,
        }
    }
}

/// How containers are charged against an invoker host's memory capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MemoryAccounting {
    /// Every container charges one uniform 256 MB slot — byte-identical to
    /// the historical count-bounded pool (`containers_per_invoker` slots).
    #[default]
    UniformSlot,
    /// Every container charges its function's declared `memory_mb`, so
    /// heavy functions genuinely crowd out light ones (the contended
    /// multi-tenant cluster model).
    FunctionMb,
}

impl MemoryAccounting {
    pub fn parse(s: &str) -> Option<MemoryAccounting> {
        match s {
            "uniform_slot" | "uniform" => Some(MemoryAccounting::UniformSlot),
            "function_mb" | "function" => Some(MemoryAccounting::FunctionMb),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            MemoryAccounting::UniformSlot => "uniform_slot",
            MemoryAccounting::FunctionMb => "function_mb",
        }
    }
}

/// Which keep-alive policy governs idle warm containers (the
/// implementations live in [`crate::platform::keepalive`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KeepAliveKind {
    /// Fixed idle TTL (`idle_eviction`), OpenWhisk-style — the historical
    /// inline behavior, kept byte-identical.
    #[default]
    FixedTtl,
    /// Never evict on idle; evict the LRU warm container only under
    /// memory pressure.
    LruPressure,
    /// Per-function keep-alive windows driven by the IAT histogram
    /// predictor (slot-survival-style lifecycle control), with LRU
    /// eviction under pressure.
    HybridHistogram,
}

impl KeepAliveKind {
    pub fn all() -> [KeepAliveKind; 3] {
        [
            KeepAliveKind::FixedTtl,
            KeepAliveKind::LruPressure,
            KeepAliveKind::HybridHistogram,
        ]
    }

    pub fn parse(s: &str) -> Option<KeepAliveKind> {
        match s {
            "fixed" | "fixed_ttl" => Some(KeepAliveKind::FixedTtl),
            "lru" | "lru_pressure" => Some(KeepAliveKind::LruPressure),
            "hybrid" | "hybrid_histogram" => Some(KeepAliveKind::HybridHistogram),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KeepAliveKind::FixedTtl => "fixed",
            KeepAliveKind::LruPressure => "lru",
            KeepAliveKind::HybridHistogram => "hybrid",
        }
    }
}

/// Which queue discipline holds invocations waiting for cluster memory
/// (the implementations live in [`crate::platform::dispatch`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueKind {
    /// Per-function queues; a freed slot retries ONE queued invocation in
    /// hash-map iteration order — the historical inline behavior, kept
    /// byte-identical.
    #[default]
    LegacyOneShot,
    /// One global arrival-order FIFO; freed memory drains the queue head
    /// by head until a retry fails to place (strict head-of-line: no
    /// queue DRAIN overtakes an older invocation — the warm-container
    /// fast paths still place directly, as in every discipline).
    FifoFair,
    /// Smallest-memory-charge-first drain (maximizes invocations resumed
    /// per freed MB), with an aging bound that promotes the oldest entry
    /// so large functions cannot starve.
    MemoryAware,
}

impl QueueKind {
    pub fn all() -> [QueueKind; 3] {
        [
            QueueKind::LegacyOneShot,
            QueueKind::FifoFair,
            QueueKind::MemoryAware,
        ]
    }

    pub fn parse(s: &str) -> Option<QueueKind> {
        match s {
            "legacy" | "legacy_one_shot" => Some(QueueKind::LegacyOneShot),
            "fifo" | "fifo_fair" => Some(QueueKind::FifoFair),
            "memaware" | "memory_aware" => Some(QueueKind::MemoryAware),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            QueueKind::LegacyOneShot => "legacy",
            QueueKind::FifoFair => "fifo",
            QueueKind::MemoryAware => "memaware",
        }
    }
}

/// Which placement strategy chooses the invoker host for a cold start
/// (the implementations live in [`crate::platform::placement`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementKind {
    /// Recycle the first parked slot whose host has room, else create on
    /// the least-loaded host — the historical inline scan, kept
    /// byte-identical and digest-pinned.
    #[default]
    LeastLoadedMb,
    /// Uniformly random host among those with room (seeded from the
    /// world's forked placement stream; spreading baseline).
    RandomUniform,
    /// Rotate a cursor over the hosts, skipping full ones.
    RoundRobin,
    /// Prefer hosts already holding live containers of the function
    /// (warm or freshen-warmed state is worth landing next to); fall back
    /// to the full legacy scan when none has room.
    WarmAffinity,
    /// Per-function affinity/anti-affinity label matching against host
    /// class names (edgeless-orc-style deployment requirements), least
    /// loaded among the admitted hosts.
    Constrained,
}

impl PlacementKind {
    pub fn all() -> [PlacementKind; 5] {
        [
            PlacementKind::LeastLoadedMb,
            PlacementKind::RandomUniform,
            PlacementKind::RoundRobin,
            PlacementKind::WarmAffinity,
            PlacementKind::Constrained,
        ]
    }

    pub fn parse(s: &str) -> Option<PlacementKind> {
        match s {
            "legacy" | "least_loaded" | "least_loaded_mb" => Some(PlacementKind::LeastLoadedMb),
            "random" | "random_uniform" => Some(PlacementKind::RandomUniform),
            "rr" | "round_robin" => Some(PlacementKind::RoundRobin),
            "affinity" | "warm_affinity" => Some(PlacementKind::WarmAffinity),
            "constrained" | "labels" => Some(PlacementKind::Constrained),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            PlacementKind::LeastLoadedMb => "legacy",
            PlacementKind::RandomUniform => "random",
            PlacementKind::RoundRobin => "rr",
            PlacementKind::WarmAffinity => "affinity",
            PlacementKind::Constrained => "constrained",
        }
    }

    /// Stable strategy code packed into the high byte of placement span
    /// payloads (index in [`PlacementKind::all`]; legacy is 0, so default
    /// spans are byte-identical to the pre-placement format).
    pub fn code(&self) -> u64 {
        match self {
            PlacementKind::LeastLoadedMb => 0,
            PlacementKind::RandomUniform => 1,
            PlacementKind::RoundRobin => 2,
            PlacementKind::WarmAffinity => 3,
            PlacementKind::Constrained => 4,
        }
    }
}

/// One class of invoker hosts in a heterogeneous cluster (cloud vs edge).
/// Configured via `Config::host_classes` / `--host-classes`, grammar
/// `name:count:capacity_mb:cold_mult_permille:net[,...]`, e.g.
/// `cloud:2:4096:1000:local,edge:2:1024:1600:edge`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostClass {
    /// Class name; the label the `Constrained` placement strategy
    /// matches function affinity/anti-affinity against.
    pub name: String,
    /// Number of hosts of this class.
    pub count: usize,
    /// Memory capacity per host, MB.
    pub capacity_mb: u64,
    /// Cold-start cost multiplier in permille (1000 = the configured
    /// `cold_start` unchanged; 1600 = 1.6x — edge nodes provision slower).
    /// Integer permille keeps the scaled duration exact and digest-stable.
    pub cold_start_mult_permille: u32,
    /// Network profile of the host's site: chain edges LEAVING a non-
    /// [`Site::Local`] host pay a sampled inter-node RTT on top of the
    /// trigger delay (the netsim link model from fig5/6).
    pub net_profile: Site,
}

impl HostClass {
    /// Parse one `name:count:capacity_mb:cold_mult_permille:net` clause.
    pub fn parse(s: &str) -> Option<HostClass> {
        let mut parts = s.split(':');
        let name = parts.next()?.trim();
        let count: usize = parts.next()?.trim().parse().ok()?;
        let capacity_mb: u64 = parts.next()?.trim().parse().ok()?;
        let cold: u32 = parts.next()?.trim().parse().ok()?;
        let net = Site::parse(parts.next()?.trim())?;
        if name.is_empty() || count == 0 || capacity_mb == 0 || cold == 0 || parts.next().is_some()
        {
            return None;
        }
        Some(HostClass {
            name: name.to_string(),
            count,
            capacity_mb,
            cold_start_mult_permille: cold,
            net_profile: net,
        })
    }

    /// Parse a comma-separated class list (the `--host-classes` grammar).
    pub fn parse_list(s: &str) -> Option<Vec<HostClass>> {
        let classes = s
            .split(',')
            .map(|c| HostClass::parse(c.trim()))
            .collect::<Option<Vec<HostClass>>>()?;
        if classes.is_empty() {
            None
        } else {
            Some(classes)
        }
    }

    /// Render back to the grammar clause (JSON round-trip + CLI echo).
    pub fn spec_str(&self) -> String {
        format!(
            "{}:{}:{}:{}:{}",
            self.name,
            self.count,
            self.capacity_mb,
            self.cold_start_mult_permille,
            self.net_profile.as_str()
        )
    }
}

/// Container isolation scope.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IsolationScope {
    /// AWS-style: a container only ever hosts one function's code.
    PerFunction,
    /// Azure-chain-style: containers are shared within an application;
    /// switching functions costs a re-init, not a cold start.
    PerApp,
}

impl IsolationScope {
    pub fn parse(s: &str) -> Option<IsolationScope> {
        match s {
            "per_function" => Some(IsolationScope::PerFunction),
            "per_app" => Some(IsolationScope::PerApp),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            IsolationScope::PerFunction => "per_function",
            IsolationScope::PerApp => "per_app",
        }
    }
}

/// Developer-chosen service category (§3.3): controls how aggressively the
/// provider freshens on the app's behalf.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceCategory {
    /// Freshen on every confident prediction.
    LatencySensitive,
    /// Freshen only on high-confidence predictions.
    Standard,
    /// Never freshen.
    LatencyInsensitive,
}

impl ServiceCategory {
    pub fn parse(s: &str) -> Option<ServiceCategory> {
        match s {
            "latency_sensitive" => Some(ServiceCategory::LatencySensitive),
            "standard" => Some(ServiceCategory::Standard),
            "latency_insensitive" => Some(ServiceCategory::LatencyInsensitive),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ServiceCategory::LatencySensitive => "latency_sensitive",
            ServiceCategory::Standard => "standard",
            ServiceCategory::LatencyInsensitive => "latency_insensitive",
        }
    }

    /// The confidence threshold this category implies (overrides the
    /// numeric `min_confidence` when stricter).
    pub fn confidence_floor(&self) -> f64 {
        match self {
            ServiceCategory::LatencySensitive => 0.2,
            ServiceCategory::Standard => 0.5,
            ServiceCategory::LatencyInsensitive => f64::INFINITY,
        }
    }
}

impl Default for FreshenConfig {
    fn default() -> FreshenConfig {
        FreshenConfig {
            enabled: true,
            min_confidence: 0.5,
            default_ttl: SimDuration::from_secs(10),
            max_freshens_per_min: 600,
            category: ServiceCategory::Standard,
        }
    }
}

impl Default for Config {
    fn default() -> Config {
        Config {
            invokers: 4,
            containers_per_invoker: 16,
            invoker_memory_mb: None,
            memory_accounting: MemoryAccounting::UniformSlot,
            keep_alive: KeepAliveKind::FixedTtl,
            queue: QueueKind::LegacyOneShot,
            placement: PlacementKind::LeastLoadedMb,
            host_classes: Vec::new(),
            queue_aging_bound: SimDuration::from_secs(30),
            freshen_incarnation_guard: false,
            // OpenWhisk docker cold starts are hundreds of ms; the paper's
            // related work (SOCK) reports ~100ms-1s. We default to 500ms.
            cold_start: SimDuration::from_millis(500),
            warm_start: SimDuration::from_millis(5),
            idle_eviction: SimDuration::from_secs(600),
            allow_container_sharing: false,
            isolation: IsolationScope::PerFunction,
            freshen: FreshenConfig::default(),
            snapshot: SnapshotConfig::default(),
            seed: 0xF5E5_4E55, // "FRESHENESS"
        }
    }
}

impl Config {
    /// Effective memory capacity of one invoker host, in MB.
    pub fn invoker_capacity_mb(&self) -> u64 {
        self.invoker_memory_mb
            .unwrap_or(self.containers_per_invoker as u64 * UNIFORM_SLOT_MB as u64)
    }

    /// The cluster's host layout as `(class_index, capacity_mb)` per host.
    /// Empty `host_classes` keeps the homogeneous legacy cluster
    /// (`invokers` hosts of [`Config::invoker_capacity_mb`], all class 0);
    /// otherwise the classes expand in declaration order, so host ids stay
    /// stable for a given spec string.
    pub fn host_layout(&self) -> Vec<(usize, u64)> {
        if self.host_classes.is_empty() {
            let cap = self.invoker_capacity_mb();
            return (0..self.invokers).map(|_| (0, cap)).collect();
        }
        let mut layout = Vec::new();
        for (class, hc) in self.host_classes.iter().enumerate() {
            for _ in 0..hc.count {
                layout.push((class, hc.capacity_mb));
            }
        }
        layout
    }

    /// Load from a JSON object; missing keys keep their defaults.
    pub fn from_json(j: &Json) -> Config {
        let mut c = Config::default();
        c.invokers = j.u64_or("invokers", c.invokers as u64) as usize;
        c.containers_per_invoker =
            j.u64_or("containers_per_invoker", c.containers_per_invoker as u64) as usize;
        c.invoker_memory_mb = j.get("invoker_memory_mb").and_then(Json::as_u64);
        if let Some(acc) = j.get("memory_accounting").and_then(Json::as_str) {
            if let Some(parsed) = MemoryAccounting::parse(acc) {
                c.memory_accounting = parsed;
            }
        }
        if let Some(ka) = j.get("keep_alive").and_then(Json::as_str) {
            if let Some(parsed) = KeepAliveKind::parse(ka) {
                c.keep_alive = parsed;
            }
        }
        if let Some(q) = j.get("queue").and_then(Json::as_str) {
            if let Some(parsed) = QueueKind::parse(q) {
                c.queue = parsed;
            }
        }
        if let Some(p) = j.get("placement").and_then(Json::as_str) {
            if let Some(parsed) = PlacementKind::parse(p) {
                c.placement = parsed;
            }
        }
        if let Some(hc) = j.get("host_classes").and_then(Json::as_str) {
            if let Some(parsed) = HostClass::parse_list(hc) {
                c.host_classes = parsed;
            }
        }
        c.queue_aging_bound = SimDuration::from_secs_f64(
            j.f64_or("queue_aging_bound_s", c.queue_aging_bound.as_secs_f64()),
        );
        c.freshen_incarnation_guard =
            j.bool_or("freshen_incarnation_guard", c.freshen_incarnation_guard);
        c.cold_start = SimDuration::from_millis_f64(
            j.f64_or("cold_start_ms", c.cold_start.as_millis_f64()),
        );
        c.warm_start = SimDuration::from_millis_f64(
            j.f64_or("warm_start_ms", c.warm_start.as_millis_f64()),
        );
        c.idle_eviction = SimDuration::from_secs_f64(
            j.f64_or("idle_eviction_s", c.idle_eviction.as_secs_f64()),
        );
        c.allow_container_sharing =
            j.bool_or("allow_container_sharing", c.allow_container_sharing);
        if let Some(iso) = j.get("isolation").and_then(Json::as_str) {
            if let Some(parsed) = IsolationScope::parse(iso) {
                c.isolation = parsed;
            }
        }
        c.seed = j.u64_or("seed", c.seed);
        if let Some(fj) = j.get("freshen") {
            c.freshen.enabled = fj.bool_or("enabled", c.freshen.enabled);
            c.freshen.min_confidence = fj.f64_or("min_confidence", c.freshen.min_confidence);
            c.freshen.default_ttl = SimDuration::from_secs_f64(
                fj.f64_or("default_ttl_s", c.freshen.default_ttl.as_secs_f64()),
            );
            c.freshen.max_freshens_per_min =
                fj.u64_or("max_freshens_per_min", c.freshen.max_freshens_per_min as u64) as u32;
            if let Some(cat) = fj.get("category").and_then(Json::as_str) {
                if let Some(parsed) = ServiceCategory::parse(cat) {
                    c.freshen.category = parsed;
                }
            }
        }
        if let Some(sj) = j.get("snapshot") {
            c.snapshot.enabled = sj.bool_or("enabled", c.snapshot.enabled);
            c.snapshot.charge_permille =
                sj.u64_or("charge_permille", c.snapshot.charge_permille as u64) as u32;
            c.snapshot.restore_base = SimDuration::from_millis_f64(
                sj.f64_or("restore_base_ms", c.snapshot.restore_base.as_millis_f64()),
            );
            c.snapshot.page_in_us_per_mb =
                sj.u64_or("page_in_us_per_mb", c.snapshot.page_in_us_per_mb);
            c.snapshot.prefetch = sj.bool_or("prefetch", c.snapshot.prefetch);
            c.snapshot.prefetch_permille =
                sj.u64_or("prefetch_permille", c.snapshot.prefetch_permille as u64) as u32;
            c.snapshot.freshen_on_restore =
                sj.bool_or("freshen_on_restore", c.snapshot.freshen_on_restore);
        }
        c
    }

    /// Serialize back to JSON (for report headers).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("invokers", Json::num(self.invokers as f64)),
            (
                "containers_per_invoker",
                Json::num(self.containers_per_invoker as f64),
            ),
            (
                "memory_accounting",
                Json::str(self.memory_accounting.as_str()),
            ),
            ("keep_alive", Json::str(self.keep_alive.as_str())),
            ("queue", Json::str(self.queue.as_str())),
            ("placement", Json::str(self.placement.as_str())),
            (
                "queue_aging_bound_s",
                Json::num(self.queue_aging_bound.as_secs_f64()),
            ),
            (
                "freshen_incarnation_guard",
                Json::Bool(self.freshen_incarnation_guard),
            ),
            ("cold_start_ms", Json::num(self.cold_start.as_millis_f64())),
            ("warm_start_ms", Json::num(self.warm_start.as_millis_f64())),
            (
                "idle_eviction_s",
                Json::num(self.idle_eviction.as_secs_f64()),
            ),
            (
                "allow_container_sharing",
                Json::Bool(self.allow_container_sharing),
            ),
            ("isolation", Json::str(self.isolation.as_str())),
            ("seed", Json::num(self.seed as f64)),
            (
                "freshen",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.freshen.enabled)),
                    ("min_confidence", Json::num(self.freshen.min_confidence)),
                    (
                        "default_ttl_s",
                        Json::num(self.freshen.default_ttl.as_secs_f64()),
                    ),
                    (
                        "max_freshens_per_min",
                        Json::num(self.freshen.max_freshens_per_min as f64),
                    ),
                    ("category", Json::str(self.freshen.category.as_str())),
                ]),
            ),
        ]);
        if let Some(mb) = self.invoker_memory_mb {
            j.set("invoker_memory_mb", Json::num(mb as f64));
        }
        if !self.host_classes.is_empty() {
            let spec = self
                .host_classes
                .iter()
                .map(HostClass::spec_str)
                .collect::<Vec<_>>()
                .join(",");
            j.set("host_classes", Json::str(&spec));
        }
        // Emitted only when configured away from the defaults, so default
        // report headers stay byte-identical to pre-snapshot builds.
        if self.snapshot != SnapshotConfig::default() {
            j.set(
                "snapshot",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.snapshot.enabled)),
                    (
                        "charge_permille",
                        Json::num(self.snapshot.charge_permille as f64),
                    ),
                    (
                        "restore_base_ms",
                        Json::num(self.snapshot.restore_base.as_millis_f64()),
                    ),
                    (
                        "page_in_us_per_mb",
                        Json::num(self.snapshot.page_in_us_per_mb as f64),
                    ),
                    ("prefetch", Json::Bool(self.snapshot.prefetch)),
                    (
                        "prefetch_permille",
                        Json::num(self.snapshot.prefetch_permille as f64),
                    ),
                    (
                        "freshen_on_restore",
                        Json::Bool(self.snapshot.freshen_on_restore),
                    ),
                ]),
            );
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert!(c.invokers > 0);
        assert!(c.cold_start > c.warm_start);
        assert!(c.freshen.enabled);
    }

    #[test]
    fn json_roundtrip() {
        let c = Config::default();
        let j = c.to_json();
        let c2 = Config::from_json(&j);
        assert_eq!(c2.invokers, c.invokers);
        assert_eq!(c2.cold_start, c.cold_start);
        assert_eq!(c2.freshen.category, c.freshen.category);
        assert_eq!(c2.freshen.default_ttl, c.freshen.default_ttl);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"invokers": 2, "freshen": {"enabled": false}}"#).unwrap();
        let c = Config::from_json(&j);
        assert_eq!(c.invokers, 2);
        assert!(!c.freshen.enabled);
        // untouched key keeps default
        assert_eq!(c.containers_per_invoker, Config::default().containers_per_invoker);
    }

    #[test]
    fn memory_and_keepalive_knobs_roundtrip() {
        let mut c = Config::default();
        assert_eq!(c.invoker_capacity_mb(), 16 * UNIFORM_SLOT_MB as u64);
        c.invoker_memory_mb = Some(8192);
        c.memory_accounting = MemoryAccounting::FunctionMb;
        c.keep_alive = KeepAliveKind::HybridHistogram;
        assert_eq!(c.invoker_capacity_mb(), 8192);
        let c2 = Config::from_json(&c.to_json());
        assert_eq!(c2.invoker_memory_mb, Some(8192));
        assert_eq!(c2.memory_accounting, MemoryAccounting::FunctionMb);
        assert_eq!(c2.keep_alive, KeepAliveKind::HybridHistogram);
        // Defaults serialize without an explicit capacity and parse back.
        let d = Config::from_json(&Config::default().to_json());
        assert_eq!(d.invoker_memory_mb, None);
        assert_eq!(d.memory_accounting, MemoryAccounting::UniformSlot);
        assert_eq!(d.keep_alive, KeepAliveKind::FixedTtl);
        // Short and long spellings both parse.
        assert_eq!(KeepAliveKind::parse("lru_pressure"), Some(KeepAliveKind::LruPressure));
        assert_eq!(KeepAliveKind::parse("hybrid"), Some(KeepAliveKind::HybridHistogram));
        assert_eq!(KeepAliveKind::parse("bogus"), None);
        assert_eq!(MemoryAccounting::parse("function"), Some(MemoryAccounting::FunctionMb));
        assert_eq!(MemoryAccounting::parse("bogus"), None);
        for k in KeepAliveKind::all() {
            assert_eq!(KeepAliveKind::parse(k.as_str()), Some(k));
        }
    }

    #[test]
    fn queue_and_guard_knobs_roundtrip() {
        let d = Config::default();
        assert_eq!(d.queue, QueueKind::LegacyOneShot, "legacy is the default");
        assert_eq!(
            d.queue_aging_bound,
            SimDuration::from_secs(30),
            "memaware aging bound defaults to the digest-pinned 30 s"
        );
        assert!(!d.freshen_incarnation_guard, "guard defaults off");
        let mut c = Config::default();
        c.queue = QueueKind::MemoryAware;
        c.queue_aging_bound = SimDuration::from_secs(7);
        c.freshen_incarnation_guard = true;
        let c2 = Config::from_json(&c.to_json());
        assert_eq!(c2.queue, QueueKind::MemoryAware);
        assert_eq!(c2.queue_aging_bound, SimDuration::from_secs(7));
        assert!(c2.freshen_incarnation_guard);
        for k in QueueKind::all() {
            assert_eq!(QueueKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(QueueKind::parse("fifo_fair"), Some(QueueKind::FifoFair));
        assert_eq!(QueueKind::parse("memory_aware"), Some(QueueKind::MemoryAware));
        assert_eq!(QueueKind::parse("bogus"), None);
        // Defaults parse back from JSON unchanged.
        let back = Config::from_json(&Config::default().to_json());
        assert_eq!(back.queue, QueueKind::LegacyOneShot);
        assert_eq!(back.queue_aging_bound, SimDuration::from_secs(30));
        assert!(!back.freshen_incarnation_guard);
    }

    #[test]
    fn placement_and_host_class_knobs_roundtrip() {
        let d = Config::default();
        assert_eq!(
            d.placement,
            PlacementKind::LeastLoadedMb,
            "legacy least-loaded placement is the default"
        );
        assert!(d.host_classes.is_empty(), "homogeneous cluster by default");
        let mut c = Config::default();
        c.placement = PlacementKind::WarmAffinity;
        c.host_classes =
            HostClass::parse_list("cloud:2:4096:1000:local,edge:2:1024:1600:edge").unwrap();
        let c2 = Config::from_json(&c.to_json());
        assert_eq!(c2.placement, PlacementKind::WarmAffinity);
        assert_eq!(c2.host_classes, c.host_classes);
        assert_eq!(c2.host_classes[1].name, "edge");
        assert_eq!(c2.host_classes[1].cold_start_mult_permille, 1600);
        assert_eq!(c2.host_classes[1].net_profile, Site::Edge);
        // Short and long spellings both parse; every as_str round-trips.
        for k in PlacementKind::all() {
            assert_eq!(PlacementKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(PlacementKind::parse("round_robin"), Some(PlacementKind::RoundRobin));
        assert_eq!(PlacementKind::parse("warm_affinity"), Some(PlacementKind::WarmAffinity));
        assert_eq!(PlacementKind::parse("labels"), Some(PlacementKind::Constrained));
        assert_eq!(PlacementKind::parse("bogus"), None);
        assert_eq!(PlacementKind::LeastLoadedMb.code(), 0, "legacy span payloads unchanged");
        // Bad grammar clauses are rejected, not silently defaulted.
        assert_eq!(HostClass::parse("cloud:0:4096:1000:local"), None, "zero count");
        assert_eq!(HostClass::parse("cloud:2:0:1000:local"), None, "zero capacity");
        assert_eq!(HostClass::parse("cloud:2:4096:0:local"), None, "zero permille");
        assert_eq!(HostClass::parse(":2:4096:1000:local"), None, "empty name");
        assert_eq!(HostClass::parse("cloud:2:4096:1000:mars"), None, "unknown site");
        assert_eq!(HostClass::parse("cloud:2:4096:1000:local:extra"), None, "trailing field");
        assert_eq!(HostClass::parse("cloud:2:4096"), None, "missing fields");
        assert_eq!(HostClass::parse_list(""), None);
        // spec_str is the exact inverse of parse.
        let hc = HostClass::parse("edge:3:512:2500:remote").unwrap();
        assert_eq!(HostClass::parse(&hc.spec_str()), Some(hc));
        // Defaults serialize without host_classes and parse back empty.
        let back = Config::from_json(&Config::default().to_json());
        assert_eq!(back.placement, PlacementKind::LeastLoadedMb);
        assert!(back.host_classes.is_empty());
    }

    #[test]
    fn host_layout_expands_classes_in_order() {
        let mut c = Config::default();
        // Homogeneous: `invokers` hosts of the derived capacity, class 0.
        assert_eq!(c.host_layout(), vec![(0, 4096); 4]);
        c.invoker_memory_mb = Some(2048);
        assert_eq!(c.host_layout(), vec![(0, 2048); 4]);
        // Heterogeneous: classes replace the invokers/invoker_memory_mb
        // sizing entirely, expanded in declaration order.
        c.host_classes =
            HostClass::parse_list("cloud:2:4096:1000:local,edge:3:1024:1600:edge").unwrap();
        assert_eq!(
            c.host_layout(),
            vec![(0, 4096), (0, 4096), (1, 1024), (1, 1024), (1, 1024)]
        );
    }

    #[test]
    fn snapshot_knobs_roundtrip() {
        let d = Config::default();
        assert!(!d.snapshot.enabled, "snapshot mitigation defaults off");
        assert!(!d.snapshot.freshen_on_restore);
        // Defaults serialize WITHOUT a snapshot object (legacy headers
        // unchanged) and parse back to the defaults.
        assert!(d.to_json().get("snapshot").is_none());
        let back = Config::from_json(&d.to_json());
        assert_eq!(back.snapshot, SnapshotConfig::default());
        // Non-default knobs round-trip exactly.
        let mut c = Config::default();
        c.snapshot.enabled = true;
        c.snapshot.charge_permille = 125;
        c.snapshot.restore_base = SimDuration::from_millis(40);
        c.snapshot.page_in_us_per_mb = 90;
        c.snapshot.prefetch = true;
        c.snapshot.prefetch_permille = 200;
        c.snapshot.freshen_on_restore = true;
        let c2 = Config::from_json(&c.to_json());
        assert_eq!(c2.snapshot, c.snapshot);
    }

    #[test]
    fn category_parse() {
        assert_eq!(
            ServiceCategory::parse("latency_sensitive"),
            Some(ServiceCategory::LatencySensitive)
        );
        assert_eq!(ServiceCategory::parse("bogus"), None);
        assert!(ServiceCategory::LatencyInsensitive
            .confidence_floor()
            .is_infinite());
    }
}
