//! Platform configuration.
//!
//! All knobs a deployment would set live here: container pool sizing,
//! cold-start costs, network site parameters, freshen policy defaults.
//! Configs load from JSON (see `Config::from_json`) so examples and the CLI
//! can share experiment setups; every field has a sensible default drawn
//! from the paper (or from the OpenWhisk defaults the paper builds on).

use crate::util::json::Json;
use crate::util::time::SimDuration;

/// Top-level platform configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of invoker hosts in the cluster.
    pub invokers: usize,
    /// Max concurrently-resident containers per invoker host.
    pub containers_per_invoker: usize,
    /// Cold-start cost: container provision + runtime `init` hook.
    pub cold_start: SimDuration,
    /// Warm-start dispatch overhead (`run` hook on a live runtime).
    pub warm_start: SimDuration,
    /// Idle duration after which a warm container is evicted
    /// (OpenWhisk's default stem-cell keep-alive is 10 minutes).
    pub idle_eviction: SimDuration,
    /// Whether different functions may share a warmed container
    /// (the paper cites [13]: most providers disallow it).
    pub allow_container_sharing: bool,
    /// Isolation scope (§6: "integrating freshen into serverless
    /// architectures that provide different isolation scopes" — Azure
    /// offers chain-level isolation). Under [`IsolationScope::PerApp`], a
    /// warm container of the same app can be re-inited for a sibling
    /// function at a fraction of a cold start, *keeping its runtime-scoped
    /// connections and freshen cache* — so freshen benefits compound
    /// across a chain's stages.
    pub isolation: IsolationScope,
    /// Freshen policy knobs.
    pub freshen: FreshenConfig,
    /// Default TTL for entries in the freshen prefetch cache.
    pub seed: u64,
}

/// Freshen policy configuration (§3.3 billing/abuse controls).
#[derive(Debug, Clone)]
pub struct FreshenConfig {
    /// Master switch; `false` reproduces the vanilla-platform baselines.
    pub enabled: bool,
    /// Minimum prediction confidence required to launch a freshen
    /// (mispredicted freshens bill the app owner, so providers gate).
    pub min_confidence: f64,
    /// Default TTL for prefetched data in the freshen cache.
    pub default_ttl: SimDuration,
    /// Per-app cap on freshen invocations per minute (abuse guard).
    pub max_freshens_per_min: u32,
    /// Service category: aggressive freshen for latency-sensitive apps.
    pub category: ServiceCategory,
}

/// Container isolation scope.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IsolationScope {
    /// AWS-style: a container only ever hosts one function's code.
    PerFunction,
    /// Azure-chain-style: containers are shared within an application;
    /// switching functions costs a re-init, not a cold start.
    PerApp,
}

impl IsolationScope {
    pub fn parse(s: &str) -> Option<IsolationScope> {
        match s {
            "per_function" => Some(IsolationScope::PerFunction),
            "per_app" => Some(IsolationScope::PerApp),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            IsolationScope::PerFunction => "per_function",
            IsolationScope::PerApp => "per_app",
        }
    }
}

/// Developer-chosen service category (§3.3): controls how aggressively the
/// provider freshens on the app's behalf.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceCategory {
    /// Freshen on every confident prediction.
    LatencySensitive,
    /// Freshen only on high-confidence predictions.
    Standard,
    /// Never freshen.
    LatencyInsensitive,
}

impl ServiceCategory {
    pub fn parse(s: &str) -> Option<ServiceCategory> {
        match s {
            "latency_sensitive" => Some(ServiceCategory::LatencySensitive),
            "standard" => Some(ServiceCategory::Standard),
            "latency_insensitive" => Some(ServiceCategory::LatencyInsensitive),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ServiceCategory::LatencySensitive => "latency_sensitive",
            ServiceCategory::Standard => "standard",
            ServiceCategory::LatencyInsensitive => "latency_insensitive",
        }
    }

    /// The confidence threshold this category implies (overrides the
    /// numeric `min_confidence` when stricter).
    pub fn confidence_floor(&self) -> f64 {
        match self {
            ServiceCategory::LatencySensitive => 0.2,
            ServiceCategory::Standard => 0.5,
            ServiceCategory::LatencyInsensitive => f64::INFINITY,
        }
    }
}

impl Default for FreshenConfig {
    fn default() -> FreshenConfig {
        FreshenConfig {
            enabled: true,
            min_confidence: 0.5,
            default_ttl: SimDuration::from_secs(10),
            max_freshens_per_min: 600,
            category: ServiceCategory::Standard,
        }
    }
}

impl Default for Config {
    fn default() -> Config {
        Config {
            invokers: 4,
            containers_per_invoker: 16,
            // OpenWhisk docker cold starts are hundreds of ms; the paper's
            // related work (SOCK) reports ~100ms-1s. We default to 500ms.
            cold_start: SimDuration::from_millis(500),
            warm_start: SimDuration::from_millis(5),
            idle_eviction: SimDuration::from_secs(600),
            allow_container_sharing: false,
            isolation: IsolationScope::PerFunction,
            freshen: FreshenConfig::default(),
            seed: 0xF5E5_4E55, // "FRESHENESS"
        }
    }
}

impl Config {
    /// Load from a JSON object; missing keys keep their defaults.
    pub fn from_json(j: &Json) -> Config {
        let mut c = Config::default();
        c.invokers = j.u64_or("invokers", c.invokers as u64) as usize;
        c.containers_per_invoker =
            j.u64_or("containers_per_invoker", c.containers_per_invoker as u64) as usize;
        c.cold_start = SimDuration::from_millis_f64(
            j.f64_or("cold_start_ms", c.cold_start.as_millis_f64()),
        );
        c.warm_start = SimDuration::from_millis_f64(
            j.f64_or("warm_start_ms", c.warm_start.as_millis_f64()),
        );
        c.idle_eviction = SimDuration::from_secs_f64(
            j.f64_or("idle_eviction_s", c.idle_eviction.as_secs_f64()),
        );
        c.allow_container_sharing =
            j.bool_or("allow_container_sharing", c.allow_container_sharing);
        if let Some(iso) = j.get("isolation").and_then(Json::as_str) {
            if let Some(parsed) = IsolationScope::parse(iso) {
                c.isolation = parsed;
            }
        }
        c.seed = j.u64_or("seed", c.seed);
        if let Some(fj) = j.get("freshen") {
            c.freshen.enabled = fj.bool_or("enabled", c.freshen.enabled);
            c.freshen.min_confidence = fj.f64_or("min_confidence", c.freshen.min_confidence);
            c.freshen.default_ttl = SimDuration::from_secs_f64(
                fj.f64_or("default_ttl_s", c.freshen.default_ttl.as_secs_f64()),
            );
            c.freshen.max_freshens_per_min =
                fj.u64_or("max_freshens_per_min", c.freshen.max_freshens_per_min as u64) as u32;
            if let Some(cat) = fj.get("category").and_then(Json::as_str) {
                if let Some(parsed) = ServiceCategory::parse(cat) {
                    c.freshen.category = parsed;
                }
            }
        }
        c
    }

    /// Serialize back to JSON (for report headers).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("invokers", Json::num(self.invokers as f64)),
            (
                "containers_per_invoker",
                Json::num(self.containers_per_invoker as f64),
            ),
            ("cold_start_ms", Json::num(self.cold_start.as_millis_f64())),
            ("warm_start_ms", Json::num(self.warm_start.as_millis_f64())),
            (
                "idle_eviction_s",
                Json::num(self.idle_eviction.as_secs_f64()),
            ),
            (
                "allow_container_sharing",
                Json::Bool(self.allow_container_sharing),
            ),
            ("isolation", Json::str(self.isolation.as_str())),
            ("seed", Json::num(self.seed as f64)),
            (
                "freshen",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.freshen.enabled)),
                    ("min_confidence", Json::num(self.freshen.min_confidence)),
                    (
                        "default_ttl_s",
                        Json::num(self.freshen.default_ttl.as_secs_f64()),
                    ),
                    (
                        "max_freshens_per_min",
                        Json::num(self.freshen.max_freshens_per_min as f64),
                    ),
                    ("category", Json::str(self.freshen.category.as_str())),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert!(c.invokers > 0);
        assert!(c.cold_start > c.warm_start);
        assert!(c.freshen.enabled);
    }

    #[test]
    fn json_roundtrip() {
        let c = Config::default();
        let j = c.to_json();
        let c2 = Config::from_json(&j);
        assert_eq!(c2.invokers, c.invokers);
        assert_eq!(c2.cold_start, c.cold_start);
        assert_eq!(c2.freshen.category, c.freshen.category);
        assert_eq!(c2.freshen.default_ttl, c.freshen.default_ttl);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"invokers": 2, "freshen": {"enabled": false}}"#).unwrap();
        let c = Config::from_json(&j);
        assert_eq!(c.invokers, 2);
        assert!(!c.freshen.enabled);
        // untouched key keeps default
        assert_eq!(c.containers_per_invoker, Config::default().containers_per_invoker);
    }

    #[test]
    fn category_parse() {
        assert_eq!(
            ServiceCategory::parse("latency_sensitive"),
            Some(ServiceCategory::LatencySensitive)
        );
        assert_eq!(ServiceCategory::parse("bogus"), None);
        assert!(ServiceCategory::LatencyInsensitive
            .confidence_floor()
            .is_infinite());
    }
}
