//! A fast, non-cryptographic hasher (FxHash, as used by rustc) for the
//! simulator's hot-path maps. SipHash's DoS resistance buys nothing inside
//! a deterministic simulation, and its cost shows up in the event loop and
//! per-op endpoint/connection lookups (§Perf change 2).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The rustc FxHasher: multiply-rotate word mixing.
#[derive(Debug, Clone, Copy, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rest.len()].copy_from_slice(rest);
            self.add_to_hash(u64::from_le_bytes(tail) ^ rest.len() as u64);
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add_to_hash(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add_to_hash(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add_to_hash(n as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_and_set_work() {
        let mut m: FxHashMap<String, u32> = FxHashMap::default();
        m.insert("a".into(), 1);
        m.insert("b".into(), 2);
        assert_eq!(m.get("a"), Some(&1));
        assert_eq!(m.len(), 2);
        let mut s: FxHashSet<u64> = FxHashSet::default();
        for i in 0..1000 {
            s.insert(i);
        }
        assert_eq!(s.len(), 1000);
        assert!(s.contains(&999));
    }

    #[test]
    fn distributes_sequential_keys() {
        // Sanity: sequential u64s should not all collide in low bits.
        use std::hash::{BuildHasher, Hash};
        let bh = FxBuildHasher::default();
        let mut low_bits: FxHashSet<u64> = FxHashSet::default();
        for i in 0u64..64 {
            let mut h = bh.build_hasher();
            i.hash(&mut h);
            low_bits.insert(h.finish() & 0x3f);
        }
        assert!(low_bits.len() > 16, "poor low-bit spread: {}", low_bits.len());
    }
}
