//! Virtual time for the discrete-event substrate.
//!
//! All simulator state is timestamped in integer **microseconds** so event
//! ordering is exact (no float comparisons in the hot loop). Conversions to
//! and from floating-point seconds are provided at the edges (reports,
//! configuration) only.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time, microseconds since simulation start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

/// A span of virtual time, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimDuration(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn micros(self) -> u64 {
        self.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }

    /// Time elapsed since `earlier`; saturates to zero if `earlier` is later.
    pub fn since(self, earlier: SimTime) -> SimDuration {
        SimDuration(self.0.saturating_sub(earlier.0))
    }
}

impl SimDuration {
    pub const ZERO: SimDuration = SimDuration(0);

    pub fn from_micros(us: u64) -> SimDuration {
        SimDuration(us)
    }

    pub fn from_millis(ms: u64) -> SimDuration {
        SimDuration(ms * 1_000)
    }

    pub fn from_secs(s: u64) -> SimDuration {
        SimDuration(s * 1_000_000)
    }

    /// Build from floating seconds, rounding to the nearest microsecond.
    /// Negative inputs clamp to zero (useful for sampled distributions).
    pub fn from_secs_f64(s: f64) -> SimDuration {
        if s <= 0.0 || !s.is_finite() {
            SimDuration(0)
        } else {
            SimDuration((s * 1e6).round() as u64)
        }
    }

    pub fn from_millis_f64(ms: f64) -> SimDuration {
        Self::from_secs_f64(ms / 1e3)
    }

    pub fn micros(self) -> u64 {
        self.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }

    pub fn saturating_sub(self, other: SimDuration) -> SimDuration {
        SimDuration(self.0.saturating_sub(other.0))
    }

    pub fn min(self, other: SimDuration) -> SimDuration {
        SimDuration(self.0.min(other.0))
    }

    pub fn max(self, other: SimDuration) -> SimDuration {
        SimDuration(self.0.max(other.0))
    }

    pub fn mul_f64(self, k: f64) -> SimDuration {
        SimDuration::from_secs_f64(self.as_secs_f64() * k)
    }

    pub fn is_zero(self) -> bool {
        self.0 == 0
    }
}

impl Add<SimDuration> for SimTime {
    type Output = SimTime;
    fn add(self, d: SimDuration) -> SimTime {
        SimTime(self.0 + d.0)
    }
}

impl AddAssign<SimDuration> for SimTime {
    fn add_assign(&mut self, d: SimDuration) {
        self.0 += d.0;
    }
}

impl Sub<SimTime> for SimTime {
    type Output = SimDuration;
    fn sub(self, rhs: SimTime) -> SimDuration {
        SimDuration(self.0.saturating_sub(rhs.0))
    }
}

impl Add for SimDuration {
    type Output = SimDuration;
    fn add(self, rhs: SimDuration) -> SimDuration {
        SimDuration(self.0 + rhs.0)
    }
}

impl AddAssign for SimDuration {
    fn add_assign(&mut self, rhs: SimDuration) {
        self.0 += rhs.0;
    }
}

impl Sub for SimDuration {
    type Output = SimDuration;
    fn sub(self, rhs: SimDuration) -> SimDuration {
        SimDuration(self.0.saturating_sub(rhs.0))
    }
}

impl std::iter::Sum for SimDuration {
    fn sum<I: Iterator<Item = SimDuration>>(iter: I) -> SimDuration {
        SimDuration(iter.map(|d| d.0).sum())
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs_f64())
    }
}

impl fmt::Display for SimDuration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 < 1_000 {
            write!(f, "{}us", self.0)
        } else if self.0 < 1_000_000 {
            write!(f, "{:.3}ms", self.as_millis_f64())
        } else {
            write!(f, "{:.3}s", self.as_secs_f64())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_roundtrips() {
        let t = SimTime::ZERO + SimDuration::from_millis(5);
        assert_eq!(t.micros(), 5_000);
        assert_eq!((t + SimDuration::from_micros(1)) - t, SimDuration(1));
        assert_eq!(t.since(SimTime::ZERO), SimDuration::from_millis(5));
        // saturation, not underflow
        assert_eq!(SimTime::ZERO.since(t), SimDuration::ZERO);
    }

    #[test]
    fn float_conversions() {
        assert_eq!(SimDuration::from_secs_f64(0.001).micros(), 1_000);
        assert_eq!(SimDuration::from_secs_f64(-3.0), SimDuration::ZERO);
        assert_eq!(SimDuration::from_secs_f64(f64::NAN), SimDuration::ZERO);
        let d = SimDuration::from_millis_f64(1.5);
        assert_eq!(d.micros(), 1_500);
        assert!((d.as_secs_f64() - 0.0015).abs() < 1e-12);
    }

    #[test]
    fn display_scales_units() {
        assert_eq!(format!("{}", SimDuration::from_micros(7)), "7us");
        assert_eq!(format!("{}", SimDuration::from_millis(12)), "12.000ms");
        assert_eq!(format!("{}", SimDuration::from_secs(3)), "3.000s");
    }

    #[test]
    fn sum_and_scale() {
        let total: SimDuration = (1..=4).map(SimDuration::from_millis).sum();
        assert_eq!(total, SimDuration::from_millis(10));
        assert_eq!(total.mul_f64(0.5), SimDuration::from_millis(5));
    }
}
