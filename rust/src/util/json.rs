//! A minimal JSON value, parser and serializer.
//!
//! `serde`/`serde_json` are unavailable in the offline vendor set, so configs,
//! traces, and experiment reports use this ~400-line implementation instead.
//! It supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) and pretty/compact printing. Object key order is
//! preserved (insertion order), which keeps emitted reports stable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Fetch `key` as f64, falling back to `default` when missing.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Json::as_u64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    /// Set/replace a key on an object (no-op on non-objects).
    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(pairs) = self {
            if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                slot.1 = value;
            } else {
                pairs.push((key.to_string(), value));
            }
        }
    }

    /// Convert to a sorted map (useful for comparisons that ignore order).
    pub fn to_map(&self) -> Option<BTreeMap<String, Json>> {
        match self {
            Json::Obj(pairs) => Some(pairs.iter().cloned().collect()),
            _ => None,
        }
    }

    // ---- parse / print ---------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact single-line rendering.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty rendering with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_number(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            pairs.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Handle surrogate pairs for completeness.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("invalid \\u escape"))?);
                            // hex4 advanced pos already; compensate for the
                            // unconditional advance below.
                            self.pos -= 1;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character (multi-byte safe).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A 😀"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Json::obj(vec![
            ("name", Json::str("λ1")),
            ("sizes", Json::arr([1.0, 2.5].map(Json::num))),
            ("warm", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        for text in [v.to_string(), v.pretty()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, v);
        }
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }

    #[test]
    fn errors_carry_offsets() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.offset > 0);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn accessor_defaults() {
        let v = Json::parse(r#"{"x": 3, "flag": true, "s": "v"}"#).unwrap();
        assert_eq!(v.f64_or("x", 0.0), 3.0);
        assert_eq!(v.f64_or("missing", 9.0), 9.0);
        assert_eq!(v.u64_or("x", 0), 3);
        assert!(v.bool_or("flag", false));
        assert_eq!(v.str_or("s", "d"), "v");
        assert_eq!(v.str_or("nope", "d"), "d");
    }

    #[test]
    fn set_replaces_or_appends() {
        let mut v = Json::obj(vec![("a", Json::num(1.0))]);
        v.set("a", Json::num(2.0));
        v.set("b", Json::str("new"));
        assert_eq!(v.f64_or("a", 0.0), 2.0);
        assert_eq!(v.str_or("b", ""), "new");
    }
}
