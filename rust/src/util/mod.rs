//! Substrate utilities.
//!
//! The offline build has no access to `rand`, `serde`, or `statrs`; these
//! modules are small, deterministic, in-repo replacements (see DESIGN.md
//! §Offline-toolchain substitutions).

pub mod config;
pub mod fxhash;
pub mod json;
pub mod rng;
pub mod stats;
pub mod time;

pub use rng::Rng;
pub use time::{SimDuration, SimTime};
