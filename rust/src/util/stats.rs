//! Descriptive statistics: summaries, percentiles, CDFs, and histograms.
//!
//! Every paper artifact we regenerate is either a table of medians (Table 1),
//! a CDF (Figure 2), or a latency-vs-parameter series (Figures 4–6); this
//! module is the shared machinery that turns raw samples into those shapes.

use crate::util::time::SimDuration;

/// A five-number-plus summary over a sample of `f64`s.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub p25: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `None` on an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut xs: Vec<f64> = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Some(Summary {
            count: n,
            mean,
            std_dev: var.sqrt(),
            min: xs[0],
            p25: percentile_sorted(&xs, 25.0),
            p50: percentile_sorted(&xs, 50.0),
            p90: percentile_sorted(&xs, 90.0),
            p95: percentile_sorted(&xs, 95.0),
            p99: percentile_sorted(&xs, 99.0),
            max: xs[n - 1],
        })
    }

    /// Summary over durations, reported in milliseconds.
    pub fn of_durations_ms(samples: &[SimDuration]) -> Option<Summary> {
        let xs: Vec<f64> = samples.iter().map(|d| d.as_millis_f64()).collect();
        Summary::of(&xs)
    }
}

/// Percentile with linear interpolation over an already-sorted slice.
/// `q` is in `[0, 100]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median of an unsorted sample.
pub fn median(samples: &[f64]) -> f64 {
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
    percentile_sorted(&xs, 50.0)
}

/// An empirical CDF: `points()` yields `(x, F(x))` suitable for plotting,
/// exactly what Figure 2 shows for functions-per-application.
#[derive(Debug, Clone)]
pub struct Cdf {
    sorted: Vec<f64>,
}

impl Cdf {
    pub fn of(samples: &[f64]) -> Cdf {
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        Cdf { sorted }
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// Fraction of samples `<= x`.
    pub fn at(&self, x: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let idx = self.sorted.partition_point(|&v| v <= x);
        idx as f64 / self.sorted.len() as f64
    }

    /// Inverse CDF (quantile), `q` in `[0, 100]`.
    pub fn quantile(&self, q: f64) -> f64 {
        percentile_sorted(&self.sorted, q)
    }

    /// Step points `(x, F(x))`, deduplicated on x (last step wins).
    pub fn points(&self) -> Vec<(f64, f64)> {
        let n = self.sorted.len();
        let mut out: Vec<(f64, f64)> = Vec::new();
        for (i, &x) in self.sorted.iter().enumerate() {
            let f = (i + 1) as f64 / n as f64;
            match out.last_mut() {
                Some(last) if last.0 == x => last.1 = f,
                _ => out.push((x, f)),
            }
        }
        out
    }

    /// Evaluate the CDF over a fixed grid — stable series for reports.
    pub fn series(&self, grid: &[f64]) -> Vec<(f64, f64)> {
        grid.iter().map(|&x| (x, self.at(x))).collect()
    }
}

/// Fixed-width binned histogram over `[lo, hi)`; used by the IAT predictor
/// (Shahrad-style) and by latency reports.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    underflow: u64,
    overflow: u64,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Histogram {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
            count: 0,
        }
    }

    pub fn record(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let w = (self.hi - self.lo) / self.bins.len() as f64;
            let idx = (((x - self.lo) / w) as usize).min(self.bins.len() - 1);
            self.bins[idx] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    /// Center of bin `i`.
    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }

    /// Index of the modal bin, `None` if no in-range samples.
    pub fn mode_bin(&self) -> Option<usize> {
        if self.bins.iter().all(|&b| b == 0) {
            return None;
        }
        let mut best = 0;
        for (i, &b) in self.bins.iter().enumerate() {
            if b > self.bins[best] {
                best = i;
            }
        }
        Some(best)
    }

    /// Fraction of in-range mass in the modal bin — a simple confidence
    /// signal for the histogram predictor.
    pub fn mode_concentration(&self) -> f64 {
        let in_range: u64 = self.bins.iter().sum();
        if in_range == 0 {
            return 0.0;
        }
        match self.mode_bin() {
            Some(i) => self.bins[i] as f64 / in_range as f64,
            None => 0.0,
        }
    }
}

/// Online mean/max counter for throughput-style metrics.
#[derive(Debug, Clone, Default)]
pub struct Running {
    pub count: u64,
    pub sum: f64,
    pub max: f64,
    pub min: f64,
}

impl Running {
    pub fn record(&mut self, x: f64) {
        if self.count == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.count += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
        assert_eq!(percentile_sorted(&[7.0], 33.0), 7.0);
    }

    #[test]
    fn cdf_monotone_and_correct() {
        let cdf = Cdf::of(&[1.0, 1.0, 2.0, 4.0]);
        assert_eq!(cdf.at(0.5), 0.0);
        assert_eq!(cdf.at(1.0), 0.5);
        assert_eq!(cdf.at(2.0), 0.75);
        assert_eq!(cdf.at(100.0), 1.0);
        let pts = cdf.points();
        assert_eq!(pts, vec![(1.0, 0.5), (2.0, 0.75), (4.0, 1.0)]);
        // monotone
        for w in pts.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn cdf_quantile_inverts() {
        let cdf = Cdf::of(&(0..101).map(|i| i as f64).collect::<Vec<_>>());
        assert!((cdf.quantile(50.0) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.record(i as f64 + 0.5);
        }
        h.record(-1.0);
        h.record(42.0);
        assert_eq!(h.count(), 12);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
        assert!(h.bins().iter().all(|&b| b == 1));
        assert!((h.bin_center(0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_mode_and_concentration() {
        let mut h = Histogram::new(0.0, 4.0, 4);
        for _ in 0..6 {
            h.record(1.5);
        }
        h.record(0.5);
        h.record(3.5);
        assert_eq!(h.mode_bin(), Some(1));
        assert!((h.mode_concentration() - 0.75).abs() < 1e-12);
        let empty = Histogram::new(0.0, 1.0, 4);
        assert_eq!(empty.mode_bin(), None);
        assert_eq!(empty.mode_concentration(), 0.0);
    }

    #[test]
    fn running_counter() {
        let mut r = Running::default();
        r.record(2.0);
        r.record(6.0);
        assert_eq!(r.count, 2);
        assert_eq!(r.mean(), 4.0);
        assert_eq!(r.min, 2.0);
        assert_eq!(r.max, 6.0);
    }
}
