//! Billing and accounting (§3.3 "Billing and accounting").
//!
//! "Since freshen runs in order to benefit the serverless application, the
//! serverless application owner should pay for it." The ledger attributes
//! every cost — invocation GB-seconds, freshen GB-seconds (useful or
//! wasted), and network bytes — to the owning app, so the confidence-gating
//! ablation can report the cost of mispredictions, and so providers can see
//! the revenue case ("a way to monetize warmed containers that are
//! otherwise sitting idle").

use crate::util::fxhash::FxHashMap;
use crate::util::time::SimDuration;

/// Billable line items per app.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AppAccount {
    /// GB-seconds consumed by function execution.
    pub exec_gb_s: f64,
    /// GB-seconds consumed by freshen runs whose prediction hit.
    pub freshen_useful_gb_s: f64,
    /// GB-seconds consumed by freshen runs whose prediction missed.
    pub freshen_wasted_gb_s: f64,
    /// Bytes moved on the app's behalf (functions + freshen).
    pub network_bytes: f64,
    /// Bytes the freshen cache saved (prefetch reuse).
    pub network_bytes_saved: f64,
    pub invocations: u64,
    pub freshens: u64,
}

impl AppAccount {
    /// Total billable GB-seconds.
    pub fn total_gb_s(&self) -> f64 {
        self.exec_gb_s + self.freshen_useful_gb_s + self.freshen_wasted_gb_s
    }

    /// Fraction of freshen spend that was wasted on mispredictions.
    pub fn waste_ratio(&self) -> f64 {
        let total = self.freshen_useful_gb_s + self.freshen_wasted_gb_s;
        if total == 0.0 {
            0.0
        } else {
            self.freshen_wasted_gb_s / total
        }
    }
}

/// Platform-wide ledger.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    /// Fx (deterministic-order) map: [`Ledger::totals`] sums f64 line items
    /// by iterating values, and float addition does not commute exactly —
    /// a std HashMap here would make total rounding differ run-to-run.
    accounts: FxHashMap<String, AppAccount>,
}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    fn acct(&mut self, app: &str) -> &mut AppAccount {
        self.accounts.entry(app.to_string()).or_default()
    }

    /// Charge a function execution.
    pub fn charge_execution(&mut self, app: &str, memory_mb: u32, duration: SimDuration) {
        let gb_s = memory_mb as f64 / 1024.0 * duration.as_secs_f64();
        let a = self.acct(app);
        a.exec_gb_s += gb_s;
        a.invocations += 1;
    }

    /// Charge a freshen run; `useful` = the predicted invocation arrived.
    pub fn charge_freshen(
        &mut self,
        app: &str,
        memory_mb: u32,
        duration: SimDuration,
        useful: bool,
    ) {
        let gb_s = memory_mb as f64 / 1024.0 * duration.as_secs_f64();
        let a = self.acct(app);
        if useful {
            a.freshen_useful_gb_s += gb_s;
        } else {
            a.freshen_wasted_gb_s += gb_s;
        }
        a.freshens += 1;
    }

    pub fn charge_network(&mut self, app: &str, bytes: f64) {
        self.acct(app).network_bytes += bytes;
    }

    pub fn credit_network_saved(&mut self, app: &str, bytes: f64) {
        self.acct(app).network_bytes_saved += bytes;
    }

    pub fn account(&self, app: &str) -> AppAccount {
        self.accounts.get(app).copied().unwrap_or_default()
    }

    pub fn apps(&self) -> Vec<&String> {
        let mut v: Vec<&String> = self.accounts.keys().collect();
        v.sort();
        v
    }

    /// Platform totals.
    pub fn totals(&self) -> AppAccount {
        let mut t = AppAccount::default();
        for a in self.accounts.values() {
            t.exec_gb_s += a.exec_gb_s;
            t.freshen_useful_gb_s += a.freshen_useful_gb_s;
            t.freshen_wasted_gb_s += a.freshen_wasted_gb_s;
            t.network_bytes += a.network_bytes;
            t.network_bytes_saved += a.network_bytes_saved;
            t.invocations += a.invocations;
            t.freshens += a.freshens;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn execution_charges_gb_seconds() {
        let mut l = Ledger::new();
        // 1024 MB for 2s = 2 GB-s
        l.charge_execution("app", 1024, SimDuration::from_secs(2));
        let a = l.account("app");
        assert!((a.exec_gb_s - 2.0).abs() < 1e-12);
        assert_eq!(a.invocations, 1);
    }

    #[test]
    fn freshen_waste_tracked_separately() {
        let mut l = Ledger::new();
        l.charge_freshen("app", 1024, SimDuration::from_secs(1), true);
        l.charge_freshen("app", 1024, SimDuration::from_secs(1), false);
        l.charge_freshen("app", 1024, SimDuration::from_secs(2), false);
        let a = l.account("app");
        assert!((a.freshen_useful_gb_s - 1.0).abs() < 1e-12);
        assert!((a.freshen_wasted_gb_s - 3.0).abs() < 1e-12);
        assert!((a.waste_ratio() - 0.75).abs() < 1e-12);
        assert_eq!(a.freshens, 3);
    }

    #[test]
    fn network_and_totals() {
        let mut l = Ledger::new();
        l.charge_network("a", 100.0);
        l.charge_network("b", 50.0);
        l.credit_network_saved("a", 40.0);
        let t = l.totals();
        assert_eq!(t.network_bytes, 150.0);
        assert_eq!(t.network_bytes_saved, 40.0);
        assert_eq!(l.apps(), vec![&"a".to_string(), &"b".to_string()]);
    }

    #[test]
    fn unknown_app_is_zeroed() {
        let l = Ledger::new();
        assert_eq!(l.account("ghost"), AppAccount::default());
        assert_eq!(l.account("ghost").waste_ratio(), 0.0);
    }
}
