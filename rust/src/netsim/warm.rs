//! The `warm_cwnd` syscall model (§3.2 "Connection warming").
//!
//! The paper proposes a new system call through which freshen sets a
//! connection's congestion window before the function runs. The final CWND
//! value — and whether warming is permitted at all — is decided by the
//! *provider* (the host kernel), based on an estimate of path capacity:
//! packet-pair probing [Keshav '95] or the CWND of recent connections to the
//! same destination.

use crate::netsim::cc::MSS;
use crate::netsim::link::Link;
use crate::netsim::tcp::{Connection, TransferDirection};
use crate::util::rng::Rng;
use crate::util::time::{SimDuration, SimTime};

/// Provider-side policy for `warm_cwnd` requests.
#[derive(Debug, Clone)]
pub struct WarmPolicy {
    /// Master switch: the host provider may disallow warming entirely.
    pub allowed: bool,
    /// Hard cap on the granted window, as a multiple of the path BDP
    /// estimate (prevents a tenant from pre-loading an abusive burst).
    pub max_bdp_fraction: f64,
    /// Absolute cap in bytes regardless of BDP.
    pub max_bytes: f64,
}

impl Default for WarmPolicy {
    fn default() -> WarmPolicy {
        WarmPolicy {
            allowed: true,
            max_bdp_fraction: 1.0,
            max_bytes: 16.0 * 1024.0 * 1024.0,
        }
    }
}

/// Outcome of a `warm_cwnd` call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WarmOutcome {
    /// Window set to this many bytes.
    Granted(f64),
    /// Provider policy refused; window unchanged.
    Denied,
}

/// Packet-pair bandwidth probe: sends two back-to-back MSS segments and
/// derives the bottleneck bandwidth from their spacing at the receiver.
/// Returns `(probe_duration, bandwidth_estimate_bytes_per_sec)`. The
/// estimate carries measurement noise.
pub fn packet_pair_probe(link: &Link, rng: &mut Rng) -> (SimDuration, f64) {
    // Two segments + echo: one RTT plus double serialization.
    let rtt = link.sample_rtt(rng);
    let dur = rtt + 2.0 * link.serialize(MSS);
    // Dispersion-based estimate: true bandwidth with ~10% multiplicative
    // noise (receiver timestamping granularity).
    let estimate = link.bandwidth * rng.lognormal(0.0, 0.10);
    (SimDuration::from_secs_f64(dur), estimate)
}

/// History of recently-observed CWND values per destination — the paper's
/// second estimation strategy ("analyzing the CWND of recent similar TCP
/// connections to the same destination").
#[derive(Debug, Clone, Default)]
pub struct CwndHistory {
    samples: Vec<(SimTime, f64)>,
    cap: usize,
}

impl CwndHistory {
    pub fn new() -> CwndHistory {
        CwndHistory {
            samples: Vec::new(),
            cap: 32,
        }
    }

    pub fn record(&mut self, at: SimTime, cwnd: f64) {
        self.samples.push((at, cwnd));
        if self.samples.len() > self.cap {
            self.samples.remove(0);
        }
    }

    /// Median of samples within `window` of `now`; `None` if no history.
    pub fn recent_estimate(&self, now: SimTime, window: SimDuration) -> Option<f64> {
        let mut xs: Vec<f64> = self
            .samples
            .iter()
            .filter(|(t, _)| now.since(*t) <= window)
            .map(|(_, w)| *w)
            .collect();
        if xs.is_empty() {
            return None;
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(xs[xs.len() / 2])
    }
}

/// The `warm_cwnd` syscall: ask the provider to set `conn`'s send window
/// for `dir` to sustain `anticipated_bytes`. The provider estimates path
/// capacity (history first, probe as fallback), clamps by policy, and
/// applies. Returns the outcome and the wall time the call consumed
/// (probing is not free — freshen pays it off the critical path).
pub fn warm_cwnd(
    conn: &mut Connection,
    dir: TransferDirection,
    anticipated_bytes: f64,
    policy: &WarmPolicy,
    history: &mut CwndHistory,
    now: SimTime,
    rng: &mut Rng,
) -> (WarmOutcome, SimDuration) {
    if !policy.allowed {
        return (WarmOutcome::Denied, SimDuration::ZERO);
    }
    // Capacity estimate: recent-connection history, else packet-pair probe.
    let (bw_est, probe_time) =
        match history.recent_estimate(now, SimDuration::from_secs(60)) {
            Some(w) => (w / conn.link.rtt, SimDuration::ZERO),
            None => {
                let (d, bw) = packet_pair_probe(&conn.link, rng);
                (bw, d)
            }
        };
    let bdp_est = bw_est * conn.link.rtt;
    let target = anticipated_bytes
        .min(bdp_est * policy.max_bdp_fraction)
        .min(policy.max_bytes)
        .max(Connection::initial_cwnd());
    let cc = match dir {
        TransferDirection::Upload => &mut conn.cc_tx,
        TransferDirection::Download => &mut conn.cc_rx,
    };
    cc.set_cwnd(target);
    history.record(now, target);
    (WarmOutcome::Granted(target), probe_time)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::cc::CongestionControl;
    use crate::netsim::link::Site;

    fn conn() -> Connection {
        let mut link = Site::Remote.link();
        link.jitter_sigma = 0.0;
        Connection::new(link, CongestionControl::Cubic)
    }

    #[test]
    fn warm_grows_window_and_speeds_transfer() {
        let mut rng = Rng::new(1);
        let mut c = conn();
        c.connect(SimTime::ZERO, &mut rng);
        let w0 = c.cwnd(TransferDirection::Upload);
        let mut hist = CwndHistory::new();
        let (outcome, _) = warm_cwnd(
            &mut c,
            TransferDirection::Upload,
            8e6,
            &WarmPolicy::default(),
            &mut hist,
            SimTime(1),
            &mut rng,
        );
        match outcome {
            WarmOutcome::Granted(w) => assert!(w > 10.0 * w0, "granted {w}"),
            WarmOutcome::Denied => panic!("should grant"),
        }
        // Warmed transfer is faster than a cold one.
        let t_warm = c.send_with_ack(SimTime(2), &mut rng, 5e6, 0.0);
        let mut cold = conn();
        cold.connect(SimTime::ZERO, &mut rng);
        let t_cold = cold.send_with_ack(SimTime(2), &mut rng, 5e6, 0.0);
        assert!(t_warm.as_secs_f64() < 0.6 * t_cold.as_secs_f64());
    }

    #[test]
    fn policy_denies_when_disallowed() {
        let mut rng = Rng::new(2);
        let mut c = conn();
        c.connect(SimTime::ZERO, &mut rng);
        let w0 = c.cwnd(TransferDirection::Upload);
        let mut hist = CwndHistory::new();
        let policy = WarmPolicy {
            allowed: false,
            ..WarmPolicy::default()
        };
        let (outcome, d) = warm_cwnd(
            &mut c,
            TransferDirection::Upload,
            8e6,
            &policy,
            &mut hist,
            SimTime(1),
            &mut rng,
        );
        assert_eq!(outcome, WarmOutcome::Denied);
        assert_eq!(d, SimDuration::ZERO);
        assert_eq!(c.cwnd(TransferDirection::Upload), w0);
    }

    #[test]
    fn policy_caps_by_bdp_fraction() {
        let mut rng = Rng::new(3);
        let mut c = conn();
        c.connect(SimTime::ZERO, &mut rng);
        let mut hist = CwndHistory::new();
        let policy = WarmPolicy {
            allowed: true,
            max_bdp_fraction: 0.1,
            max_bytes: 1e12,
        };
        let (outcome, _) = warm_cwnd(
            &mut c,
            TransferDirection::Upload,
            1e12,
            &policy,
            &mut hist,
            SimTime(1),
            &mut rng,
        );
        let bdp = c.link.bdp_bytes();
        match outcome {
            WarmOutcome::Granted(w) => {
                assert!(w <= bdp * 0.1 * 1.5, "w={w} bdp={bdp}"); // probe noise slack
            }
            _ => panic!(),
        }
    }

    #[test]
    fn history_avoids_probe_cost() {
        let mut rng = Rng::new(4);
        let mut c = conn();
        c.connect(SimTime::ZERO, &mut rng);
        let mut hist = CwndHistory::new();
        // First call probes (non-zero duration)...
        let (_, d1) = warm_cwnd(
            &mut c,
            TransferDirection::Upload,
            8e6,
            &WarmPolicy::default(),
            &mut hist,
            SimTime(1),
            &mut rng,
        );
        assert!(d1 > SimDuration::ZERO);
        // ...second call within the window uses history (free).
        let (_, d2) = warm_cwnd(
            &mut c,
            TransferDirection::Upload,
            8e6,
            &WarmPolicy::default(),
            &mut hist,
            SimTime(2),
            &mut rng,
        );
        assert_eq!(d2, SimDuration::ZERO);
    }

    #[test]
    fn history_estimate_windows() {
        let mut h = CwndHistory::new();
        h.record(SimTime(0), 100.0);
        h.record(SimTime(1_000_000), 200.0);
        let now = SimTime(2_000_000);
        assert_eq!(
            h.recent_estimate(now, SimDuration::from_secs(10)),
            Some(200.0)
        );
        assert_eq!(
            h.recent_estimate(now, SimDuration::from_millis(500)),
            None
        );
    }
}
