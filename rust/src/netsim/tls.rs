//! TLS handshake cost model.
//!
//! §3.2 "Other connection-oriented protocols": freshen can establish and
//! warm protocols on top of TCP, TLS foremost, as long as credentials are
//! constant. We model the handshake's round trips and crypto CPU cost, plus
//! session resumption (which freshen effectively enables by keeping a live,
//! recently-used session around).

use crate::netsim::link::Link;
use crate::util::rng::Rng;
use crate::util::time::SimDuration;

/// TLS protocol version in play.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TlsVersion {
    /// Full handshake: 2 RTT.
    Tls12,
    /// Full handshake: 1 RTT.
    Tls13,
}

/// Handshake flavour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TlsHandshake {
    Full(TlsVersion),
    /// Session resumption (TLS 1.2 session IDs / TLS 1.3 PSK): 1 RTT.
    Resumed(TlsVersion),
    /// TLS 1.3 0-RTT early data (when the server allows replay risk).
    ZeroRtt,
}

/// Crypto CPU cost of the asymmetric handshake (sign + key exchange),
/// seconds. Measured values for RSA-2048/X25519 are ~1–3 ms on server CPUs.
pub const FULL_HANDSHAKE_CPU: f64 = 2.0e-3;
/// Resumption uses symmetric crypto only.
pub const RESUMED_HANDSHAKE_CPU: f64 = 0.2e-3;

/// Per-session TLS state carried by a connection.
#[derive(Debug, Clone)]
pub struct TlsSession {
    pub version: TlsVersion,
    pub established: bool,
    /// Whether a resumption ticket is cached for this destination.
    pub has_ticket: bool,
}

impl TlsSession {
    pub fn new(version: TlsVersion) -> TlsSession {
        TlsSession {
            version,
            established: false,
            has_ticket: false,
        }
    }

    /// Which handshake the next establishment would use.
    pub fn next_handshake(&self) -> TlsHandshake {
        if self.has_ticket {
            TlsHandshake::Resumed(self.version)
        } else {
            TlsHandshake::Full(self.version)
        }
    }

    /// Perform a handshake: returns its duration and records the ticket.
    pub fn establish(&mut self, link: &Link, rng: &mut Rng) -> SimDuration {
        let hs = self.next_handshake();
        let d = handshake_duration(hs, link, rng);
        self.established = true;
        self.has_ticket = true;
        d
    }

    /// Drop the session (e.g. connection died); the ticket survives — that
    /// is precisely what makes freshen re-establishment cheap.
    pub fn invalidate(&mut self) {
        self.established = false;
    }
}

/// Duration of a given handshake over a given link.
pub fn handshake_duration(hs: TlsHandshake, link: &Link, rng: &mut Rng) -> SimDuration {
    let (rtts, cpu) = match hs {
        TlsHandshake::Full(TlsVersion::Tls12) => (2.0, FULL_HANDSHAKE_CPU),
        TlsHandshake::Full(TlsVersion::Tls13) => (1.0, FULL_HANDSHAKE_CPU),
        TlsHandshake::Resumed(_) => (1.0, RESUMED_HANDSHAKE_CPU),
        TlsHandshake::ZeroRtt => (0.0, RESUMED_HANDSHAKE_CPU),
    };
    let mut t = cpu;
    for _ in 0..rtts as u32 {
        t += link.sample_rtt(rng);
    }
    SimDuration::from_secs_f64(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::Site;

    fn quiet_link() -> Link {
        let mut l = Site::Remote.link();
        l.jitter_sigma = 0.0;
        l
    }

    #[test]
    fn tls12_costs_two_rtt_tls13_one() {
        let link = quiet_link();
        let mut rng = Rng::new(1);
        let d12 = handshake_duration(TlsHandshake::Full(TlsVersion::Tls12), &link, &mut rng);
        let d13 = handshake_duration(TlsHandshake::Full(TlsVersion::Tls13), &link, &mut rng);
        assert!((d12.as_secs_f64() - (2.0 * link.rtt + FULL_HANDSHAKE_CPU)).abs() < 1e-9);
        assert!((d13.as_secs_f64() - (link.rtt + FULL_HANDSHAKE_CPU)).abs() < 1e-9);
    }

    #[test]
    fn resumption_is_cheaper_and_sticky() {
        let link = quiet_link();
        let mut rng = Rng::new(2);
        let mut sess = TlsSession::new(TlsVersion::Tls12);
        assert_eq!(sess.next_handshake(), TlsHandshake::Full(TlsVersion::Tls12));
        let d_full = sess.establish(&link, &mut rng);
        sess.invalidate();
        assert_eq!(
            sess.next_handshake(),
            TlsHandshake::Resumed(TlsVersion::Tls12)
        );
        let d_resumed = sess.establish(&link, &mut rng);
        assert!(d_resumed < d_full);
        assert!((d_resumed.as_secs_f64() - (link.rtt + RESUMED_HANDSHAKE_CPU)).abs() < 1e-9);
    }

    #[test]
    fn zero_rtt_is_cpu_only() {
        let link = quiet_link();
        let mut rng = Rng::new(3);
        let d = handshake_duration(TlsHandshake::ZeroRtt, &link, &mut rng);
        assert!(d.as_secs_f64() < 1e-3);
    }
}
