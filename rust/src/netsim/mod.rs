//! Fluid-model network simulator.
//!
//! The paper's evaluation (Figures 4–6) ran over real TCP on CloudLab: a
//! local on-host server, an edge server on the same 10 Gbps LAN, and a
//! remote server ~50 ms away. We reproduce those experiments with a
//! packet-free **fluid TCP model**: transfer time is computed analytically
//! from the connection's congestion-window state, the link's RTT/bandwidth,
//! and the handshake sequence — the quantities that fully determine the
//! deltas the paper measures.
//!
//! Components:
//! - [`link`] — the three site profiles (plus custom links).
//! - [`cc`] — congestion-control algorithms (Reno, CUBIC).
//! - [`tcp`] — connection state machine: handshake, slow start, congestion
//!   avoidance, RFC 2861 idle decay, keepalive, idle timeout.
//! - [`tls`] — TLS 1.2/1.3 handshake costs and session resumption.
//! - [`warm`] — the paper's `warm_cwnd` syscall model + packet-pair probing.
//! - [`metrics_cache`] — `tcp_no_metrics_save` semantics and TCP Fast Open.

pub mod cc;
pub mod link;
pub mod metrics_cache;
pub mod tcp;
pub mod tls;
pub mod warm;

pub use cc::CongestionControl;
pub use link::{Link, Site};
pub use tcp::{ConnState, Connection, TransferDirection};
