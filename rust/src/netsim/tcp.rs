//! TCP connection state machine over the fluid model.
//!
//! A [`Connection`] tracks everything the paper's §2/§3.2 discussion turns
//! on: establishment (3-way handshake), per-direction congestion windows
//! evolving through slow start and congestion avoidance ([`super::cc`]),
//! **RFC 2861 idle decay** (the reason keepalives alone don't keep a
//! connection *fast*), server/NAT idle timeouts (the reason runtime-scoped
//! connections go dead between invocations), and keepalive probing.
//!
//! All methods take the current virtual time and return the operation's
//! duration; the caller (platform ops or the serve engine) schedules the
//! completion. The model is deterministic given the `Rng` stream.

use crate::netsim::cc::{CcState, CongestionControl, INIT_CWND_SEGMENTS, MSS};
use crate::netsim::link::Link;
use crate::util::rng::Rng;
use crate::util::time::{SimDuration, SimTime};

/// Lifecycle of a simulated connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnState {
    /// Never connected (or explicitly closed).
    Closed,
    /// Live and usable.
    Established,
    /// Silently dropped by the peer/NAT after an idle timeout; the next
    /// use discovers the failure and must re-establish.
    Dead,
}

/// Which direction carries the bulk data.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferDirection {
    /// Remote sends to us (a `DataGet` response).
    Download,
    /// We send to remote (a `DataPut`).
    Upload,
}

/// Default server-side idle timeout (many LBs/NATs use 300–350 s; ALB
/// defaults to 60 s — we default to 300 s, configurable per connection).
pub const DEFAULT_IDLE_TIMEOUT: f64 = 300.0;

/// Receiver window cap in bytes (Linux autotuned buffers, ~8 MB).
pub const DEFAULT_RWND: f64 = 8.0 * 1024.0 * 1024.0;

/// A simulated TCP connection to one destination.
#[derive(Debug, Clone)]
pub struct Connection {
    pub link: Link,
    pub state: ConnState,
    /// Congestion state for data we send (uploads).
    pub cc_tx: CcState,
    /// Congestion state of the peer sending to us (downloads).
    pub cc_rx: CcState,
    /// Receiver-window cap applied to both directions.
    pub rwnd: f64,
    /// Virtual time of last segment in either direction.
    pub last_activity: SimTime,
    pub established_at: SimTime,
    /// Peer idle timeout (seconds); idling longer kills the connection.
    pub idle_timeout: f64,
    /// Cumulative bytes moved (both directions) — metrics/billing.
    pub bytes_transferred: f64,
    /// Number of times this connection was (re)established.
    pub establish_count: u32,
}

impl Connection {
    pub fn new(link: Link, algo: CongestionControl) -> Connection {
        Connection {
            link,
            state: ConnState::Closed,
            cc_tx: CcState::new(algo),
            cc_rx: CcState::new(algo),
            rwnd: DEFAULT_RWND,
            last_activity: SimTime::ZERO,
            established_at: SimTime::ZERO,
            idle_timeout: DEFAULT_IDLE_TIMEOUT,
            bytes_transferred: 0.0,
            establish_count: 0,
        }
    }

    /// Retransmission-timeout estimate used for RFC 2861 decay pacing.
    pub fn rto(&self) -> f64 {
        (4.0 * self.link.rtt).max(0.2) // Linux TCP_RTO_MIN = 200ms
    }

    /// Has the peer's idle timeout passed? (Discovered lazily on next use.)
    pub fn idle_expired(&self, now: SimTime) -> bool {
        self.state == ConnState::Established
            && now.since(self.last_activity).as_secs_f64() > self.idle_timeout
    }

    /// 3-way handshake. Returns the time until the connection is usable
    /// for data (client may piggyback on the final ACK, so 1 RTT).
    pub fn connect(&mut self, now: SimTime, rng: &mut Rng) -> SimDuration {
        let rtt = self.link.sample_rtt(rng);
        let t = rtt + self.link.endpoint_overhead;
        let algo = self.cc_tx.algo;
        self.cc_tx = CcState::new(algo);
        self.cc_rx = CcState::new(algo);
        self.state = ConnState::Established;
        self.establish_count += 1;
        self.established_at = now + SimDuration::from_secs_f64(t);
        self.last_activity = self.established_at;
        SimDuration::from_secs_f64(t)
    }

    /// Re-establish with cached metrics (see [`super::metrics_cache`]):
    /// `ssthresh_hint` seeds ssthresh (Linux metric caching), and
    /// `fast_open` skips the handshake RTT (TFO with a valid cookie).
    pub fn connect_with(
        &mut self,
        now: SimTime,
        rng: &mut Rng,
        ssthresh_hint: Option<f64>,
        fast_open: bool,
    ) -> SimDuration {
        let d = if fast_open {
            // Data rides in the SYN; only endpoint overhead before first data.
            let algo = self.cc_tx.algo;
            self.cc_tx = CcState::new(algo);
            self.cc_rx = CcState::new(algo);
            self.state = ConnState::Established;
            self.establish_count += 1;
            self.established_at = now;
            self.last_activity = now;
            SimDuration::from_secs_f64(self.link.endpoint_overhead)
        } else {
            self.connect(now, rng)
        };
        if let Some(ss) = ssthresh_hint {
            // Metric caching restores ssthresh but NOT cwnd — the paper's
            // §2 point: tcp_no_metrics_save "does not apply to important
            // parameters such as CWND".
            self.cc_tx.ssthresh = ss;
            self.cc_rx.ssthresh = ss;
        }
        d
    }

    /// Mark the connection dead (peer idle-timeout or reset).
    pub fn kill(&mut self) {
        self.state = ConnState::Dead;
    }

    /// Lazily apply RFC 2861 idle decay to both directions.
    fn apply_idle(&mut self, now: SimTime) {
        let idle = now.since(self.last_activity).as_secs_f64();
        let rto = self.rto();
        self.cc_tx.apply_idle_decay(idle, rto);
        self.cc_rx.apply_idle_decay(idle, rto);
    }

    /// Fluid send: time from first byte sent until the receiver holds the
    /// last byte, evolving `cc` round-by-round.
    fn send_duration(cc: &mut CcState, link: &Link, rwnd: f64, bytes: f64, rng: &mut Rng) -> f64 {
        debug_assert!(bytes >= 0.0);
        if bytes == 0.0 {
            return 0.5 * link.sample_rtt(rng);
        }
        let mut remaining = bytes;
        let mut t = link.endpoint_overhead;
        loop {
            let rtt = link.sample_rtt(rng);
            // Loss event this round? Multiplicative decrease + a recovery
            // round (fast retransmit: one extra RTT, no forward progress
            // for the lost portion).
            if link.loss_per_round > 0.0 && rng.bernoulli(link.loss_per_round) {
                cc.on_loss();
                t += rtt;
            }
            let w = cc.cwnd.min(rwnd);
            if remaining <= w {
                // Final flight: serialize + propagate half an RTT.
                t += link.serialize(remaining) + 0.5 * rtt;
                cc.on_round(remaining, rtt);
                break;
            }
            // Full window in flight; round completes when acks return.
            // max() smoothly hands over to bandwidth-limited behaviour as
            // the window approaches the BDP.
            t += link.serialize(w).max(rtt);
            cc.on_round(w, rtt);
            remaining -= w;
        }
        t
    }

    /// Request/response exchange (`DataGet`): send `req_bytes`, receive
    /// `resp_bytes`; includes `server_time` of remote processing.
    /// Returns total duration. Connection must be `Established`.
    pub fn request_response(
        &mut self,
        now: SimTime,
        rng: &mut Rng,
        req_bytes: f64,
        resp_bytes: f64,
        server_time: f64,
    ) -> SimDuration {
        debug_assert_eq!(self.state, ConnState::Established, "use connect() first");
        self.apply_idle(now);
        let up = Self::send_duration(&mut self.cc_tx, &self.link, self.rwnd, req_bytes, rng);
        let down = Self::send_duration(&mut self.cc_rx, &self.link, self.rwnd, resp_bytes, rng);
        let total = up + server_time + down;
        self.bytes_transferred += req_bytes + resp_bytes;
        self.last_activity = now + SimDuration::from_secs_f64(total);
        SimDuration::from_secs_f64(total)
    }

    /// One-way bulk send plus an application-level completion ack
    /// (`DataPut`, and the Figures 5/6 measurement: "time of a client
    /// initiating a file transfer to the response from the server
    /// indicating completion").
    pub fn send_with_ack(
        &mut self,
        now: SimTime,
        rng: &mut Rng,
        bytes: f64,
        server_time: f64,
    ) -> SimDuration {
        debug_assert_eq!(self.state, ConnState::Established, "use connect() first");
        self.apply_idle(now);
        let up = Self::send_duration(&mut self.cc_tx, &self.link, self.rwnd, bytes, rng);
        let ack = 0.5 * self.link.sample_rtt(rng);
        let total = up + server_time + ack;
        self.bytes_transferred += bytes;
        self.last_activity = now + SimDuration::from_secs_f64(total);
        SimDuration::from_secs_f64(total)
    }

    /// TCP keepalive probe: discovers whether the peer still holds the
    /// connection. Returns `(probe_duration, alive)`. A dead connection
    /// transitions to [`ConnState::Dead`] so the caller can re-establish —
    /// exactly the freshen liveness check of §3.2.
    pub fn keepalive(&mut self, now: SimTime, rng: &mut Rng) -> (SimDuration, bool) {
        match self.state {
            ConnState::Closed | ConnState::Dead => {
                (SimDuration::from_secs_f64(self.link.endpoint_overhead), false)
            }
            ConnState::Established => {
                if self.idle_expired(now) {
                    // Peer already dropped it; probe times out after ~RTO.
                    self.state = ConnState::Dead;
                    (SimDuration::from_secs_f64(self.rto()), false)
                } else {
                    let rtt = self.link.sample_rtt(rng);
                    // Probe counts as activity (keeps NAT state alive) but
                    // does NOT regrow cwnd; idle decay up to now applies.
                    self.apply_idle(now);
                    let d = SimDuration::from_secs_f64(rtt);
                    self.last_activity = now + d;
                    (d, true)
                }
            }
        }
    }

    /// Effective cwnd (bytes) in the given direction, for reports.
    pub fn cwnd(&self, dir: TransferDirection) -> f64 {
        match dir {
            TransferDirection::Upload => self.cc_tx.cwnd,
            TransferDirection::Download => self.cc_rx.cwnd,
        }
    }

    /// Initial-window bytes (what a fresh connection starts at).
    pub fn initial_cwnd() -> f64 {
        INIT_CWND_SEGMENTS * MSS
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::Site;

    fn quiet(mut link: Link) -> Link {
        link.jitter_sigma = 0.0;
        link
    }

    fn conn(site: Site) -> Connection {
        Connection::new(quiet(site.link()), CongestionControl::Cubic)
    }

    #[test]
    fn connect_costs_one_rtt() {
        let mut c = conn(Site::Remote);
        let mut rng = Rng::new(1);
        let d = c.connect(SimTime::ZERO, &mut rng);
        let expected = c.link.rtt + c.link.endpoint_overhead;
        assert!((d.as_secs_f64() - expected).abs() < 1e-9);
        assert_eq!(c.state, ConnState::Established);
        assert_eq!(c.establish_count, 1);
    }

    #[test]
    fn transfer_grows_cwnd() {
        let mut c = conn(Site::Remote);
        let mut rng = Rng::new(2);
        c.connect(SimTime::ZERO, &mut rng);
        let w0 = c.cwnd(TransferDirection::Upload);
        c.send_with_ack(SimTime(100_000), &mut rng, 1e6, 0.0);
        assert!(c.cwnd(TransferDirection::Upload) > 4.0 * w0);
    }

    #[test]
    fn warmed_transfer_is_much_faster_on_wan() {
        // The Figure 5/6 effect: a prior large transfer leaves cwnd large,
        // so the next large send completes in far fewer rounds.
        let mut rng = Rng::new(3);
        let mut cold = conn(Site::Remote);
        cold.connect(SimTime::ZERO, &mut rng);
        let t_cold = cold.send_with_ack(SimTime(1), &mut rng, 10e6, 0.0);

        let mut warm = conn(Site::Remote);
        warm.connect(SimTime::ZERO, &mut rng);
        warm.send_with_ack(SimTime(1), &mut rng, 20e6, 0.0); // warming send
        let t_warm = warm.send_with_ack(SimTime(2), &mut rng, 10e6, 0.0);

        let saving = 1.0 - t_warm.as_secs_f64() / t_cold.as_secs_f64();
        assert!(saving > 0.4, "saving {saving}");
    }

    #[test]
    fn small_transfers_see_little_warming_benefit() {
        // Below the initial window the transfer is one flight either way.
        let mut rng = Rng::new(4);
        let mut cold = conn(Site::Remote);
        cold.connect(SimTime::ZERO, &mut rng);
        let t_cold = cold.send_with_ack(SimTime(1), &mut rng, 1_000.0, 0.0);

        let mut warm = conn(Site::Remote);
        warm.connect(SimTime::ZERO, &mut rng);
        warm.send_with_ack(SimTime(1), &mut rng, 20e6, 0.0);
        let t_warm = warm.send_with_ack(SimTime(2), &mut rng, 1_000.0, 0.0);

        let ratio = t_warm.as_secs_f64() / t_cold.as_secs_f64();
        assert!((0.9..=1.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn idle_decay_slows_next_transfer() {
        let mut rng = Rng::new(5);
        let mut c = conn(Site::Remote);
        c.connect(SimTime::ZERO, &mut rng);
        c.send_with_ack(SimTime(1), &mut rng, 10e6, 0.0); // warm it
        let w_warm = c.cwnd(TransferDirection::Upload);
        // Idle 30s (< idle_timeout, so still alive) then observe decay.
        let later = SimTime::ZERO + SimDuration::from_secs(30);
        c.send_with_ack(later, &mut rng, 1_000.0, 0.0);
        assert!(
            c.cwnd(TransferDirection::Upload) < w_warm / 4.0,
            "cwnd should have decayed: {} vs {}",
            c.cwnd(TransferDirection::Upload),
            w_warm
        );
    }

    #[test]
    fn keepalive_detects_dead_connection() {
        let mut rng = Rng::new(6);
        let mut c = conn(Site::Edge);
        c.connect(SimTime::ZERO, &mut rng);
        // Past the peer idle timeout.
        let later = SimTime::ZERO + SimDuration::from_secs(400);
        assert!(c.idle_expired(later));
        let (d, alive) = c.keepalive(later, &mut rng);
        assert!(!alive);
        assert_eq!(c.state, ConnState::Dead);
        assert!(d.as_secs_f64() >= 0.2); // timed-out probe costs ~RTO
        // Re-establish works and resets the window.
        let d2 = c.connect(later + d, &mut rng);
        assert!(d2.as_secs_f64() > 0.0);
        assert_eq!(c.state, ConnState::Established);
    }

    #[test]
    fn keepalive_keeps_alive_but_does_not_warm() {
        let mut rng = Rng::new(7);
        let mut c = conn(Site::Remote);
        c.connect(SimTime::ZERO, &mut rng);
        c.send_with_ack(SimTime(1), &mut rng, 10e6, 0.0);
        let w_warm = c.cwnd(TransferDirection::Upload);
        // Keepalive every 60s for 5 minutes: stays established...
        let mut t = SimTime::ZERO;
        for _ in 0..5 {
            t = t + SimDuration::from_secs(60);
            let (_, alive) = c.keepalive(t, &mut rng);
            assert!(alive);
        }
        // ...but cwnd has decayed to the restart window (the paper's point).
        assert!(c.cwnd(TransferDirection::Upload) < w_warm / 8.0);
        assert!(
            (c.cwnd(TransferDirection::Upload) - Connection::initial_cwnd()).abs() < 1.0
        );
    }

    #[test]
    fn metrics_cache_restores_ssthresh_not_cwnd() {
        let mut rng = Rng::new(8);
        let mut c = conn(Site::Remote);
        let d = c.connect_with(SimTime::ZERO, &mut rng, Some(64.0 * MSS), false);
        assert!(d.as_secs_f64() > 0.0);
        assert_eq!(c.cc_tx.ssthresh, 64.0 * MSS);
        assert!((c.cc_tx.cwnd - Connection::initial_cwnd()).abs() < 1.0);
    }

    #[test]
    fn fast_open_skips_handshake_rtt() {
        let mut rng = Rng::new(9);
        let mut tfo = conn(Site::Remote);
        let d_tfo = tfo.connect_with(SimTime::ZERO, &mut rng, None, true);
        let mut normal = conn(Site::Remote);
        let d_normal = normal.connect(SimTime::ZERO, &mut rng);
        assert!(d_tfo.as_secs_f64() < 0.1 * d_normal.as_secs_f64());
    }

    #[test]
    fn request_response_includes_server_time() {
        let mut rng = Rng::new(10);
        let mut c = conn(Site::Edge);
        c.connect(SimTime::ZERO, &mut rng);
        let t0 = c.request_response(SimTime(1), &mut rng, 200.0, 1000.0, 0.0);
        let mut c2 = conn(Site::Edge);
        c2.connect(SimTime::ZERO, &mut rng);
        let t1 = c2.request_response(SimTime(1), &mut rng, 200.0, 1000.0, 0.010);
        assert!((t1.as_secs_f64() - t0.as_secs_f64() - 0.010).abs() < 1e-3);
    }
}
