//! Kernel TCP metric caching and TCP Fast Open — the existing mechanisms the
//! paper argues are *insufficient* (§2 "Runtime reuse inefficiencies").
//!
//! - Linux caches per-destination metrics (RTT, ssthresh) unless
//!   `tcp_no_metrics_save` is set, but **not CWND** — a new connection still
//!   slow-starts from the initial window.
//! - TCP Fast Open removes the handshake RTT on repeat connections, but
//!   requires both endpoints to support it and caps the data carried in the
//!   SYN.
//!
//! This module models both so the baselines in Figures 4–6 (and the
//! ablations) can include them, demonstrating the residual gap freshen
//! closes.

use crate::util::fxhash::FxHashMap;
use crate::util::time::SimTime;

/// Destination key (host:port equivalent).
pub type DestKey = String;

/// Per-destination cached TCP metrics, as the Linux kernel keeps them.
#[derive(Debug, Clone, Copy)]
pub struct DestMetrics {
    pub rtt_estimate: f64,
    pub ssthresh: f64,
    pub recorded_at: SimTime,
}

/// TFO cookie state for a destination.
#[derive(Debug, Clone, Copy)]
pub struct TfoCookie {
    pub obtained_at: SimTime,
}

/// Maximum payload a TFO SYN may carry (RFC 7413's practical limit is one
/// MSS minus options; we use 1420 bytes).
pub const TFO_SYN_DATA_CAP: f64 = 1420.0;

/// Host-wide TCP metrics cache.
#[derive(Debug, Clone, Default)]
pub struct TcpMetricsCache {
    /// `tcp_no_metrics_save`: when true, nothing is cached (metrics off).
    pub no_metrics_save: bool,
    /// Whether this host and its peers support TFO.
    pub tfo_enabled: bool,
    metrics: FxHashMap<DestKey, DestMetrics>,
    cookies: FxHashMap<DestKey, TfoCookie>,
}

impl TcpMetricsCache {
    pub fn new() -> TcpMetricsCache {
        TcpMetricsCache::default()
    }

    /// Record metrics at connection close (kernel behaviour).
    pub fn record(&mut self, dest: &str, rtt: f64, ssthresh: f64, now: SimTime) {
        if self.no_metrics_save {
            return;
        }
        self.metrics.insert(
            dest.to_string(),
            DestMetrics {
                rtt_estimate: rtt,
                ssthresh,
                recorded_at: now,
            },
        );
    }

    /// ssthresh hint for a new connection to `dest` (NOT cwnd — that is the
    /// gap freshen's `warm_cwnd` fills).
    pub fn ssthresh_hint(&self, dest: &str) -> Option<f64> {
        if self.no_metrics_save {
            return None;
        }
        self.metrics.get(dest).map(|m| m.ssthresh)
    }

    pub fn rtt_hint(&self, dest: &str) -> Option<f64> {
        if self.no_metrics_save {
            return None;
        }
        self.metrics.get(dest).map(|m| m.rtt_estimate)
    }

    /// After a successful full handshake the client holds a TFO cookie.
    pub fn grant_tfo_cookie(&mut self, dest: &str, now: SimTime) {
        if self.tfo_enabled {
            self.cookies
                .insert(dest.to_string(), TfoCookie { obtained_at: now });
        }
    }

    /// Can the next connection to `dest` use TFO (0-RTT SYN data)?
    pub fn can_fast_open(&self, dest: &str) -> bool {
        self.tfo_enabled && self.cookies.contains_key(dest)
    }

    /// How much of `payload` may ride in the TFO SYN; the remainder still
    /// waits a round trip. Returns `(in_syn, deferred)`.
    pub fn tfo_split(&self, payload: f64) -> (f64, f64) {
        let in_syn = payload.min(TFO_SYN_DATA_CAP);
        (in_syn, payload - in_syn)
    }

    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caches_and_returns_hints() {
        let mut c = TcpMetricsCache::new();
        c.record("s3.local:443", 0.05, 90_000.0, SimTime(1));
        assert_eq!(c.ssthresh_hint("s3.local:443"), Some(90_000.0));
        assert_eq!(c.rtt_hint("s3.local:443"), Some(0.05));
        assert_eq!(c.ssthresh_hint("other:80"), None);
    }

    #[test]
    fn no_metrics_save_disables_cache() {
        let mut c = TcpMetricsCache::new();
        c.no_metrics_save = true;
        c.record("d", 0.05, 90_000.0, SimTime(1));
        assert_eq!(c.ssthresh_hint("d"), None);
        assert!(c.is_empty());
    }

    #[test]
    fn tfo_requires_enablement_and_cookie() {
        let mut c = TcpMetricsCache::new();
        // Not enabled: no cookie granted.
        c.grant_tfo_cookie("d", SimTime(0));
        assert!(!c.can_fast_open("d"));
        c.tfo_enabled = true;
        assert!(!c.can_fast_open("d")); // no cookie yet
        c.grant_tfo_cookie("d", SimTime(1));
        assert!(c.can_fast_open("d"));
    }

    #[test]
    fn tfo_data_cap_limits_syn_payload() {
        let c = TcpMetricsCache::new();
        let (in_syn, deferred) = c.tfo_split(10_000.0);
        assert_eq!(in_syn, TFO_SYN_DATA_CAP);
        assert_eq!(deferred, 10_000.0 - TFO_SYN_DATA_CAP);
        let (small, rest) = c.tfo_split(100.0);
        assert_eq!(small, 100.0);
        assert_eq!(rest, 0.0);
    }
}
