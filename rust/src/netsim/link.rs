//! Link profiles.
//!
//! The paper's three server placements (§4): local on-host, edge on-site
//! (same 10 Gbps LAN), and remote off-site (~50 ms away). Each profile fixes
//! the path RTT, bottleneck bandwidth, and a small jitter model so repeated
//! iterations show realistic spread.

use crate::util::rng::Rng;

/// Server placement used throughout the evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Site {
    /// Same host (loopback / local bridge).
    Local,
    /// Same site, 10 Gbps LAN (the paper's "edge on-site").
    Edge,
    /// Off-site WAN path averaging 50 ms (the paper's "remote off-site").
    Remote,
}

impl Site {
    pub fn all() -> [Site; 3] {
        [Site::Local, Site::Edge, Site::Remote]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Site::Local => "local",
            Site::Edge => "edge",
            Site::Remote => "remote",
        }
    }

    /// Inverse of [`Site::as_str`] (the `--host-classes` net field).
    pub fn parse(s: &str) -> Option<Site> {
        match s {
            "local" => Some(Site::Local),
            "edge" => Some(Site::Edge),
            "remote" => Some(Site::Remote),
            _ => None,
        }
    }

    pub fn link(&self) -> Link {
        match self {
            // Loopback: tens of microseconds, memory-bandwidth-ish ceiling.
            Site::Local => Link::new("local", 50e-6, 20e9 / 8.0),
            // 10 Gbps LAN, ~200us switch+stack RTT.
            Site::Edge => Link::new("edge", 200e-6, 10e9 / 8.0),
            // 50ms WAN, 1 Gbps bottleneck.
            Site::Remote => Link::new("remote", 50e-3, 1e9 / 8.0),
        }
    }
}

/// A point-to-point path with fixed base RTT and bottleneck bandwidth.
#[derive(Debug, Clone)]
pub struct Link {
    pub name: &'static str,
    /// Base round-trip time in seconds.
    pub rtt: f64,
    /// Bottleneck bandwidth in bytes/second.
    pub bandwidth: f64,
    /// Multiplicative jitter sigma applied per-RTT sample (lognormal).
    pub jitter_sigma: f64,
    /// Fixed per-operation endpoint overhead (kernel + runtime), seconds.
    /// Dominates on-host transfers, negligible on WAN — this is why the
    /// paper's Figure 6 (edge) shows *larger relative* warming benefit:
    /// network delay, not system overhead, dominates there.
    pub endpoint_overhead: f64,
    /// Probability that a congestion/loss event hits a given send round
    /// (0 = lossless, the clean-testbed default). Loss triggers the
    /// congestion controller's multiplicative decrease, so warming's
    /// benefit degrades realistically on lossy paths.
    pub loss_per_round: f64,
}

impl Link {
    pub fn new(name: &'static str, rtt: f64, bandwidth: f64) -> Link {
        Link {
            name,
            rtt,
            bandwidth,
            jitter_sigma: 0.03,
            endpoint_overhead: 250e-6,
            loss_per_round: 0.0,
        }
    }

    pub fn with_loss(mut self, loss_per_round: f64) -> Link {
        self.loss_per_round = loss_per_round;
        self
    }

    /// Bandwidth-delay product in bytes.
    pub fn bdp_bytes(&self) -> f64 {
        self.rtt * self.bandwidth
    }

    /// One RTT sample with jitter (deterministic given the rng state).
    pub fn sample_rtt(&self, rng: &mut Rng) -> f64 {
        if self.jitter_sigma == 0.0 {
            return self.rtt;
        }
        // Lognormal multiplicative jitter centred on 1.0.
        self.rtt * rng.lognormal(0.0, self.jitter_sigma)
    }

    /// Serialization time for `bytes` at the bottleneck.
    pub fn serialize(&self, bytes: f64) -> f64 {
        bytes / self.bandwidth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_profiles_ordered_by_distance() {
        let local = Site::Local.link();
        let edge = Site::Edge.link();
        let remote = Site::Remote.link();
        assert!(local.rtt < edge.rtt && edge.rtt < remote.rtt);
        assert!(remote.bandwidth < edge.bandwidth);
        // Remote BDP is large: warming matters most there.
        assert!(remote.bdp_bytes() > 1e6);
        assert!(edge.bdp_bytes() < remote.bdp_bytes());
    }

    #[test]
    fn site_parse_roundtrips() {
        for s in Site::all() {
            assert_eq!(Site::parse(s.as_str()), Some(s));
        }
        assert_eq!(Site::parse("mars"), None);
    }

    #[test]
    fn jitter_is_centred_and_bounded() {
        let link = Site::Remote.link();
        let mut rng = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| link.sample_rtt(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean / link.rtt - 1.0).abs() < 0.01, "mean ratio {}", mean / link.rtt);
    }

    #[test]
    fn serialization_scales_linearly() {
        let link = Site::Edge.link();
        let t1 = link.serialize(1e6);
        let t10 = link.serialize(1e7);
        assert!((t10 / t1 - 10.0).abs() < 1e-9);
    }
}
