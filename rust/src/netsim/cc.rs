//! Congestion-control algorithms for the fluid TCP model.
//!
//! The model is round-based: each simulated RTT the algorithm is asked how
//! the congestion window evolves given the bytes acknowledged that round.
//! Two algorithms are provided — **Reno** (slow start + AIMD, the textbook
//! model, and what the paper's CWND discussion assumes) and **CUBIC** (the
//! Linux default the paper's testbed actually ran). Experiments default to
//! CUBIC; benches expose both so the warming benefit can be compared.

/// Linux default initial congestion window (RFC 6928): 10 segments.
pub const INIT_CWND_SEGMENTS: f64 = 10.0;
/// Ethernet-typical MSS in bytes.
pub const MSS: f64 = 1460.0;

/// Congestion-control algorithm choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CongestionControl {
    Reno,
    Cubic,
}

impl CongestionControl {
    pub fn as_str(&self) -> &'static str {
        match self {
            CongestionControl::Reno => "reno",
            CongestionControl::Cubic => "cubic",
        }
    }
}

/// Per-connection congestion state evolved round-by-round.
#[derive(Debug, Clone)]
pub struct CcState {
    pub algo: CongestionControl,
    /// Congestion window in bytes.
    pub cwnd: f64,
    /// Slow-start threshold in bytes (infinite until first loss).
    pub ssthresh: f64,
    /// CUBIC: window size before the last reduction (W_max), bytes.
    pub w_max: f64,
    /// CUBIC: time since the last reduction, seconds.
    pub epoch_elapsed: f64,
}

impl CcState {
    pub fn new(algo: CongestionControl) -> CcState {
        CcState {
            algo,
            cwnd: INIT_CWND_SEGMENTS * MSS,
            ssthresh: f64::INFINITY,
            w_max: 0.0,
            epoch_elapsed: 0.0,
        }
    }

    pub fn with_ssthresh(algo: CongestionControl, ssthresh: f64) -> CcState {
        let mut s = CcState::new(algo);
        s.ssthresh = ssthresh;
        s
    }

    pub fn in_slow_start(&self) -> bool {
        self.cwnd < self.ssthresh
    }

    /// Advance one RTT-round in which `acked` bytes were acknowledged and no
    /// loss occurred. `rtt` is the round duration in seconds.
    pub fn on_round(&mut self, acked: f64, rtt: f64) {
        self.epoch_elapsed += rtt;
        if self.in_slow_start() {
            // Slow start: cwnd grows by one MSS per acked MSS (doubling per
            // RTT when the window is fully used).
            self.cwnd += acked;
            if self.cwnd >= self.ssthresh {
                self.cwnd = self.ssthresh.max(self.cwnd.min(self.ssthresh * 1.0));
                // fall through to CA next round
            }
            return;
        }
        match self.algo {
            CongestionControl::Reno => {
                // AIMD: +1 MSS per RTT (scaled by utilisation).
                let utilisation = (acked / self.cwnd).clamp(0.0, 1.0);
                self.cwnd += MSS * utilisation;
            }
            CongestionControl::Cubic => {
                // W(t) = C*(t-K)^3 + W_max, K = cbrt(W_max*beta/C)
                // (windows in MSS units for the standard constants).
                const C: f64 = 0.4;
                const BETA: f64 = 0.7;
                let w_max_seg = (self.w_max.max(self.cwnd)) / MSS;
                let k = (w_max_seg * (1.0 - BETA) / C).cbrt();
                let t = self.epoch_elapsed;
                let target_seg = C * (t - k).powi(3) + w_max_seg;
                let target = target_seg * MSS;
                if target > self.cwnd {
                    // Approach the cubic target but never more than a 50%
                    // step per round (RFC 8312's per-RTT clamp behaviour).
                    self.cwnd = target.min(self.cwnd * 1.5);
                } else {
                    // TCP-friendly region: at least Reno's growth.
                    self.cwnd += MSS * (acked / self.cwnd).clamp(0.0, 1.0);
                }
            }
        }
    }

    /// Multiplicative decrease on loss.
    pub fn on_loss(&mut self) {
        let beta = match self.algo {
            CongestionControl::Reno => 0.5,
            CongestionControl::Cubic => 0.7,
        };
        self.w_max = self.cwnd;
        self.ssthresh = (self.cwnd * beta).max(2.0 * MSS);
        self.cwnd = self.ssthresh;
        self.epoch_elapsed = 0.0;
    }

    /// RFC 2861 congestion-window validation: after an idle period the
    /// window decays by half per RTO elapsed, down to the restart window
    /// (the initial window). This is the decay the paper's `freshen`
    /// warming fights — keepalives keep the connection *alive* but do not
    /// preserve CWND.
    pub fn apply_idle_decay(&mut self, idle: f64, rto: f64) {
        if idle <= rto {
            return;
        }
        let halvings = (idle / rto).floor() as u32;
        let floor = INIT_CWND_SEGMENTS * MSS;
        for _ in 0..halvings.min(64) {
            self.cwnd = (self.cwnd / 2.0).max(floor);
        }
        // ssthresh keeps its value (metric retained), matching Linux.
        self.epoch_elapsed = 0.0;
    }

    /// Directly set the window — the `warm_cwnd` syscall's effect, subject
    /// to provider policy (see [`crate::netsim::warm`]).
    pub fn set_cwnd(&mut self, bytes: f64) {
        self.cwnd = bytes.max(2.0 * MSS);
        self.epoch_elapsed = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slow_start_doubles_per_round() {
        let mut cc = CcState::new(CongestionControl::Reno);
        let w0 = cc.cwnd;
        cc.on_round(cc.cwnd, 0.05);
        assert!((cc.cwnd - 2.0 * w0).abs() < 1.0);
        cc.on_round(cc.cwnd, 0.05);
        assert!((cc.cwnd - 4.0 * w0).abs() < 1.0);
    }

    #[test]
    fn reno_linear_after_ssthresh() {
        let mut cc = CcState::with_ssthresh(CongestionControl::Reno, 20.0 * MSS);
        cc.cwnd = 20.0 * MSS; // at threshold -> congestion avoidance
        cc.on_round(cc.cwnd, 0.05);
        assert!((cc.cwnd - 21.0 * MSS).abs() < 1.0);
    }

    #[test]
    fn loss_halves_reno() {
        let mut cc = CcState::new(CongestionControl::Reno);
        cc.cwnd = 100.0 * MSS;
        cc.on_loss();
        assert!((cc.cwnd - 50.0 * MSS).abs() < 1.0);
        assert!(!cc.in_slow_start());
    }

    #[test]
    fn cubic_decrease_is_gentler_and_regrows() {
        let mut cc = CcState::new(CongestionControl::Cubic);
        cc.cwnd = 100.0 * MSS;
        cc.on_loss();
        assert!((cc.cwnd - 70.0 * MSS).abs() < 1.0);
        let before = cc.cwnd;
        // Simulate 40 RTT rounds; CUBIC should recover towards w_max.
        for _ in 0..40 {
            cc.on_round(cc.cwnd, 0.05);
        }
        assert!(cc.cwnd > before);
        assert!(cc.cwnd > 90.0 * MSS, "cwnd {} segs", cc.cwnd / MSS);
    }

    #[test]
    fn idle_decay_halves_to_restart_window() {
        let mut cc = CcState::new(CongestionControl::Cubic);
        cc.cwnd = 400.0 * MSS;
        // idle of 3 RTOs -> three halvings: 400 -> 200 -> 100 -> 50
        cc.apply_idle_decay(0.9, 0.3);
        assert!((cc.cwnd - 50.0 * MSS).abs() < 1.0);
        // very long idle floors at the initial window
        cc.apply_idle_decay(1e6, 0.3);
        assert!((cc.cwnd - INIT_CWND_SEGMENTS * MSS).abs() < 1.0);
        // short idle: no change
        let w = cc.cwnd;
        cc.apply_idle_decay(0.1, 0.3);
        assert_eq!(cc.cwnd, w);
    }

    #[test]
    fn set_cwnd_floors_at_two_mss() {
        let mut cc = CcState::new(CongestionControl::Reno);
        cc.set_cwnd(1.0);
        assert_eq!(cc.cwnd, 2.0 * MSS);
        cc.set_cwnd(1e6);
        assert_eq!(cc.cwnd, 1e6);
    }
}
