//! `artifacts/manifest.json`: what the AOT pass produced.
//!
//! Written by `python/compile/aot.py` (or `repro gen-artifacts` for
//! native-only sets); read here so the rust runtime knows the artifact
//! shapes, available batch sizes, the sample-check numerics the
//! integration tests assert against, and — since the native backend —
//! where the raw weight sidecars live.
//!
//! # Weight sidecar schema (`"weights"`)
//!
//! ```json
//! "weights": {
//!   "format": "f32-le",
//!   "normalize": {"mean": 0.5, "std": 0.25},
//!   "layers": [
//!     {"in": 3072, "out": 512, "relu": true,
//!      "weights": "layer0.w.bin", "bias": "layer0.b.bin"},
//!     ...
//!   ]
//! }
//! ```
//!
//! Each `weights` blob is `in × out` raw little-endian `f32`s, row-major
//! exactly as JAX holds the parameter (so `aot.py` dumps with
//! `np.asarray(w, dtype="<f4").tofile(...)`); each `bias` blob is `out`
//! values. `normalize` carries the input-standardization constants the
//! forward pass applies before the first layer. The section is optional:
//! manifests without it can only serve the PJRT backend.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One classifier layer's sidecar entry.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub input: usize,
    pub output: usize,
    pub relu: bool,
    pub weights_file: String,
    pub bias_file: String,
}

/// The parsed `weights` sidecar section.
#[derive(Debug, Clone)]
pub struct WeightsSpec {
    /// Input standardization constants ((x - mean) / std).
    pub mean: f64,
    pub std: f64,
    pub layers: Vec<LayerSpec>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub input_dim: usize,
    pub classes: usize,
    /// AOT batch sizes, sorted ascending and deduplicated.
    pub batches: Vec<usize>,
    pub predictor_batch: usize,
    pub predictor_weights: Vec<f64>,
    pub predictor_bias: f64,
    /// artifact key -> file name
    pub artifacts: Vec<(String, String)>,
    /// Expected logits for the linspace(-1,1) sample input (batch 1).
    pub check_logits_b1: Vec<f64>,
    /// (features, expected score) rows for the predictor check.
    pub check_predictor: Vec<(Vec<f64>, f64)>,
    /// Native-backend weight sidecars (absent on PJRT-only manifests).
    pub weights: Option<WeightsSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut batches = j
            .get("batches")
            .and_then(Json::as_arr)
            .context("manifest: batches")?
            .iter()
            .filter_map(Json::as_u64)
            .map(|b| b as usize)
            .collect::<Vec<_>>();
        batches.sort_unstable();
        batches.dedup();
        if batches.is_empty() {
            bail!("manifest: no batch sizes");
        }
        if batches[0] == 0 {
            bail!("manifest: batch size 0 is invalid");
        }

        let artifacts = match j.get("artifacts") {
            Some(Json::Obj(pairs)) => pairs
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect(),
            _ => bail!("manifest: artifacts object missing"),
        };

        let check = j.get("check").context("manifest: check")?;
        let check_logits_b1 = check
            .get("classifier_logits_b1")
            .and_then(Json::as_arr)
            .context("manifest: check logits")?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let feats = check
            .get("predictor_feats")
            .and_then(Json::as_arr)
            .context("manifest: predictor feats")?;
        let scores = check
            .get("predictor_scores")
            .and_then(Json::as_arr)
            .context("manifest: predictor scores")?;
        if feats.len() != scores.len() {
            bail!(
                "manifest: {} predictor_feats rows but {} predictor_scores",
                feats.len(),
                scores.len()
            );
        }
        let check_predictor = feats
            .iter()
            .zip(scores.iter())
            .filter_map(|(f, s)| {
                let row: Vec<f64> = f.as_arr()?.iter().filter_map(Json::as_f64).collect();
                Some((row, s.as_f64()?))
            })
            .collect();

        let weights = match j.get("weights") {
            Some(section) => Some(parse_weights(section)?),
            None => None,
        };

        Ok(Manifest {
            input_dim: j.u64_or("input_dim", 3072) as usize,
            classes: j.u64_or("classes", 10) as usize,
            batches,
            predictor_batch: j.u64_or("predictor_batch", 16) as usize,
            predictor_weights: j
                .get("predictor_weights")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default(),
            predictor_bias: j.f64_or("predictor_bias", 0.0),
            artifacts,
            check_logits_b1,
            check_predictor,
            weights,
            dir: dir.to_path_buf(),
        })
    }

    /// Path of the classifier artifact for `batch`.
    pub fn classifier_path(&self, batch: usize) -> Option<PathBuf> {
        let key = format!("classifier_b{batch}");
        self.artifacts
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, f)| self.dir.join(f))
    }

    pub fn predictor_path(&self) -> Option<PathBuf> {
        self.artifacts
            .iter()
            .find(|(k, _)| k == "predictor")
            .map(|(_, f)| self.dir.join(f))
    }
}

/// Parse and validate the `weights` sidecar section (schema in the
/// module docs).
fn parse_weights(section: &Json) -> Result<WeightsSpec> {
    let format = section.str_or("format", "f32-le");
    if format != "f32-le" {
        bail!("manifest: unsupported weights format '{format}' (want f32-le)");
    }
    let (mean, std) = match section.get("normalize") {
        Some(n) => (n.f64_or("mean", 0.0), n.f64_or("std", 1.0)),
        None => (0.0, 1.0),
    };
    if std <= 0.0 {
        bail!("manifest: weights normalize.std must be positive, got {std}");
    }
    let layers_json = section
        .get("layers")
        .and_then(Json::as_arr)
        .context("manifest: weights.layers array")?;
    if layers_json.is_empty() {
        bail!("manifest: weights.layers is empty");
    }
    let mut layers = Vec::with_capacity(layers_json.len());
    for (i, l) in layers_json.iter().enumerate() {
        let input = l.u64_or("in", 0) as usize;
        let output = l.u64_or("out", 0) as usize;
        if input == 0 || output == 0 {
            bail!("manifest: weights layer {i} needs positive 'in' and 'out'");
        }
        let weights_file = l
            .get("weights")
            .and_then(Json::as_str)
            .with_context(|| format!("manifest: weights layer {i} 'weights' file"))?
            .to_string();
        let bias_file = l
            .get("bias")
            .and_then(Json::as_str)
            .with_context(|| format!("manifest: weights layer {i} 'bias' file"))?
            .to_string();
        layers.push(LayerSpec {
            input,
            output,
            relu: l.bool_or("relu", false),
            weights_file,
            bias_file,
        });
    }
    for pair in layers.windows(2) {
        if pair[0].output != pair[1].input {
            bail!(
                "manifest: weights layer chain broken ({} out vs {} in)",
                pair[0].output,
                pair[1].input
            );
        }
    }
    Ok(WeightsSpec { mean, std, layers })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_synthetic_manifest() {
        let dir = std::env::temp_dir().join("freshen-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "input_dim": 8, "classes": 2, "batches": [1, 4],
              "predictor_batch": 16,
              "predictor_weights": [3.2, 1.8, 0.9, -0.6], "predictor_bias": -2.0,
              "artifacts": {"classifier_b1": "c1.hlo.txt", "classifier_b4": "c4.hlo.txt",
                             "predictor": "p.hlo.txt"},
              "check": {"classifier_logits_b1": [0.5, -0.5],
                         "predictor_feats": [[1, 0, 0, 0]],
                         "predictor_scores": [0.76]}
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.input_dim, 8);
        assert_eq!(m.batches, vec![1, 4]);
        assert_eq!(
            m.classifier_path(4).unwrap().file_name().unwrap(),
            "c4.hlo.txt"
        );
        assert!(m.classifier_path(2).is_none());
        assert_eq!(m.check_predictor.len(), 1);
        assert_eq!(m.predictor_weights.len(), 4);
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("freshen-manifest-missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    /// Write `text` as a manifest in a fresh temp dir and load it.
    fn load_text(name: &str, text: &str) -> Result<Manifest> {
        let dir = std::env::temp_dir().join(format!("freshen-manifest-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        Manifest::load(&dir)
    }

    const VALID: &str = r#"{
      "input_dim": 8, "classes": 2, "batches": [4, 1, 4],
      "predictor_batch": 16,
      "predictor_weights": [3.2, 1.8, 0.9, -0.6], "predictor_bias": -2.0,
      "artifacts": {},
      "check": {"classifier_logits_b1": [0.5, -0.5],
                 "predictor_feats": [[1, 0, 0, 0]],
                 "predictor_scores": [0.76]}
    }"#;

    #[test]
    fn batches_are_sorted_and_deduplicated() {
        let m = load_text("sortdedup", VALID).unwrap();
        assert_eq!(m.batches, vec![1, 4]);
        assert!(m.weights.is_none(), "no weights section parsed as None");
    }

    #[test]
    fn missing_batches_errors() {
        let text = VALID.replacen(r#""batches": [4, 1, 4],"#, "", 1);
        assert!(load_text("nobatches", &text).is_err());
        let empty = VALID.replacen("[4, 1, 4]", "[]", 1);
        assert!(load_text("emptybatches", &empty).is_err());
        let zero = VALID.replacen("[4, 1, 4]", "[0, 1]", 1);
        assert!(load_text("zerobatch", &zero).is_err());
    }

    #[test]
    fn malformed_artifacts_object_errors() {
        for (name, bad) in [
            ("arr", r#""artifacts": [1, 2]"#),
            ("str", r#""artifacts": "classifier_b1.hlo.txt""#),
            ("num", r#""artifacts": 7"#),
        ] {
            let text = VALID.replacen(r#""artifacts": {}"#, bad, 1);
            assert!(
                load_text(&format!("badart-{name}"), &text).is_err(),
                "artifacts as {name} must fail"
            );
        }
    }

    #[test]
    fn mismatched_predictor_check_lengths_error() {
        let text = VALID.replacen("[0.76]", "[0.76, 0.12]", 1);
        let err = load_text("mismatch", &text).unwrap_err();
        assert!(
            format!("{err:#}").contains("predictor_scores"),
            "error should name the mismatch: {err:#}"
        );
    }

    fn with_weights(weights: &str) -> String {
        VALID.replacen(
            r#""artifacts": {},"#,
            &format!(r#""artifacts": {{}}, "weights": {weights},"#),
            1,
        )
    }

    #[test]
    fn weights_section_parses() {
        let text = with_weights(
            r#"{
              "format": "f32-le",
              "normalize": {"mean": 0.5, "std": 0.25},
              "layers": [
                {"in": 8, "out": 4, "relu": true,
                 "weights": "l0.w.bin", "bias": "l0.b.bin"},
                {"in": 4, "out": 2, "relu": false,
                 "weights": "l1.w.bin", "bias": "l1.b.bin"}
              ]
            }"#,
        );
        let m = load_text("weights-ok", &text).unwrap();
        let w = m.weights.expect("parsed");
        assert_eq!(w.mean, 0.5);
        assert_eq!(w.std, 0.25);
        assert_eq!(w.layers.len(), 2);
        assert!(w.layers[0].relu && !w.layers[1].relu);
        assert_eq!(w.layers[1].weights_file, "l1.w.bin");
    }

    #[test]
    fn weights_section_is_validated() {
        // Broken dimension chain (layer 0 emits 4, layer 1 expects 5).
        let broken = with_weights(
            r#"{"layers": [
                {"in": 8, "out": 4, "weights": "a.bin", "bias": "b.bin"},
                {"in": 5, "out": 2, "weights": "c.bin", "bias": "d.bin"}
            ]}"#,
        );
        assert!(load_text("weights-chain", &broken).is_err());
        // Unknown blob format.
        let fmt = with_weights(r#"{"format": "f64-be", "layers": []}"#);
        assert!(load_text("weights-fmt", &fmt).is_err());
        // Empty layer list.
        let empty = with_weights(r#"{"layers": []}"#);
        assert!(load_text("weights-empty", &empty).is_err());
        // Missing file names.
        let nofile = with_weights(r#"{"layers": [{"in": 8, "out": 2}]}"#);
        assert!(load_text("weights-nofile", &nofile).is_err());
        // Non-positive std.
        let badstd = with_weights(
            r#"{"normalize": {"mean": 0, "std": 0},
                "layers": [{"in": 8, "out": 2, "weights": "a", "bias": "b"}]}"#,
        );
        assert!(load_text("weights-std", &badstd).is_err());
    }
}
