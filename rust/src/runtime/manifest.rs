//! `artifacts/manifest.json`: what the AOT pass produced.
//!
//! Written by `python/compile/aot.py`; read here so the rust runtime knows
//! the artifact shapes, available batch sizes, and the sample-check
//! numerics the integration tests assert against.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub input_dim: usize,
    pub classes: usize,
    pub batches: Vec<usize>,
    pub predictor_batch: usize,
    pub predictor_weights: Vec<f64>,
    pub predictor_bias: f64,
    /// artifact key -> file name
    pub artifacts: Vec<(String, String)>,
    /// Expected logits for the linspace(-1,1) sample input (batch 1).
    pub check_logits_b1: Vec<f64>,
    /// (features, expected score) rows for the predictor check.
    pub check_predictor: Vec<(Vec<f64>, f64)>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let batches = j
            .get("batches")
            .and_then(Json::as_arr)
            .context("manifest: batches")?
            .iter()
            .filter_map(Json::as_u64)
            .map(|b| b as usize)
            .collect::<Vec<_>>();
        if batches.is_empty() {
            bail!("manifest: no batch sizes");
        }

        let artifacts = match j.get("artifacts") {
            Some(Json::Obj(pairs)) => pairs
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect(),
            _ => bail!("manifest: artifacts object missing"),
        };

        let check = j.get("check").context("manifest: check")?;
        let check_logits_b1 = check
            .get("classifier_logits_b1")
            .and_then(Json::as_arr)
            .context("manifest: check logits")?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let feats = check
            .get("predictor_feats")
            .and_then(Json::as_arr)
            .context("manifest: predictor feats")?;
        let scores = check
            .get("predictor_scores")
            .and_then(Json::as_arr)
            .context("manifest: predictor scores")?;
        let check_predictor = feats
            .iter()
            .zip(scores.iter())
            .filter_map(|(f, s)| {
                let row: Vec<f64> = f.as_arr()?.iter().filter_map(Json::as_f64).collect();
                Some((row, s.as_f64()?))
            })
            .collect();

        Ok(Manifest {
            input_dim: j.u64_or("input_dim", 3072) as usize,
            classes: j.u64_or("classes", 10) as usize,
            batches,
            predictor_batch: j.u64_or("predictor_batch", 16) as usize,
            predictor_weights: j
                .get("predictor_weights")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default(),
            predictor_bias: j.f64_or("predictor_bias", 0.0),
            artifacts,
            check_logits_b1,
            check_predictor,
            dir: dir.to_path_buf(),
        })
    }

    /// Path of the classifier artifact for `batch`.
    pub fn classifier_path(&self, batch: usize) -> Option<PathBuf> {
        let key = format!("classifier_b{batch}");
        self.artifacts
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, f)| self.dir.join(f))
    }

    pub fn predictor_path(&self) -> Option<PathBuf> {
        self.artifacts
            .iter()
            .find(|(k, _)| k == "predictor")
            .map(|(_, f)| self.dir.join(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_synthetic_manifest() {
        let dir = std::env::temp_dir().join("freshen-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "input_dim": 8, "classes": 2, "batches": [1, 4],
              "predictor_batch": 16,
              "predictor_weights": [3.2, 1.8, 0.9, -0.6], "predictor_bias": -2.0,
              "artifacts": {"classifier_b1": "c1.hlo.txt", "classifier_b4": "c4.hlo.txt",
                             "predictor": "p.hlo.txt"},
              "check": {"classifier_logits_b1": [0.5, -0.5],
                         "predictor_feats": [[1, 0, 0, 0]],
                         "predictor_scores": [0.76]}
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.input_dim, 8);
        assert_eq!(m.batches, vec![1, 4]);
        assert_eq!(
            m.classifier_path(4).unwrap().file_name().unwrap(),
            "c4.hlo.txt"
        );
        assert!(m.classifier_path(2).is_none());
        assert_eq!(m.check_predictor.len(), 1);
        assert_eq!(m.predictor_weights.len(), 4);
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("freshen-manifest-missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
