//! Pluggable inference execution: one trait, two engines.
//!
//! [`InferenceBackend`] is the seam between the typed runtimes
//! ([`crate::runtime::model`]) and whatever actually executes the model.
//! The runtimes own everything batch-policy-shaped — input validation,
//! chunking, pad-to-AOT-size, statistics, self-checks — and hand the
//! backend a fully padded flat buffer; the backend only runs math:
//!
//! - [`NativeMlpBackend`] / [`NativeLogisticBackend`] — the pure-rust
//!   engines in [`crate::nn`], fed from the manifest's weight sidecars.
//!   Always available; the default.
//! - [`PjrtBackend`] — the compiled HLO artifacts through PJRT. Only
//!   works when the real `xla` crate is patched in over the vendored
//!   stub; with the stub it fails at load time with a descriptive error.
//!
//! Because both backends execute behind the same padded-batch contract,
//! A/B-ing them (`repro serve --backend native|pjrt`) exercises identical
//! batcher and runtime behavior — only the executor changes.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::nn;
use crate::runtime::manifest::Manifest;
use crate::runtime::{compile_hlo_file, cpu_client};

/// Which executor a runtime should load.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-rust `nn` engine (weight sidecars; works offline).
    #[default]
    Native,
    /// PJRT execution of the HLO artifacts (needs the real `xla` crate).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend '{other}' (expected 'native' or 'pjrt')"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// An executor for one model: given exactly `rows` rows of flat row-major
/// `f32` input (a compiled AOT batch size for PJRT; any row count for
/// native), produce `rows` rows of flat output.
///
/// Implementations are used from a single thread (the serving engine's
/// inference thread owns its runtime); PJRT state is not `Send`, so the
/// trait deliberately has no `Send` bound.
pub trait InferenceBackend {
    /// Human-readable platform tag (`check-artifacts` prints it).
    fn name(&self) -> String;

    /// Execute on `rows × in_dim` values; returns `rows × out_dim`.
    fn execute(&mut self, rows: usize, flat: &[f32]) -> Result<Vec<f32>>;
}

/// The classifier MLP on the native `nn` engine.
pub struct NativeMlpBackend {
    mlp: nn::Mlp,
}

impl NativeMlpBackend {
    pub fn load(manifest: &Manifest) -> Result<NativeMlpBackend> {
        Ok(NativeMlpBackend {
            mlp: nn::Mlp::load(manifest)?,
        })
    }
}

impl InferenceBackend for NativeMlpBackend {
    fn name(&self) -> String {
        "native-rust".to_string()
    }

    fn execute(&mut self, rows: usize, flat: &[f32]) -> Result<Vec<f32>> {
        self.mlp.forward_flat(rows, flat)
    }
}

/// The learned next-invocation scorer on the native engine (the logistic
/// weights ride in the manifest itself — no sidecar files needed).
pub struct NativeLogisticBackend {
    weights: Vec<f32>,
    bias: f32,
}

impl NativeLogisticBackend {
    pub fn load(manifest: &Manifest) -> Result<NativeLogisticBackend> {
        if manifest.predictor_weights.is_empty() {
            bail!("manifest has no predictor_weights (native predictor backend needs them)");
        }
        Ok(NativeLogisticBackend {
            weights: manifest.predictor_weights.iter().map(|&w| w as f32).collect(),
            bias: manifest.predictor_bias as f32,
        })
    }
}

impl InferenceBackend for NativeLogisticBackend {
    fn name(&self) -> String {
        "native-rust".to_string()
    }

    fn execute(&mut self, rows: usize, flat: &[f32]) -> Result<Vec<f32>> {
        let x = nn::Matrix::from_slice(rows, self.weights.len(), flat)?;
        nn::kernels::logistic_score(&x, &self.weights, self.bias)
    }
}

/// Compiled HLO artifacts executed through PJRT, one executable per AOT
/// batch size.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    in_dim: usize,
}

impl PjrtBackend {
    /// Compile every `classifier_b{N}` artifact listed in the manifest.
    pub fn load_classifier(manifest: &Manifest) -> Result<PjrtBackend> {
        let client = cpu_client()?;
        let mut exes = BTreeMap::new();
        for &b in &manifest.batches {
            let path = manifest
                .classifier_path(b)
                .with_context(|| format!("manifest lacks classifier_b{b}"))?;
            exes.insert(b, compile_hlo_file(&client, &path)?);
        }
        if exes.is_empty() {
            bail!("no classifier artifacts found in {}", manifest.dir.display());
        }
        Ok(PjrtBackend {
            client,
            exes,
            in_dim: manifest.input_dim,
        })
    }

    /// Compile the predictor artifact (fixed batch).
    pub fn load_predictor(manifest: &Manifest) -> Result<PjrtBackend> {
        let client = cpu_client()?;
        let path = manifest
            .predictor_path()
            .context("manifest lacks predictor artifact")?;
        let exe = compile_hlo_file(&client, &path)?;
        let mut exes = BTreeMap::new();
        exes.insert(manifest.predictor_batch, exe);
        Ok(PjrtBackend {
            client,
            exes,
            in_dim: 4,
        })
    }
}

impl InferenceBackend for PjrtBackend {
    fn name(&self) -> String {
        self.client.platform_name()
    }

    fn execute(&mut self, rows: usize, flat: &[f32]) -> Result<Vec<f32>> {
        let exe = self
            .exes
            .get(&rows)
            .with_context(|| format!("no compiled executable for batch {rows}"))?;
        let x = xla::Literal::vec1(flat).reshape(&[rows as i64, self.in_dim as i64])?;
        let result = exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?; // lowered with return_tuple=True
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Native);
        assert_eq!(BackendKind::Native.as_str(), "native");
        assert_eq!(BackendKind::Pjrt.as_str(), "pjrt");
    }

    #[test]
    fn pjrt_backend_fails_descriptively_on_the_stub() {
        // With the vendored xla stub, PJRT load errors mention the patch
        // path instead of panicking.
        let dir = std::env::temp_dir().join("freshen-backend-stub");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "input_dim": 4, "classes": 2, "batches": [1],
              "artifacts": {"classifier_b1": "c1.hlo.txt", "predictor": "p.hlo.txt"},
              "check": {"classifier_logits_b1": [0, 0],
                         "predictor_feats": [], "predictor_scores": []}
            }"#,
        )
        .unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let err = PjrtBackend::load_classifier(&manifest).unwrap_err();
        assert!(
            format!("{err:#}").contains("unavailable"),
            "stub error should say the backend is unavailable: {err:#}"
        );
    }
}
