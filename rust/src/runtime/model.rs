//! Typed, batched execution of the classifier and predictor artifacts.
//!
//! [`ClassifierRuntime`] holds one compiled executable per AOT batch size
//! and serves arbitrary request batches by picking the smallest artifact
//! batch that fits and zero-padding (standard static-batch serving).

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::Manifest;
use crate::runtime::{compile_hlo_file, cpu_client};

/// The λ1 image classifier, compiled for each AOT batch size.
pub struct ClassifierRuntime {
    client: xla::PjRtClient,
    exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    /// Cumulative inference statistics.
    pub executions: u64,
    pub rows_served: u64,
    pub padded_rows: u64,
    pub exec_time: Duration,
}

impl ClassifierRuntime {
    /// Load every classifier artifact listed in `dir`'s manifest.
    pub fn load(dir: &Path) -> Result<ClassifierRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = cpu_client()?;
        let mut exes = BTreeMap::new();
        for &b in &manifest.batches {
            let path = manifest
                .classifier_path(b)
                .with_context(|| format!("manifest lacks classifier_b{b}"))?;
            exes.insert(b, compile_hlo_file(&client, &path)?);
        }
        if exes.is_empty() {
            bail!("no classifier artifacts found in {}", dir.display());
        }
        Ok(ClassifierRuntime {
            client,
            exes,
            manifest,
            executions: 0,
            rows_served: 0,
            padded_rows: 0,
            exec_time: Duration::ZERO,
        })
    }

    /// Largest compiled batch (the batcher's cap).
    pub fn max_batch(&self) -> usize {
        *self.exes.keys().max().expect("non-empty")
    }

    /// Smallest compiled batch >= n (or the max batch when n exceeds it).
    pub fn pick_batch(&self, n: usize) -> usize {
        self.exes
            .keys()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.max_batch())
    }

    /// Run inference on up to `max_batch()` rows of `input_dim` floats.
    /// Returns one logits row (`classes` floats) per input row.
    pub fn infer(&mut self, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let dim = self.manifest.input_dim;
        for (i, r) in rows.iter().enumerate() {
            if r.len() != dim {
                bail!("row {i} has {} features, expected {dim}", r.len());
            }
        }
        if rows.len() > self.max_batch() {
            bail!(
                "batch {} exceeds max compiled batch {}",
                rows.len(),
                self.max_batch()
            );
        }
        let b = self.pick_batch(rows.len());
        // Zero-pad to the artifact batch.
        let mut flat = vec![0f32; b * dim];
        for (i, r) in rows.iter().enumerate() {
            flat[i * dim..(i + 1) * dim].copy_from_slice(r);
        }
        let x = xla::Literal::vec1(&flat).reshape(&[b as i64, dim as i64])?;
        let t0 = Instant::now();
        let exe = self.exes.get(&b).expect("picked existing batch");
        let result = exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        self.exec_time += t0.elapsed();
        self.executions += 1;
        self.rows_served += rows.len() as u64;
        self.padded_rows += (b - rows.len()) as u64;
        let out = result.to_tuple1()?; // lowered with return_tuple=True
        let flat_out = out.to_vec::<f32>()?;
        let classes = self.manifest.classes;
        Ok(rows
            .iter()
            .enumerate()
            .map(|(i, _)| flat_out[i * classes..(i + 1) * classes].to_vec())
            .collect())
    }

    /// Verify the artifact against the manifest's sample check: the
    /// linspace input must reproduce the recorded logits. This is the
    /// rust-side half of the AOT numerics contract.
    pub fn self_check(&mut self) -> Result<f64> {
        let dim = self.manifest.input_dim;
        let row: Vec<f32> = (0..dim)
            .map(|i| -1.0 + 2.0 * i as f32 / (dim as f32 - 1.0))
            .collect();
        let logits = self.infer(&[row])?;
        let want = &self.manifest.check_logits_b1;
        if want.len() != logits[0].len() {
            bail!("class count mismatch");
        }
        let mut max_err: f64 = 0.0;
        for (g, w) in logits[0].iter().zip(want.iter()) {
            max_err = max_err.max((*g as f64 - w).abs());
        }
        if max_err > 1e-3 {
            bail!("artifact self-check failed: max |err| = {max_err}");
        }
        Ok(max_err)
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }
}

/// The learned next-invocation scorer artifact (fixed batch).
pub struct PredictorRuntime {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub manifest: Manifest,
}

impl PredictorRuntime {
    pub fn load(dir: &Path) -> Result<PredictorRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = cpu_client()?;
        let path = manifest
            .predictor_path()
            .context("manifest lacks predictor artifact")?;
        let exe = compile_hlo_file(&client, &path)?;
        Ok(PredictorRuntime {
            exe,
            batch: manifest.predictor_batch,
            manifest,
        })
    }

    /// Score up to `batch` feature rows `[chain, hist, recency, log_lead]`.
    pub fn score(&self, rows: &[[f32; 4]]) -> Result<Vec<f32>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        if rows.len() > self.batch {
            bail!("predictor batch {} > {}", rows.len(), self.batch);
        }
        let mut flat = vec![0f32; self.batch * 4];
        for (i, r) in rows.iter().enumerate() {
            flat[i * 4..(i + 1) * 4].copy_from_slice(r);
        }
        let x = xla::Literal::vec1(&flat).reshape(&[self.batch as i64, 4])?;
        let result = self.exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?.to_vec::<f32>()?;
        Ok(out[..rows.len()].to_vec())
    }

    /// Check the artifact agrees with the manifest's recorded scores AND
    /// with the native rust scorer in `predict::learned`.
    pub fn self_check(&self) -> Result<f64> {
        let rows: Vec<[f32; 4]> = self
            .manifest
            .check_predictor
            .iter()
            .map(|(f, _)| [f[0] as f32, f[1] as f32, f[2] as f32, f[3] as f32])
            .collect();
        let want: Vec<f64> = self.manifest.check_predictor.iter().map(|(_, s)| *s).collect();
        let got = self.score(&rows)?;
        let mut max_err: f64 = 0.0;
        for (g, w) in got.iter().zip(want.iter()) {
            max_err = max_err.max((*g as f64 - w).abs());
        }
        // Native scorer agreement.
        let native = crate::predict::learned::LearnedScorer::default();
        for (row, g) in rows.iter().zip(got.iter()) {
            let f = crate::predict::learned::Features {
                chain_conf: row[0] as f64,
                hist_conf: row[1] as f64,
                recency: row[2] as f64,
                log_lead: row[3] as f64,
            };
            max_err = max_err.max((native.score(&f) - *g as f64).abs());
        }
        if max_err > 1e-4 {
            bail!("predictor self-check failed: max |err| = {max_err}");
        }
        Ok(max_err)
    }
}
