//! Typed, batched execution of the classifier and predictor models.
//!
//! [`ClassifierRuntime`] serves arbitrary request batches over any
//! [`InferenceBackend`]: it picks the smallest AOT batch size that fits,
//! zero-pads up to it (standard static-batch serving), and chunks
//! oversized inputs into `max_batch()`-sized slices. The pad/chunk policy
//! lives here — *above* the backend seam — so batcher behavior is
//! identical whether the executor is PJRT or the native `nn` engine.

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::runtime::backend::{
    BackendKind, InferenceBackend, NativeLogisticBackend, NativeMlpBackend, PjrtBackend,
};
use crate::runtime::manifest::Manifest;

/// The λ1 image classifier behind the pad-to-AOT-batch policy.
pub struct ClassifierRuntime {
    backend: Box<dyn InferenceBackend>,
    pub kind: BackendKind,
    pub manifest: Manifest,
    /// Pad each chunk up to the smallest AOT batch that fits (the static-
    /// batch serving discipline). The native engine can execute any row
    /// count, so this can be switched off (`--no-pad`) for exact-size
    /// executions; PJRT executables are compiled per batch size and
    /// always pad.
    pad_to_aot: bool,
    /// Cumulative inference statistics.
    pub executions: u64,
    pub rows_served: u64,
    pub padded_rows: u64,
    pub exec_time: Duration,
}

impl ClassifierRuntime {
    /// Load from `dir`'s manifest on the default backend (native).
    pub fn load(dir: &Path) -> Result<ClassifierRuntime> {
        ClassifierRuntime::load_with(dir, BackendKind::default())
    }

    /// Load on an explicit backend.
    pub fn load_with(dir: &Path, kind: BackendKind) -> Result<ClassifierRuntime> {
        let manifest = Manifest::load(dir)?;
        let backend: Box<dyn InferenceBackend> = match kind {
            BackendKind::Native => Box::new(NativeMlpBackend::load(&manifest)?),
            BackendKind::Pjrt => Box::new(PjrtBackend::load_classifier(&manifest)?),
        };
        Ok(ClassifierRuntime {
            backend,
            kind,
            manifest,
            pad_to_aot: true,
            executions: 0,
            rows_served: 0,
            padded_rows: 0,
            exec_time: Duration::ZERO,
        })
    }

    /// Switch the pad-to-AOT-batch policy. A `false` is honoured only on
    /// the native backend — PJRT executables exist per compiled batch
    /// size, so they silently keep padding. Returns the effective value.
    pub fn set_pad_to_aot(&mut self, pad: bool) -> bool {
        self.pad_to_aot = pad || self.kind == BackendKind::Pjrt;
        self.pad_to_aot
    }

    /// Is the pad-to-AOT-batch policy active?
    pub fn pads_to_aot(&self) -> bool {
        self.pad_to_aot
    }

    /// Largest AOT batch (one backend execution never exceeds this).
    pub fn max_batch(&self) -> usize {
        *self.manifest.batches.last().expect("manifest has batches")
    }

    /// Smallest AOT batch >= n (or the max batch when n exceeds it).
    pub fn pick_batch(&self, n: usize) -> usize {
        self.manifest
            .batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.max_batch())
    }

    /// Run inference on any number of rows of `input_dim` floats.
    /// Oversized inputs are chunked into `max_batch()`-sized executions;
    /// each chunk is zero-padded to the smallest AOT batch that fits.
    /// Returns one logits row (`classes` floats) per input row.
    pub fn infer(&mut self, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        let dim = self.manifest.input_dim;
        for (i, r) in rows.iter().enumerate() {
            if r.len() != dim {
                bail!("row {i} has {} features, expected {dim}", r.len());
            }
        }
        let max = self.max_batch();
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(max) {
            out.extend(self.infer_chunk(chunk)?);
        }
        Ok(out)
    }

    /// One backend execution for `rows.len() <= max_batch()` rows —
    /// padded to the smallest fitting AOT batch, or exact-size when the
    /// pad policy is off (native backend only).
    fn infer_chunk(&mut self, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let dim = self.manifest.input_dim;
        let b = if self.pad_to_aot {
            self.pick_batch(rows.len())
        } else {
            rows.len()
        };
        // Pad to the artifact batch. Padded rows' outputs are discarded,
        // so the fill value is free to choose: use the normalize mean,
        // which standardizes to exactly 0.0 and lets the native kernel's
        // zero-skip path make the padded tail nearly free.
        let pad = self
            .manifest
            .weights
            .as_ref()
            .map(|w| w.mean as f32)
            .unwrap_or(0.0);
        let mut flat = vec![pad; b * dim];
        for (i, r) in rows.iter().enumerate() {
            flat[i * dim..(i + 1) * dim].copy_from_slice(r);
        }
        let t0 = Instant::now();
        let flat_out = self.backend.execute(b, &flat)?;
        self.exec_time += t0.elapsed();
        self.executions += 1;
        self.rows_served += rows.len() as u64;
        self.padded_rows += (b - rows.len()) as u64;
        let classes = self.manifest.classes;
        if flat_out.len() != b * classes {
            bail!(
                "backend returned {} values, expected {} ({b} rows x {classes} classes)",
                flat_out.len(),
                b * classes
            );
        }
        Ok((0..rows.len())
            .map(|i| flat_out[i * classes..(i + 1) * classes].to_vec())
            .collect())
    }

    /// Verify the loaded model against the manifest's sample check: the
    /// linspace input must reproduce the recorded logits. This is the
    /// rust-side half of the AOT numerics contract — and, on the native
    /// backend, the blocked-kernel-vs-reference parity check.
    pub fn self_check(&mut self) -> Result<f64> {
        let row = crate::nn::gen::check_probe(self.manifest.input_dim);
        let logits = self.infer(&[row])?;
        let want = &self.manifest.check_logits_b1;
        if want.len() != logits[0].len() {
            bail!("class count mismatch");
        }
        let mut max_err: f64 = 0.0;
        for (g, w) in logits[0].iter().zip(want.iter()) {
            max_err = max_err.max((*g as f64 - w).abs());
        }
        if max_err > 1e-3 {
            bail!("artifact self-check failed: max |err| = {max_err}");
        }
        Ok(max_err)
    }

    pub fn platform_name(&self) -> String {
        self.backend.name()
    }
}

/// The learned next-invocation scorer (fixed AOT batch).
pub struct PredictorRuntime {
    backend: Box<dyn InferenceBackend>,
    pub kind: BackendKind,
    pub batch: usize,
    pub manifest: Manifest,
}

impl PredictorRuntime {
    /// Load from `dir`'s manifest on the default backend (native).
    pub fn load(dir: &Path) -> Result<PredictorRuntime> {
        PredictorRuntime::load_with(dir, BackendKind::default())
    }

    pub fn load_with(dir: &Path, kind: BackendKind) -> Result<PredictorRuntime> {
        let manifest = Manifest::load(dir)?;
        let backend: Box<dyn InferenceBackend> = match kind {
            BackendKind::Native => Box::new(NativeLogisticBackend::load(&manifest)?),
            BackendKind::Pjrt => Box::new(PjrtBackend::load_predictor(&manifest)?),
        };
        Ok(PredictorRuntime {
            backend,
            kind,
            batch: manifest.predictor_batch,
            manifest,
        })
    }

    /// Score up to `batch` feature rows `[chain, hist, recency, log_lead]`.
    pub fn score(&mut self, rows: &[[f32; 4]]) -> Result<Vec<f32>> {
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        if rows.len() > self.batch {
            bail!("predictor batch {} > {}", rows.len(), self.batch);
        }
        let mut flat = vec![0f32; self.batch * 4];
        for (i, r) in rows.iter().enumerate() {
            flat[i * 4..(i + 1) * 4].copy_from_slice(r);
        }
        let out = self.backend.execute(self.batch, &flat)?;
        if out.len() < rows.len() {
            bail!("backend returned {} scores for {} rows", out.len(), rows.len());
        }
        Ok(out[..rows.len()].to_vec())
    }

    /// Check the model agrees with the manifest's recorded scores AND
    /// with the native rust scorer in `predict::learned`.
    pub fn self_check(&mut self) -> Result<f64> {
        for (i, (f, _)) in self.manifest.check_predictor.iter().enumerate() {
            if f.len() != 4 {
                bail!(
                    "manifest predictor check row {i} has {} features, expected 4",
                    f.len()
                );
            }
        }
        let rows: Vec<[f32; 4]> = self
            .manifest
            .check_predictor
            .iter()
            .map(|(f, _)| [f[0] as f32, f[1] as f32, f[2] as f32, f[3] as f32])
            .collect();
        let want: Vec<f64> = self.manifest.check_predictor.iter().map(|(_, s)| *s).collect();
        let got = self.score(&rows)?;
        let mut max_err: f64 = 0.0;
        for (g, w) in got.iter().zip(want.iter()) {
            max_err = max_err.max((*g as f64 - w).abs());
        }
        // Native scorer agreement.
        let native = crate::predict::learned::LearnedScorer::default();
        for (row, g) in rows.iter().zip(got.iter()) {
            let f = crate::predict::learned::Features {
                chain_conf: row[0] as f64,
                hist_conf: row[1] as f64,
                recency: row[2] as f64,
                log_lead: row[3] as f64,
            };
            max_err = max_err.max((native.score(&f) - *g as f64).abs());
        }
        if max_err > 1e-4 {
            bail!("predictor self-check failed: max |err| = {max_err}");
        }
        Ok(max_err)
    }

    pub fn platform_name(&self) -> String {
        self.backend.name()
    }
}
