//! Model runtime: load the AOT artifact set and execute it from rust,
//! on a pluggable backend.
//!
//! This is the L3↔L2 bridge. `make artifacts` lowers the JAX/Pallas model
//! and writes an artifact directory containing `manifest.json` (shapes,
//! batch sizes, sample-check numerics — see [`manifest`]), HLO **text**
//! modules for PJRT, and raw `f32` weight sidecars for the native engine;
//! `repro gen-artifacts` writes a native-only set without python. The
//! typed runtimes in [`model`] load the manifest and serve batched,
//! validated inference to the serving engine; python never runs here.
//!
//! # Architecture: backend trait under the batch policy
//!
//! ```text
//!   serve::engine (batcher, one inference thread)
//!        │ rows
//!   model::{ClassifierRuntime, PredictorRuntime}
//!        │   validate → chunk to max_batch → zero-pad to AOT batch
//!        │   (identical policy for every backend)
//!        ▼ padded flat f32 batch
//!   backend::InferenceBackend          ← the seam
//!     ├── NativeMlpBackend / NativeLogisticBackend   (nn, default)
//!     └── PjrtBackend                                 (real `xla` crate)
//! ```
//!
//! The **native** backend ([`crate::nn`]) is pure rust and always
//! available — a fresh offline checkout can generate, check, and serve an
//! artifact set with no external dependencies. The **PJRT** backend
//! compiles the HLO text with `HloModuleProto::from_text_file` on the
//! PJRT CPU client; in the default build it is a vendored compile-time
//! stub that errors descriptively at load, and patching the real `xla`
//! crate into the workspace enables it with no source changes
//! (`--backend pjrt`).
//!
//! Thread model: the `xla` crate's wrappers hold raw pointers and are not
//! `Send`, so runtimes live on whichever thread created them; the serving
//! engine dedicates one inference thread that owns its
//! [`model::ClassifierRuntime`] (the vLLM-style "engine loop"). The
//! native backend has no such constraint but follows the same discipline.

pub mod backend;
pub mod manifest;
pub mod model;

use anyhow::{Context, Result};
use std::path::Path;

/// Load an HLO-text artifact and compile it on `client`.
pub fn compile_hlo_file(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

/// Create the CPU PJRT client.
pub fn cpu_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu().context("creating PJRT CPU client")
}
