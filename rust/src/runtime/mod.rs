//! PJRT runtime: load and execute the AOT artifacts from rust.
//!
//! This is the L3↔L2 bridge. `make artifacts` lowers the JAX/Pallas model
//! to HLO **text**; this module loads the text with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client,
//! and exposes typed, batched execution to the serving engine. Python never
//! runs here — the binary is self-contained once `artifacts/` exists.
//!
//! Thread model: the `xla` crate's wrappers hold raw pointers and are not
//! `Send`, so all PJRT state lives on whichever thread created it; the
//! serving engine dedicates one inference thread that owns a
//! [`model::ClassifierRuntime`] (the vLLM-style "engine loop").

pub mod manifest;
pub mod model;

use anyhow::{Context, Result};
use std::path::Path;

/// Load an HLO-text artifact and compile it on `client`.
pub fn compile_hlo_file(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

/// Create the CPU PJRT client.
pub fn cpu_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu().context("creating PJRT CPU client")
}
