//! Invocation traces: JSON-lines records, writable and replayable.
//!
//! Examples and the CLI use traces so experiments can be re-run on the
//! exact same invocation stream (and users can bring their own).

use std::io::{BufRead, Write};

use crate::util::json::Json;
use crate::util::time::SimTime;

/// One trace record: invoke `function` at virtual time `at`.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    pub at: SimTime,
    pub function: String,
}

impl TraceRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("t_us", Json::num(self.at.micros() as f64)),
            ("function", Json::str(&self.function)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<TraceRecord> {
        Some(TraceRecord {
            at: SimTime(j.get("t_us")?.as_u64()?),
            function: j.get("function")?.as_str()?.to_string(),
        })
    }
}

/// Write records as JSON lines.
pub fn write_trace<W: Write>(records: &[TraceRecord], mut w: W) -> std::io::Result<()> {
    for r in records {
        writeln!(w, "{}", r.to_json().to_string())?;
    }
    Ok(())
}

/// Read records from JSON lines; skips malformed lines with a count.
pub fn read_trace<R: BufRead>(r: R) -> (Vec<TraceRecord>, usize) {
    let mut out = Vec::new();
    let mut skipped = 0;
    for line in r.lines().map_while(Result::ok) {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match Json::parse(line).ok().and_then(|j| TraceRecord::from_json(&j)) {
            Some(rec) => out.push(rec),
            None => skipped += 1,
        }
    }
    out.sort_by_key(|r| r.at);
    (out, skipped)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let recs = vec![
            TraceRecord {
                at: SimTime(5_000),
                function: "f2".into(),
            },
            TraceRecord {
                at: SimTime(1_000),
                function: "f1".into(),
            },
        ];
        let mut buf = Vec::new();
        write_trace(&recs, &mut buf).unwrap();
        let (back, skipped) = read_trace(buf.as_slice());
        assert_eq!(skipped, 0);
        // read_trace sorts by time
        assert_eq!(back[0].function, "f1");
        assert_eq!(back[1].function, "f2");
    }

    #[test]
    fn malformed_lines_are_skipped() {
        let text = "{\"t_us\": 1, \"function\": \"a\"}\nnot json\n{\"function\": \"no time\"}\n";
        let (recs, skipped) = read_trace(text.as_bytes());
        assert_eq!(recs.len(), 1);
        assert_eq!(skipped, 2);
    }
}
