//! Invocation traces: JSON-lines records, writable and replayable.
//!
//! Examples and the CLI use traces so experiments can be re-run on the
//! exact same invocation stream (and users can bring their own).

use std::io::{BufRead, Write};

use crate::util::json::Json;
use crate::util::time::SimTime;

/// One trace record: invoke `function` at virtual time `at`.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    pub at: SimTime,
    pub function: String,
}

impl TraceRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("t_us", Json::num(self.at.micros() as f64)),
            ("function", Json::str(&self.function)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<TraceRecord> {
        Some(TraceRecord {
            at: SimTime(j.get("t_us")?.as_u64()?),
            function: j.get("function")?.as_str()?.to_string(),
        })
    }
}

/// Write records as JSON lines.
pub fn write_trace<W: Write>(records: &[TraceRecord], mut w: W) -> std::io::Result<()> {
    for r in records {
        writeln!(w, "{}", r.to_json().to_string())?;
    }
    Ok(())
}

/// Streaming trace reader: yields records one line at a time (file order,
/// NOT time-sorted), skipping malformed lines with a count. One line
/// buffer in memory regardless of trace size — callers that schedule as
/// they read (the CLI replayer, the macro benchmark's JSONL path) never
/// buffer the trace at all. [`read_trace`] remains the collect-and-sort
/// convenience wrapper on top.
pub struct TraceReader<R: BufRead> {
    src: R,
    line: String,
    skipped: usize,
    io_error: Option<std::io::Error>,
}

impl<R: BufRead> TraceReader<R> {
    pub fn new(src: R) -> TraceReader<R> {
        TraceReader {
            src,
            line: String::new(),
            skipped: 0,
            io_error: None,
        }
    }

    /// Malformed lines skipped so far.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// The I/O error that ended iteration early, if any — `None` after a
    /// clean EOF. Callers that must not silently truncate (the CLI
    /// replayer) check this after draining.
    pub fn io_error(&self) -> Option<&std::io::Error> {
        self.io_error.as_ref()
    }
}

impl<R: BufRead> Iterator for TraceReader<R> {
    type Item = TraceRecord;

    fn next(&mut self) -> Option<TraceRecord> {
        loop {
            self.line.clear();
            match self.src.read_line(&mut self.line) {
                Ok(0) => return None,
                Err(e) => {
                    self.io_error = Some(e);
                    return None;
                }
                Ok(_) => {}
            }
            let line = self.line.trim();
            if line.is_empty() {
                continue;
            }
            match Json::parse(line).ok().and_then(|j| TraceRecord::from_json(&j)) {
                Some(rec) => return Some(rec),
                None => self.skipped += 1,
            }
        }
    }
}

/// Read records from JSON lines, sorted by time; skips malformed lines
/// with a count. Thin buffering wrapper over [`TraceReader`] — prefer the
/// iterator for large traces.
pub fn read_trace<R: BufRead>(r: R) -> (Vec<TraceRecord>, usize) {
    let mut reader = TraceReader::new(r);
    let mut out: Vec<TraceRecord> = reader.by_ref().collect();
    out.sort_by_key(|r| r.at);
    (out, reader.skipped())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let recs = vec![
            TraceRecord {
                at: SimTime(5_000),
                function: "f2".into(),
            },
            TraceRecord {
                at: SimTime(1_000),
                function: "f1".into(),
            },
        ];
        let mut buf = Vec::new();
        write_trace(&recs, &mut buf).unwrap();
        let (back, skipped) = read_trace(buf.as_slice());
        assert_eq!(skipped, 0);
        // read_trace sorts by time
        assert_eq!(back[0].function, "f1");
        assert_eq!(back[1].function, "f2");
    }

    #[test]
    fn malformed_lines_are_skipped() {
        let text = "{\"t_us\": 1, \"function\": \"a\"}\nnot json\n{\"function\": \"no time\"}\n";
        let (recs, skipped) = read_trace(text.as_bytes());
        assert_eq!(recs.len(), 1);
        assert_eq!(skipped, 2);
    }

    #[test]
    fn streaming_reader_preserves_file_order_and_counts_skips() {
        let text = "{\"t_us\": 5000, \"function\": \"late\"}\n\nbogus\n{\"t_us\": 1000, \"function\": \"early\"}\n";
        let mut reader = TraceReader::new(text.as_bytes());
        // File order, not time order: streaming never buffers to sort.
        assert_eq!(reader.next().unwrap().function, "late");
        assert_eq!(reader.skipped(), 0, "skips counted lazily as lines pass");
        assert_eq!(reader.next().unwrap().function, "early");
        assert!(reader.next().is_none());
        assert_eq!(reader.skipped(), 1);
        // The wrapper sorts the same records.
        let (recs, skipped) = read_trace(text.as_bytes());
        assert_eq!(skipped, 1);
        assert_eq!(recs[0].function, "early");
        assert_eq!(recs[1].function, "late");
    }

    #[test]
    fn io_errors_end_iteration_but_are_observable() {
        struct Flaky(usize);
        impl std::io::Read for Flaky {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.0 == 0 {
                    return Err(std::io::Error::other("disk gone"));
                }
                self.0 -= 1;
                let line = b"{\"t_us\": 1, \"function\": \"a\"}\n";
                buf[..line.len()].copy_from_slice(line);
                Ok(line.len())
            }
        }
        let mut reader = TraceReader::new(std::io::BufReader::new(Flaky(2)));
        assert_eq!(reader.by_ref().count(), 2, "reads before the fault parse");
        assert!(reader.io_error().is_some(), "the I/O error must be visible");
        let mut clean = TraceReader::new("{\"t_us\": 1, \"function\": \"a\"}\n".as_bytes());
        assert_eq!(clean.by_ref().count(), 1);
        assert!(clean.io_error().is_none());
    }
}
