//! Arrival-process generators.
//!
//! Drives the platform with realistic invocation streams: Poisson (open
//! loop), periodic-with-jitter (cron-style, the histogram predictor's
//! best case), and bursty on/off (its worst case).

use crate::util::rng::Rng;
use crate::util::time::{SimDuration, SimTime};

/// An arrival process emitting invocation times for one function.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Poisson with the given rate (events/sec).
    Poisson { rate: f64 },
    /// Periodic with multiplicative jitter (sigma as fraction of period).
    Periodic { period: SimDuration, jitter: f64 },
    /// On/off bursts: `burst_len` arrivals spaced `intra`, then an
    /// exponential gap with mean `off_mean_s`.
    Bursty {
        burst_len: u32,
        intra: SimDuration,
        off_mean_s: f64,
    },
}

impl ArrivalProcess {
    /// Generate arrival times in `[0, horizon)`.
    pub fn generate(&self, horizon: SimDuration, rng: &mut Rng) -> Vec<SimTime> {
        let mut out = Vec::new();
        let end = SimTime::ZERO + horizon;
        let mut t = SimTime::ZERO;
        match self {
            ArrivalProcess::Poisson { rate } => loop {
                t = t + SimDuration::from_secs_f64(rng.exponential(*rate));
                if t >= end {
                    break;
                }
                out.push(t);
            },
            ArrivalProcess::Periodic { period, jitter } => loop {
                let step = period.mul_f64(rng.lognormal(0.0, *jitter));
                t = t + step.max(SimDuration(1));
                if t >= end {
                    break;
                }
                out.push(t);
            },
            ArrivalProcess::Bursty {
                burst_len,
                intra,
                off_mean_s,
            } => loop {
                for _ in 0..*burst_len {
                    if t >= end {
                        return out;
                    }
                    out.push(t);
                    t = t + *intra;
                }
                t = t + SimDuration::from_secs_f64(rng.exponential(1.0 / off_mean_s.max(1e-9)));
                if t >= end {
                    break;
                }
            },
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_respected() {
        let mut rng = Rng::new(1);
        let arr = ArrivalProcess::Poisson { rate: 10.0 }
            .generate(SimDuration::from_secs(100), &mut rng);
        // ~1000 arrivals expected.
        assert!((900..1100).contains(&arr.len()), "{}", arr.len());
        assert!(arr.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn periodic_spacing() {
        let mut rng = Rng::new(2);
        let arr = ArrivalProcess::Periodic {
            period: SimDuration::from_secs(10),
            jitter: 0.05,
        }
        .generate(SimDuration::from_secs(1000), &mut rng);
        assert!((90..=110).contains(&arr.len()), "{}", arr.len());
    }

    #[test]
    fn bursts_have_structure() {
        let mut rng = Rng::new(3);
        let arr = ArrivalProcess::Bursty {
            burst_len: 5,
            intra: SimDuration::from_millis(10),
            off_mean_s: 30.0,
        }
        .generate(SimDuration::from_secs(600), &mut rng);
        assert!(!arr.is_empty());
        // Contains both tight gaps and long gaps.
        let gaps: Vec<f64> = arr.windows(2).map(|w| (w[1] - w[0]).as_secs_f64()).collect();
        assert!(gaps.iter().any(|&g| g < 0.02));
        assert!(gaps.iter().any(|&g| g > 5.0));
    }
}
