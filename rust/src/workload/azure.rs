//! Synthetic Azure-like application population (Figure 2).
//!
//! Figure 2 compares the CDF of functions-per-application for
//! **orchestration-framework** apps against **all** apps in the Azure
//! trace: "applications utilizing Orchestration frameworks typically
//! consist of more functions: 8 functions in the median Orchestration case
//! versus 2 functions in the median case of all." The trace itself is not
//! public in raw form; we synthesize a population matching the published
//! statistics:
//!
//! - all apps: median 2 functions, heavy right tail (most apps are small;
//!   a few have dozens of functions) — geometric-ish body + Pareto tail;
//! - orchestration apps: median 8 functions, broader body;
//! - orchestration apps are a minority of the population (~5%);
//! - median function runtime ~700 ms (used for the chain-window estimate:
//!   "opportunities for prediction could be as high as ~5.6 s in the
//!   extreme case of a linear chain" = 8 × 700 ms).

use crate::util::rng::Rng;

/// One synthesized application.
#[derive(Debug, Clone)]
pub struct SynthApp {
    pub id: String,
    pub functions: u32,
    pub orchestrated: bool,
    /// Median runtime of this app's functions, seconds.
    pub fn_runtime_s: f64,
}

/// Population parameters (defaults calibrated to [9]).
#[derive(Debug, Clone)]
pub struct AzurePopulationCfg {
    pub apps: usize,
    /// Fraction of apps using an orchestration framework.
    pub orchestration_fraction: f64,
    /// Target median functions/app over ALL apps.
    pub median_all: f64,
    /// Target median functions/app over orchestration apps.
    pub median_orch: f64,
    /// Median function runtime (seconds); [9] reports ~0.7s.
    pub median_runtime_s: f64,
}

impl Default for AzurePopulationCfg {
    fn default() -> AzurePopulationCfg {
        AzurePopulationCfg {
            apps: 20_000,
            orchestration_fraction: 0.05,
            median_all: 2.0,
            median_orch: 8.0,
            median_runtime_s: 0.7,
        }
    }
}

/// Sample a function count with median `m` and a heavy right tail:
/// a lognormal body (median = m) mixed with a Pareto tail, clamped ≥ 1.
fn sample_fn_count(rng: &mut Rng, median: f64, sigma: f64) -> u32 {
    let x = if rng.bernoulli(0.95) {
        rng.lognormal(median.ln(), sigma)
    } else {
        rng.pareto(median * 2.0, 1.5)
    };
    // simlint: allow(D005, float-to-u32 casts saturate and the max/min pins the range anyway)
    x.round().max(1.0).min(1_000.0) as u32
}

/// Synthesize a single application with index `i`. Public so the
/// macro-trace synthesizer (`workload::macrotrace::synth`) can sample app
/// `i` from its *own* per-app RNG stream — the property that lets every
/// shard materialise exactly its apps without a shared sequential stream.
pub fn sample_app(cfg: &AzurePopulationCfg, i: usize, rng: &mut Rng) -> SynthApp {
    let orchestrated = rng.bernoulli(cfg.orchestration_fraction);
    let functions = if orchestrated {
        sample_fn_count(rng, cfg.median_orch, 0.7)
    } else {
        sample_fn_count(rng, cfg.median_all, 0.8)
    };
    SynthApp {
        id: format!("app-{i}"),
        functions,
        orchestrated,
        fn_runtime_s: rng.lognormal(cfg.median_runtime_s.ln(), 0.9),
    }
}

/// Synthesize the population.
pub fn synthesize(cfg: &AzurePopulationCfg, rng: &mut Rng) -> Vec<SynthApp> {
    (0..cfg.apps).map(|i| sample_app(cfg, i, rng)).collect()
}

/// The two Figure 2 series: functions/app CDF samples for (all apps,
/// orchestration apps).
pub fn figure2_series(apps: &[SynthApp]) -> (Vec<f64>, Vec<f64>) {
    let all: Vec<f64> = apps.iter().map(|a| a.functions as f64).collect();
    let orch: Vec<f64> = apps
        .iter()
        .filter(|a| a.orchestrated)
        .map(|a| a.functions as f64)
        .collect();
    (all, orch)
}

/// The paper's headline chain-window estimate over raw orchestration
/// chain-length samples: median chain length × median runtime ("~5.6s in
/// the extreme case of a linear chain"). The upper-median element is used
/// (not an interpolated percentile) to match the paper's integer chain
/// length. `fig2::run_multi` pools samples across seeds and calls this.
pub fn linear_chain_window_from_counts(orch_counts: &[f64], median_runtime_s: f64) -> f64 {
    if orch_counts.is_empty() {
        return 0.0;
    }
    let mut sorted = orch_counts.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN chain length"));
    sorted[sorted.len() / 2] * median_runtime_s
}

/// [`linear_chain_window_from_counts`] over a synthesized population.
pub fn linear_chain_window_s(apps: &[SynthApp], median_runtime_s: f64) -> f64 {
    let orch: Vec<f64> = apps
        .iter()
        .filter(|a| a.orchestrated)
        .map(|a| a.functions as f64)
        .collect();
    linear_chain_window_from_counts(&orch, median_runtime_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::median;

    #[test]
    fn medians_match_paper() {
        let mut rng = Rng::new(2020);
        let apps = synthesize(&AzurePopulationCfg::default(), &mut rng);
        let (all, orch) = figure2_series(&apps);
        let m_all = median(&all);
        let m_orch = median(&orch);
        assert!(
            (1.0..=3.0).contains(&m_all),
            "all-apps median {m_all} (paper: 2)"
        );
        assert!(
            (6.0..=10.0).contains(&m_orch),
            "orchestration median {m_orch} (paper: 8)"
        );
        assert!(m_orch > m_all);
    }

    #[test]
    fn population_shape() {
        let mut rng = Rng::new(7);
        let cfg = AzurePopulationCfg {
            apps: 5_000,
            ..Default::default()
        };
        let apps = synthesize(&cfg, &mut rng);
        assert_eq!(apps.len(), 5_000);
        let orch_count = apps.iter().filter(|a| a.orchestrated).count();
        let frac = orch_count as f64 / apps.len() as f64;
        assert!((frac - 0.05).abs() < 0.02, "orch fraction {frac}");
        // Heavy tail: someone has a lot of functions.
        assert!(apps.iter().map(|a| a.functions).max().unwrap() > 20);
        assert!(apps.iter().all(|a| a.functions >= 1));
    }

    #[test]
    fn chain_window_near_5_6s() {
        let mut rng = Rng::new(2020);
        let apps = synthesize(&AzurePopulationCfg::default(), &mut rng);
        let window = linear_chain_window_s(&apps, 0.7);
        // paper: 8 x 0.7s = ~5.6s
        assert!((4.0..=7.5).contains(&window), "window {window}");
    }
}
