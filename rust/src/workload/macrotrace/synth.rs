//! Deterministic Azure-trace synthesizer: the offline stand-in for the
//! (non-redistributable) Azure Functions 2019 dataset.
//!
//! Calibration follows the published statistics the repo already encodes
//! in [`crate::workload::azure`] plus the invocation-side findings of
//! Shahrad et al. [9]:
//!
//! - functions-per-app and orchestration mix come from
//!   [`azure::sample_app`] (median 2 functions per app overall, 8 for the
//!   ~5% of orchestrated apps, lognormal+Pareto tail);
//! - invocation rates are extremely skewed: most functions fire rarely
//!   (≲ 1/hour), a band is cron-periodic, and a small hot fraction with a
//!   heavy-tailed rate dominates total volume;
//! - per-function p50 runtimes are lognormal around the app's ~700 ms
//!   median, and memory is a coarse lognormal around 256 MB.
//!
//! **Shardability contract:** app `i`'s rows depend only on
//! `(cfg.seed, i)` — every app gets its own forked RNG stream — so any
//! shard can materialise exactly the apps it owns without scanning or
//! synthesizing the rest of the trace. This is what lets the `azure-macro`
//! benchmark run offline at millions of invocations with no global
//! materialisation step.

use std::io::Write;

use crate::util::rng::{mix64, Rng};
use crate::workload::azure::{sample_app, AzurePopulationCfg, SynthApp};
use crate::workload::macrotrace::ingest::TraceRow;

/// Functions-per-app cap applied to the Pareto tail when emitting rows
/// (a 1000-function chain row would be all cost and no extra signal).
pub const MAX_FUNCTIONS_PER_APP: u32 = 64;

/// Synthesizer configuration.
#[derive(Debug, Clone)]
pub struct SynthTraceCfg {
    /// Applications in the trace.
    pub apps: usize,
    /// Trace horizon in minutes (the Azure dataset uses 1440 = one day).
    pub minutes: usize,
    /// Trace seed; app `i` derives its stream from `(seed, i)`.
    pub seed: u64,
    /// Population shape (functions/app, orchestration mix, runtimes).
    pub population: AzurePopulationCfg,
    /// Cap on a hot function's mean external arrivals per minute.
    pub peak_rpm: f64,
}

impl Default for SynthTraceCfg {
    fn default() -> SynthTraceCfg {
        SynthTraceCfg {
            // ~6-7k functions at a skewed ~1.4 inv/fn/min over three hours:
            // a comfortably >1M-invocation trace that still replays in
            // minutes on a laptop.
            apps: 2000,
            minutes: 180,
            seed: 0xA27E_2019,
            population: AzurePopulationCfg::default(),
            peak_rpm: 120.0,
        }
    }
}

/// Per-function arrival behaviour, sampled per function from the skewed
/// mix above.
#[derive(Debug, Clone, Copy)]
enum ArrivalClass {
    /// ≲ 1/hour Poisson background (the dataset's long tail).
    Rare { per_min: f64 },
    /// Cron-style: one invocation every `period_min` minutes.
    Cron { period_min: u32, phase: u32 },
    /// Steady Poisson traffic.
    Steady { per_min: f64 },
    /// Hot on/off traffic: bursts of `per_min` with quiet valleys.
    Hot { per_min: f64, period_min: u32, duty: f64 },
}

fn sample_class(rng: &mut Rng, peak_rpm: f64) -> ArrivalClass {
    let roll = rng.f64();
    if roll < 0.45 {
        ArrivalClass::Rare {
            per_min: rng.uniform(0.005, 0.03),
        }
    } else if roll < 0.75 {
        let period_min = *rng.choice(&[1u32, 5, 5, 15, 15, 30, 60]);
        ArrivalClass::Cron {
            period_min,
            phase: u32::try_from(rng.below(period_min as u64)).expect("phase below period"),
        }
    } else if roll < 0.90 {
        ArrivalClass::Steady {
            per_min: rng.uniform(0.5, 5.0),
        }
    } else {
        ArrivalClass::Hot {
            per_min: rng.pareto(5.0, 1.2).min(peak_rpm),
            period_min: u32::try_from(rng.range(10, 40)).expect("period fits u32"),
            duty: rng.uniform(0.2, 0.6),
        }
    }
}

/// Knuth Poisson sampler (normal approximation above λ=30, plenty for
/// per-minute counts).
fn poisson(rng: &mut Rng, lambda: f64) -> u32 {
    if lambda <= 0.0 {
        return 0;
    }
    if lambda > 30.0 {
        // simlint: allow(D005, float-to-u32 casts saturate and the value is clamped non-negative)
        return rng.normal_with(lambda, lambda.sqrt()).round().max(0.0) as u32;
    }
    let l = (-lambda).exp();
    let mut k = 0u32;
    let mut p = 1.0f64;
    loop {
        p *= rng.f64();
        if p <= l {
            return k;
        }
        k += 1;
    }
}

fn class_counts(class: ArrivalClass, minutes: usize, rng: &mut Rng) -> Vec<u32> {
    (0..minutes)
        .map(|m| {
            let minute = u32::try_from(m).expect("minute index fits u32");
            match class {
                ArrivalClass::Rare { per_min } => poisson(rng, per_min),
                ArrivalClass::Cron { period_min, phase } => {
                    u32::from((minute + phase) % period_min == 0)
                }
                ArrivalClass::Steady { per_min } => poisson(rng, per_min),
                ArrivalClass::Hot {
                    per_min,
                    period_min,
                    duty,
                } => {
                    let pos = (minute % period_min) as f64 / period_min as f64;
                    let rate = if pos < duty { per_min } else { per_min * 0.05 };
                    poisson(rng, rate)
                }
            }
        })
        .collect()
}

/// The per-app RNG stream: depends only on `(seed, index)`.
fn app_rng(seed: u64, index: usize) -> Rng {
    Rng::new(mix64(seed, index as u64))
}

/// The population entry for app `index` (id, function count, orchestration
/// flag, runtime scale) — the first draws of the app's stream.
pub fn app_spec(cfg: &SynthTraceCfg, index: usize) -> SynthApp {
    let mut rng = app_rng(cfg.seed, index);
    sample_app(&cfg.population, index, &mut rng)
}

/// Synthesize app `index`'s trace rows. Deterministic in `(cfg, index)`;
/// independent of every other app. Equivalent to
/// [`app_rows_for_day`]`(cfg, index, 0)`.
///
/// Orchestrated apps emit a chain: function 0 carries the external
/// arrivals and successors mirror its counts (each stage runs once per
/// chain execution; stage runtimes are well under a minute), with the
/// `orchestration` trigger marking chain membership for the replayer.
pub fn app_rows(cfg: &SynthTraceCfg, index: usize) -> Vec<TraceRow> {
    app_rows_for_day(cfg, index, 0)
}

/// Day-sliced synthesis for multi-day horizons: day `d` keeps day 0's
/// population, arrival classes, durations, memory and triggers (the app
/// *is* the same app every day) and redraws only the per-minute counts
/// from a `(seed, index, day)`-forked stream. Day 0 draws its counts
/// inline from the app's base stream, so `app_rows_for_day(cfg, i, 0)` is
/// byte-identical to the historical `app_rows(cfg, i)` — the single-day
/// replay contract is untouched.
pub fn app_rows_for_day(cfg: &SynthTraceCfg, index: usize, day: usize) -> Vec<TraceRow> {
    let mut rng = app_rng(cfg.seed, index);
    let app = sample_app(&cfg.population, index, &mut rng);
    let nfns = app.functions.min(MAX_FUNCTIONS_PER_APP) as usize;
    // The day fork: only consulted for day > 0 counts, so the base
    // stream's draw sequence is identical for every day.
    let mut day_rng =
        Rng::new(mix64(mix64(cfg.seed, index as u64), 0xDA11_511C_ED00 + day as u64));
    let mut rows = Vec::with_capacity(nfns);
    if app.orchestrated {
        let head_class = sample_class(&mut rng, cfg.peak_rpm);
        let base_head = class_counts(head_class, cfg.minutes, &mut rng);
        let head_counts = if day == 0 {
            base_head
        } else {
            class_counts(head_class, cfg.minutes, &mut day_rng)
        };
        for f in 0..nfns {
            rows.push(TraceRow {
                app: app.id.clone(),
                function: format!("{}-f{f}", app.id),
                trigger: "orchestration".to_string(),
                duration_ms: (app.fn_runtime_s * 1e3 * rng.lognormal(0.0, 0.4))
                    .clamp(1.0, 30_000.0),
                memory_mb: sample_memory(&mut rng),
                counts: head_counts.clone(),
            });
        }
    } else {
        for f in 0..nfns {
            let class = sample_class(&mut rng, cfg.peak_rpm);
            let base_counts = class_counts(class, cfg.minutes, &mut rng);
            let counts = if day == 0 {
                base_counts
            } else {
                class_counts(class, cfg.minutes, &mut day_rng)
            };
            let trigger = *rng.choice(&["http", "queue", "storage", "timer"]);
            rows.push(TraceRow {
                app: app.id.clone(),
                function: format!("{}-f{f}", app.id),
                trigger: trigger.to_string(),
                duration_ms: (app.fn_runtime_s * 1e3 * rng.lognormal(0.0, 0.4))
                    .clamp(1.0, 30_000.0),
                memory_mb: sample_memory(&mut rng),
                counts,
            });
        }
    }
    rows
}

fn sample_memory(rng: &mut Rng) -> u32 {
    // simlint: allow(D005, float-to-u32 casts saturate and the clamp pins the range anyway)
    (rng.lognormal((256.0f64).ln(), 0.6) as u32).clamp(64, 4096)
}

/// Totals reported by [`write_csv`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SynthSummary {
    pub apps: u64,
    pub functions: u64,
    pub invocations: u64,
}

/// Stream the synthesized trace out as an ingestion-compatible CSV; one
/// app's rows in memory at a time. The written file round-trips exactly
/// through [`AzureTraceReader`]: `duration_ms` uses `f64`'s shortest
/// round-trip `Display`, so a replay from the CSV is byte-identical to a
/// replay straight from the synthesizer.
///
/// [`AzureTraceReader`]: crate::workload::macrotrace::ingest::AzureTraceReader
pub fn write_csv<W: Write>(cfg: &SynthTraceCfg, mut w: W) -> std::io::Result<SynthSummary> {
    write!(w, "HashApp,HashFunction,Trigger,AvgDurationMs,MemoryMb")?;
    for m in 1..=cfg.minutes {
        write!(w, ",{m}")?;
    }
    writeln!(w)?;
    let mut summary = SynthSummary::default();
    for i in 0..cfg.apps {
        let rows = app_rows(cfg, i);
        summary.apps += 1;
        for row in &rows {
            summary.functions += 1;
            summary.invocations += row.invocations();
            write!(
                w,
                "{},{},{},{},{}",
                row.app, row.function, row.trigger, row.duration_ms, row.memory_mb
            )?;
            for c in &row.counts {
                write!(w, ",{c}")?;
            }
            writeln!(w)?;
        }
    }
    // Surface buffered-write failures here rather than letting a BufWriter
    // drop swallow them (a truncated trace must not report success).
    w.flush()?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::macrotrace::ingest::AzureTraceReader;

    fn small() -> SynthTraceCfg {
        SynthTraceCfg {
            apps: 60,
            minutes: 30,
            seed: 7,
            ..SynthTraceCfg::default()
        }
    }

    #[test]
    fn rows_are_deterministic_and_app_local() {
        let cfg = small();
        let a = app_rows(&cfg, 11);
        let b = app_rows(&cfg, 11);
        assert_eq!(a, b, "same (cfg, index) must give identical rows");
        assert!(!a.is_empty());
        assert!(a.iter().all(|r| r.app == "app-11"));
        assert!(a.iter().all(|r| r.counts.len() == cfg.minutes));
        // A different seed changes the rows.
        let mut other = cfg.clone();
        other.seed = 8;
        assert_ne!(a, app_rows(&other, 11));
    }

    #[test]
    fn orchestrated_apps_form_chains_with_mirrored_counts() {
        let cfg = small();
        let mut saw_chain = false;
        for i in 0..cfg.apps {
            let rows = app_rows(&cfg, i);
            if rows.len() > 1 && rows[0].trigger == "orchestration" {
                saw_chain = true;
                assert!(rows.iter().all(|r| r.trigger == "orchestration"));
                assert!(rows.iter().all(|r| r.counts == rows[0].counts));
            }
        }
        assert!(saw_chain, "population should contain orchestrated apps");
    }

    #[test]
    fn day_slices_keep_the_population_and_redraw_counts() {
        let cfg = small();
        for i in [0usize, 3, 17] {
            let d0 = app_rows_for_day(&cfg, i, 0);
            assert_eq!(d0, app_rows(&cfg, i), "day 0 must be the legacy rows");
            let d1 = app_rows_for_day(&cfg, i, 1);
            let d1_again = app_rows_for_day(&cfg, i, 1);
            assert_eq!(d1, d1_again, "day slices are deterministic");
            assert_eq!(d0.len(), d1.len(), "same functions every day");
            for (a, b) in d0.iter().zip(d1.iter()) {
                assert_eq!(a.function, b.function);
                assert_eq!(a.trigger, b.trigger);
                assert_eq!(a.duration_ms, b.duration_ms, "durations are stable");
                assert_eq!(a.memory_mb, b.memory_mb, "memory is stable");
                assert_eq!(a.counts.len(), b.counts.len());
            }
            // Chain mirroring survives the day fork.
            if d1.len() > 1 && d1[0].trigger == "orchestration" {
                assert!(d1.iter().all(|r| r.counts == d1[0].counts));
            }
        }
        // Some busy app's counts actually change across days.
        let changed = (0..cfg.apps).any(|i| {
            let d0 = app_rows_for_day(&cfg, i, 0);
            let d1 = app_rows_for_day(&cfg, i, 1);
            d0.iter().zip(d1.iter()).any(|(a, b)| a.counts != b.counts)
        });
        assert!(changed, "day slicing must redraw arrival counts");
    }

    #[test]
    fn csv_round_trips_exactly() {
        let cfg = small();
        let mut buf = Vec::new();
        let summary = write_csv(&cfg, &mut buf).unwrap();
        assert_eq!(summary.apps, cfg.apps as u64);
        assert!(summary.invocations > 0);
        let mut reader = AzureTraceReader::new(buf.as_slice()).unwrap();
        let mut functions = 0u64;
        let mut invocations = 0u64;
        let mut direct = Vec::new();
        for i in 0..cfg.apps {
            direct.extend(app_rows(&cfg, i));
        }
        for (read, synth) in reader.by_ref().zip(direct.iter()) {
            assert_eq!(&read, synth, "CSV row must round-trip bit-exactly");
            functions += 1;
            invocations += read.invocations();
        }
        assert_eq!(reader.skipped(), 0);
        assert_eq!(functions, summary.functions);
        assert_eq!(invocations, summary.invocations);
    }

    #[test]
    fn default_cfg_reaches_macro_scale() {
        // Expected volume of the default trace: estimate from a sample of
        // apps instead of synthesizing all 1500 (keeps the test fast).
        let cfg = SynthTraceCfg::default();
        let sample = 100usize;
        let mut inv = 0u64;
        for i in 0..sample {
            for row in app_rows(&cfg, i * (cfg.apps / sample)) {
                inv += row.invocations();
            }
        }
        let projected = inv * (cfg.apps as u64) / sample as u64;
        assert!(
            projected > 1_000_000,
            "default synth trace projects only ~{projected} invocations"
        );
    }
}
