//! Streaming ingestion of Azure-Functions-2019-shaped trace CSVs.
//!
//! The Azure Functions 2019 dataset (Shahrad et al. [9]) ships
//! per-function rows of per-minute invocation counts: hash columns
//! identifying owner/app/function, a trigger class, then one column per
//! minute of the day. [`AzureTraceReader`] consumes that shape — plus two
//! optional columns folding in the companion duration/memory percentile
//! files — **one row at a time**: the full trace is never materialised in
//! memory. A row's `Vec<u32>` of counts *is* the compact representation;
//! expanding counts into individual invocation events only happens lazily,
//! per app, inside the replay engine.
//!
//! Header layout (column order is free; names are matched):
//!
//! ```csv
//! HashApp,HashFunction,Trigger,AvgDurationMs,MemoryMb,1,2,3,...,N
//! ```
//!
//! - `HashApp`, `HashFunction` — required identifiers (any string).
//! - `HashOwner` — accepted and ignored (the public dataset has it).
//! - `Trigger` — optional; `orchestration` rows form explicit chains,
//!   anything else (`http`, `queue`, `storage`, `timer`, ...) is a
//!   standalone function. Defaults to `http`.
//! - `AvgDurationMs` (alias `percentile_Average_50`) — optional p50
//!   execution time; defaults to the paper's ~700 ms median.
//! - `MemoryMb` (alias `AverageAllocatedMb`) — optional; defaults 256.
//! - Every remaining column whose header parses as an integer is a
//!   per-minute invocation-count column, in header order.
//!
//! Malformed rows are skipped and counted, mirroring
//! [`crate::workload::trace::read_trace`]'s lenient contract.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// p50 function execution time when the trace carries no duration column
/// (the paper reports a ~700 ms median across the Azure population).
pub const DEFAULT_DURATION_MS: f64 = 700.0;
/// Allocated memory when the trace carries no memory column.
pub const DEFAULT_MEMORY_MB: u32 = 256;

/// One function's row: identity, shape, and its per-minute counts.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRow {
    pub app: String,
    pub function: String,
    /// Trigger class; `"orchestration"` marks explicit-chain membership.
    pub trigger: String,
    /// p50 execution time, milliseconds.
    pub duration_ms: f64,
    pub memory_mb: u32,
    /// Invocation count per minute, in trace order.
    pub counts: Vec<u32>,
}

impl TraceRow {
    /// Total invocations across the row's horizon.
    pub fn invocations(&self) -> u64 {
        self.counts.iter().map(|&c| c as u64).sum()
    }
}

/// Column map resolved from the header line.
#[derive(Debug, Clone)]
struct Columns {
    app: usize,
    function: usize,
    trigger: Option<usize>,
    duration: Option<usize>,
    memory: Option<usize>,
    /// Indices of the per-minute count columns, in header order.
    minutes: Vec<usize>,
}

fn parse_header(line: &str) -> Result<Columns> {
    let mut app = None;
    let mut function = None;
    let mut trigger = None;
    let mut duration = None;
    let mut memory = None;
    let mut minutes = Vec::new();
    for (i, raw) in line.split(',').enumerate() {
        let name = raw.trim();
        match name {
            "HashApp" => app = Some(i),
            "HashFunction" => function = Some(i),
            "Trigger" => trigger = Some(i),
            "AvgDurationMs" | "percentile_Average_50" => duration = Some(i),
            "MemoryMb" | "AverageAllocatedMb" => memory = Some(i),
            // The public dataset's owner hash and any future metadata
            // columns are tolerated; integer headers are minute columns.
            _ => {
                if name.parse::<u32>().is_ok() {
                    minutes.push(i);
                }
            }
        }
    }
    let app = app.context("trace header is missing a HashApp column")?;
    let function = function.context("trace header is missing a HashFunction column")?;
    if minutes.is_empty() {
        bail!("trace header has no per-minute count columns (integer headers)");
    }
    Ok(Columns {
        app,
        function,
        trigger,
        duration,
        memory,
        minutes,
    })
}

/// Streaming reader: one [`TraceRow`] in memory at a time.
///
/// Iteration ends at EOF *or* on an I/O error; the two are distinguished
/// by [`io_error`](AzureTraceReader::io_error), which callers that must
/// not silently truncate (the sharded replay) check after draining.
pub struct AzureTraceReader<R: BufRead> {
    src: R,
    cols: Columns,
    line: String,
    fields: Vec<(usize, usize)>, // (start, end) byte ranges per field
    skipped: usize,
    rows: u64,
    io_error: Option<std::io::Error>,
}

impl AzureTraceReader<BufReader<File>> {
    /// Open a trace CSV from disk.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<AzureTraceReader<BufReader<File>>> {
        let path = path.as_ref();
        let file = File::open(path)
            .with_context(|| format!("opening trace {}", path.display()))?;
        AzureTraceReader::new(BufReader::new(file))
            .with_context(|| format!("reading trace header of {}", path.display()))
    }
}

impl<R: BufRead> AzureTraceReader<R> {
    /// Parse the header and wrap the source.
    pub fn new(mut src: R) -> Result<AzureTraceReader<R>> {
        let mut header = String::new();
        src.read_line(&mut header).context("reading trace header")?;
        if header.trim().is_empty() {
            bail!("empty trace: no header line");
        }
        let cols = parse_header(header.trim_end())?;
        Ok(AzureTraceReader {
            src,
            cols,
            line: String::new(),
            fields: Vec::new(),
            skipped: 0,
            rows: 0,
            io_error: None,
        })
    }

    /// The I/O error that ended iteration early, if any. `None` after a
    /// clean EOF.
    pub fn io_error(&self) -> Option<&std::io::Error> {
        self.io_error.as_ref()
    }

    /// Minutes per row in this trace.
    pub fn minutes(&self) -> usize {
        self.cols.minutes.len()
    }

    /// Malformed data rows skipped so far.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// Well-formed rows yielded so far.
    pub fn rows(&self) -> u64 {
        self.rows
    }

    fn field(&self, i: usize) -> Option<&str> {
        let &(s, e) = self.fields.get(i)?;
        Some(self.line[s..e].trim())
    }

    /// Parse the current `line` buffer into a row, or `None` if malformed.
    fn parse_row(&self) -> Option<TraceRow> {
        let app = self.field(self.cols.app)?;
        let function = self.field(self.cols.function)?;
        if app.is_empty() || function.is_empty() {
            return None;
        }
        let trigger = self
            .cols
            .trigger
            .and_then(|i| self.field(i))
            .filter(|t| !t.is_empty())
            .unwrap_or("http")
            .to_string();
        let duration_ms = match self.cols.duration.and_then(|i| self.field(i)) {
            // Non-finite durations are malformed like negative ones:
            // `f64::parse` happily yields `inf`/`NaN` for "inf"/"nan"
            // cells, and a `>= 0.0` check alone waves `+inf` through
            // into `SimDuration::from_millis_f64` (and from there into
            // every latency histogram). Skip-count them instead, exactly
            // as the memory column below does.
            Some(t) if !t.is_empty() => {
                t.parse::<f64>().ok().filter(|d| *d >= 0.0 && d.is_finite())?
            }
            _ => DEFAULT_DURATION_MS,
        };
        let memory_mb = match self.cols.memory.and_then(|i| self.field(i)) {
            // The real dataset's memory averages are fractional
            // (`AverageAllocatedMb` like `170.33`): accept floats and
            // round, exactly as the duration column does. Integer-valued
            // cells (what `write_csv` emits) round-trip unchanged.
            Some(t) if !t.is_empty() => {
                let mb = t.parse::<f64>().ok().filter(|m| *m >= 0.0 && m.is_finite())?;
                // simlint: allow(D005, value is validated non-negative finite and clamped below u32::MAX)
                mb.round().min(u32::MAX as f64) as u32
            }
            _ => DEFAULT_MEMORY_MB,
        };
        let mut counts = Vec::with_capacity(self.cols.minutes.len());
        for &i in &self.cols.minutes {
            let t = self.field(i)?;
            // Blank minute cells read as zero (the dataset leaves quiet
            // minutes empty); anything else must parse.
            counts.push(if t.is_empty() { 0 } else { t.parse::<u32>().ok()? });
        }
        Some(TraceRow {
            app: app.to_string(),
            function: function.to_string(),
            trigger,
            duration_ms,
            memory_mb,
            counts,
        })
    }
}

impl<R: BufRead> Iterator for AzureTraceReader<R> {
    type Item = TraceRow;

    fn next(&mut self) -> Option<TraceRow> {
        loop {
            self.line.clear();
            match self.src.read_line(&mut self.line) {
                Ok(0) => return None,
                Err(e) => {
                    self.io_error = Some(e);
                    return None;
                }
                Ok(_) => {}
            }
            if self.line.trim().is_empty() {
                continue;
            }
            // Split once into byte ranges (no per-field allocation).
            self.fields.clear();
            let trimmed_len = self.line.trim_end().len();
            let mut start = 0usize;
            for (i, b) in self.line.as_bytes()[..trimmed_len].iter().enumerate() {
                if *b == b',' {
                    self.fields.push((start, i));
                    start = i + 1;
                }
            }
            self.fields.push((start, trimmed_len));
            match self.parse_row() {
                Some(row) => {
                    self.rows += 1;
                    return Some(row);
                }
                None => self.skipped += 1,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CSV: &str = "\
HashApp,HashFunction,Trigger,AvgDurationMs,MemoryMb,1,2,3,4
app-a,f0,http,120.5,128,0,3,,1
app-a,f1,orchestration,700,256,1,0,2,0
app-b,g0,timer,50,512,1,1,1,1
";

    #[test]
    fn streams_rows_with_defaults_and_blanks() {
        let mut r = AzureTraceReader::new(CSV.as_bytes()).unwrap();
        assert_eq!(r.minutes(), 4);
        let a = r.next().unwrap();
        assert_eq!(a.app, "app-a");
        assert_eq!(a.function, "f0");
        assert_eq!(a.counts, vec![0, 3, 0, 1]); // blank cell -> 0
        assert_eq!(a.invocations(), 4);
        assert!((a.duration_ms - 120.5).abs() < 1e-12);
        let b = r.next().unwrap();
        assert_eq!(b.trigger, "orchestration");
        let c = r.next().unwrap();
        assert_eq!(c.memory_mb, 512);
        assert!(r.next().is_none());
        assert_eq!(r.rows(), 3);
        assert_eq!(r.skipped(), 0);
    }

    #[test]
    fn malformed_rows_are_skipped_not_fatal() {
        let csv = "\
HashApp,HashFunction,1,2
a,f,1,2
a,,3,4
a,g,nope,4
a,h,5,6
";
        let mut r = AzureTraceReader::new(csv.as_bytes()).unwrap();
        let rows: Vec<TraceRow> = r.by_ref().collect();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].function, "f");
        assert_eq!(rows[1].function, "h");
        assert_eq!(r.skipped(), 2);
        // Missing optional columns fall back to defaults.
        assert_eq!(rows[0].trigger, "http");
        assert_eq!(rows[0].memory_mb, DEFAULT_MEMORY_MB);
        assert!((rows[0].duration_ms - DEFAULT_DURATION_MS).abs() < 1e-12);
    }

    #[test]
    fn fractional_memory_rounds_instead_of_skipping() {
        // The real dataset's AverageAllocatedMb averages are fractional;
        // they must round like the duration column, not drop the row.
        let csv = "\
HashApp,HashFunction,AvgDurationMs,MemoryMb,1,2
a,f,120.5,170.33,1,2
a,g,50,169.5,0,1
a,h,50,-3.0,1,1
";
        let mut r = AzureTraceReader::new(csv.as_bytes()).unwrap();
        let rows: Vec<TraceRow> = r.by_ref().collect();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].memory_mb, 170);
        assert_eq!(rows[1].memory_mb, 170, "round half up");
        assert_eq!(r.skipped(), 1, "negative memory is still malformed");
    }

    #[test]
    fn non_finite_duration_and_memory_cells_are_malformed() {
        // `"inf".parse::<f64>()` succeeds, and `inf >= 0.0` holds — so a
        // sign check alone admits infinite durations/memory. Both columns
        // must treat non-finite cells as malformed (skip-counted), not
        // feed them into the simulator's integer time/memory domains.
        let csv = "\
HashApp,HashFunction,AvgDurationMs,MemoryMb,1,2
a,ok,120.5,128,1,2
a,dinf,inf,128,1,0
a,dnan,NaN,128,1,0
a,dneg,-5,128,1,0
a,minf,50,inf,1,0
a,mnan,50,nan,1,0
";
        let mut r = AzureTraceReader::new(csv.as_bytes()).unwrap();
        let rows: Vec<TraceRow> = r.by_ref().collect();
        assert_eq!(rows.len(), 1, "only the finite row survives");
        assert_eq!(rows[0].function, "ok");
        assert_eq!(r.skipped(), 5);
        assert!(rows[0].duration_ms.is_finite());
    }

    #[test]
    fn header_order_is_free_and_owner_is_ignored() {
        let csv = "HashOwner,1,HashFunction,2,HashApp\nowner,7,f,8,a\n";
        let mut r = AzureTraceReader::new(csv.as_bytes()).unwrap();
        let row = r.next().unwrap();
        assert_eq!(row.app, "a");
        assert_eq!(row.function, "f");
        assert_eq!(row.counts, vec![7, 8]);
    }

    #[test]
    fn mid_file_io_errors_are_surfaced_not_swallowed() {
        /// Reader that fails after the first `ok_reads` fills.
        struct Flaky {
            data: &'static [u8],
            pos: usize,
            ok_reads: usize,
        }
        impl std::io::Read for Flaky {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.ok_reads == 0 {
                    return Err(std::io::Error::other("disk gone"));
                }
                self.ok_reads -= 1;
                let n = buf.len().min(self.data.len() - self.pos);
                buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
                self.pos += n;
                Ok(n)
            }
        }
        // Small capacity so the header read succeeds and a later fill hits
        // the injected failure mid-file.
        let src = std::io::BufReader::with_capacity(
            8,
            Flaky {
                data: CSV.as_bytes(),
                pos: 0,
                ok_reads: 8,
            },
        );
        let mut r = AzureTraceReader::new(src).unwrap();
        let drained: Vec<TraceRow> = r.by_ref().collect();
        assert!(drained.len() < 3, "error must end iteration early");
        assert!(r.io_error().is_some(), "the I/O error must be observable");
        // Clean EOF leaves no error behind.
        let mut clean = AzureTraceReader::new(CSV.as_bytes()).unwrap();
        assert_eq!(clean.by_ref().count(), 3);
        assert!(clean.io_error().is_none());
    }

    #[test]
    fn bad_headers_error() {
        assert!(AzureTraceReader::new("".as_bytes()).is_err());
        assert!(AzureTraceReader::new("HashApp,1,2\n".as_bytes()).is_err());
        assert!(AzureTraceReader::new("HashApp,HashFunction\n".as_bytes()).is_err());
    }
}
